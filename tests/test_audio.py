"""Audio pipeline: golden log-mel frontend, streaming exactness, and the
end-to-end transcribe API."""

import functools

import jax
import numpy as np
import pytest

from repro.audio.features import (FrontendConfig, audio_frames, log_mel,
                                  log_mel_ref, mel_filterbank,
                                  mel_to_frames, resample_linear)
from repro.audio.stream import (StreamingFrontend, chunk_list,
                                synth_waveform)
from repro.audio.transcribe import transcribe
from repro.configs import get_config, reduced
from repro.models import encdec
from repro.models.model import build
from repro.serving.engine import (AudioRequest, ServeEngine,
                                  StreamingAudioRequest)
from repro.serving.scheduler import BatchScheduler

CFG = FrontendConfig()


@functools.lru_cache(maxsize=1)
def _whisper():
    cfg = reduced(get_config("whisper-tiny-en"))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))
    return cfg, model, params


# ------------------------------------------------------------- frontend


def test_log_mel_matches_numpy_reference():
    """The JAX frontend is golden against the NumPy reference, including
    an input whose last frame is partial (zero-padded tail)."""
    for n in (400, 1000, 8000):   # exact window / partial tail / long
        x = synth_waveform(1.0)[:n]
        got = np.asarray(log_mel(x, CFG))
        ref = log_mel_ref(x, CFG)
        assert got.shape == ref.shape == (CFG.n_frames(n), CFG.n_mels)
        np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)


def test_log_mel_silence_hits_fixed_floor():
    """Silence maps every bin to the fixed-reference floor: mel=0 ->
    log10 clamp at -8 -> (-8+4)/4 = -1 (no utterance-global max — the
    streaming-causal normalization)."""
    lm = np.asarray(log_mel(np.zeros(1600, np.float32), CFG))
    assert lm.shape == (10, CFG.n_mels)
    np.testing.assert_allclose(lm, -1.0)
    np.testing.assert_allclose(log_mel_ref(np.zeros(1600, np.float32),
                                           CFG), -1.0)


def test_log_mel_edge_lengths():
    assert np.asarray(log_mel(np.zeros(0, np.float32), CFG)).shape \
        == (0, CFG.n_mels)
    # shorter than one hop: still one (padded) frame
    one = np.asarray(log_mel(0.1 * np.ones(50, np.float32), CFG))
    assert one.shape == (1, CFG.n_mels)
    assert np.isfinite(one).all()


def test_mel_filterbank_covers_spectrum():
    fb = mel_filterbank(CFG)
    assert fb.shape == (CFG.n_freq, CFG.n_mels)
    assert (fb >= 0).all()
    # every filter has support; interior frequency bins are covered
    assert (fb.sum(axis=0) > 0).all()
    assert (fb[1:-1].sum(axis=1) >= 0).any()


def test_mel_to_frames_pools_odd_tail():
    lm = np.linspace(0, 1, 5 * CFG.n_mels, dtype=np.float32) \
        .reshape(5, CFG.n_mels)
    out = np.asarray(mel_to_frames(lm, 64, CFG))
    assert out.shape == (3, 64)      # ceil(5/2) with zero-padded tail


def test_streaming_frontend_bit_exact():
    """Incremental push/flush equals one-shot audio_frames exactly,
    whatever the push granularity."""
    x = synth_waveform(0.7)
    one = np.asarray(audio_frames(x, 128, CFG))
    for step in (173, 1777, len(x)):
        sf = StreamingFrontend(128, CFG)
        outs = [sf.push(x[i:i + step]) for i in range(0, len(x), step)]
        outs.append(sf.flush())
        got = np.concatenate(outs)
        assert got.shape == one.shape
        assert np.array_equal(got, one)
        assert sf.frames_emitted == one.shape[0]
    with pytest.raises(ValueError):
        sf.push(x[:10])              # push after flush


def test_log_mel_accepts_2d_loader_shapes():
    """(1, N)/(N, 1) loader outputs are flattened, not truncated."""
    x = synth_waveform(0.2)
    want = log_mel_ref(x, CFG)
    assert want.shape[0] == CFG.n_frames(len(x))
    np.testing.assert_array_equal(log_mel_ref(x.reshape(1, -1), CFG), want)
    np.testing.assert_array_equal(log_mel_ref(x.reshape(-1, 1), CFG), want)
    np.testing.assert_array_equal(np.asarray(log_mel(x.reshape(1, -1),
                                                     CFG)), np.asarray(
                                                         log_mel(x, CFG)))


def test_resample_linear_identity_and_rate():
    x = synth_waveform(0.1)
    assert resample_linear(x, 16_000, 16_000) is x or \
        np.array_equal(resample_linear(x, 16_000, 16_000), x)
    y = resample_linear(x, 8_000, 16_000)
    assert abs(len(y) - 2 * len(x)) <= 1


# ------------------------------------------------- chunked encode (model)


def test_chunked_encode_is_block_diagonal():
    """A chunk's states depend only on its own frames: prefix states are
    unchanged when more audio is appended (the streaming invariant)."""
    cfg, model, params = _whisper()
    rng = np.random.default_rng(3)
    frames = jax.numpy.asarray(
        rng.standard_normal((1, 12, cfg.d_model)).astype(np.float32) * 0.5)
    full = encdec.encode_chunked(params, cfg, frames, chunk=4)
    prefix = encdec.encode_chunked(params, cfg, frames[:, :8], chunk=4)
    assert full.shape == (1, 12, cfg.d_model)
    np.testing.assert_array_equal(np.asarray(full[:, :8], np.float32),
                                  np.asarray(prefix, np.float32))
    # and each chunk equals its independent encode
    alone = encdec.encode(params, cfg, frames[:, 4:8])
    np.testing.assert_array_equal(np.asarray(full[:, 4:8], np.float32),
                                  np.asarray(alone, np.float32))


def test_cross_attn_kv_matches_prefill_planes():
    """Incremental cross-K/V extension writes the same planes the
    prompt prefill writes: feed two chunks (the second lands via the
    donated ``_extend_cross_cache`` jit), then finalize (which
    re-writes the whole slot
    from one prefill over the same chunked states) — the extended
    region must already hold the prefill's values."""
    cfg, model, params = _whisper()
    rng = np.random.default_rng(5)
    c1 = rng.standard_normal((6, cfg.d_model)).astype(np.float32) * 0.5
    c2 = rng.standard_normal((5, cfg.d_model)).astype(np.float32) * 0.5

    eng = ServeEngine(model, params, n_slots=1, max_len=32, enc_len=16)
    st = eng.open_stream(StreamingAudioRequest(
        uid=0, tokens=[1, 2], max_new=4, eos_id=-2, chunks=[c1, c2]))
    eng.stream_feed(st, c1)                   # anchor (prefill over c1)
    eng.stream_feed(st, c2)                   # incremental extension
    k_inc = np.asarray(
        eng.cache["layers"]["cross"]["k"][:, 0, 6:11], np.float32)
    v_inc = np.asarray(
        eng.cache["layers"]["cross"]["v"][:, 0, 6:11], np.float32)
    assert eng._enc_lens[0] == 11
    eng.stream_finalize(st)                   # prefill over c1+c2 states
    k_fin = np.asarray(
        eng.cache["layers"]["cross"]["k"][:, 0, 6:11], np.float32)
    v_fin = np.asarray(
        eng.cache["layers"]["cross"]["v"][:, 0, 6:11], np.float32)
    assert np.abs(k_inc).max() > 0
    np.testing.assert_allclose(k_inc, k_fin, atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(v_inc, v_fin, atol=2e-2, rtol=2e-2)


# ------------------------------------------------- streaming == one-shot


def test_streaming_serve_matches_one_shot_tokens():
    """The acceptance property: chunk-at-a-time streaming serving emits
    the same final transcript as one-shot serving of the same audio,
    token for token, and records partial hypotheses along the way."""
    cfg, model, params = _whisper()
    wave = synth_waveform(0.4)
    one = transcribe(wave, 16_000, model=model, params=params,
                     chunk_frames=6, max_new=5)
    streamed = transcribe(wave, 16_000, model=model, params=params,
                          chunk_frames=6, max_new=5, stream=True,
                          engine=one.engine)
    assert streamed.tokens == one.tokens
    assert len(streamed.partials) >= 2       # emitted while audio arrived
    assert one.partials == []
    assert streamed.n_frames == one.n_frames


def test_streaming_scheduler_mixed_with_audio_requests():
    """Streams and plain audio requests share the pool: both complete,
    slots are recycled, stream bookkeeping drains."""
    cfg, model, params = _whisper()
    eng = ServeEngine(model, params, n_slots=2, max_len=32, enc_len=16)
    sched = BatchScheduler(eng)
    rng = np.random.default_rng(0)
    frames = rng.standard_normal((10, cfg.d_model)).astype(np.float32) * 0.5
    sched.submit(StreamingAudioRequest(
        uid=0, tokens=[1, 2], max_new=4, eos_id=-2,
        chunks=chunk_list(frames, 4)))
    sched.submit(AudioRequest(uid=1, tokens=[3, 4, 5], max_new=3,
                              eos_id=-2, enc_frames=frames))
    sched.run_until_drained(max_ticks=100)
    assert sched.drained and eng.n_streams == 0
    assert len(sched.results[0].out) == 4
    assert len(sched.results[0].partials) >= 3   # one per chunk + final
    assert len(sched.results[1].out) == 3
    assert not sched.results[0].error and not sched.results[1].error
    assert sorted(eng.free) == [0, 1]


def test_streaming_validate_and_rejection():
    cfg, model, params = _whisper()
    eng = ServeEngine(model, params, n_slots=1, max_len=32, enc_len=8)
    d = cfg.d_model
    big = [np.zeros((6, d), np.float32), np.zeros((6, d), np.float32)]
    assert eng.validate(StreamingAudioRequest(
        uid=0, tokens=[1], max_new=2, chunks=big))   # 12 > enc_len 8
    with pytest.raises(ValueError):
        eng.admit(StreamingAudioRequest(uid=1, tokens=[1], max_new=2,
                                        chunks=[np.zeros((2, d))]))
    with pytest.raises(ValueError):
        StreamingAudioRequest(uid=2, tokens=[1], max_new=2, chunks=[])
    # both encoder inputs on a plain request is unservable
    assert eng.validate(AudioRequest(
        uid=3, tokens=[1], max_new=2,
        enc_frames=np.zeros((4, d), np.float32),
        enc_states=np.zeros((4, d), np.float32)))
    # scheduler completes an unservable stream as a failed state
    sched = BatchScheduler(eng)
    st = sched.submit(StreamingAudioRequest(uid=4, tokens=[1], max_new=2,
                                            chunks=big))
    assert st is not None and st.error and st.slot == -1


# -------------------------------------------------------- transcribe API


def test_transcribe_smoke_whisper_tiny():
    cfg, model, params = _whisper()
    wave = synth_waveform(0.3)
    r = transcribe(wave, 16_000, model=model, params=params,
                   chunk_frames=8, max_new=4)
    assert len(r.tokens) == 4
    assert all(0 <= t < cfg.vocab for t in r.tokens)
    assert r.n_frames == CFG.n_embed_frames(len(wave))
    assert r.audio_s == pytest.approx(0.3, abs=1e-3)
    assert r.energy is None and r.platform is None
    assert r.text == " ".join(str(t) for t in r.tokens)


def test_transcribe_platform_energy_and_q8():
    cfg, model, params = _whisper()
    wave = synth_waveform(0.3)
    r = transcribe(wave, 16_000, model=model, params=params,
                   chunk_frames=8, max_new=4, platform="imax3-28nm",
                   cache_dtype="q8_0")
    assert r.platform == "imax3-28nm/32k"
    assert r.cache_dtype == "q8_0"
    e = r.energy
    assert e["joules_per_audio_s"] > 0 and np.isfinite(
        e["joules_per_audio_s"])
    assert e["joules_per_audio_s"] == pytest.approx(
        e["pdp_j"] / r.audio_s, rel=1e-6)


def test_transcribe_engine_reuse_reports_per_call_stats():
    """A reused engine must not leak the previous call's ticks/energy
    into the next result, and conflicting explicit policies raise."""
    cfg, model, params = _whisper()
    wave = synth_waveform(0.3)
    a = transcribe(wave, 16_000, model=model, params=params,
                   chunk_frames=8, max_new=4, platform="imax3-28nm")
    b = transcribe(wave, 16_000, model=model, params=params,
                   chunk_frames=8, max_new=4, engine=a.engine)
    assert b.ticks == a.ticks
    assert b.energy["joules_per_audio_s"] == pytest.approx(
        a.energy["joules_per_audio_s"], rel=1e-6)
    assert b.platform == a.platform and b.cache_dtype == a.cache_dtype
    with pytest.raises(ValueError):
        transcribe(wave, 16_000, model=model, params=params,
                   chunk_frames=8, max_new=4, engine=a.engine,
                   cache_dtype="q8_0")
    with pytest.raises(ValueError):
        transcribe(wave, 16_000, model=model, params=params,
                   chunk_frames=8, max_new=4, engine=a.engine,
                   platform="rtx-4090")


def test_transcribe_rejects_non_enc_dec_and_empty_audio():
    with pytest.raises(ValueError):
        transcribe(synth_waveform(0.2), 16_000, arch="qwen3-4b")
    cfg, model, params = _whisper()
    with pytest.raises(ValueError):
        transcribe(np.zeros(0, np.float32), 16_000, model=model,
                   params=params)
