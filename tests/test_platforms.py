"""The Platform API: registry, dispatch derivation, serving energy."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.energy import imax_power
from repro.kernels.api import (DispatchContext, dispatch, dispatch_trace,
                               reset_dispatch_log, use_context)
from repro.platforms import (MemoryHierarchy, Platform, PowerModel,
                             get_platform, list_platforms,
                             register_platform)
from repro.platforms.registry import _ALIASES, _REGISTRY


# ------------------------------------------------------------- registry


def test_builtin_platforms_registered():
    names = list_platforms()
    for expected in ("imax3-28nm/16k", "imax3-28nm/32k", "imax3-28nm/64k",
                     "imax3-28nm/128k", "imax3-28nm/256k", "imax3-fpga",
                     "tpu-v5e", "cortex-a72", "jetson-agx-orin",
                     "rtx-4090"):
        assert expected in names, names


def test_registry_round_trip():
    p = Platform(name="test-chip/1", family="test-chip", kind="tpu",
                 memory=MemoryHierarchy(local_bytes=1234, main_bw=1e9),
                 power=PowerModel(nominal_w=5.0),
                 compute={"bf16": 1e12},
                 aliases=("test-chip",))
    try:
        assert register_platform(p) is p
        assert get_platform("test-chip/1") is p
        assert get_platform("test-chip") is p          # alias
        assert get_platform(p) is p                    # pass-through
        assert "test-chip/1" in list_platforms("test-chip")
        with pytest.raises(ValueError, match="already registered"):
            register_platform(dataclasses.replace(p, aliases=()))
        register_platform(dataclasses.replace(p, kind="cpu"),
                          overwrite=True)
        assert get_platform("test-chip/1").kind == "cpu"
    finally:
        _REGISTRY.pop("test-chip/1", None)
        _ALIASES.pop("test-chip", None)


def test_unknown_platform_errors_with_known_names():
    with pytest.raises(KeyError, match="imax3-28nm/32k"):
        get_platform("no-such-chip")


def test_alias_resolves_to_pdp_optimum():
    assert get_platform("imax3-28nm").name == "imax3-28nm/32k"
    assert get_platform("imax3-28nm").vmem_budget == 32 * 1024


def test_power_model_curves_and_flat():
    imax = get_platform("imax3-28nm/32k")
    assert imax.platform_power("fp16") == pytest.approx(0.647)
    assert imax.platform_power("q8_0") == pytest.approx(1.32)
    assert imax.platform_power("q8_0", lanes=2) == pytest.approx(2.64)
    # arbitrary sizes interpolate on the same curves as core.energy
    assert imax.power.power("fp16", 48 * 1024) == pytest.approx(
        imax_power(48 * 1024, "fp16"))
    # flat target: utilization-scaled nominal power
    tpu = get_platform("tpu-v5e")
    assert tpu.power.power(util=0.0) == pytest.approx(60.0)
    assert tpu.power.power(util=1.0) == pytest.approx(200.0)


def test_peak_flops_fallback_chain():
    tpu = get_platform("tpu-v5e")
    assert tpu.peak_flops("bf16") == pytest.approx(197e12)
    assert tpu.peak_flops("q8_0") == pytest.approx(394e12)   # -> int8
    a72 = get_platform("cortex-a72")
    assert a72.peak_flops("q8_0") == a72.peak_flops("f16")   # no int8 rate


# ------------------------------------------- DispatchContext.for_platform


def test_for_platform_derives_budget_policy_platform():
    ctx = DispatchContext.for_platform("imax3-28nm/64k")
    assert ctx.vmem_budget == 64 * 1024
    assert ctx.policy == "optimized"
    assert ctx.platform == "imax3-28nm/64k"
    # alias derives the canonical name
    assert DispatchContext.for_platform("imax3-28nm").platform \
        == "imax3-28nm/32k"


def test_for_platform_allow_pallas_gated_by_env(monkeypatch):
    # platform says "may", environment says "can": with the env opt-in,
    # kernel-offload targets bind pallas and plain hosts never do
    monkeypatch.setenv("REPRO_ALLOW_PALLAS", "1")
    assert DispatchContext.for_platform("tpu-v5e").allow_pallas
    assert DispatchContext.for_platform("imax3-28nm/32k").allow_pallas
    assert not DispatchContext.for_platform("cortex-a72").allow_pallas
    monkeypatch.setenv("REPRO_ALLOW_PALLAS", "0")
    assert not DispatchContext.for_platform("tpu-v5e").allow_pallas
    # explicit override wins over both
    assert DispatchContext.for_platform("tpu-v5e",
                                        allow_pallas=True).allow_pallas


def test_host_platform_routes_everything_host():
    # cortex-a72 has no offload surface: budget 0 -> every op HOST
    assert DispatchContext.for_platform("cortex-a72").vmem_budget == 0


def test_from_env_platform(monkeypatch):
    monkeypatch.setenv("REPRO_PLATFORM", "imax3-28nm/128k")
    ctx = DispatchContext.from_env()
    assert ctx.platform == "imax3-28nm/128k"
    assert ctx.vmem_budget == 128 * 1024
    # explicit budget knob still wins
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "4096")
    assert DispatchContext.from_env().vmem_budget == 4096


def test_dispatch_record_carries_platform_identity():
    import jax.numpy as jnp
    from repro.core.quantize import quantize_q8_0
    x = jax.random.normal(jax.random.key(0), (4, 64), jnp.float32)
    wq = quantize_q8_0(
        jax.random.normal(jax.random.key(1), (64, 32), jnp.float32), axis=0)
    reset_dispatch_log()
    try:
        with use_context(DispatchContext.for_platform("imax3-28nm/32k")):
            dispatch("q8_matmul", x, wq)
        with use_context(DispatchContext(vmem_budget=1024)):
            dispatch("q8_matmul", x, wq)
        recs = dispatch_trace()
        assert [r.platform for r in recs] == ["imax3-28nm/32k", ""]
        assert recs[0].budget == 32 * 1024
    finally:
        reset_dispatch_log()


# ------------------------------------------------- serving energy report


def _serve_whisper(cache_dtype, platform, n_new=3):
    from repro.configs import get_config, reduced
    from repro.models.model import build
    from repro.serving.engine import AudioRequest, ServeEngine
    cfg = reduced(get_config("whisper-tiny-en"))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))
    eng = ServeEngine(model, params, n_slots=2, max_len=64, enc_len=16,
                      cache_dtype=cache_dtype, platform=platform)
    rng = np.random.default_rng(0)
    frames = rng.standard_normal((8, cfg.d_model)).astype(np.float32) * 0.5
    eng.admit(AudioRequest(uid=0, tokens=[5, 6, 7], max_new=n_new,
                           eos_id=-2, enc_frames=frames))
    while eng.n_active:
        eng.step()
    return eng


def test_energy_report_finite_on_required_platforms():
    reset_dispatch_log()
    for plat in ("imax3-28nm/32k", "tpu-v5e"):
        for cdt in ("bf16", "q8_0"):
            eng = _serve_whisper(cdt, plat)
            rep = eng.energy_report()
            assert rep["platform"] == plat
            assert rep["tokens"] > 0 and rep["ticks"] > 0
            for key in ("joules_per_token", "pdp_j", "cache_energy_j",
                        "power_w", "latency_s"):
                assert np.isfinite(rep[key]) and rep[key] > 0, (plat, cdt,
                                                                key, rep)
            assert 0.0 <= rep["accel_flops_share"] <= 1.0
            assert rep["trace_records"] > 0
    reset_dispatch_log()


def test_energy_report_q8_cache_cheaper():
    """The paper's C1 LOAD saving shows up as serving energy: a q8_0 KV
    pool streams ~0.53x the cache bytes/step of bf16, so its cache
    energy (and joules/token, decode being memory-bound) is no worse."""
    reset_dispatch_log()
    eb = _serve_whisper("bf16", "imax3-28nm/32k").energy_report()
    eq = _serve_whisper("q8_0", "imax3-28nm/32k").energy_report()
    reset_dispatch_log()
    assert eq["ticks"] == eb["ticks"]
    assert eq["cache_energy_j"] <= eb["cache_energy_j"]
    assert eq["cache_energy_j"] / eb["cache_energy_j"] == \
        pytest.approx(0.53125, rel=1e-3)
    assert eq["joules_per_token"] <= eb["joules_per_token"]


def test_energy_reports_do_not_cross_contaminate():
    """Two engines on the same platform in one process must attribute
    trace records to themselves (per-engine context tags), not pool
    them by platform name."""
    reset_dispatch_log()
    try:
        e1 = _serve_whisper("bf16", "imax3-28nm/32k")
        r1 = e1.energy_report()
        e2 = _serve_whisper("q8_0", "imax3-28nm/32k")   # no reset between
        r2 = e2.energy_report()
        assert e1.dispatch_ctx.tag != e2.dispatch_ctx.tag
        # the pooled-by-platform view sees both engines' records; each
        # engine's report sees only its own
        pooled = len([r for r in dispatch_trace()
                      if r.platform == "imax3-28nm/32k"])
        assert r1["trace_records"] > 0 and r2["trace_records"] > 0
        assert pooled == r1["trace_records"] + r2["trace_records"]
    finally:
        reset_dispatch_log()


def test_calibrate_missing_observables_raises():
    """A platform without the q8 observables must fail the calibration
    guard with a clear ValueError, not a TypeError downstream."""
    import dataclasses as dc
    from repro.core.energy import calibrate_imax
    from repro.core.workload import WHISPER_TINY, whisper_workload
    w16 = whisper_workload(WHISPER_TINY, dtype="f16")
    w8 = whisper_workload(WHISPER_TINY, dtype="q8_0")
    base = get_platform("imax3-28nm/32k")
    fp16_only = dc.replace(base, paper={
        "latency_s": {"fp16": 13.5},
        "exec_share": {"fp16": 0.6089},
    })
    with pytest.raises(ValueError, match="q8"):
        calibrate_imax(w16, w8, platform=fp16_only)


def test_energy_report_requires_platform():
    from repro.configs import get_config, reduced
    from repro.models.model import build
    from repro.serving.engine import ServeEngine
    cfg = reduced(get_config("qwen3-4b"))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))
    eng = ServeEngine(model, params, n_slots=1, max_len=32)
    with pytest.raises(ValueError, match="platform"):
        eng.energy_report()
