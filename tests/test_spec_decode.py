"""Self-speculative decoding: token parity, rollback, sync counts.

The speculative tick (``ServeEngine(spec_k=K)``) drafts ``K - 1``
tokens with q4-quantized weights and verifies all ``K`` positions in
one full-model multi-query forward, inside the same donated jit as the
plain fused tick. Greedy outputs must be token-identical to plain
``decode_block`` serving in every configuration — EOS mid-draft,
zero-acceptance drafts, paged pools, streaming whisper lanes — while
still syncing to host exactly once per tick.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.quantize import quantize_tree
from repro.models.model import build
from repro.serving.engine import (AudioRequest, Request, ServeEngine,
                                  StreamingAudioRequest)
from repro.serving.scheduler import BatchScheduler

WHISPER_PROMPTS = [[5, 6, 7, 8], [9, 10, 11], [3, 4, 5, 6, 7]]


def _setup(arch="whisper-tiny-en", seed=0):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init_values(jax.random.key(seed))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("enc_len", 16)
    return ServeEngine(model, params, **kw)


def _frames(cfg, rng, lens=(8, 12, 8)):
    return [rng.standard_normal((n, cfg.d_model)).astype(np.float32) * 0.5
            for n in lens]


def _admit_all(eng, frames, max_new=8, eos=-2, prompts=None):
    prompts = prompts or WHISPER_PROMPTS
    return [eng.admit(AudioRequest(uid=i, tokens=list(p), max_new=max_new,
                                   eos_id=eos, enc_frames=f))
            for i, (p, f) in enumerate(zip(prompts, frames))]


def _drain(eng, k=None):
    n = 0
    while eng.n_active:
        eng.step(k)
        n += 1
    return n


# ------------------------------------------------------------- parity


@pytest.mark.parametrize("cache_dtype", ["bf16", "q8_0", "q4_0"])
def test_spec_tick_parity(cache_dtype):
    """The speculative tick == the plain fused tick, token for token,
    on every cache tier — with exactly one host sync per tick."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    frames = _frames(cfg, rng)

    eng_p = _engine(model, params, cache_dtype=cache_dtype,
                    decode_block=4)
    sts_p = _admit_all(eng_p, frames)
    _drain(eng_p)

    eng_s = _engine(model, params, cache_dtype=cache_dtype,
                    decode_block=4, spec_k=4)
    sts_s = _admit_all(eng_s, frames)
    syncs0 = eng_s._host_syncs
    ticks = _drain(eng_s)

    assert [st.out for st in sts_s] == [st.out for st in sts_p]
    assert eng_s._host_syncs - syncs0 == ticks == eng_s._ticks
    # round accounting: every tick ran decode_block // spec_k rounds
    assert eng_s._spec_rounds == eng_s._ticks
    assert eng_s._draft_steps == 3 * eng_s._spec_rounds
    assert eng_s._verify_steps == eng_s._spec_rounds
    assert 0.0 <= eng_s.acceptance_rate <= 1.0


def test_spec_parity_eos_mid_draft():
    """A lane whose greedy stream hits EOS *inside* a draft window must
    stop exactly there: later in-round candidates are masked even if
    the draft happened to match them."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    frames = _frames(cfg, rng)

    probe = _engine(model, params)
    sts = _admit_all(probe, frames, max_new=8)
    _drain(probe, k=1)
    eos = sts[0].out[2]   # lands at round position 2 of a spec_k=4 round

    eng_p = _engine(model, params, decode_block=4)
    sts_p = _admit_all(eng_p, frames, max_new=8, eos=eos)
    _drain(eng_p)

    eng_s = _engine(model, params, decode_block=4, spec_k=4)
    sts_s = _admit_all(eng_s, frames, max_new=8, eos=eos)
    _drain(eng_s)

    assert [st.out for st in sts_s] == [st.out for st in sts_p]
    assert sts_s[0].out[-1] == eos
    assert all(st.done for st in sts_s)


def test_spec_zero_acceptance_worst_case():
    """An adversarial draft (weights from a different init) almost
    never matches the target — the engine must degrade to one verified
    token per round with outputs still token-identical to plain
    decode."""
    cfg, model, params = _setup()
    _, _, other = _setup(seed=7)
    rng = np.random.default_rng(0)
    frames = _frames(cfg, rng)

    eng_p = _engine(model, params, decode_block=4)
    sts_p = _admit_all(eng_p, frames)
    _drain(eng_p)

    eng_s = _engine(model, params, decode_block=4, spec_k=4,
                    draft_params=quantize_tree(other, tier="q4_0"))
    sts_s = _admit_all(eng_s, frames)
    _drain(eng_s)

    assert [st.out for st in sts_s] == [st.out for st in sts_p]
    # near-total rejection: progress comes from the verify forward
    assert eng_s.acceptance_rate < 0.5
    assert eng_s._spec_emitted >= eng_s._spec_live_rounds


def test_spec_paged_parity():
    """Speculative decode over the paged pool: rejected-tail writes
    land on allocated headroom/scratch pages, never on another lane."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    frames = _frames(cfg, rng)

    eng_p = _engine(model, params, decode_block=4, paged=True,
                    page_size=8, cache_dtype="q4_0")
    sts_p = _admit_all(eng_p, frames)
    _drain(eng_p)

    eng_s = _engine(model, params, decode_block=4, spec_k=4, paged=True,
                    page_size=8, cache_dtype="q4_0")
    sts_s = _admit_all(eng_s, frames)
    _drain(eng_s)

    assert [st.out for st in sts_s] == [st.out for st in sts_p]
    # every page returned: no leak through the speculative headroom
    rep = eng_s.paging_report()
    assert rep["self"]["pages_in_use"] == 0
    assert rep["cross"]["pages_in_use"] == 0


def test_spec_streaming_whisper_parity():
    """Streaming lanes (chunked audio, mid-stream parking, final
    re-anchor) served by a speculative engine match the plain engine's
    transcript and partial hypotheses."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(1)
    chunks = [rng.standard_normal((4, cfg.d_model)).astype(np.float32) * 0.5
              for _ in range(3)]
    frames = _frames(cfg, rng, lens=(8,))

    def serve(spec_k):
        eng = _engine(model, params, decode_block=4, spec_k=spec_k)
        sched = BatchScheduler(eng)
        sched.submit(StreamingAudioRequest(uid=0, tokens=[5, 6], max_new=2,
                                           eos_id=-2, chunks=chunks))
        sched.submit(AudioRequest(uid=1, tokens=[7, 8, 9], max_new=9,
                                  eos_id=-2, enc_frames=frames[0]))
        sched.run_until_drained(max_ticks=100)
        assert sched.drained
        return sched.results

    plain, spec = serve(0), serve(4)
    assert spec[0].out == plain[0].out
    assert spec[0].partials == plain[0].partials
    assert spec[1].out == plain[1].out


def test_spec_decoder_only_parity():
    cfg, model, params = _setup("qwen3-4b")
    prompts = [[5, 6, 7, 8], [9, 10, 11]]

    def serve(spec_k):
        eng = _engine(model, params, max_len=96, decode_block=4,
                      spec_k=spec_k)
        sts = [eng.admit(Request(uid=i, tokens=p, max_new=9, eos_id=-2))
               for i, p in enumerate(prompts)]
        _drain(eng)
        return [st.out for st in sts]

    assert serve(0) == serve(2) == serve(4)


# --------------------------------------------- donation / validation


def test_spec_decode_jit_donates_cache_and_state():
    cfg, model, params = _setup()
    eng = _engine(model, params, decode_block=4, spec_k=4)
    fn = eng._build_decode(4)
    lowered = fn.lower(params, eng.cache, eng._tokens, eng._pos,
                       eng._lane_active, eng._lane_out, eng._enc_lens,
                       eng._lane_eos, eng._lane_max)
    assert lowered.as_text().count("tf.aliasing_output") >= 5


def test_spec_knob_validation():
    cfg, model, params = _setup()
    with pytest.raises(ValueError, match="spec_k"):
        _engine(model, params, spec_k=1)
    with pytest.raises(ValueError, match="multiple"):
        _engine(model, params, decode_block=3, spec_k=2)
    with pytest.raises(ValueError, match="draft_dtype"):
        _engine(model, params, decode_block=2, spec_k=2,
                draft_dtype="int3")
    eng = _engine(model, params, decode_block=4, spec_k=4)
    rng = np.random.default_rng(0)
    _admit_all(eng, _frames(cfg, rng))
    with pytest.raises(ValueError, match="multiple"):
        eng.step_begin(k=6)
    # quantized served params need explicit draft weights
    with pytest.raises(ValueError, match="draft_params"):
        _engine(model, quantize_tree(params), decode_block=2, spec_k=2)


def test_spec_recurrent_lane_rejected():
    cfg, model, params = _setup("xlstm-350m")
    with pytest.raises(ValueError, match="roll"):
        _engine(model, params, decode_block=2, spec_k=2)


def test_spec_validate_headroom():
    """Speculative lanes keep spec_k - 1 extra KV positions of
    headroom; a request that fits a plain engine exactly is TOO_LONG
    for the speculative one."""
    cfg, model, params = _setup()
    plain = _engine(model, params)
    spec = _engine(model, params, decode_block=4, spec_k=4)
    req = AudioRequest(uid=0, tokens=list(range(2, 33)), max_new=32,
                       eos_id=-2,
                       enc_frames=np.zeros((8, cfg.d_model), np.float32))
    assert plain.validate(req) is None         # 31 + 32 < 64
    rej = spec.validate(req)
    assert rej is not None and rej.code.value == "too_long"
    req2 = AudioRequest(uid=1, tokens=list(range(2, 30)), max_new=32,
                        eos_id=-2,
                        enc_frames=np.zeros((8, cfg.d_model), np.float32))
    assert spec.validate(req2) is None         # 28 + 32 + 3 < 64


def test_spec_energy_report_fields():
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    eng = _engine(model, params, decode_block=4, spec_k=4,
                  cache_dtype="q4_0", platform="imax3-28nm/32k")
    _admit_all(eng, _frames(cfg, rng))
    _drain(eng)
    er = eng.energy_report()
    spec = er["speculative"]
    assert spec["spec_k"] == 4 and spec["draft_dtype"] == "q4_0"
    assert spec["draft_steps"] == 3 * spec["rounds"]
    assert spec["verify_steps"] == spec["rounds"]
    assert 0.0 <= spec["acceptance_rate"] <= 1.0
    assert 0 < spec["draft_weight_bytes"] < er["weight_bytes"]
    assert er["modeled_tokens_per_s"] > 0
