"""§Perf variants: grouped vs global MoE, baseline-flag paths."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced


def test_grouped_moe_matches_global_at_high_capacity():
    """With capacity >= tokens (no drops), grouped and global dispatch
    compute the same mixture."""
    import dataclasses
    from repro.models import moe
    from repro.models.layers import KeyGen, split_params
    cfg = dataclasses.replace(reduced(get_config("mixtral-8x7b")),
                              capacity_factor=8.0)
    keys = KeyGen(jax.random.key(0))
    params, _ = split_params(moe.init_moe(keys, cfg))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.5
    y_g = moe.moe_ffn(params, x, cfg, grouped=True)
    y_glob = moe.moe_ffn(params, x, cfg, grouped=False)
    np.testing.assert_allclose(np.asarray(y_g, np.float32),
                               np.asarray(y_glob, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_grouped_moe_capacity_is_per_row():
    """Grouped dispatch caps per batch row: a row whose tokens all pick
    one expert drops beyond cap, independent of other rows."""
    from repro.models import moe
    from repro.models.layers import KeyGen, split_params
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("qwen3-moe-30b-a3b")),
                              capacity_factor=1.0)
    keys = KeyGen(jax.random.key(2))
    params, _ = split_params(moe.init_moe(keys, cfg))
    x = jax.random.normal(jax.random.key(3), (3, 8, cfg.d_model)) * 0.5
    y = moe.moe_ffn(params, x, cfg, grouped=True)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


@pytest.mark.slow
def test_baseline_flag_restores_prehillclimb_paths():
    """REPRO_BASELINE=1: models still run and produce finite outputs
    through every legacy path (f32 attention, ys-decode, global MoE,
    in-scan sLSTM gates)."""
    code = """
import os
os.environ["REPRO_BASELINE"] = "1"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.models.model import build
for a in ("mixtral-8x7b", "xlstm-350m", "whisper-base", "qwen3-4b"):
    cfg = reduced(get_config(a))
    m = build(cfg)
    v = m.init_values(jax.random.key(0))
    if cfg.enc_dec:
        batch = {"enc_frames": jnp.zeros((2, 8, cfg.d_model), jnp.bfloat16),
                 "tokens": jnp.zeros((2, 8), jnp.int32)}
    else:
        batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    logits, _ = m.forward(v, batch, mode="train")
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), a
    # decode through the legacy ys path
    b = 2
    cache = m.init_cache(b, 32, enc_len=8)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    _, cache = m.forward(v, pre, mode="prefill", cache=cache)
    ld, _ = m.forward(v, {"tokens": batch["tokens"][:, -1:]},
                      mode="decode", cache=cache,
                      pos=jnp.asarray(batch["tokens"].shape[1] - 1))
    assert bool(jnp.isfinite(ld.astype(jnp.float32)).all()), a
print("BASELINE-OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd="/root/repo", timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "BASELINE-OK" in r.stdout
