"""Serving engine + scheduler: correctness under continuous batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import build
from repro.serving.engine import Request, ServeEngine, _bucket
from repro.serving.scheduler import BatchScheduler


def _engine(arch="qwen3-4b", n_slots=4, max_len=96, seed=0):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init_values(jax.random.key(seed))
    return cfg, model, params, ServeEngine(model, params, n_slots=n_slots,
                                           max_len=max_len)


def _greedy_reference(model, params, prompt, n_new):
    """Slot-free reference: full forward re-run per token (greedy)."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits, _ = model.forward(params,
                                  {"tokens": jnp.asarray([toks])},
                                  mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_slotfree_reference():
    """Tokens from the batched continuous engine == full-forward greedy."""
    cfg, model, params, eng = _engine()
    prompts = [[5, 6, 7, 8], [9, 10, 11], [3, 4, 5, 6, 7, 8, 9]]
    sts = [eng.admit(Request(uid=i, tokens=p, max_new=4, eos_id=-2))
           for i, p in enumerate(prompts)]
    while eng.n_active:
        eng.step()
    for st, p in zip(sts, prompts):
        want = _greedy_reference(model, params, p, 4)
        assert st.out == want, (st.out, want)


def test_interleaved_admission_does_not_corrupt():
    """A request admitted mid-decode of others produces the same tokens
    as one decoded alone — the cache-isolation property."""
    cfg, model, params, eng = _engine()
    st0 = eng.admit(Request(uid=0, tokens=[5, 6, 7], max_new=6, eos_id=-2))
    eng.step()
    eng.step()
    st1 = eng.admit(Request(uid=1, tokens=[8, 9, 10, 11], max_new=4,
                            eos_id=-2))
    while eng.n_active:
        eng.step()

    _, model2, params2, eng2 = _engine()
    st1_alone = eng2.admit(Request(uid=9, tokens=[8, 9, 10, 11], max_new=4,
                                   eos_id=-2))
    while eng2.n_active:
        eng2.step()
    assert st1.out == st1_alone.out


def test_eos_stops_early():
    cfg, model, params, eng = _engine()
    st = eng.admit(Request(uid=0, tokens=[5, 6, 7], max_new=50, eos_id=-2))
    want = _greedy_reference(model, params, [5, 6, 7], 3)
    eos = want[1]
    st2 = eng.admit(Request(uid=1, tokens=[5, 6, 7], max_new=50, eos_id=eos))
    while eng.n_active:
        eng.step()
    assert st2.out[-1] == eos and len(st2.out) == 2


def test_pool_exhaustion_returns_none():
    cfg, model, params, eng = _engine(n_slots=1)
    assert eng.admit(Request(uid=0, tokens=[3, 4], max_new=8,
                             eos_id=-2)) is not None
    assert eng.admit(Request(uid=1, tokens=[5, 6], max_new=8,
                             eos_id=-2)) is None


def test_request_too_long_raises():
    cfg, model, params, eng = _engine(max_len=32)
    with pytest.raises(ValueError):
        eng.admit(Request(uid=0, tokens=list(range(3, 30)), max_new=16))


def test_scheduler_drains_and_reuses_slots():
    cfg, model, params, eng = _engine(n_slots=2)
    sched = BatchScheduler(eng)
    for i in range(7):
        sched.submit(Request(uid=i, tokens=[3 + i, 4, 5], max_new=3,
                             eos_id=-2))
    sched.run_until_drained(max_ticks=200)
    assert sched.drained
    assert sched.metrics.completed == 7
    assert len(sched.results) == 7
    assert sched.metrics.mean_occupancy > 0.3


def test_bucket_rounding():
    assert _bucket(3) == 32
    assert _bucket(33) == 64
    assert _bucket(5000) == 6144
