"""Serving engine + scheduler: correctness under continuous batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels.api import reset_dispatch_log
from repro.models.model import build
from repro.serving.engine import (AudioRequest, Request, ServeEngine,
                                  _bucket)
from repro.serving.scheduler import BatchScheduler


def _engine(arch="qwen3-4b", n_slots=4, max_len=96, seed=0, **kw):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init_values(jax.random.key(seed))
    return cfg, model, params, ServeEngine(model, params, n_slots=n_slots,
                                           max_len=max_len, **kw)


def _greedy_reference(model, params, prompt, n_new):
    """Slot-free reference: full forward re-run per token (greedy)."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits, _ = model.forward(params,
                                  {"tokens": jnp.asarray([toks])},
                                  mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


# logit margin under which a greedy pick may legitimately flip between
# the engine's decode path and the full-forward reference (accumulation
# order differs; bf16 activations round ~1e-2-scale logit differences).
# Keyed by the config's *compute* dtype — params are stored f32.
_TIE_MARGIN = {"bf16": 0.15, "f16": 0.05}
_TIE_MARGIN_DEFAULT = 1e-3


def _assert_greedy_matches(model, params, prompt, got, margin):
    """Engine tokens must equal the slot-free greedy reference, except
    that at the FIRST divergence the engine's pick must be a near-tie:
    its reference logit within ``margin`` of the reference argmax. After
    a tie flip the sequences legitimately differ, so comparison stops
    there (the prefix equality is still asserted)."""
    toks = list(prompt)
    for i, tok in enumerate(got):
        logits, _ = model.forward(params,
                                  {"tokens": jnp.asarray([toks])},
                                  mode="train")
        lg = np.asarray(logits[0, -1], np.float32)
        want = int(lg.argmax())
        if tok == want:
            toks.append(tok)
            continue
        gap = float(lg[want] - lg[tok])
        assert gap < margin, (
            f"engine diverged at step {i} ({tok} vs {want}) with a "
            f"non-tie logit gap {gap:.4f} >= {margin}")
        return
    # fully identical sequences


def test_engine_matches_slotfree_reference():
    """Tokens from the batched continuous engine == full-forward greedy,
    up to near-ties at the bf16 rounding boundary (per-dtype margin)."""
    cfg, model, params, eng = _engine()
    margin = _TIE_MARGIN.get(cfg.dtype, _TIE_MARGIN_DEFAULT)
    prompts = [[5, 6, 7, 8], [9, 10, 11], [3, 4, 5, 6, 7, 8, 9]]
    sts = [eng.admit(Request(uid=i, tokens=p, max_new=4, eos_id=-2))
           for i, p in enumerate(prompts)]
    while eng.n_active:
        eng.step()
    for st, p in zip(sts, prompts):
        _assert_greedy_matches(model, params, p, st.out, margin)


def test_interleaved_admission_does_not_corrupt():
    """A request admitted mid-decode of others produces the same tokens
    as one decoded alone — the cache-isolation property."""
    cfg, model, params, eng = _engine()
    eng.admit(Request(uid=0, tokens=[5, 6, 7], max_new=6, eos_id=-2))
    eng.step()
    eng.step()
    st1 = eng.admit(Request(uid=1, tokens=[8, 9, 10, 11], max_new=4,
                            eos_id=-2))
    while eng.n_active:
        eng.step()

    _, model2, params2, eng2 = _engine()
    st1_alone = eng2.admit(Request(uid=9, tokens=[8, 9, 10, 11], max_new=4,
                                   eos_id=-2))
    while eng2.n_active:
        eng2.step()
    assert st1.out == st1_alone.out


def test_eos_stops_early():
    cfg, model, params, eng = _engine()
    eng.admit(Request(uid=0, tokens=[5, 6, 7], max_new=50, eos_id=-2))
    want = _greedy_reference(model, params, [5, 6, 7], 3)
    eos = want[1]
    st2 = eng.admit(Request(uid=1, tokens=[5, 6, 7], max_new=50, eos_id=eos))
    while eng.n_active:
        eng.step()
    assert st2.out[-1] == eos and len(st2.out) == 2


def test_pool_exhaustion_returns_none():
    cfg, model, params, eng = _engine(n_slots=1)
    assert eng.admit(Request(uid=0, tokens=[3, 4], max_new=8,
                             eos_id=-2)) is not None
    assert eng.admit(Request(uid=1, tokens=[5, 6], max_new=8,
                             eos_id=-2)) is None


def test_request_too_long_raises():
    cfg, model, params, eng = _engine(max_len=32)
    with pytest.raises(ValueError):
        eng.admit(Request(uid=0, tokens=list(range(3, 30)), max_new=16))


def test_scheduler_drains_and_reuses_slots():
    cfg, model, params, eng = _engine(n_slots=2)
    sched = BatchScheduler(eng)
    for i in range(7):
        sched.submit(Request(uid=i, tokens=[3 + i, 4, 5], max_new=3,
                             eos_id=-2))
    sched.run_until_drained(max_ticks=200)
    assert sched.drained
    assert sched.metrics.completed == 7
    assert len(sched.results) == 7
    assert sched.metrics.mean_occupancy > 0.3


def test_bucket_rounding():
    assert _bucket(3) == 32
    assert _bucket(33) == 64
    assert _bucket(5000) == 6144


# --------------------------------------------------------------- enc-dec


WHISPER_PROMPTS = [[5, 6, 7, 8], [9, 10, 11], [3, 4, 5, 6, 7]]


def _whisper_frames(cfg, rng, lens=(8, 12, 8)):
    return [rng.standard_normal((n, cfg.d_model)).astype(np.float32) * 0.5
            for n in lens]


def _greedy_encdec_reference(model, params, prompt, frames, n_new):
    """Slot-free enc-dec reference: full forward re-run per token."""
    toks = list(prompt)
    out = []
    fr = jnp.asarray(frames)[None]
    for _ in range(n_new):
        logits, _ = model.forward(
            params, {"tokens": jnp.asarray([toks]), "enc_frames": fr},
            mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def _run_whisper_engine(cache_dtype, frames, n_new=4):
    cfg, model, params, eng = _engine("whisper-tiny-en", n_slots=4,
                                      max_len=64, enc_len=16,
                                      cache_dtype=cache_dtype)
    sts = [eng.admit(AudioRequest(uid=i, tokens=p, max_new=n_new,
                                  eos_id=-2, enc_frames=f))
           for i, (p, f) in enumerate(zip(WHISPER_PROMPTS, frames))]
    while eng.n_active:
        eng.step()
    return cfg, model, params, eng, sts


def test_whisper_engine_matches_slotfree_reference():
    """Enc-dec serving parity: the engine encodes frames at their exact
    length, caches per-slot encoder K/V (padded to the pool enc_len),
    and masks each lane's cross-attention — so batched continuous
    decoding must equal the slot-free full-forward greedy reference."""
    rng = np.random.default_rng(0)
    cfg0 = reduced(get_config("whisper-tiny-en"))
    frames = _whisper_frames(cfg0, rng)
    cfg, model, params, eng, sts = _run_whisper_engine("bf16", frames)
    for st, p, f in zip(sts, WHISPER_PROMPTS, frames):
        want = _greedy_encdec_reference(model, params, p, f, 4)
        assert st.out == want, (st.out, want)


def test_whisper_missing_frames_rejected():
    cfg, model, params, eng = _engine("whisper-tiny-en", n_slots=2,
                                      max_len=32, enc_len=8)
    assert eng.validate(Request(uid=0, tokens=[1, 2], max_new=2))
    with pytest.raises(ValueError):
        eng.admit(Request(uid=0, tokens=[1, 2], max_new=2))
    # frames longer than the pool's enc_len are also unservable
    frames = np.zeros((9, model.cfg.d_model), np.float32)
    assert eng.validate(AudioRequest(uid=1, tokens=[1, 2], max_new=2,
                                     enc_frames=frames))


# --------------------------------------------------------- q8_0 KV cache


def test_q8_cache_engine_matches_bf16_and_routes_kernel():
    """The q8_0 cache-dtype policy: same whisper workload served through
    a quantized KV pool stays token-exact vs the bf16 engine (Q8_0 KV
    error ~0.4% — near-ties can flip in principle, but not on this
    pinned workload), and every decode tick's cache matvec routes
    through the q8_decode_attention op."""
    rng = np.random.default_rng(0)
    cfg0 = reduced(get_config("whisper-tiny-en"))
    frames = _whisper_frames(cfg0, rng)
    *_, sts_bf16 = _run_whisper_engine("bf16", frames)
    reset_dispatch_log()
    cfg, model, params, eng8, sts_q8 = _run_whisper_engine("q8_0", frames)

    agree = sum(a == b for a, b in
                zip((st.out for st in sts_q8),
                    (st.out for st in sts_bf16)))
    assert agree == len(sts_q8), [(a.out, b.out)
                                  for a, b in zip(sts_q8, sts_bf16)]

    rep = eng8.dispatch_report()
    q8_calls = sum(n for (op, _, _), n in rep["counters"].items()
                   if op == "q8_decode_attention")
    assert q8_calls > 0, rep["counters"]
    assert rep["cache"]["cache_dtype"] == "q8_0"
    assert rep["cache"]["traffic_ratio_vs_bf16"] == pytest.approx(0.53125)


def test_q8_cache_bytes_ratio():
    """Pool bytes: q8_0 stores 1.0625 bytes/elem vs 2 for bf16 — the
    paper's C1 LOAD saving on the decode-cache stream (~0.53x)."""
    rng = np.random.default_rng(0)
    cfg0 = reduced(get_config("whisper-tiny-en"))
    frames = _whisper_frames(cfg0, rng, lens=(8, 8, 8))
    *_, eng_bf, _ = _run_whisper_engine("bf16", frames, n_new=2)
    *_, eng_q8, _ = _run_whisper_engine("q8_0", frames, n_new=2)
    rb, rq = eng_bf.cache_report(), eng_q8.cache_report()
    assert rq["bytes_per_step"] / rb["bytes_per_step"] == \
        pytest.approx(0.53125)
    assert rq["self_kv_bytes_per_token"] / rb["self_kv_bytes_per_token"] \
        == pytest.approx(0.53125)


def test_q8_decode_attention_module_close_to_bf16():
    """One decode step through models.attention with a q8_0 cache is
    within the Q8 error envelope of the bf16 cache path (per-lane
    positions, stacked cache — the serving configuration)."""
    from repro.core.quantize import quantize_q8_0
    from repro.models.attention import attention, init_attention
    from repro.models.layers import KeyGen, split_params
    cfg = reduced(get_config("whisper-tiny-en"))
    p, _ = split_params(init_attention(KeyGen(jax.random.key(5)), cfg))
    b, s, hkv, d = 2, 32, cfg.n_kv_heads, cfg.head_dim
    key = jax.random.key(7)
    x = jax.random.normal(jax.random.fold_in(key, 0), (b, 1, cfg.d_model),
                          jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, b, s, hkv, d),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, b, s, hkv, d),
                          jnp.bfloat16)
    kt, vt = quantize_q8_0(k, axis=-1), quantize_q8_0(v, axis=-1)
    pos = jnp.asarray([5, 9], jnp.int32)
    y_bf, _ = attention(p, x, cfg, mode="decode", use_rope=False,
                        cache={"k": k, "v": v}, pos=pos, layer_idx=0)
    y_q8, c_q8 = attention(p, x, cfg, mode="decode", use_rope=False,
                           cache={"kq": kt.q, "ks": kt.scale,
                                  "vq": vt.q, "vs": vt.scale},
                           pos=pos, layer_idx=0)
    rel = float(jnp.linalg.norm((y_q8 - y_bf).astype(jnp.float32))
                / jnp.linalg.norm(y_bf.astype(jnp.float32)))
    assert rel < 0.05, rel
    # the write quantized the new token in place at each lane's pos
    got = np.asarray(c_q8["kq"])[0, np.arange(b), np.asarray(pos)]
    assert np.abs(got).sum() > 0


# ---------------------------------------------------- robustness bugfixes


def test_freed_slots_reset_parked_state():
    """Parked lanes must not attend their dead context: freeing a slot
    zeroes its pos/tokens, so a parked lane decodes exactly one
    position per tick (the comment in engine.py is now enforced)."""
    cfg, model, params, eng = _engine(n_slots=3, max_len=64)
    sts = [eng.admit(Request(uid=i, tokens=[5 + i, 6, 7], max_new=3,
                             eos_id=-2)) for i in range(3)]
    while eng.n_active:
        eng.step()
    assert all(st.done for st in sts)
    assert sorted(eng.free) == [0, 1, 2]
    assert (eng._pos == 0).all(), eng._pos
    assert (eng._tokens == 0).all(), eng._tokens
    assert (eng._enc_lens == 0).all()


def test_scheduler_survives_bad_requests():
    """One unservable request must not kill the serving loop: it is
    completed as a failed RequestState in results, everything else
    drains normally."""
    cfg, model, params, eng = _engine(n_slots=2, max_len=32)
    sched = BatchScheduler(eng)
    sched.submit(Request(uid=0, tokens=list(range(3, 30)), max_new=16,
                         eos_id=-2))                     # too long
    sched.submit(Request(uid=1, tokens=[4, 5, 6], max_new=3, eos_id=-2))
    sched.submit(Request(uid=2, tokens=[7, 8], max_new=3, eos_id=-2,
                         enc_frames=np.zeros((4, 8), np.float32)))
    sched.submit(Request(uid=3, tokens=[9, 10], max_new=3, eos_id=-2))
    sched.run_until_drained(max_ticks=100)
    assert sched.drained
    assert sched.metrics.rejected == 2
    assert sched.metrics.completed == 2
    assert sched.results[0].error and sched.results[0].slot == -1
    assert sched.results[2].error
    assert len(sched.results[1].out) == 3 and not sched.results[1].error
    assert len(sched.results[3].out) == 3
