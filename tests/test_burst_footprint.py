"""Burst partitioning (C2) + footprint/coverage model (C3/C4)."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core.burst import offload_rate, optimal_burst, split_burst
from repro.core.footprint import (block_vmem_bytes, coverage_cdf,
                                  kernel_footprint, select_blocks)
from repro.core.workload import (WHISPER_TINY, WHISPER_BASE, WHISPER_SMALL,
                                 k_length_histogram, whisper_workload)


# ---------------------------------------------------------------- burst (C2)

def test_split_exact():
    s = split_burst(100, 16)
    assert (s.k_main, s.k_residual) == (96, 4)
    assert s.k_main % 16 == 0
    assert s.k_main + s.k_residual == 100


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([4, 8, 16, 32, 64]))
def test_property_split(k, burst):
    s = split_burst(k, burst)
    assert s.k_main % burst == 0
    assert 0 <= s.k_residual < burst
    assert s.k_main + s.k_residual == k


def test_offload_rate_whisper_residual_small():
    """Paper Sec III-B: at burst=16 the CPU residual is ~5% of compute."""
    hist = k_length_histogram(whisper_workload(WHISPER_TINY))
    rate = offload_rate(hist, 16)
    assert rate > 0.90, rate


def test_optimal_burst_is_16():
    """Paper: 16 found optimal over Whisper's K-length distribution."""
    hist = k_length_histogram(whisper_workload(WHISPER_TINY))
    best = optimal_burst(hist)
    assert best.burst == 16, best


def test_burst_tradeoff_monotonicity():
    """Larger burst -> lower offload rate (more residual), fewer setups."""
    hist = {100: 10, 200: 5, 65: 20}
    rates = [offload_rate(hist, b) for b in (4, 8, 16, 32, 64)]
    assert all(a >= b for a, b in zip(rates, rates[1:]))


# ---------------------------------------------------------- footprint (C3/4)

def test_footprint_policies_ordering():
    """The optimized tile beats staging the whole padded plane whenever
    the plane is meaningfully larger than one (n_tile+1)-row tile — the
    regime the paper's Table I is about (decode m=1 attention rows with
    K<=28 are smaller than any tile; exempt)."""
    work = whisper_workload(WHISPER_TINY)
    for spec in work:
        if spec.n >= 4 * 5:   # plane at least ~4 tiles tall
            assert kernel_footprint(spec, "optimized") <= \
                kernel_footprint(spec, "baseline") + 64, spec


def test_coverage_monotone_in_limit():
    work = whisper_workload(WHISPER_TINY)
    for policy in ("baseline", "optimized"):
        rows = coverage_cdf(work, policy)
        pcts = [r.coverage_pct for r in rows]
        assert all(a <= b + 1e-9 for a, b in zip(pcts, pcts[1:]))
    # optimized reaches full coverage by 256 KB (baseline need not: the
    # staged logits plane exceeds any LMM — exactly the paper's point)
    assert coverage_cdf(work, "optimized")[-1].coverage_pct == \
        pytest.approx(100.0)


def test_table1_structure():
    """Paper Table I structure: near-zero baseline coverage at 32 KB,
    >90% optimized coverage at 32 KB for tiny."""
    work = whisper_workload(WHISPER_TINY)
    base = {r.limit_bytes: r.coverage_pct
            for r in coverage_cdf(work, "baseline")}
    opt = {r.limit_bytes: r.coverage_pct
           for r in coverage_cdf(work, "optimized")}
    assert base[32 * 1024] < 35.0          # baseline barely fits
    assert opt[32 * 1024] > 90.0           # paper: 93.80 %
    assert opt[8 * 1024] > 50.0            # paper: 64.96 %


def test_table4_structure_base_small_need_64k():
    """Paper Table IV signature: base/small flat 16->32 KB (their d_ff
    GEMMs don't fit until 64 KB); tiny jumps at 32 KB (d_ff=1536 fits)."""
    for dims in (WHISPER_BASE, WHISPER_SMALL):
        work = whisper_workload(dims)
        opt = {r.limit_bytes: r.coverage_pct
               for r in coverage_cdf(work, "optimized")}
        assert opt[32 * 1024] - opt[16 * 1024] < 2.0, dims.name
        assert opt[64 * 1024] - opt[32 * 1024] > 3.0, dims.name
        assert opt[64 * 1024] > 94.0, dims.name
    tiny = {r.limit_bytes: r.coverage_pct
            for r in coverage_cdf(whisper_workload(WHISPER_TINY),
                                  "optimized")}
    assert tiny[32 * 1024] - tiny[16 * 1024] > 3.0


def test_dot_product_counts_scale_like_paper():
    """Sec V-C: dot products grow tiny < base < small with ~4x tiny->small."""
    from repro.core.workload import total_dot_products
    tiny = total_dot_products(whisper_workload(WHISPER_TINY))
    base = total_dot_products(whisper_workload(WHISPER_BASE))
    small = total_dot_products(whisper_workload(WHISPER_SMALL))
    assert tiny < base < small
    assert 2.5 < small / tiny < 6.0


# ------------------------------------------------------------ select_blocks

def test_select_blocks_fits_and_aligned():
    for budget in (256 * 1024, 1024 * 1024, 4 * 1024 * 1024):
        b = select_blocks(512, 4096, 4096, budget)
        assert b.vmem_bytes <= budget
        assert b.bn % 128 == 0 and b.bm % 8 == 0 and b.bk % 32 == 0


def test_select_blocks_monotone_in_budget():
    """More VMEM -> at least as large a tile (the LMM-size knob)."""
    sizes = []
    for budget in (128 * 1024, 512 * 1024, 2 * 1024 * 1024, 8 * 1024 * 1024):
        b = select_blocks(1024, 8192, 8192, budget)
        sizes.append(b.bm * b.bn * b.bk)
    assert all(a <= b for a, b in zip(sizes, sizes[1:]))


def test_select_blocks_raises_when_impossible():
    with pytest.raises(ValueError):
        select_blocks(8, 128, 32, 128)   # 128 B cannot hold any tile


@settings(max_examples=30, deadline=None)
@given(st.sampled_from([128, 256, 512, 2048]),
       st.sampled_from([256, 4096, 16384]),
       st.sampled_from([512, 4096]),
       st.sampled_from([262144, 1048576, 8388608]))
def test_property_select_blocks(m, n, k, budget):
    b = select_blocks(m, n, k, budget)
    assert b.vmem_bytes <= budget
    assert block_vmem_bytes(b.bm, b.bn, b.bk, "bf16", "bf16") <= budget
