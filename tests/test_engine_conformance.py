"""Cross-family engine conformance: the whole model zoo through one
``ServeEngine``.

Every family the lane-state spec (``Model.state_spec``) declares —
dense causal KV (qwen3), enc-dec self+cross KV (whisper), MoE KV +
expert-routing counters (qwen3-moe), hybrid KV + SSM state (zamba2),
pure recurrent mLSTM/sLSTM state (xlstm) — runs the same battery:

  admit -> (exact or bucketed) prefill -> fused decode ticks ->
  EOS mid-block -> abort -> drain

with the same invariants asserted for each: engine tokens equal the
slot-free full-forward greedy reference (up to documented near-tie
flips at the compute-dtype rounding boundary), the fused tick is
token-identical to sequential single steps, exactly one host sync per
tick, and the lane-state ledger (``engine.lanestate``) drains to zero
through every exit path. q8_0 rows run wherever the family's spec
supports the quantized KV tier; unsupported families reject the tier
with a spec-driven error.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import build
from repro.serving.engine import AudioRequest, Request, ServeEngine
from repro.serving.scheduler import BatchScheduler

ARCHS = ("qwen3-4b", "whisper-tiny-en", "qwen3-moe-30b-a3b",
         "zamba2-7b", "xlstm-350m")
# families whose spec supports the q8_0 KV tier (asserted against the
# spec itself in test_q8_support_matrix)
Q8_ARCHS = ("qwen3-4b", "whisper-tiny-en", "qwen3-moe-30b-a3b",
            "zamba2-7b")
PAIRS = [(a, "bf16") for a in ARCHS] + [(a, "q8_0") for a in Q8_ARCHS]

PROMPTS = ([5, 6, 7], [9, 10, 11, 12])
MAX_NEW = 6

# see tests/test_serving.py: greedy picks may flip at near-ties under
# bf16 accumulation-order differences
_TIE_MARGIN = {"bf16": 0.15, "f16": 0.05}
_TIE_MARGIN_DEFAULT = 1e-3

_SETUP_CACHE: dict = {}


def _setup(arch):
    if arch not in _SETUP_CACHE:
        cfg = reduced(get_config(arch))
        if cfg.is_moe:
            # raised so no token is capacity-dropped: the slot-free
            # reference recomputes the whole sequence each step and
            # would otherwise make *different* (correct-but-unequal)
            # capacity cuts than the engine's incremental path — same
            # idiom as test_prefill_decode_equals_forward; binding
            # capacity is covered by test_moe_prefill_padding_mask
            cfg = dataclasses.replace(
                cfg, capacity_factor=float(cfg.n_experts))
        model = build(cfg)
        params = model.init_values(jax.random.key(0))
        _SETUP_CACHE[arch] = (cfg, model, params)
    return _SETUP_CACHE[arch]


def _engine(arch, **kw):
    cfg, model, params = _setup(arch)
    kw.setdefault("n_slots", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("enc_len", 16)
    kw.setdefault("decode_block", 4)
    return cfg, model, params, ServeEngine(model, params, **kw)


def _frames(cfg, uid):
    rng = np.random.default_rng(uid)
    return rng.standard_normal((8 + 2 * (uid % 4), cfg.d_model)).astype(
        np.float32) * 0.5


def _request(cfg, uid, tokens, max_new=MAX_NEW, eos=-2, fuid=None):
    """``fuid`` pins the (seeded) audio frames independently of the
    request uid, so a later request can replay an earlier workload."""
    if cfg.enc_dec:
        return AudioRequest(uid=uid, tokens=list(tokens),
                            max_new=max_new, eos_id=eos,
                            enc_frames=_frames(
                                cfg, uid if fuid is None else fuid))
    return Request(uid=uid, tokens=list(tokens), max_new=max_new,
                   eos_id=eos)


def _ref_logits(model, params, toks, frames):
    batch = {"tokens": jnp.asarray([toks])}
    if frames is not None:
        batch["enc_frames"] = jnp.asarray(frames)[None]
    logits, _ = model.forward(params, batch, mode="train")
    return np.asarray(logits[0, -1], np.float32)


def _greedy_ref(model, params, prompt, frames, n_new):
    toks, out = list(prompt), []
    for _ in range(n_new):
        nxt = int(_ref_logits(model, params, toks, frames).argmax())
        out.append(nxt)
        toks.append(nxt)
    return out


def _assert_matches_ref(model, params, prompt, frames, got, margin):
    """Engine tokens == slot-free greedy reference, except the first
    divergence must be a near-tie (reference logit of the engine's
    pick within ``margin`` of the reference argmax); comparison stops
    at a tie flip — the sequences legitimately differ after it."""
    toks = list(prompt)
    for i, tok in enumerate(got):
        lg = _ref_logits(model, params, toks, frames)
        want = int(lg.argmax())
        if tok == want:
            toks.append(tok)
            continue
        gap = float(lg[want] - lg[tok])
        assert gap < margin, (
            f"engine diverged at step {i} ({tok} vs {want}) with a "
            f"non-tie logit gap {gap:.4f} >= {margin}")
        return


def _drain(eng):
    while eng.n_active:
        eng.step()


# ---------------------------------------------------------- the battery


@pytest.mark.parametrize("arch,cache_dtype", PAIRS,
                         ids=[f"{a}|{d}" for a, d in PAIRS])
def test_conformance_battery(arch, cache_dtype):
    cfg, model, params, eng = _engine(arch, cache_dtype=cache_dtype)
    margin = _TIE_MARGIN.get(cfg.dtype, _TIE_MARGIN_DEFAULT)

    # --- admit -> prefill -> fused decode -> drain -------------------
    sts = [eng.admit(_request(cfg, i, p)) for i, p in enumerate(PROMPTS)]
    assert all(st is not None for st in sts)
    assert all(eng.lanestate.holds(st.slot) for st in sts)
    _drain(eng)
    assert eng.lanestate.drained and not eng.active
    assert eng._host_syncs == eng._ticks      # one host sync per tick
    full = [list(st.out) for st in sts]
    assert all(len(o) == MAX_NEW for o in full)

    # --- token parity vs the slot-free reference ---------------------
    # (the q8_0 rows too: Q8_0 KV error ~0.4% stays inside the greedy
    # near-tie envelope on these workloads)
    for st, p in zip(sts, PROMPTS):
        frames = _frames(cfg, st.req.uid) if cfg.enc_dec else None
        _assert_matches_ref(model, params, p, frames, st.out, margin)

    # --- fused tick == sequential single steps -----------------------
    *_, eng_seq = _engine(arch, cache_dtype=cache_dtype)
    sts_seq = [eng_seq.admit(_request(cfg, i, p))
               for i, p in enumerate(PROMPTS)]
    while eng_seq.n_active:
        eng_seq.step(1)
    assert [st.out for st in sts_seq] == full
    assert eng._decode_steps == eng.decode_block * eng._ticks
    assert eng._ticks < eng_seq._ticks

    # --- EOS mid-block ----------------------------------------------
    # stop on the token this engine emits at step 2: it lands inside a
    # decode_block=4 tick, so the lane must freeze on device mid-block
    eos = full[0][2]
    want = full[0][:full[0].index(eos) + 1]
    st = eng.admit(_request(cfg, 7, PROMPTS[0], eos=eos, fuid=0))
    _drain(eng)
    assert st.out == want and st.out[-1] == eos
    assert eng.lanestate.drained

    # --- abort releases every reserved state kind --------------------
    sts = [eng.admit(_request(cfg, 10 + i, p, fuid=i))
           for i, p in enumerate(PROMPTS)]
    eng.step()
    victim, survivor = sts
    slot = victim.slot
    eng.abort(victim)
    assert not eng.lanestate.holds(slot) and slot in eng.free
    assert victim.done and not eng.lanestate.drained   # survivor lives
    # the freed slot is immediately reusable mid-decode
    st3 = eng.admit(_request(cfg, 12, PROMPTS[0], fuid=0))
    assert st3.slot == slot
    _drain(eng)
    assert st3.out == full[0]        # same workload, same tokens
    assert len(survivor.out) == MAX_NEW
    assert eng.lanestate.drained and eng._host_syncs == eng._ticks

    # --- spec-consistent accounting ----------------------------------
    spec = eng.spec
    rep = eng.cache_report()
    assert rep["family"] == spec.family
    assert rep["state_kinds"] == list(spec.state_kinds)
    assert rep["bytes_per_step"] > 0
    if spec.recurrent:
        assert rep["state_bytes_total"] > 0
    if not spec.self_kv:
        assert rep["kv_bytes_total"] == 0


# --------------------------------------------------- scheduler teardown


@pytest.mark.parametrize("arch", ARCHS)
def test_scheduler_serves_family(arch):
    """The continuous-batching scheduler drives every family with slot
    churn (5 requests through 2 slots), including a queued-request
    cancel — and the lane-state ledger is empty when drained."""
    cfg, model, params, eng = _engine(arch, n_slots=2)
    sched = BatchScheduler(eng)
    for i in range(5):
        sched.submit(_request(cfg, i, PROMPTS[i % 2], max_new=3))
    assert sched.abort(3) is not None       # still queued: cancelled
    sched.run_until_drained(max_ticks=200)
    assert sched.drained and eng.lanestate.drained
    assert sched.metrics.completed == 4
    assert sched.results[3].error_code is not None
    done = [sched.results[i].out for i in (0, 1, 2, 4)]
    assert all(len(o) == 3 for o in done)


@pytest.mark.parametrize("arch", ("xlstm-350m", "qwen3-moe-30b-a3b"))
def test_gateway_serves_family(arch):
    """The asyncio gateway fronts the spec-driven engine for the
    non-attention/MoE families too: one-shot token requests resolve
    with the same tokens the bare engine produced, and ``report()``
    carries the served family's lane-state spec."""
    import asyncio

    from repro.gateway import Gateway

    cfg, model, params, eng = _engine(arch, n_slots=2)
    sts = [eng.admit(_request(cfg, i, p)) for i, p in enumerate(PROMPTS)]
    _drain(eng)
    want = [list(st.out) for st in sts]

    *_, eng2 = _engine(arch, n_slots=2)

    async def go():
        async with Gateway(eng2, shed_on_submit=False) as gw:
            outs = await asyncio.gather(*[
                gw.submit_tokens(list(p), max_new=MAX_NEW, eos_id=-2)
                for p in PROMPTS])
            return outs, gw.report()

    outs, rep = asyncio.run(go())
    assert all(r.ok for r in outs)
    assert [list(r.tokens) for r in outs] == want
    assert rep["engine"]["family"] == eng2.spec.family
    assert rep["engine"]["state_kinds"] == list(eng2.spec.state_kinds)
    assert rep["engine"]["prefill_exact"] == eng2.spec.prefill_exact
    assert eng2.lanestate.drained


# ------------------------------------------------------- the q8 policy


def test_q8_support_matrix():
    """``LaneStateSpec.q8_supported`` is the single source of truth for
    the quantized-KV tier: families with q8-compatible KV planes accept
    it, pure-recurrent and windowed-attention families do not."""
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        spec = build(cfg).state_spec()
        assert spec.q8_supported == (arch in Q8_ARCHS), arch
    # mixtral declares KV but a sliding window — q8 tier unsupported
    mix = build(reduced(get_config("mixtral-8x7b"))).state_spec()
    assert mix.self_kv and not mix.q8_supported


def test_q8_rejected_for_pure_recurrent():
    cfg, model, params = _setup("xlstm-350m")
    with pytest.raises(ValueError, match="q8_0"):
        ServeEngine(model, params, n_slots=2, max_len=64, enc_len=16,
                    cache_dtype="q8_0")


def test_q8_shrinks_decode_stream():
    """Where the spec supports q8_0, the per-step cache stream shrinks;
    spec-declared recurrent/routing state is dtype-unaffected."""
    *_, eng_bf = _engine("qwen3-moe-30b-a3b", cache_dtype="bf16")
    *_, eng_q8 = _engine("qwen3-moe-30b-a3b", cache_dtype="q8_0")
    rb, rq = eng_bf.cache_report(), eng_q8.cache_report()
    assert rq["kv_bytes_total"] < rb["kv_bytes_total"]
    assert rq["bytes_per_step"] < rb["bytes_per_step"]
    assert rq["state_bytes_per_step"] == rb["state_bytes_per_step"]


def test_moe_prefill_padding_mask():
    """At *binding* capacity (the production capacity_factor), bucket
    padding must not evict live tokens from their experts: capacity
    routing is non-causal, so — unlike attention, where the causal mask
    hides the padded tail — an unmasked padded bucket changes live
    tokens' expert assignments. ``valid_len`` (threaded from the
    engine's prefill as ``batch[\"n_valid\"]``) zeroes padding gates
    before the per-expert top-C cut."""
    from repro.models import moe
    from repro.models.layers import KeyGen, split_params

    cfg = reduced(get_config("qwen3-moe-30b-a3b"))   # cf=1.25: binding
    p, _ = split_params(moe.init_moe(KeyGen(jax.random.key(0)), cfg))
    n, bucket = 4, 32
    # seed chosen so the exact-length pass is itself drop-free (its
    # per-expert top-C keeps every live token) — the oracle is clean
    xl = jax.random.normal(jax.random.key(20),
                           (1, n, cfg.d_model), jnp.float32) * 0.5
    # adversarial padding: amplified copies of a live token, routing
    # hard into its experts — exactly the crowding a padded bucket does
    pad = jnp.tile(xl[:, :1] * 6.0, (1, bucket - n, 1))
    x = jnp.concatenate([xl, pad], axis=1)

    exact = moe.moe_ffn(p, xl, cfg)
    masked = moe.moe_ffn(p, x, cfg, valid_len=n)[:, :n]
    unmasked = moe.moe_ffn(p, x, cfg)[:, :n]
    np.testing.assert_allclose(np.asarray(masked), np.asarray(exact),
                               atol=1e-5)
    assert not np.allclose(unmasked, exact, atol=5e-2), \
        "padding eviction did not occur: the mask is untested"
    # the baseline global dispatch honors the same mask (its different
    # gather order rounds differently in bf16 — routing-level drift
    # would be ~0.1+, cf. the unmasked assertion above)
    g = moe.moe_ffn(p, x, cfg, grouped=False, valid_len=n)[:, :n]
    np.testing.assert_allclose(np.asarray(g), np.asarray(exact),
                               atol=5e-3)


# -------------------------------------------------- routing diagnostics


def test_moe_routing_counters_reconcile():
    """The MoE lane's routing counters count executed top-k assignments
    exactly: prefill tokens + decode steps, per layer, per lane."""
    cfg, model, params, eng = _engine("qwen3-moe-30b-a3b")
    sts = [eng.admit(_request(cfg, i, p)) for i, p in enumerate(PROMPTS)]
    _drain(eng)
    rep = eng.routing_report()
    assert rep["n_experts"] == cfg.n_experts
    assert rep["top_k"] == cfg.top_k
    # the counters are a device-work diagnostic: prefill executes the
    # whole padded bucket through the experts, and the fused tick
    # executes every slot each step — parked/empty lanes included
    from repro.serving.engine import _bucket
    prefill_tokens = sum(min(_bucket(len(p)), eng.max_len)
                         for p in PROMPTS)
    decode_tokens = eng.n_slots * eng._decode_steps
    want = (prefill_tokens + decode_tokens) * rep["moe_layers"] \
        * cfg.top_k
    assert rep["executed_assignments"] == want
    # per-lane counts are nonnegative and sum to the total
    per_lane = np.asarray(rep["per_lane"])
    assert per_lane.sum() == want and (per_lane >= 0).all()
