"""Synthetic pipeline: determinism, shard disjointness, restart replay."""

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.synthetic import SyntheticDataset, batch_for_step


def _cfg(name="qwen3-4b"):
    return reduced(get_config(name))


def test_deterministic_across_instances():
    ds1 = SyntheticDataset(_cfg(), 32, 8, seed=7, n_shards=2)
    ds2 = SyntheticDataset(_cfg(), 32, 8, seed=7, n_shards=2)
    b1, b2 = ds1.global_batch_at(5), ds2.global_batch_at(5)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])


def test_steps_differ():
    ds = SyntheticDataset(_cfg(), 32, 4, seed=0)
    assert not np.array_equal(ds.global_batch_at(0)["tokens"],
                              ds.global_batch_at(1)["tokens"])


def test_seeds_differ():
    a = batch_for_step(_cfg(), 32, 4, seed=0, step=0)
    b = batch_for_step(_cfg(), 32, 4, seed=1, step=0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_shards_disjoint_and_stable():
    ds = SyntheticDataset(_cfg(), 16, 8, seed=3, n_shards=4)
    shards = [ds.shard_batch_at(2, s) for s in range(4)]
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(shards[i]["tokens"],
                                      shards[j]["tokens"])


def test_elastic_reshard_preserves_global_batch():
    """Same (seed, step) -> same global batch under any shard count —
    the property that makes restart-on-a-different-mesh deterministic."""
    # NOTE: shards are keyed by shard index; global batch = concat of
    # n_shards slices, so equality requires the same n_shards. The
    # elastic guarantee is at the (seed, step, shard-plan) level: we pin
    # n_shards in the dataset spec and re-slice for the local mesh.
    ds = SyntheticDataset(_cfg(), 16, 8, seed=3, n_shards=4)
    g1 = ds.global_batch_at(0)
    # a restarted job with the same logical shard plan:
    ds2 = SyntheticDataset(_cfg(), 16, 8, seed=3, n_shards=4)
    g2 = ds2.global_batch_at(0)
    for k in g1:
        np.testing.assert_array_equal(g1[k], g2[k])


def test_targets_are_shifted_tokens():
    b = batch_for_step(_cfg(), 16, 2, seed=0, step=0)
    # targets[t] is the token that followed tokens[t] in the stream
    assert b["tokens"].shape == b["targets"].shape
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_vocab_range():
    cfg = _cfg()
    b = batch_for_step(cfg, 64, 4, seed=0, step=0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab


def test_encdec_batch_layout():
    cfg = reduced(get_config("whisper-base"))
    b = batch_for_step(cfg, 32, 2, seed=0, step=0)
    assert b["enc_frames"].shape == (2, 16, cfg.d_model)
    assert b["tokens"].shape == (2, 16)


def test_vlm_batch_masks_image_prefix():
    cfg = reduced(get_config("llava-next-34b"))
    b = batch_for_step(cfg, 32, 2, seed=0, step=0)
    n_img = cfg.n_img_tokens
    assert b["img_embed"].shape[1] == n_img
    assert (b["targets"][:, :n_img] == -1).all()
    assert b["targets"].shape[1] == 32


def test_bad_shard_config_raises():
    with pytest.raises(AssertionError):
        SyntheticDataset(_cfg(), 16, 8, n_shards=3)
