"""Energy model (C5): calibration residuals, PDP minimum, platform table."""

import pytest

from repro import hw
from repro.core.energy import (calibrate_imax, imax_power, interp_power,
                               lmm_sweep, pdp, platform_pdp_table)
from repro.core.workload import WHISPER_TINY, whisper_workload


def _calib():
    w16 = whisper_workload(WHISPER_TINY, dtype="f16")
    w8 = whisper_workload(WHISPER_TINY, dtype="q8_0")
    return w16, w8, calibrate_imax(w16, w8)


def test_calibration_fits_fp16_observables():
    _, _, calib = _calib()
    # fit observables close by construction
    assert abs(calib.residuals["latency_fp16(fit)"]) < 0.02
    assert abs(calib.residuals["exec_share_fp16(fit)"]) < 0.02


def test_calibration_predicts_q8_within_tolerance():
    """Q8_0 rows are cross-validation predictions (DESIGN.md §2); the
    model should land within ~35% of the paper's measured values."""
    _, _, calib = _calib()
    assert abs(calib.residuals["latency_q8(pred)"]) < 0.35
    assert abs(calib.residuals["exec_share_q8(pred)"]) < 0.35


def test_pdp_minimum_at_32kb():
    """Paper Fig 6: PDP minimum at 32 KB for both models."""
    w16, w8, calib = _calib()
    for work, kern in ((w16, "fp16"), (w8, "q8_0")):
        pts = lmm_sweep(work, calib.model, kern)
        best = min(pts, key=lambda p: p.pdp_j)
        assert best.budget_bytes == 32 * 1024, \
            [(p.budget_bytes, p.pdp_j) for p in pts]


def test_lmm_16kb_latency_degrades():
    """Fig 6: 16 KB forces CPU fallbacks -> latency worse than 32 KB."""
    w16, _, calib = _calib()
    pts = {p.budget_bytes: p for p in lmm_sweep(w16, calib.model, "fp16")}
    assert pts[16 * 1024].latency_s > pts[32 * 1024].latency_s


def test_power_interpolation_matches_table2():
    assert imax_power(32 * 1024, "fp16") == pytest.approx(0.647)
    assert imax_power(32 * 1024, "q8_0") == pytest.approx(1.32)
    assert imax_power(32 * 1024, "fp16", lanes=2) == pytest.approx(1.294)
    # monotone in size
    ps = [imax_power(k * 1024, "fp16") for k in (16, 32, 64, 128, 256)]
    assert all(a <= b for a, b in zip(ps, ps[1:]))


def test_pdp_eq1():
    assert pdp(11.1, 1.32) == pytest.approx(14.652)


def test_platform_table_reproduces_paper_ratios():
    """Paper headline: IMAX Q8_0 PDP 12.6 J -> 1.90x vs Orin, 9.83x vs
    4090. The published Fig-5 values use measured phase power (their
    §IV-A caveat); the ratios are checked on the published numbers and
    our Eq-1 model lands within 15% of Eq-1-with-nominal-constants."""
    from repro import hw
    w16, w8, calib = _calib()
    rows = platform_pdp_table(w16, w8, calib)
    by = {(r["device"], r["kernel"]): r for r in rows}
    pub = hw.PAPER_PDP_J
    assert pub[("jetson-agx-orin", "q8_0")] / pub[("imax3-28nm", "q8_0")] \
        == pytest.approx(1.90, rel=0.02)
    assert pub[("rtx-4090", "q8_0")] / pub[("imax3-28nm", "q8_0")] \
        == pytest.approx(9.83, rel=0.02)
    eq1_nominal = (hw.PAPER_LATENCY_S[("imax3-28nm", "q8_0")]
                   * hw.IMAX_POWER_Q8_W[32 * 1024])
    assert by[("imax3-28nm(model)", "q8_0")]["pdp_j"] == \
        pytest.approx(eq1_nominal, rel=0.15)


def test_exec_share_shows_compute_bound():
    """Fig 7: EXEC dominates accel time (>=60% fp16, higher for q8_0)."""
    from repro.core.offload import execution_breakdown
    w16, w8, calib = _calib()
    bd16 = execution_breakdown(w16, calib.model, 32 * 1024)
    bd8 = execution_breakdown(w8, calib.model, 32 * 1024)
    assert bd16.exec_share > 0.55
    assert bd8.exec_share > bd16.exec_share   # Q8_0 cuts LOAD, raising EXEC


def test_interp_power_bounds():
    t = {16384: 1.0, 32768: 2.0}
    assert interp_power(t, 8000) == 1.0
    assert interp_power(t, 50000) == 2.0
    # log-linear: the geometric-mean size maps to the mean power ...
    assert interp_power(t, round(16384 * 2 ** 0.5)) == pytest.approx(
        1.5, abs=1e-4)
    # ... so the byte midpoint sits above the linear-in-bytes value
    assert interp_power(t, 24576) == pytest.approx(1.585, abs=1e-3)


def test_interp_power_32k_64k_midpoint():
    """Pin the Table-II 32KB->64KB segment: interpolation is log-linear
    in size, so the geometric mean (32*sqrt(2) KB) yields the arithmetic
    mean power, and the 48KB byte-midpoint lands log2(1.5) of the way up
    the segment — not halfway."""
    lo, hi = hw.IMAX_POWER_FP16_W[32 * 1024], hw.IMAX_POWER_FP16_W[64 * 1024]
    geo = round(32 * 1024 * 2 ** 0.5)
    assert imax_power(geo, "fp16") == pytest.approx((lo + hi) / 2, rel=1e-4)
    t = 0.5849625007211562      # log2(1.5)
    assert imax_power(48 * 1024, "fp16") == pytest.approx(
        lo + t * (hi - lo), rel=1e-6)
    assert imax_power(48 * 1024, "fp16") > lo + 0.5 * (hi - lo)
