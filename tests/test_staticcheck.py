"""repro.staticcheck: the clean tree passes every invariant, and each
seeded violation class (un-donated pool, hidden host callback, f32 leak
in a q8 plane path, backend-less kernel op, footprint drift) is caught
under its check ID. Static verdicts for prefill / fused decode /
cross-cache-extend must agree with the dynamic assertions in
``tests/test_decode_fused.py``."""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import registry
from repro.kernels.registry import KernelOp
from repro.core.workload import KernelSpec
from repro.staticcheck import StaticcheckConfig, run_all
from repro.staticcheck.config import Waiver, _pattern_match
from repro.staticcheck.donation import check_donation
from repro.staticcheck.dtypeplanes import check_dtype_planes
from repro.staticcheck.footprint import check_footprint, check_registry
from repro.staticcheck.harness import HotProgram
from repro.staticcheck.report import Report
from repro.staticcheck.run import apply_waivers
from repro.staticcheck.syncpoints import check_program_sync, scan_source

PLANE_DIMS = (4, 64, 16, 32)   # harness pool: n_slots, max_len, enc_len, dh


def _prog(name, fn, *args, donate=(), plane_dims=(), cache_dtypes=()):
    jitted = fn if hasattr(fn, "trace") else jax.jit(fn)
    traced = jitted.trace(*args)
    leaves = len(jax.tree.leaves(tuple(args[i] for i in donate)))
    return HotProgram(name=name, jaxpr=traced.jaxpr,
                      stablehlo=traced.lower().as_text(),
                      donated_leaves=leaves, cache_dtypes=cache_dtypes,
                      plane_dims=plane_dims)


# ------------------------------------------------------------- clean tree

@pytest.fixture(scope="module")
def clean_report() -> Report:
    return run_all()


def test_clean_tree_passes(clean_report):
    assert clean_report.ok, clean_report.human()
    assert clean_report.failed_checks() == []


def test_verdicts_match_dynamic_decode_tests(clean_report):
    """The static verdicts must assert exactly what the dynamic tests
    in test_decode_fused.py / test_serving.py observe at runtime:
    donated+aliased pools, one-sync ticks, intact dtype planes for
    prefill, the fused decode tick, and the cross-cache extension."""
    funcs = clean_report.function_verdicts()
    for dt in ("q8_0", "bf16"):
        for fn in ("prefill", "decode_block", "extend_cross_cache",
                   "paged_prefill", "paged_decode_block",
                   "paged_extend_cross"):
            v = funcs[f"{fn}[{dt}]"]
            assert v["donation"] is True, (fn, dt, v)
            assert v["sync_free"] is True, (fn, dt, v)
            assert v["dtype_planes"] is True, (fn, dt, v)
    assert funcs["frontend_gemm"]["sync_free"] is True


def test_waivers_are_exercised(clean_report):
    """Every waiver in staticcheck.toml matches at least one finding —
    a dead waiver is a stale exception that must be pruned."""
    cfg = StaticcheckConfig.load()
    assert cfg.waivers, "expected reviewed waivers in staticcheck.toml"
    subjects = [(f.check, f.subject) for f in clean_report.findings]
    for w in cfg.waivers:
        assert any(w.matches(c, s) for c, s in subjects), \
            f"dead waiver: {w}"


# ------------------------------------------------- seeded violations

def test_seeded_undonated_pool_fails_sc_don():
    pool = {"k": jnp.zeros((4, 64, 2, 32), jnp.bfloat16),
            "v": jnp.zeros((4, 64, 2, 32), jnp.bfloat16)}
    # jit WITHOUT donate_argnums: the pool comes back as a copy
    bad = _prog("bad_prefill",
                jax.jit(lambda p, x: jax.tree.map(lambda a: a + x, p)),
                pool, jnp.bfloat16(1.0), donate=(0,))
    findings = check_donation([bad])
    assert [f.check for f in findings] == ["SC-DON"]
    assert not findings[0].ok
    assert findings[0].data["aliased"] == 0


def test_seeded_hidden_callback_fails_sc_sync():
    from jax.experimental import io_callback

    def tick(x):
        # a hidden device->host round trip inside the per-tick program
        y = io_callback(lambda v: v, jax.ShapeDtypeStruct(x.shape,
                                                          x.dtype), x)
        return y * 2

    bad = _prog("bad_tick", tick, jnp.ones((4,), jnp.float32))
    (f,) = check_program_sync([bad])
    assert f.check == "SC-SYNC" and not f.ok
    assert "callback" in f.detail


def test_seeded_device_get_in_tick_fails_sc_ast():
    src = textwrap.dedent("""
        import jax

        class Engine:
            def tick(self, x):
                t = jax.device_get(x)
                return float(t)
    """)
    findings = scan_source("fake.py", src, "src/repro/serving/fake.py")
    bad = {(f.data["call"], f.ok) for f in findings}
    assert ("jax.device_get", False) in bad
    assert ("float", False) in bad


def test_inventoried_sync_site_passes_sc_ast():
    src = textwrap.dedent("""
        import jax

        class ServeEngine:
            def step_fetch(self, pending):
                return jax.device_get(pending)
    """)
    findings = scan_source("engine.py", src, "src/repro/serving/engine.py")
    (f,) = findings
    assert f.ok and "inventory" in f.detail


def test_seeded_f32_plane_leak_fails_sc_dtype():
    plane = jnp.zeros((16, 64, 32), jnp.int8)   # flattened q8 pool plane
    bad = _prog("bad_q8_read", lambda p: p.astype(jnp.float32).sum(),
                plane, plane_dims=PLANE_DIMS, cache_dtypes=("int8",))
    (f,) = check_dtype_planes([bad])
    assert f.check == "SC-DTYPE" and not f.ok
    assert "int8" in f.subject


def test_small_activation_upcast_passes_sc_dtype():
    x = jnp.zeros((4, 32), jnp.bfloat16)   # per-token activation
    good = _prog("activation", lambda a: a.astype(jnp.float32) * 2, x,
                 plane_dims=PLANE_DIMS, cache_dtypes=("bfloat16",))
    (f,) = check_dtype_planes([good])
    assert f.ok


def test_seeded_backendless_op_fails_sc_reg():
    op = KernelOp(
        name="test_pallas_only",
        spec=lambda x: KernelSpec("test_pallas_only", m=1, n=1, k=1,
                                  dtype="f32"),
        backends={"pallas": lambda ctx, x: x})
    registry.register(op)
    try:
        (f,) = check_registry(["test_pallas_only"])
        assert f.check == "SC-REG" and not f.ok
        assert "no host backend" in f.detail
    finally:
        registry._REGISTRY.pop("test_pallas_only")


def test_seeded_footprint_drift_fails_sc_foot():
    # spec claims 500x the flops the backend executes: outside any band
    op = KernelOp(
        name="test_bloated_gemm",
        spec=lambda x, w: KernelSpec(
            "test_bloated_gemm", m=x.shape[0], n=w.shape[1],
            k=x.shape[1], dtype="f32", count=500),
        backends={"xla": lambda ctx, x, w: x @ w})
    registry.register(op)
    try:
        x = jnp.ones((8, 64), jnp.float32)
        w = jnp.ones((64, 32), jnp.float32)
        (f,) = check_footprint(StaticcheckConfig(),
                               op_names=["test_bloated_gemm"],
                               reps={"test_bloated_gemm": ((x, w), {})})
        assert f.check == "SC-FOOT" and not f.ok
        assert f.data["flops_ratio"] < 0.01
    finally:
        registry._REGISTRY.pop("test_bloated_gemm")


def test_waiver_turns_violation_into_pass():
    pool = {"k": jnp.zeros((8, 8), jnp.float32)}
    bad = _prog("waived_prog",
                jax.jit(lambda p: jax.tree.map(lambda a: a + 1, p)),
                pool, donate=(0,))
    findings = check_donation([bad])
    assert not findings[0].ok
    cfg = StaticcheckConfig(waivers=[
        Waiver("SC-DON", "waived_prog", "seeded-violation test")])
    rep = Report(apply_waivers(findings, cfg))
    assert rep.ok
    assert rep.findings[0].waived
    assert rep.findings[0].waiver_reason == "seeded-violation test"


def test_pattern_match_is_literal_with_star():
    assert _pattern_match("prefill[q8_0]:bfloat16*", "prefill[q8_0]:bfloat16(2, 1, 64, 2, 32)")
    assert not _pattern_match("prefill[q8_0]:*", "prefill[x]:foo")
    assert not _pattern_match("a.b", "axb")


# ------------------------------------------------------------------ CLI

def test_cli_fast_checks_json_roundtrip():
    out = subprocess.run(
        [sys.executable, "-m", "repro.staticcheck",
         "--only", "SC-AST,SC-REG", "--json", "-"],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    doc = json.loads(out.stdout)
    assert doc["ok"] is True
    assert doc["checks"]["SC-AST"] is True
    assert doc["checks"]["SC-REG"] is True


def test_cli_rejects_unknown_check_id():
    out = subprocess.run(
        [sys.executable, "-m", "repro.staticcheck", "--only", "SC-NOPE"],
        capture_output=True, text=True)
    assert out.returncode != 0


# --------------------------------------------- xla q8 backend numerics

def test_q8_decode_attention_xla_close_to_ref():
    """The bf16-dequant xla backend (what host serving now routes) stays
    within the Q8 error envelope of the f32 ref oracle."""
    from repro.core.quantize import quantize_q8_0
    from repro.kernels.q8_attention.ref import q8_decode_attention_ref
    from repro.kernels.q8_attention.xla import q8_decode_attention_xla

    key = jax.random.key(3)
    bh, s, d = 4, 64, 32
    q = jax.random.normal(jax.random.fold_in(key, 0), (bh, 1, d),
                          jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (bh, s, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, d))
    kt, vt = quantize_q8_0(k, axis=-1), quantize_q8_0(v, axis=-1)
    lens = jnp.asarray([5, 33, 64, 1], jnp.int32)
    got = q8_decode_attention_xla(q, kt.q, kt.scale, vt.q, vt.scale,
                                  lens)
    want = q8_decode_attention_ref(q, kt.q, kt.scale, vt.q, vt.scale,
                                   lens)
    assert got.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.06, atol=0.06)
