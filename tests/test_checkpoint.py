"""Checkpoint store: atomicity, async writer, retention, elastic restore."""

import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (CheckpointManager, latest_step,
                                    restore_checkpoint, save_checkpoint)


def _tree(v=0.0):
    return {"a": jnp.full((4, 4), v), "b": {"c": jnp.arange(6.0),
                                            "step": jnp.asarray(3)}}


def test_save_restore_roundtrip(tmp_path):
    root = str(tmp_path)
    save_checkpoint(root, 7, _tree(1.5))
    out, step = restore_checkpoint(root, _tree())
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["a"]), np.full((4, 4), 1.5))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.arange(6.0))


def test_atomic_no_tmp_left(tmp_path):
    root = str(tmp_path)
    save_checkpoint(root, 1, _tree())
    assert not [d for d in os.listdir(root) if d.startswith("tmp-")]


def test_latest_step_and_retention(tmp_path):
    root = str(tmp_path)
    mgr = CheckpointManager(root, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(float(s)))
    mgr.wait()
    assert mgr.latest_step() == 4
    kept = sorted(d for d in os.listdir(root) if d.startswith("step-"))
    assert len(kept) == 2 and kept[-1].endswith("4".zfill(9))


def test_async_writer_overlaps(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1.0))
    # main thread can proceed immediately; wait() then joins
    assert isinstance(mgr._thread, threading.Thread)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path), _tree())


def test_restore_shape_mismatch_raises(tmp_path):
    root = str(tmp_path)
    save_checkpoint(root, 1, _tree())
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.zeros(6),
                                         "step": jnp.asarray(0)}}
    with pytest.raises(ValueError):
        restore_checkpoint(root, bad)


def test_corrupt_partial_dir_ignored(tmp_path):
    """A step dir without manifest (crashed mid-write before rename could
    never produce this, but belt-and-braces) is not selected."""
    root = str(tmp_path)
    save_checkpoint(root, 3, _tree())
    os.makedirs(os.path.join(root, "step-000000009"))
    assert latest_step(root) == 3


def test_restore_preserves_dtypes(tmp_path):
    root = str(tmp_path)
    tree = {"w": jnp.ones((2, 2), jnp.bfloat16),
            "n": jnp.asarray(5, jnp.int32)}
    save_checkpoint(root, 1, tree)
    out, _ = restore_checkpoint(root, tree)
    assert out["w"].dtype == jnp.bfloat16
    assert out["n"].dtype == jnp.int32
