"""Distributed behaviour on forced host devices (subprocess-isolated so
the main pytest process keeps 1 device)."""

import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow


def _run(code: str, devices: int = 4, timeout: int = 420) -> str:
    pre = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        "import sys; sys.path.insert(0, 'src')\n"
    )
    r = subprocess.run([sys.executable, "-c", pre + code],
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=timeout)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """2x2 DP×TP sharded train step == unsharded step (same init/batch)."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import rules_for
from repro.train import step as step_mod

cfg = reduced(get_config('qwen3-4b'))
model = build(cfg)
opt = AdamWConfig(lr=1e-3, total_steps=10)
state = step_mod.init_train_state(model, jax.random.key(0))
from repro.data.synthetic import batch_for_step
batch = {k: jnp.asarray(v) for k, v in
         batch_for_step(cfg, 32, 4, seed=0, step=0).items()}

# single-device reference
ref_step = jax.jit(step_mod.make_train_step(model, opt))
ref_state, ref_m = ref_step(state, batch)

# 2x2 mesh
mesh = jax.make_mesh((2, 2), ('data', 'model'))
rules = rules_for(cfg, mesh, mode='train')
sh = step_mod.state_shardings(model, mesh, rules)
bsh = step_mod.batch_shardings(cfg, 'train_4k', mesh, rules)
fn = step_mod.make_train_step(model, opt, mesh=mesh, rules=rules)
state_d = jax.device_put(state, sh)
batch_d = {k: jax.device_put(v, bsh[k if k in bsh else 'tokens'])
           for k, v in batch.items()}
step_d = jax.jit(fn, in_shardings=(sh, None), out_shardings=(sh, None))
new_state, m = step_d(state_d, batch_d)

np.testing.assert_allclose(float(m['loss']), float(ref_m['loss']),
                           rtol=2e-4)
for a, b in zip(jax.tree.leaves(new_state['params']),
                jax.tree.leaves(ref_state['params'])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-2, atol=2e-4)
print('MATCH', float(m['loss']))
""")
    assert "MATCH" in out


def test_pipeline_parallel_equivalence():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import make_pipelined_fn
mesh = jax.make_mesh((4,), ('pipe',))
L, D = 8, 16
w = jax.random.normal(jax.random.key(0), (L, D, D)) * 0.1
layer_fn = lambda lp, h: jnp.tanh(h @ lp)
mbs = jax.random.normal(jax.random.key(1), (6, 4, D))
f = make_pipelined_fn(layer_fn, mesh, n_stages=4)
out = jax.jit(f)(w, mbs)
def ref(x):
    for i in range(L):
        x = layer_fn(w[i], x)
    return x
want = jnp.stack([ref(mbs[i]) for i in range(6)])
np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5)
g = jax.grad(lambda w: jnp.sum(f(w, mbs) ** 2))(w)
assert float(jnp.linalg.norm(g.reshape(-1))) > 0
print('PIPE-OK')
""")
    assert "PIPE-OK" in out


def test_compressed_allreduce_close_to_exact():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.parallel.collectives import (compressed_psum, init_error_state,
                                        compression_ratio)
mesh = jax.make_mesh((4,), ('data',))
g = jax.random.normal(jax.random.key(0), (4, 256))   # per-device rows
err = jnp.zeros((4, 256))

def f(g, e):
    m, ne = compressed_psum({'g': g[0]}, {'g': e[0]}, 'data')
    return m['g'], ne['g']

mean, new_err = jax.jit(shard_map(
    f, mesh=mesh, in_specs=(P('data'), P('data')),
    out_specs=(P(), P('data'))))(g, err)
exact = jnp.mean(g, axis=0)
rel = float(jnp.linalg.norm(mean - exact) / jnp.linalg.norm(exact))
assert rel < 0.02, rel
# error feedback: accumulated residual is bounded by quantization step
assert float(jnp.abs(new_err).max()) < float(jnp.abs(g).max()) / 64
assert compression_ratio({'g': g}) < 0.27
print('COMPRESS-OK', rel)
""")
    assert "COMPRESS-OK" in out


def test_elastic_checkpoint_reshard():
    """Save on a 2x2 mesh; restore onto 4x1 — global arrays re-shard."""
    out = _run("""
import tempfile, jax, jax.numpy as jnp, numpy as np
from repro.checkpoint.store import CheckpointManager
from repro.configs import get_config, reduced
from repro.models.model import build
from repro.parallel.sharding import rules_for
from repro.train import step as step_mod

cfg = reduced(get_config('qwen3-4b'))
model = build(cfg)
state = step_mod.init_train_state(model, jax.random.key(0))

mesh1 = jax.make_mesh((2, 2), ('data', 'model'))
sh1 = step_mod.state_shardings(model, mesh1, rules_for(cfg, mesh1))
state1 = jax.device_put(state, sh1)

with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(11, state1)
    mgr.wait()
    mesh2 = jax.make_mesh((4, 1), ('data', 'model'))
    sh2 = step_mod.state_shardings(model, mesh2, rules_for(cfg, mesh2))
    restored, step = mgr.restore(state, shardings=sh2)
    assert step == 11
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
    # verify the restored arrays actually carry the new sharding
    leaf = restored['params']['embed']['table']
    assert leaf.sharding.mesh.shape['data'] == 4
print('ELASTIC-OK')
""")
    assert "ELASTIC-OK" in out


def test_multipod_mesh_and_dryrun_smoke():
    """A small (pod,data,model) mesh lowers the real train step and the
    HLO contains cross-pod collectives."""
    out = _run("""
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import rules_for
from repro.train import step as step_mod
from repro.analysis.hlo import analyze_hlo

cfg = reduced(get_config('gemma2-2b'))
model = build(cfg)
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
rules = rules_for(cfg, mesh, mode='train')
sh = step_mod.state_shardings(model, mesh, rules)
bsh = step_mod.batch_shardings(cfg, 'train_4k', mesh, rules)
fn = step_mod.make_train_step(model, AdamWConfig(), mesh=mesh, rules=rules)
state_shapes = jax.eval_shape(
    lambda k: step_mod.init_train_state(model, k), jax.random.key(0))
import jax.numpy as jnp
specs = {'tokens': jax.ShapeDtypeStruct((8, 32), jnp.int32),
         'targets': jax.ShapeDtypeStruct((8, 32), jnp.int32)}
comp = jax.jit(fn, in_shardings=(sh, bsh),
               out_shardings=(sh, None)).lower(state_shapes, specs).compile()
c = analyze_hlo(comp.as_text())
assert c.collective_bytes > 0, c.collectives
print('MULTIPOD-OK', sorted(c.collectives))
""", devices=8)
    assert "MULTIPOD-OK" in out


def test_compressed_train_step_tracks_exact():
    """The int8 error-feedback DP step follows the exact-FP step: loss
    within noise each step, params within the compression envelope after
    a few steps (error feedback keeps the bias bounded)."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.data.synthetic import batch_for_step
from repro.train import step as step_mod

cfg = reduced(get_config('qwen3-4b'))
model = build(cfg)
opt = AdamWConfig(lr=1e-3, total_steps=50)
mesh = jax.make_mesh((4,), ('data',))
from repro.parallel.sharding import rules_for
rules = rules_for(cfg, mesh, mode='train')

exact = jax.jit(step_mod.make_train_step(model, opt))
comp = jax.jit(step_mod.make_compressed_train_step(model, opt, mesh, rules))

se = step_mod.init_train_state(model, jax.random.key(0))
sc = step_mod.init_compressed_state(model, jax.random.key(0), mesh)
for t in range(5):
    batch = {k: jnp.asarray(v) for k, v in
             batch_for_step(cfg, 32, 8, seed=0, step=t).items()}
    se, me = exact(se, batch)
    sc, mc = comp(sc, batch)
    assert abs(float(me['loss']) - float(mc['loss'])) < 0.05, \\
        (t, float(me['loss']), float(mc['loss']))
# parameter drift bounded
num = den = 0.0
for a, b in zip(jax.tree.leaves(sc['params']), jax.tree.leaves(se['params'])):
    num += float(jnp.sum((a.astype(jnp.float32) - b.astype(jnp.float32))**2))
    den += float(jnp.sum(b.astype(jnp.float32)**2))
rel = (num / den) ** 0.5
assert rel < 5e-3, rel
print('COMPRESS-STEP-OK', rel)
""")
    assert "COMPRESS-STEP-OK" in out
