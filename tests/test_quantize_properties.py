"""Q4_0/Q8_0 tier properties: analytic round-trip bounds, nibble
pack/unpack bijection, idempotence on saturated planes.

The deterministic versions always run; the hypothesis-driven sweeps
(arbitrary shapes/axes/value ranges) engage when hypothesis is
installed (``pip install -e .[test]``), mirroring
tests/test_paging_properties.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import (BYTES_PER_ELEM, Q4_BYTES_PER_ELEM,
                                 QBLOCK, Q4Tensor, bytes_per_elem,
                                 dequantize_q4_0, dequantize_q8_0,
                                 pack_q4, pad_to_block,
                                 quantization_error_bound,
                                 quantize_q4_0, quantize_q8_0,
                                 quantize_tree, unpack_q4)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# deterministic versions (always run)
# ---------------------------------------------------------------------------

def _check_q4_roundtrip(x, axis=-1):
    t = quantize_q4_0(x, axis=axis)
    err = jnp.abs(dequantize_q4_0(t, axis=axis) - x)
    bound = jnp.repeat(quantization_error_bound(t), QBLOCK, axis=axis)
    # 1% headroom for the f16 scale representation error
    bound = bound * 1.01 + 1e-6
    assert bool(jnp.all(err <= bound)), float(jnp.max(err - bound))


def test_q4_roundtrip_error_within_bound():
    x = jax.random.normal(jax.random.key(0), (8, 256), jnp.float32)
    _check_q4_roundtrip(x)


def test_q4_roundtrip_along_leading_axis():
    x = jax.random.normal(jax.random.key(1), (64, 5), jnp.float32)
    _check_q4_roundtrip(x, axis=0)


def test_q4_shapes_and_dtypes():
    x = jnp.ones((4, 64), jnp.bfloat16)
    t = quantize_q4_0(x)
    # nibble-packed: the quantize axis halves in the uint8 plane
    assert t.q.shape == (4, 32) and t.q.dtype == jnp.uint8
    assert t.scale.shape == (4, 2) and t.scale.dtype == jnp.float16
    assert t.shape == (4, 32)


def test_pack_unpack_bijection():
    rng = np.random.default_rng(0)
    codes = rng.integers(-8, 8, size=(6, 96), dtype=np.int64)
    c = jnp.asarray(codes, jnp.int8)
    packed = pack_q4(c)
    assert packed.shape == (6, 48) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(unpack_q4(packed)),
                                  codes)


def test_pack_unpack_bijection_leading_axis():
    rng = np.random.default_rng(1)
    codes = rng.integers(-8, 8, size=(32, 7), dtype=np.int64)
    c = jnp.asarray(codes, jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(unpack_q4(pack_q4(c, axis=0), axis=0)), codes)


def test_pack_odd_length_raises_and_pad_fixes():
    with pytest.raises(ValueError):
        pack_q4(jnp.zeros((2, 33), jnp.int8))
    x = jnp.ones((2, 33))
    with pytest.raises(ValueError):
        quantize_q4_0(x)
    xp = pad_to_block(x)
    assert xp.shape == (2, 64)
    t = quantize_q4_0(xp)      # no raise
    assert t.q.shape == (2, 32)


def test_q4_saturated_plane_idempotent():
    # a plane pinned at +/-amax maps to codes +/-7 and dequantizes back
    # exactly (amax/7 * 7); re-quantizing is a fixed point
    amax = 3.0
    sign = jnp.asarray(np.random.default_rng(2).choice(
        [-1.0, 1.0], size=(4, 64)), jnp.float32)
    x = sign * amax
    t = quantize_q4_0(x)
    y = dequantize_q4_0(t)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               rtol=1e-3)   # f16 scale rounding only
    t2 = quantize_q4_0(y)
    np.testing.assert_array_equal(np.asarray(t.q), np.asarray(t2.q))
    np.testing.assert_array_equal(np.asarray(t.scale),
                                  np.asarray(t2.scale))


def test_zero_plane_is_exact():
    t = quantize_q4_0(jnp.zeros((1, 32)))
    assert float(jnp.max(jnp.abs(dequantize_q4_0(t)))) == 0.0


def test_q4_packed_bytes_ratio():
    x = jnp.ones((16, 320))
    t = quantize_q4_0(x)
    assert t.nbytes_packed == int(x.size * Q4_BYTES_PER_ELEM)


def test_bytes_per_elem_table():
    assert bytes_per_elem("q4_0") == Q4_BYTES_PER_ELEM == 0.5625
    assert bytes_per_elem("q8_0") == 1.0625
    with pytest.raises(ValueError) as e:
        bytes_per_elem("q2_k")
    # the error names every supported tier
    for tier in BYTES_PER_ELEM:
        assert tier in str(e.value)


def test_quantize_tree_q4_selectivity():
    params = {"w": jnp.ones((64, 8)), "norm": jnp.ones((8,)),
              "odd": jnp.ones((33, 5))}
    qt = quantize_tree(params, tier="q4_0")
    assert isinstance(qt["w"], Q4Tensor)
    assert not isinstance(qt["norm"], Q4Tensor)
    assert not isinstance(qt["odd"], Q4Tensor)
    with pytest.raises(ValueError):
        quantize_tree(params, tier="q2_k")


def test_q4_vs_q8_bound_ordering():
    # q4's 15-level grid is coarser than q8's 255-level grid: on the
    # same data the q4 analytic bound dominates, and both hold
    x = jax.random.normal(jax.random.key(3), (4, 128), jnp.float32)
    b4 = quantization_error_bound(quantize_q4_0(x))
    b8 = quantization_error_bound(quantize_q8_0(x))
    assert bool(jnp.all(b4 >= b8))


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    _dims = st.tuples(st.integers(1, 6),
                      st.integers(1, 4).map(lambda b: b * QBLOCK))

    @settings(max_examples=40, deadline=None)
    @given(_dims, st.integers(0, 2 ** 31 - 1), st.floats(0.1, 100.0))
    def test_prop_q4_roundtrip_bound(dims, seed, scale):
        rows, k = dims
        x = jax.random.normal(jax.random.key(seed), (rows, k),
                              jnp.float32) * scale
        _check_q4_roundtrip(x)

    @settings(max_examples=40, deadline=None)
    @given(_dims, st.integers(0, 2 ** 31 - 1), st.floats(0.1, 100.0))
    def test_prop_q8_roundtrip_bound(dims, seed, scale):
        rows, k = dims
        x = jax.random.normal(jax.random.key(seed), (rows, k),
                              jnp.float32) * scale
        t = quantize_q8_0(x)
        err = jnp.abs(dequantize_q8_0(t) - x)
        bound = jnp.repeat(quantization_error_bound(t), QBLOCK,
                           axis=-1) * 1.01 + 1e-6
        assert bool(jnp.all(err <= bound))

    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 8), st.integers(1, 64),
           st.integers(0, 2 ** 31 - 1))
    def test_prop_pack_unpack_bijection(rows, half_k, seed):
        rng = np.random.default_rng(seed)
        codes = rng.integers(-8, 8, size=(rows, 2 * half_k))
        c = jnp.asarray(codes, jnp.int8)
        np.testing.assert_array_equal(np.asarray(unpack_q4(pack_q4(c))),
                                      codes)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 5).map(lambda n: 2 * n + 1),
           st.integers(0, 2 ** 31 - 1))
    def test_prop_odd_lastdim_pads_then_roundtrips(k_odd, seed):
        # odd / non-block last dims: pad_to_block, quantize, and the
        # valid prefix round-trips within bound
        x = jax.random.normal(jax.random.key(seed), (3, k_odd),
                              jnp.float32)
        xp = pad_to_block(x)
        assert xp.shape[-1] % QBLOCK == 0
        t = quantize_q4_0(xp)
        err = jnp.abs(dequantize_q4_0(t)[:, :k_odd] - x)
        bound = jnp.repeat(quantization_error_bound(t), QBLOCK,
                           axis=-1)[:, :k_odd] * 1.01 + 1e-6
        assert bool(jnp.all(err <= bound))

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.floats(0.25, 16.0))
    def test_prop_saturated_plane_idempotent(seed, amax):
        sign = jnp.asarray(
            np.random.default_rng(seed).choice([-1.0, 1.0],
                                               size=(2, QBLOCK)),
            jnp.float32)
        t = quantize_q4_0(sign * amax)
        t2 = quantize_q4_0(dequantize_q4_0(t))
        np.testing.assert_array_equal(np.asarray(t.q), np.asarray(t2.q))
        np.testing.assert_array_equal(np.asarray(t.scale),
                                      np.asarray(t2.scale))
