"""Fault-tolerant loop: resume determinism, preemption, NaN guard,
straggler detection."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointManager
from repro.configs import get_config, reduced
from repro.data.synthetic import SyntheticDataset
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.step import init_train_state, make_train_step


def _setup(tmp_path, total_steps=6, save_every=2, arch="qwen3-4b"):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3,
                                                      total_steps=100)))
    ds = SyntheticDataset(cfg, 16, 4, seed=0, n_shards=2)
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    loop = TrainLoop(step, ds, ckpt,
                     LoopConfig(total_steps=total_steps,
                                save_every=save_every))
    return model, loop, ckpt


def test_restart_resumes_exactly(tmp_path):
    """Train 6 straight vs train 4 + crash + resume: identical losses
    AND identical final params (counter-based data + checkpointed state)."""
    model, loop, _ = _setup(tmp_path / "a", total_steps=6)
    state = init_train_state(model, jax.random.key(0))
    final_a, res_a = loop.run(state)

    model, loop1, _ = _setup(tmp_path / "b", total_steps=4)
    state = init_train_state(model, jax.random.key(0))
    _, res_b1 = loop1.run(state)
    model, loop2, _ = _setup(tmp_path / "b", total_steps=6)
    # fresh (different) init: must be overwritten by the checkpoint
    final_b, res_b2 = loop2.run(init_train_state(model, jax.random.key(9)))

    np.testing.assert_allclose(res_a.losses[:4], res_b1.losses, rtol=1e-6)
    np.testing.assert_allclose(res_a.losses[4:], res_b2.losses, rtol=1e-6)
    for la, lb in zip(jax.tree.leaves(final_a["params"]),
                      jax.tree.leaves(final_b["params"])):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32), atol=1e-6)


def test_preemption_saves_and_stops(tmp_path):
    model, loop, ckpt = _setup(tmp_path, total_steps=50, save_every=100)
    state = init_train_state(model, jax.random.key(0))
    loop.on_step = lambda step, loss: (
        loop.request_preempt() if step == 3 else None)
    _, res = loop.run(state)
    assert res.preempted and res.final_step == 3
    assert ckpt.latest_step() == 3


def test_nan_guard_aborts(tmp_path):
    model, loop, _ = _setup(tmp_path, total_steps=5)
    bad_step = lambda state, batch: (state, {"loss": jnp.asarray(float("nan")),
                                             "grad_norm": jnp.asarray(0.0)})
    loop.step_fn = bad_step
    with pytest.raises(FloatingPointError):
        loop.run(init_train_state(model, jax.random.key(0)))


@pytest.mark.slow   # wall-clock-timing heuristic, not correctness
def test_straggler_detection(tmp_path):
    model, loop, _ = _setup(tmp_path, total_steps=8, save_every=100)
    loop.cfg.straggler_factor = 2.0
    real_step = loop.step_fn
    # warm the jit cache so the first in-loop step isn't compile-skewed
    state0 = init_train_state(model, jax.random.key(0))
    real_step(state0, loop.put_batch(loop.dataset.global_batch_at(0)))

    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 6:
            time.sleep(1.0)      # inject a straggler
        return real_step(state, batch)

    loop.step_fn = slow_step
    _, res = loop.run(state0)
    assert any(e["step"] == 5 for e in res.straggler_events), \
        res.straggler_events


def test_loss_decreases_over_training(tmp_path):
    model, loop, _ = _setup(tmp_path, total_steps=30, save_every=100)
    _, res = loop.run(init_train_state(model, jax.random.key(0)))
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first, (first, last)
