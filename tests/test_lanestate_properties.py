"""Property-based invariants of the spec-driven lane-state allocator
(``repro.serving.lanestate``), hypothesis-driven like
tests/test_paging_properties.py; the engine conformance suite carries
the deterministic end-to-end versions.

Invariants under arbitrary reserve/extend/release sequences over
*mixed-family* lanes (the allocator is deliberately family-agnostic —
one run interleaves dense-KV, enc-dec, MoE, hybrid-SSM and pure
recurrent specs in one pool):

* a lane's reservation always carries exactly its spec's state kinds,
  with recurrent kinds pinned to 1 unit and routing to ``n_experts``;
* double-reserve of a held slot and cross-extension of a lane without
  cross-KV state fail without mutating the ledger (shadow model match);
* totals are the exact sum of the shadow model at every step;
* releasing every held lane drains the pool to zero across all kinds —
  no path leaks pages, recurrent buffers, or counters.
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e .[test])")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.model import LaneStateSpec  # noqa: E402
from repro.serving.lanestate import LaneStatePool  # noqa: E402

N_SLOTS = 6

# one spec per served family, as Model.state_spec() derives them
SPECS = (
    LaneStateSpec(family="dense", self_kv=True, cross_kv=False),
    LaneStateSpec(family="audio", self_kv=True, cross_kv=True),
    LaneStateSpec(family="moe", self_kv=True, cross_kv=False,
                  moe_experts=4, moe_top_k=2),
    LaneStateSpec(family="hybrid", self_kv=True, cross_kv=False,
                  recurrent=("ssm",), prefill_exact=True),
    LaneStateSpec(family="ssm", self_kv=False, cross_kv=False,
                  recurrent=("mstate", "sstate"), prefill_exact=True),
)


def _expected(spec, n_tokens, enc_frames):
    r = {}
    if spec.self_kv:
        r["self_kv"] = n_tokens
    if spec.cross_kv:
        r["cross_kv"] = enc_frames
    for kind in spec.recurrent:
        r[kind] = 1
    if spec.moe_experts:
        r["routing"] = spec.moe_experts
    return r


_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("reserve"), st.integers(0, N_SLOTS - 1),
                  st.integers(0, len(SPECS) - 1), st.integers(0, 48),
                  st.integers(0, 16)),
        st.tuples(st.just("extend"), st.integers(0, 200),
                  st.integers(0, 8)),
        st.tuples(st.just("release"), st.integers(0, 200)),
    ),
    max_size=80)


@settings(max_examples=60, deadline=None)
@given(_OPS)
def test_ledger_matches_shadow_and_drains(ops):
    pool = LaneStatePool(N_SLOTS)
    shadow: dict[int, dict] = {}       # slot -> expected reservation
    for op in ops:
        if op[0] == "reserve":
            _, slot, si, n_tokens, enc_frames = op
            spec = SPECS[si]
            if slot in shadow:
                with pytest.raises(ValueError):
                    pool.reserve(slot, spec, n_tokens=n_tokens,
                                 enc_frames=enc_frames)
            else:
                got = pool.reserve(slot, spec, n_tokens=n_tokens,
                                   enc_frames=enc_frames)
                want = _expected(spec, n_tokens, enc_frames)
                assert got == want
                shadow[slot] = want
        elif op[0] == "extend":
            _, pick, frames = op
            live = sorted(shadow)
            if not live:
                continue
            slot = live[pick % len(live)]
            if "cross_kv" in shadow[slot]:
                pool.extend_cross(slot, frames)
                shadow[slot]["cross_kv"] += frames
            else:
                with pytest.raises(ValueError):
                    pool.extend_cross(slot, frames)
        else:
            _, pick = op
            live = sorted(shadow)
            if not live:
                continue
            slot = live[pick % len(live)]
            assert pool.release(slot) == shadow.pop(slot)
            assert not pool.holds(slot)
        # ledger == shadow at every step
        assert pool.n_live == len(shadow)
        totals = pool.totals()
        for kind in totals:
            assert totals[kind] == sum(r.get(kind, 0)
                                       for r in shadow.values())
        for slot, want in shadow.items():
            assert pool.held(slot) == want
        pool.check()
    # drain: releasing every held lane zeroes every state kind
    for slot in sorted(shadow):
        pool.release(slot)
    assert pool.drained
    assert all(v == 0 for v in pool.totals().values())
    pool.check()


@settings(max_examples=30, deadline=None)
@given(st.integers(0, len(SPECS) - 1), st.integers(-4, 9))
def test_reserve_bounds(si, slot):
    pool = LaneStatePool(N_SLOTS)
    spec = SPECS[si]
    if 0 <= slot < N_SLOTS:
        pool.reserve(slot, spec, n_tokens=8)
        assert set(pool.held(slot)) == set(spec.state_kinds)
    else:
        with pytest.raises(ValueError):
            pool.reserve(slot, spec, n_tokens=8)
        assert pool.drained


def test_negative_extents_rejected():
    pool = LaneStatePool(2)
    with pytest.raises(ValueError):
        pool.reserve(0, SPECS[0], n_tokens=-1)
    pool.reserve(0, SPECS[1], n_tokens=4, enc_frames=4)
    with pytest.raises(ValueError):
        pool.extend_cross(0, -2)
    assert pool.held(0) == {"self_kv": 4, "cross_kv": 4}
