"""Property-based invariants of the page allocator and lane manager
(``repro.paging``), driven by hypothesis when it is installed
(``pip install -e .[test]``); tests/test_paging.py carries seeded
deterministic versions that always run.

Invariants under arbitrary operation sequences:
* the pool never leaks or double-frees a page — ``used_pages`` always
  equals the shadow model, every refcount matches;
* allocation is all-or-nothing (a failed multi-page alloc changes
  nothing);
* lane admit/free sequences drain both pools to exactly zero, with the
  prefix store evicted once its last holder frees.
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e .[test])")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.paging import PageAllocError, PagePool, PagedKV  # noqa: E402

P = 8


@settings(max_examples=60, deadline=None)
@given(st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(1, 4)),
        st.tuples(st.just("retain"), st.integers(0, 200)),
        st.tuples(st.just("free"), st.integers(0, 200)),
    ),
    max_size=60))
def test_pool_refcounts_match_shadow_model(ops):
    pool = PagePool(12, P)
    shadow: dict[int, int] = {}
    for op, arg in ops:
        if op == "alloc":
            free_before = pool.free_pages
            got = pool.try_alloc(arg)
            if got is None:
                assert free_before < arg          # only fails when short
                assert pool.free_pages == free_before   # all-or-nothing
            else:
                assert len(set(got)) == arg
                for pg in got:
                    assert pg not in shadow and pg != 0
                    shadow[pg] = 1
        elif op == "retain":
            live = sorted(shadow)
            if not live:
                continue
            pg = live[arg % len(live)]
            pool.retain(pg)
            shadow[pg] += 1
        else:
            live = sorted(shadow)
            if not live:
                continue
            pg = live[arg % len(live)]
            pool.free(pg)
            shadow[pg] -= 1
            if shadow[pg] == 0:
                del shadow[pg]
        assert pool.used_pages == len(shadow)
        for pg, n in shadow.items():
            assert pool.refcount(pg) == n
        pool.check()
    # drain: refcounts wind down to exactly zero, every page returns
    for pg, n in list(shadow.items()):
        for _ in range(n):
            pool.free(pg)
    assert pool.used_pages == 0 and pool.free_pages == 11
    pool.check()


@settings(max_examples=40, deadline=None)
@given(st.lists(
    st.tuples(
        st.integers(0, 3),                       # slot
        st.integers(0, 2),                       # audio content id
        st.integers(1, 16),                      # prompt tokens
        st.integers(1, 8),                       # max_new
    ),
    max_size=24))
def test_lane_admits_always_drain_to_zero(admits):
    kv = PagedKV(n_slots=4, max_len=32, enc_len=16, page_size=P,
                 n_pages=12, n_cross_pages=6)
    held: dict[int, bool] = {}
    for slot, audio, n_tok, max_new in admits:
        if held.get(slot):
            kv.free_lane(slot)
            held[slot] = False
        if n_tok + max_new > kv.max_len:
            continue
        # anchor-style prompt: shared first page when n_tok >= P
        tokens = list(range(min(n_tok, kv.max_len)))
        try:
            kv.admit_lane(slot, tokens, f"digest-{audio}",
                          max_new=max_new, enc_s=8)
        except PageAllocError:
            # rolled back: the failed admit must not retain anything
            assert slot not in kv.lanes
            continue
        held[slot] = True
        kv.check()
    for slot, h in held.items():
        if h:
            kv.free_lane(slot)
    assert kv.self_pool.used_pages == 0
    assert kv.cross_pool.used_pages == 0
    assert kv.self_prefix.stats()["entries"] == 0
    assert kv.cross_prefix.stats()["entries"] == 0
    kv.check()
