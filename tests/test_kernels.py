"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes × dtypes, including non-multiple K (the C2 mixed-execution
split) and budget-driven block selection (the C4 VMEM knob).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import quantize_q8_0
from repro.kernels.fp16_matmul.ops import fp16_matmul, offload_info
from repro.kernels.fp16_matmul.ref import fp16_matmul_ref
from repro.kernels.q8_matmul.ops import q8_matmul, q8_matmul_xla
from repro.kernels.q8_matmul.ref import q8_matmul_ref
from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

KEY = jax.random.key(42)


# ------------------------------------------------------------------ q8 gemm

@pytest.mark.parametrize("m,n,k", [
    (8, 128, 64), (16, 128, 128),
    pytest.param(128, 256, 512, marks=pytest.mark.slow),  # big-tile sweep
    (8, 128, 96),          # K not a multiple of default bk -> C2 residual
    (5, 130, 64),          # ragged M/N -> padding path
    (1, 128, 2048),        # matvec (decode shape)
])
def test_q8_matmul_matches_ref(m, n, k):
    x = jax.random.normal(jax.random.fold_in(KEY, m * n), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, k), (k, n), jnp.float32)
    wq = quantize_q8_0(w, axis=0)
    got = q8_matmul(x, wq, interpret=True)
    want = q8_matmul_ref(x, wq.q, wq.scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("budget", [256 * 1024, 1024 * 1024, 8 * 1024 * 1024])
def test_q8_matmul_budget_sweep(budget):
    """The C4 knob: result identical under any VMEM budget."""
    x = jax.random.normal(KEY, (32, 320), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 7), (320, 256), jnp.float32)
    wq = quantize_q8_0(w, axis=0)
    got = q8_matmul(x, wq, vmem_budget=budget, interpret=True)
    want = q8_matmul_ref(x, wq.q, wq.scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_q8_matmul_approximates_dense():
    """Quantized GEMM ~= dense GEMM within the Q8 error envelope."""
    x = jax.random.normal(KEY, (16, 256), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (256, 128), jnp.float32)
    wq = quantize_q8_0(w, axis=0)
    got = q8_matmul(x, wq, interpret=True)
    dense = x @ w
    # relative error ~ 1/127 per element, sqrt(K) accumulation
    rel = float(jnp.linalg.norm(got - dense) / jnp.linalg.norm(dense))
    assert rel < 0.02, rel


def test_q8_matmul_batched_input():
    x = jax.random.normal(KEY, (2, 4, 64), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 9), (64, 128), jnp.float32)
    wq = quantize_q8_0(w, axis=0)
    got = q8_matmul(x, wq, interpret=True)
    assert got.shape == (2, 4, 128)
    want = q8_matmul_xla(x, wq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


# ----------------------------------------------------------------- fp16 gemm

@pytest.mark.parametrize("m,n,k,dtype", [
    (8, 128, 64, jnp.float16), (64, 256, 512, jnp.float16),
    (16, 128, 100, jnp.float16),    # K=100: split 96+4 at burst 16
    (7, 99, 35, jnp.bfloat16),      # fully ragged
    (1, 512, 1024, jnp.bfloat16),   # matvec
])
def test_fp16_matmul_matches_ref(m, n, k, dtype):
    x = jax.random.normal(jax.random.fold_in(KEY, m + n), (m, k)).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, k + 1), (k, n)).astype(dtype)
    got = fp16_matmul(x, w, interpret=True)
    want = fp16_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_fp16_offload_info_reports_split():
    info = offload_info(64, 128, 1000)
    assert info["k_main"] + info["k_residual"] == 1000
    assert info["k_main"] % info["bk"] == 0
    assert 0.85 < info["offload_fraction"] <= 1.0
    # hardware-aligned K (all assigned archs): full offload
    info = offload_info(64, 128, 4096)
    assert info["offload_fraction"] == 1.0


# ------------------------------------------------------------ flash attention

@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None), (True, 64, None), (True, None, 30.0),
    (False, None, None),
])
def test_flash_attention_matches_ref(causal, window, softcap):
    bh, s, d = 4, 256, 64
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (bh, s, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (bh, s, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (bh, s, d), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 softcap=softcap, bq=64, bk=64,
                                 interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window,
                         softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s", [
    128,
    pytest.param(192, marks=pytest.mark.slow),
    pytest.param(384, marks=pytest.mark.slow),
])
def test_flash_attention_seq_sweep(s):
    bh, d = 2, 32
    q = jax.random.normal(jax.random.fold_in(KEY, s), (bh, s, d))
    k = jax.random.normal(jax.random.fold_in(KEY, s + 1), (bh, s, d))
    v = jax.random.normal(jax.random.fold_in(KEY, s + 2), (bh, s, d))
    got = flash_attention_pallas(q, k, v, causal=True, bq=64, bk=64,
                                 interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_gqa_wrapper():
    """(B,S,H,D) GQA wrapper: kv heads repeat to q heads."""
    b, s, h, hkv, d = 2, 128, 4, 2, 32
    q = jax.random.normal(jax.random.fold_in(KEY, 21), (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 22), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 23), (b, s, hkv, d))
    got = flash_attention(q, k, v, causal=True, interpret=True)
    kr = jnp.repeat(k, 2, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vr = jnp.repeat(v, 2, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    want = attention_ref(qr, kr, vr, causal=True).reshape(
        b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------- chunked-XLA attention

def test_chunked_attention_equals_dense():
    """The model's chunked online-softmax (XLA binding of the kernel)
    must equal dense attention — incl. local windows and softcaps.

    Tolerance: the production path streams Q/K/V/P into the dot in bf16
    with f32 accumulation (the C1-inline optimization, §Perf cell C), so
    agreement with the f32 dense oracle is at bf16 input precision
    (~8-bit mantissa -> ~1e-2 relative)."""
    from repro.models.attention import chunked_attention
    b, s, h, d = 2, 200, 4, 32
    q = jax.random.normal(jax.random.fold_in(KEY, 11), (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 12), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 13), (b, s, h, d))
    for window, softcap in [(None, None), (37, None), (None, 25.0)]:
        got = chunked_attention(q, k, v, causal=True, window=window,
                                softcap=softcap, chunk=64)
        want = attention_ref(
            q.transpose(0, 2, 1, 3).reshape(b * h, s, d),
            k.transpose(0, 2, 1, 3).reshape(b * h, s, d),
            v.transpose(0, 2, 1, 3).reshape(b * h, s, d),
            causal=True, window=window, softcap=softcap,
        ).reshape(b, h, s, d).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)


# ------------------------------------------------------------- slstm kernel

@pytest.mark.parametrize("s,b,h,hd,t", [
    (64, 2, 4, 32, 64), (100, 2, 4, 32, 32),   # ragged S -> padded chunk
    pytest.param(128, 1, 2, 128, 32, marks=pytest.mark.slow),
])
def test_slstm_scan_kernel_matches_ref(s, b, h, hd, t):
    """Time-chunked Pallas sLSTM (state resident in VMEM) ≡ lax.scan
    oracle, including state-preserving chunk padding (§Perf cell A)."""
    from repro.kernels.slstm_scan.ops import slstm_scan
    from repro.kernels.slstm_scan.ref import slstm_scan_ref
    wx = jax.random.normal(jax.random.fold_in(KEY, s),
                           (s, 4, b, h, hd), jnp.float32) * 0.5
    r = jax.random.normal(jax.random.fold_in(KEY, s + 1),
                          (4, h, hd, hd), jnp.float32) * 0.1
    s0 = jnp.stack([jnp.zeros((b, h, hd))] * 3
                   + [jnp.full((b, h, hd), -1e30)])
    hs, st = slstm_scan(wx, r, s0, t_chunk=t, interpret=True)
    hs_ref, st_ref = slstm_scan_ref(wx, r, s0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-5, atol=2e-5)


def test_slstm_kernel_vmem_budget():
    """Resident R + state fit VMEM with double-buffered wx chunks (C4)."""
    from repro.kernels.slstm_scan.ops import kernel_traffic_model
    m = kernel_traffic_model(4096, 16, 4, 256, n_segments=12)
    wx_chunk = 64 * 4 * 16 * 4 * 256 * 4          # (T,4,B,H,hd) f32
    assert m["vmem_resident"] + 2 * wx_chunk < 128 * 1024 * 1024


# ------------------------------------------------------- q8 decode attention

@pytest.mark.parametrize("bh,s,d,length,bk", [
    (4, 256, 64, 200, 128),       # masked tail
    (2, 300, 32, 300, 128),       # ragged S -> padded blocks
    (8, 128, 128, 1, 64),         # single valid position
])
def test_q8_decode_attention_matches_ref(bh, s, d, length, bk):
    """Dequant-in-kernel Q8_0 KV attention ≡ dequantized dense oracle
    (paper C1 applied to the decode cache — the §Roofline decode
    bottleneck; cache stream 0.53x of bf16)."""
    from repro.kernels.q8_attention.ops import (q8_decode_attention,
                                                quantize_kv)
    from repro.kernels.q8_attention.ref import q8_decode_attention_ref
    q = jax.random.normal(jax.random.fold_in(KEY, bh), (bh, 1, d))
    k = jax.random.normal(jax.random.fold_in(KEY, s), (bh, s, d))
    v = jax.random.normal(jax.random.fold_in(KEY, d), (bh, s, d))
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    got = q8_decode_attention(q, kq, ks, vq, vs, length, bk=bk,
                              interpret=True)
    want = q8_decode_attention_ref(q, kq, ks, vq, vs, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_q8_decode_attention_per_lane_lengths():
    """(BH,) length vector: each lane masks at its own depth — the
    serving engine's continuous-batching configuration — and must agree
    with per-lane scalar-length calls."""
    from repro.kernels.q8_attention.ops import (q8_decode_attention,
                                                quantize_kv)
    from repro.kernels.q8_attention.ref import q8_decode_attention_ref
    bh, s, d = 4, 128, 32
    q = jax.random.normal(jax.random.fold_in(KEY, 41), (bh, 1, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 42), (bh, s, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 43), (bh, s, d))
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    lens = jnp.asarray([1, 17, 64, 128], jnp.int32)
    got = q8_decode_attention(q, kq, ks, vq, vs, lens, bk=64,
                              interpret=True)
    want = q8_decode_attention_ref(q, kq, ks, vq, vs, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # per-lane == the same lane run alone at a scalar length
    for i, n in enumerate(lens):
        one = q8_decode_attention_ref(q[i:i + 1], kq[i:i + 1],
                                      ks[i:i + 1], vq[i:i + 1],
                                      vs[i:i + 1], int(n))
        np.testing.assert_allclose(np.asarray(want[i]), np.asarray(one[0]),
                                   rtol=1e-5, atol=1e-5)


def test_q8_decode_attention_close_to_exact():
    """Within the Q8 error envelope of exact bf16 attention."""
    from repro.kernels.q8_attention.ops import (q8_decode_attention,
                                                quantize_kv)
    bh, s, d = 4, 256, 64
    q = jax.random.normal(jax.random.fold_in(KEY, 31), (bh, 1, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 32), (bh, s, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 33), (bh, s, d))
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    got = q8_decode_attention(q, kq, ks, vq, vs, s, interpret=True)
    sd = jnp.einsum("bqd,bkd->bqk", q, k) * d ** -0.5
    dense = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(sd, -1), v)
    rel = float(jnp.linalg.norm(got - dense) / jnp.linalg.norm(dense))
    assert rel < 0.02, rel
