"""Optimizer + schedule properties (hypothesis)."""

import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.optim.adamw import (AdamWConfig, apply_updates, global_norm,
                               init_state, schedule)


def _params(seed, n=3):
    key = jax.random.key(seed)
    return {"w": jax.random.normal(key, (4, 4)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (4,))}


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 5), st.floats(1e-5, 1e-2))
def test_update_moves_against_gradient(seed, lr):
    """One AdamW step on f(p)=0.5||p||^2 reduces the loss."""
    cfg = AdamWConfig(lr=lr, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, clip_norm=None)
    p = _params(seed)
    g = p  # grad of 0.5||p||^2 is p
    new_p, _, _ = apply_updates(p, g, init_state(p), cfg)
    before = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(p))
    after = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(new_p))
    assert after < before


@settings(max_examples=25, deadline=None)
@given(st.floats(0.1, 10.0))
def test_clip_bounds_effective_norm(scale):
    """With clip_norm=1, the applied gradient has norm <= 1 (+eps)."""
    p = _params(0)
    g = jax.tree.map(lambda x: x * scale, p)
    gnorm = float(global_norm(g))
    # reconstruct the clip factor the optimizer applied
    expected_scale = min(1.0, 1.0 / (gnorm + 1e-9))
    clipped = jax.tree.map(lambda x: x * expected_scale, g)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5


def test_schedule_shape():
    """Warmup ramps to lr, cosine decays to min_lr_ratio*lr."""
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(t))) for t in range(0, 101, 5)]
    assert lrs[0] < lrs[1] < lrs[2]                 # warmup
    assert abs(lrs[2] - 1e-3) < 1e-4                # peak ~ lr
    assert all(a >= b - 1e-12 for a, b in zip(lrs[2:], lrs[3:]))  # decay
    assert abs(lrs[-1] - 1e-4) < 2e-5               # floor


def test_moments_shapes_and_step_counter():
    p = _params(1)
    st_ = init_state(p)
    cfg = AdamWConfig()
    _, st2, m = apply_updates(p, p, st_, cfg)
    assert int(st2["step"]) == 1
    for a, b in zip(jax.tree.leaves(st2["m"]), jax.tree.leaves(p)):
        assert a.shape == b.shape
    assert float(m["grad_norm"]) > 0
