"""Device-resident fused decode loop: parity, donation, sync counts.

The fused tick (``ServeEngine.step`` with ``decode_block=K``) must be
token-identical to K sequential single steps — including lanes that hit
EOS mid-block and parked streaming lanes — while donating the KV pool
and syncing to host exactly once per tick.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import build
from repro.serving.engine import (AudioRequest, Request, ServeEngine,
                                  StreamingAudioRequest)
from repro.serving.scheduler import BatchScheduler

WHISPER_PROMPTS = [[5, 6, 7, 8], [9, 10, 11], [3, 4, 5, 6, 7]]


def _setup(arch="whisper-tiny-en", seed=0):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init_values(jax.random.key(seed))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("enc_len", 16)
    return ServeEngine(model, params, **kw)


def _frames(cfg, rng, lens=(8, 12, 8)):
    return [rng.standard_normal((n, cfg.d_model)).astype(np.float32) * 0.5
            for n in lens]


def _admit_all(eng, cfg, frames, max_new=8, eos=-2, prompts=None):
    prompts = prompts or WHISPER_PROMPTS
    return [eng.admit(AudioRequest(uid=i, tokens=list(p), max_new=max_new,
                                   eos_id=eos, enc_frames=f))
            for i, (p, f) in enumerate(zip(prompts, frames))]


def _drain(eng, k=None):
    while eng.n_active:
        eng.step(k)


# ------------------------------------------------------------- parity


@pytest.mark.parametrize("cache_dtype", ["bf16", "q8_0", "q4_0"])
def test_fused_tick_parity(cache_dtype):
    """K-step fused decode == K sequential step() calls, token for
    token, for bf16 and q8_0 cache pools."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    frames = _frames(cfg, rng)

    eng_seq = _engine(model, params, cache_dtype=cache_dtype)
    sts_seq = _admit_all(eng_seq, cfg, frames)
    _drain(eng_seq, k=1)

    eng_fus = _engine(model, params, cache_dtype=cache_dtype,
                      decode_block=4)
    sts_fus = _admit_all(eng_fus, cfg, frames)
    _drain(eng_fus)

    assert [st.out for st in sts_fus] == [st.out for st in sts_seq]
    # a fused tick buys decode_block steps per host sync
    assert eng_fus._host_syncs == eng_fus._ticks
    assert eng_fus._decode_steps == 4 * eng_fus._ticks
    assert eng_fus._ticks < eng_seq._ticks


@pytest.mark.parametrize("cache_dtype", ["bf16", "q8_0", "q4_0"])
def test_fused_tick_parity_eos_mid_block(cache_dtype):
    """A lane that hits EOS at a step that is NOT a block boundary must
    freeze mid-scan: its later in-block emits are masked, and every
    other lane is unaffected."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    frames = _frames(cfg, rng)

    # discover the greedy streams, then pick an eos that lane 0 emits at
    # step 2 of an 8-token run — inside a decode_block=4 tick
    probe = _engine(model, params, cache_dtype=cache_dtype)
    sts = _admit_all(probe, cfg, frames, max_new=8)
    _drain(probe, k=1)
    eos = sts[0].out[2]

    eng_seq = _engine(model, params, cache_dtype=cache_dtype)
    sts_seq = _admit_all(eng_seq, cfg, frames, max_new=8, eos=eos)
    _drain(eng_seq, k=1)

    eng_fus = _engine(model, params, cache_dtype=cache_dtype,
                      decode_block=4)
    sts_fus = _admit_all(eng_fus, cfg, frames, max_new=8, eos=eos)
    _drain(eng_fus)

    assert [st.out for st in sts_fus] == [st.out for st in sts_seq]
    assert sts_fus[0].out[-1] == eos and len(sts_fus[0].out) <= 4
    assert all(st.done for st in sts_fus)


def test_fused_tick_parity_with_parked_streaming_lane():
    """A streaming lane that exhausted max_new mid-stream parks (keeps
    its slot, stops decoding); fused ticks must keep it frozen while
    other lanes decode, and the finalized stream must match the
    sequential engine's transcript and partials."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(1)
    chunks = [rng.standard_normal((4, cfg.d_model)).astype(np.float32) * 0.5
              for _ in range(3)]
    frames = _frames(cfg, rng, lens=(8,))

    def serve(block):
        eng = _engine(model, params, decode_block=block)
        sched = BatchScheduler(eng)
        # max_new=2: the streaming lane finishes its mid-stream
        # hypothesis immediately and parks until the next chunk
        sched.submit(StreamingAudioRequest(uid=0, tokens=[5, 6], max_new=2,
                                           eos_id=-2, chunks=chunks))
        sched.submit(AudioRequest(uid=1, tokens=[7, 8, 9], max_new=9,
                                  eos_id=-2, enc_frames=frames[0]))
        sched.run_until_drained(max_ticks=100)
        assert sched.drained
        return sched.results

    seq, fus = serve(1), serve(4)
    assert fus[0].out == seq[0].out
    assert fus[0].partials == seq[0].partials
    assert fus[1].out == seq[1].out


def test_fused_decoder_only_parity():
    cfg, model, params = _setup("qwen3-4b")
    prompts = [[5, 6, 7, 8], [9, 10, 11]]

    def serve(block):
        eng = _engine(model, params, max_len=96, decode_block=block)
        sts = [eng.admit(Request(uid=i, tokens=p, max_new=9, eos_id=-2))
               for i, p in enumerate(prompts)]
        _drain(eng)
        return [st.out for st in sts]

    assert serve(1) == serve(4) == serve(16)


def test_step_k_overrides_block():
    """step(k) fuses k steps regardless of the engine default — the
    mutable-knob path transcribe(engine=...) uses."""
    cfg, model, params = _setup("qwen3-4b")
    eng = _engine(model, params, max_len=96)
    eng.admit(Request(uid=0, tokens=[5, 6, 7], max_new=9, eos_id=-2))
    eng.step(4)
    assert eng._decode_steps == 4 and eng._ticks == 1


def test_decode_block_validation():
    cfg, model, params = _setup("qwen3-4b")
    with pytest.raises(ValueError, match="decode_block"):
        _engine(model, params, decode_block=0)
    # mutable-knob path: a 0-block step would be a 0-length scan that
    # emits nothing and never drains — step() must refuse it too
    eng = _engine(model, params, max_len=96)
    eng.admit(Request(uid=0, tokens=[5, 6, 7], max_new=4, eos_id=-2))
    eng.decode_block = 0
    with pytest.raises(ValueError, match="block"):
        eng.step()


def test_transcribe_decode_block_validation():
    from repro.audio.transcribe import transcribe
    with pytest.raises(ValueError, match="decode_block"):
        transcribe(np.zeros(1600, np.float32), 16_000, decode_block=0)


# ------------------------------------------- donation & device residency


def test_decode_jit_donates_cache_and_state():
    """The fused decode jit must donate the KV pool and the lane-state
    buffers — the lowering carries input/output aliasing, so on
    donation-capable backends the pool is updated in place instead of
    copied every tick."""
    cfg, model, params = _setup()
    eng = _engine(model, params)
    fn = eng._build_decode(2)
    lowered = fn.lower(params, eng.cache, eng._tokens, eng._pos,
                       eng._lane_active, eng._lane_out, eng._enc_lens,
                       eng._lane_eos, eng._lane_max)
    txt = lowered.as_text()
    # cache leaves + tokens/pos/active/n_out: at least 5 donated inputs
    assert txt.count("tf.aliasing_output") >= 5, \
        txt.count("tf.aliasing_output")


def test_prefill_jit_donates_pool_and_returns_scalar_argmax():
    """Prefill takes the pool (donated: the slot scatter is an in-place
    lane write) and returns the first token as a device scalar — the
    [1, bucket, vocab] logits never reach the host."""
    cfg, model, params = _setup("qwen3-4b")
    eng = _engine(model, params, max_len=96)
    fn = eng._prefill_fn(32)
    toks = jnp.zeros((1, 32), jnp.int32)
    lowered = fn.lower(params, eng.cache, toks, 3, 0)
    txt = lowered.as_text()
    assert "tf.aliasing_output" in txt
    first, pool = jax.eval_shape(fn, params, eng.cache, toks, 3, 0)
    assert first.shape == () and first.dtype == jnp.int32


def test_decode_state_is_device_resident():
    """The per-lane decode state lives in jax arrays owned by the
    engine — nothing is re-uploaded from host NumPy per tick."""
    cfg, model, params = _setup("qwen3-4b")
    eng = _engine(model, params, max_len=96)
    for name in ("_tokens", "_pos", "_enc_lens", "_lane_active",
                 "_lane_eos", "_lane_max", "_lane_out"):
        assert isinstance(getattr(eng, name), jax.Array), name
    st = eng.admit(Request(uid=0, tokens=[5, 6, 7], max_new=4, eos_id=-2))
    assert int(eng._lane_active.sum()) == 1
    assert int(eng._lane_max[st.slot]) == 4
    assert int(eng._lane_out[st.slot]) == 1
    _drain(eng)
    assert int(eng._lane_active.sum()) == 0
    assert (np.asarray(eng._pos) == 0).all()


def test_one_host_sync_per_tick():
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    eng = _engine(model, params, decode_block=4)
    _admit_all(eng, cfg, _frames(cfg, rng), max_new=8)
    syncs0 = eng._host_syncs
    n = 0
    while eng.n_active:
        eng.step()
        n += 1
    assert eng._host_syncs - syncs0 == n == eng._ticks


# -------------------------------------------------- energy accounting


def test_energy_report_multi_token_ticks():
    """joules/token must not change when ticks advance once per K
    tokens: the stream is priced per decode step, and with a workload
    that has no in-block waste the fused and sequential reports are
    identical (bar tick counts)."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    frames = _frames(cfg, rng, lens=(8, 8))

    def serve(block):
        eng = _engine(model, params, n_slots=2, decode_block=block,
                      platform="imax3-28nm/32k")
        for i, f in enumerate(frames):
            # 1 prefill + 8 decode tokens; 8 % 4 == 0 -> no waste
            eng.admit(AudioRequest(uid=i, tokens=[5 + i, 6, 7], max_new=9,
                                   eos_id=-1, enc_frames=f))
        _drain(eng)
        return eng.energy_report()

    seq, fus = serve(1), serve(4)
    assert fus["decode_block"] == 4
    assert fus["ticks"] == seq["ticks"] / 4
    assert fus["decode_steps"] == seq["decode_steps"] == 8
    assert fus["tokens"] == seq["tokens"] == 18
    assert fus["stream_bytes_total"] == seq["stream_bytes_total"]
    assert fus["joules_per_token"] == pytest.approx(
        seq["joules_per_token"])
    assert fus["host_syncs"] == seq["host_syncs"] / 4
