"""Recurrent-step parity oracles: the chunked-scan *prefill* paths hand
exactly the state a step-wise recurrence would have produced.

The serving engine admits recurrent lanes with an exact-length chunked
prefill (``mode="prefill"``) and then continues token-by-token through
the fused decode tick — so the end-of-prefill state is load-bearing:
any drift there corrupts every subsequent decode step. Each family's
oracle here runs the same sequence two ways —

  chunked prefill over the prompt, then step-wise decode of the tail
  vs. step-wise decode of the whole sequence from zero state

— and asserts the tail outputs agree. sLSTM additionally pins the
fused-scan formulation against the legacy per-step-GEMV baseline
(``flags.BASELINE``), state included.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.layers import KeyGen, split_params

B, S, SPLIT = 2, 12, 7          # prefill x[:, :SPLIT], decode the rest


def _x(cfg, seed):
    return jax.random.normal(jax.random.key(seed),
                             (B, S, cfg.d_model), jnp.float32) * 0.5


def _tail_stepwise(block, params, x, cfg, cache, t0):
    ys = []
    for t in range(t0, x.shape[1]):
        y, cache = block(params, x[:, t:t + 1], cfg, mode="decode",
                         cache=cache, pos=t)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), cache


def _assert_close(a, b, tol=2e-2):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=tol, atol=tol)


def test_mamba_prefill_state_matches_stepwise():
    """zamba2's SSD chunked prefill state == step-wise SSM state: the
    decode tail continued from the prefill cache equals the tail of the
    all-steps reference (``mamba_recurrent_ref`` stepping from zero)."""
    from repro.models import ssm
    cfg = reduced(get_config("zamba2-7b"))
    params, _ = split_params(ssm.init_mamba(KeyGen(jax.random.key(3)),
                                            cfg))
    x = _x(cfg, 4)
    cache = ssm.init_mamba_cache(cfg, B, jnp.float32)
    _, cache = ssm.mamba_block(params, x[:, :SPLIT], cfg,
                               mode="prefill", cache=cache)
    y_tail, _ = _tail_stepwise(ssm.mamba_block, params, x, cfg, cache,
                               SPLIT)
    y_ref = ssm.mamba_recurrent_ref(params, x, cfg)
    _assert_close(y_tail, y_ref[:, SPLIT:])


def test_mlstm_prefill_state_matches_stepwise():
    """xlstm's chunked-parallel mLSTM prefill hands the same ``(C, n,
    m)`` a pure ``_mlstm_core_step`` recurrence reaches."""
    from repro.models import xlstm
    cfg = reduced(get_config("xlstm-350m"))
    params, _ = split_params(xlstm.init_mlstm(KeyGen(jax.random.key(5)),
                                              cfg))
    x = _x(cfg, 6)
    cache = xlstm.init_mlstm_cache(cfg, B, jnp.float32)
    _, cache = xlstm.mlstm_block(params, x[:, :SPLIT], cfg,
                                 mode="prefill", cache=cache)
    y_tail, _ = _tail_stepwise(xlstm.mlstm_block, params, x, cfg,
                               cache, SPLIT)
    ref_cache = xlstm.init_mlstm_cache(cfg, B, jnp.float32)
    y_ref, _ = _tail_stepwise(xlstm.mlstm_block, params, x, cfg,
                              ref_cache, 0)
    _assert_close(y_tail, y_ref[:, SPLIT:])


def test_slstm_prefill_state_matches_stepwise():
    """sLSTM's fused-scan prefill state == per-token ``_slstm_step``
    state."""
    from repro.models import xlstm
    cfg = reduced(get_config("xlstm-350m"))
    params, _ = split_params(xlstm.init_slstm(KeyGen(jax.random.key(7)),
                                              cfg))
    x = _x(cfg, 8)
    cache = xlstm.init_slstm_cache(cfg, B, jnp.float32)
    _, cache = xlstm.slstm_block(params, x[:, :SPLIT], cfg,
                                 mode="prefill", cache=cache)
    y_tail, _ = _tail_stepwise(xlstm.slstm_block, params, x, cfg,
                               cache, SPLIT)
    ref_cache = xlstm.init_slstm_cache(cfg, B, jnp.float32)
    y_ref, _ = _tail_stepwise(xlstm.slstm_block, params, x, cfg,
                              ref_cache, 0)
    _assert_close(y_tail, y_ref[:, SPLIT:])


def test_slstm_scan_matches_legacy_baseline(monkeypatch):
    """The hoisted-GEMM sLSTM scan tracks the legacy per-step formulation
    (``flags.BASELINE``): same prefill outputs AND the same handed-off
    ``(c, n, h, m)`` state leaves — to bf16 input precision, since the
    hoisted gate GEMMs run with bf16 operands (f32 accumulate) where the
    legacy in-scan GEMVs were full f32."""
    from repro import flags
    from repro.models import xlstm
    cfg = reduced(get_config("xlstm-350m"))
    params, _ = split_params(xlstm.init_slstm(KeyGen(jax.random.key(9)),
                                              cfg))
    x = _x(cfg, 10)

    def prefill():
        cache = xlstm.init_slstm_cache(cfg, B, jnp.float32)
        return xlstm.slstm_block(params, x, cfg, mode="prefill",
                                 cache=cache)

    y_fast, cache_fast = prefill()
    monkeypatch.setattr(flags, "BASELINE", True)
    y_legacy, cache_legacy = prefill()
    _assert_close(y_fast, y_legacy)
    for k in ("c", "n", "h", "m"):
        _assert_close(cache_fast[k], cache_legacy[k])
