"""Kernel-dispatch API: registry, the executable ACCEL/HOST control law,
backend equivalence, and the acceptance routing criteria (ISSUE 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.offload import offload_decision, plan_offload
from repro.core.quantize import quantize_q8_0, quantize_tree
from repro.core.workload import WHISPER_TINY, whisper_workload
from repro.kernels import registry
from repro.kernels.api import (DispatchContext, decide, dispatch,
                               dispatch_counters, dispatch_trace,
                               reset_dispatch_log, use_context)

KEY = jax.random.key(7)
LOOSE = DispatchContext(vmem_budget=64 * 2 ** 20, allow_pallas=True,
                        interpret=True)
ZERO = DispatchContext(vmem_budget=0, allow_pallas=True, interpret=True)


@pytest.fixture(autouse=True)
def _clean_log():
    reset_dispatch_log()
    yield
    reset_dispatch_log()


def _q8_operands(m=8, k=256, n=128):
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (k, n), jnp.float32)
    return x, quantize_q8_0(w, axis=0)


# ------------------------------------------------------------------ registry

def test_registry_has_all_builtin_ops():
    assert registry.list_ops() == sorted([
        "q8_matmul", "q4_matmul", "fp16_matmul", "flash_attention",
        "q8_decode_attention", "q4_decode_attention",
        "paged_decode_attention", "slstm_scan"])


def test_registry_unknown_op_raises():
    with pytest.raises(KeyError, match="unknown kernel op"):
        registry.get_op("nope")


def test_registry_rejects_bad_backend_name():
    with pytest.raises(ValueError, match="unknown backends"):
        registry.KernelOp(name="bad", spec=lambda: None,
                          backends={"cuda": lambda ctx: None})


def test_kernels_package_exports():
    import repro.kernels as K
    for name in ("q8_matmul", "fp16_matmul", "flash_attention",
                 "q8_decode_attention", "quantize_kv", "slstm_scan",
                 "dispatch", "DispatchContext"):
        assert hasattr(K, name), name


# ------------------------------------------------- control law / decisions

def test_decision_tracks_budget():
    op = registry.get_op("q8_matmul")
    x, wq = _q8_operands()
    spec = op.spec(x, wq)
    assert decide("q8_matmul", spec, LOOSE) == ("accel", "pallas")
    assert decide("q8_matmul", spec, ZERO)[0] == "host"
    # without allow_pallas the ACCEL decision binds to the XLA path
    cpu = DispatchContext(vmem_budget=64 * 2 ** 20, allow_pallas=False)
    assert decide("q8_matmul", spec, cpu) == ("accel", "xla")


def test_decide_matches_plan_offload_over_whisper_workload():
    work = whisper_workload(WHISPER_TINY, dtype="q8_0")
    for budget in (16 * 1024, 32 * 1024):
        plan = plan_offload(work, budget)
        ctx = DispatchContext(vmem_budget=budget, allow_pallas=True)
        accel_ids = {id(s) for s in plan.accel}
        for spec in work:
            want = "accel" if id(spec) in accel_ids else "host"
            assert decide("q8_matmul", spec, ctx)[0] == want
            assert offload_decision(spec, budget) == want


def test_routing_counters_accel_vs_host():
    x, wq = _q8_operands()
    with use_context(LOOSE):
        y_accel = dispatch("q8_matmul", x, wq)
    assert dispatch_counters()[("q8_matmul", "accel", "pallas")] == 1
    reset_dispatch_log()
    with use_context(ZERO):
        y_host = dispatch("q8_matmul", x, wq)
    assert dispatch_counters()[("q8_matmul", "host", "xla")] == 1
    np.testing.assert_allclose(np.asarray(y_accel), np.asarray(y_host),
                               rtol=1e-4, atol=1e-3)
    rec = dispatch_trace()[-1]
    assert rec.op == "q8_matmul" and rec.budget == 0
    assert rec.footprint > 0


def test_pallas_block_miss_falls_back_to_host():
    """Analytic footprint fits but no MXU-aligned block does: the call
    lands on the host path (the paper's residual machinery), recorded as
    accel->host."""
    x, wq = _q8_operands(m=8, k=512, n=128)
    op = registry.get_op("q8_matmul")
    spec = op.spec(x, wq)
    budget = 12 * 1024
    assert offload_decision(spec, budget) == "accel"
    with use_context(DispatchContext(vmem_budget=budget, allow_pallas=True,
                                     interpret=True)):
        y = dispatch("q8_matmul", x, wq)
    c = dispatch_counters()
    assert c[("q8_matmul", "accel->host", "xla")] == 1, dict(c)
    ref = dispatch("q8_matmul", x, wq, ctx=ZERO)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


def test_env_force_backend(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")
    x, wq = _q8_operands()
    dispatch("q8_matmul", x, wq)
    assert dispatch_counters()[("q8_matmul", "forced", "ref")] == 1


def test_per_op_backend_override():
    ctx = DispatchContext(vmem_budget=64 * 2 ** 20, allow_pallas=True,
                          backends={"q8_matmul": "xla"})
    x, wq = _q8_operands()
    dispatch("q8_matmul", x, wq, ctx=ctx)
    assert dispatch_counters()[("q8_matmul", "forced", "xla")] == 1


def test_forced_backend_not_registered_falls_back_to_host():
    """Global xla force on a pallas/ref-only op lands on its host chain
    instead of crashing."""
    wx = jax.random.normal(jax.random.fold_in(KEY, 40), (16, 4, 1, 2, 8))
    r = jax.random.normal(jax.random.fold_in(KEY, 41), (4, 2, 8, 8)) * 0.1
    s0 = jnp.stack([jnp.zeros((1, 2, 8))] * 3
                   + [jnp.full((1, 2, 8), -1e30)])
    dispatch("slstm_scan", wx, r, s0, ctx=_force("xla"))
    assert dispatch_counters()[("slstm_scan", "forced", "ref")] == 1


def test_forced_backend_typo_raises():
    x, wq = _q8_operands()
    with pytest.raises(ValueError, match="forced backend 'reff'"):
        dispatch("q8_matmul", x, wq,
                 ctx=DispatchContext(vmem_budget=0, force_backend="reff"))


def test_env_bools_case_insensitive(monkeypatch):
    from repro import flags
    for raw, want in (("False", False), ("NO", False), ("0", False),
                      ("TRUE", True), ("1", True)):
        monkeypatch.setenv("REPRO_ALLOW_PALLAS", raw)
        assert flags.allow_pallas_default() is want, raw


def test_grad_safe_context_strips_pallas():
    from repro.kernels.api import grad_safe_context
    ctx = DispatchContext(vmem_budget=1, allow_pallas=True,
                          force_backend="pallas",
                          backends={"q8_matmul": "pallas",
                                    "fp16_matmul": "ref"})
    g = grad_safe_context(ctx)
    assert not g.allow_pallas and g.force_backend is None
    assert g.backends == {"fp16_matmul": "ref"}
    assert g.vmem_budget == 1


def test_cross_attention_falls_back_under_pallas():
    """sq != skv (encoder-decoder cross attention) can't take the Pallas
    flash kernel; dispatch lands it on the host path."""
    from repro.configs import get_config, reduced
    from repro.models.attention import attention, init_cross_attention
    from repro.models.layers import KeyGen
    cfg = reduced(get_config("qwen3-4b"))
    p = jax.tree.map(lambda t: t.value if hasattr(t, "value") else t,
                     init_cross_attention(KeyGen(KEY), cfg),
                     is_leaf=lambda t: hasattr(t, "value"))
    x = jax.random.normal(jax.random.fold_in(KEY, 50),
                          (1, 8, cfg.d_model), jnp.bfloat16)
    enc = jax.random.normal(jax.random.fold_in(KEY, 51),
                            (1, 24, cfg.d_model), jnp.bfloat16)
    with use_context(LOOSE):
        y, _ = attention(p, x, cfg, mode="prefill", x_kv=enc,
                         use_rope=False)
    c = dispatch_counters()
    assert c[("flash_attention", "accel->host", "xla")] == 1, dict(c)
    assert y.shape == (1, 8, cfg.d_model)


def test_train_step_differentiable_under_pallas_context():
    """Training grads must not route through VJP-less Pallas kernels
    even when the ambient context allows them."""
    from repro.configs import get_config, reduced
    from repro.models.model import build
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import init_train_state, make_train_step
    cfg = reduced(get_config("qwen3-4b"))
    model = build(cfg)
    state = init_train_state(model, jax.random.key(0))
    step = make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=0,
                                              total_steps=10))
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "targets": jnp.zeros((2, 8), jnp.int32),
             "positions": jnp.broadcast_to(jnp.arange(8), (2, 8))}
    with use_context(LOOSE):          # pallas allowed ambiently
        state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert not any(b == "pallas" for (_, _, b) in dispatch_counters())


# ------------------------------------------- backend equivalence (mm/mm_out)

def _force(backend):
    return DispatchContext(vmem_budget=64 * 2 ** 20, allow_pallas=True,
                           interpret=True, force_backend=backend)


@pytest.mark.parametrize("backend", ["ref", "xla", "pallas"])
def test_mm_q8_backend_sweep(backend):
    from repro.models.layers import mm
    x, wq = _q8_operands(m=5, k=96, n=64)    # ragged M + C2 residual K
    got = mm(x, wq, jnp.float32)
    with use_context(_force(backend)):
        got_b = mm(x, wq, jnp.float32)
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(got),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("backend", ["ref", "xla", "pallas"])
def test_mm_dense_and_mm_out_backend_sweep(backend):
    from repro.models.layers import mm, mm_out
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 8, 64)) / 8
    w = jax.random.normal(jax.random.fold_in(KEY, 4), (64, 32)) / 8
    wo = jax.random.normal(jax.random.fold_in(KEY, 5), (4, 16, 24)) / 8
    xo = jax.random.normal(jax.random.fold_in(KEY, 6), (2, 8, 4, 16)) / 8
    want = np.asarray(jnp.einsum(
        "...k,kn->...n", x, w).astype(jnp.float32))
    want_o = np.asarray(jnp.einsum(
        "...hd,hdn->...n", xo, wo).astype(jnp.float32))
    with use_context(_force(backend)):
        got = mm(x, w, jnp.bfloat16)
        got_o = mm_out(xo, wo, jnp.bfloat16)
    # bf16 compute dtype on the xla path: agree at bf16 precision
    np.testing.assert_allclose(np.asarray(got, jnp.float32), want,
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(got_o, jnp.float32), want_o,
                               rtol=2e-2, atol=2e-2)


# ------------------------------------- acceptance: routed Q8 model forward

def test_layers_has_no_direct_ref_import():
    import repro.models.layers as L
    src = open(L.__file__).read()
    assert "q8_matmul_ref" not in src


def test_q8_forward_routes_by_budget():
    """Acceptance: generous budget -> Pallas wrapper; 0-byte budget ->
    host path; identical outputs (bf16, atol<=1e-2)."""
    from repro.models.layers import mlp
    d, ff = 64, 128
    params = {
        "up": jax.random.normal(jax.random.fold_in(KEY, 10), (d, ff)) / 8,
        "gate": jax.random.normal(jax.random.fold_in(KEY, 11), (d, ff)) / 8,
        "down": jax.random.normal(jax.random.fold_in(KEY, 12), (ff, d)) / 8,
    }
    q8 = quantize_tree(params)
    x = jax.random.normal(jax.random.fold_in(KEY, 13), (2, 4, d),
                          jnp.bfloat16)

    with use_context(LOOSE):
        y_accel = mlp(q8, x)
    c = dispatch_counters()
    assert c[("q8_matmul", "accel", "pallas")] == 3, dict(c)

    reset_dispatch_log()
    with use_context(ZERO):
        y_host = mlp(q8, x)
    c = dispatch_counters()
    assert sum(v for (op, dec, b), v in c.items()
               if op == "q8_matmul" and dec == "host" and b in ("xla", "ref")
               ) == 3, dict(c)
    assert not any(b == "pallas" for (_, _, b) in c), dict(c)
    np.testing.assert_allclose(np.asarray(y_accel, jnp.float32),
                               np.asarray(y_host, jnp.float32),
                               atol=1e-2, rtol=1e-2)


# ----------------------------------------------- flash attention dispatch

def test_flash_attention_backend_sweep():
    b, s, h, hkv, dh = 2, 64, 4, 2, 32
    q = jax.random.normal(jax.random.fold_in(KEY, 20), (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 21), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 22), (b, s, hkv, dh))
    outs = {}
    for backend in ("ref", "xla", "pallas"):
        with use_context(_force(backend)):
            outs[backend] = np.asarray(
                dispatch("flash_attention", q, k, v, causal=True),
                np.float32)
    np.testing.assert_allclose(outs["xla"], outs["ref"], rtol=2e-2,
                               atol=2e-2)
    np.testing.assert_allclose(outs["pallas"], outs["ref"], rtol=2e-3,
                               atol=2e-3)


def test_attention_module_routes_through_dispatch():
    """models.attention's train path must go through the dispatcher."""
    from repro.configs import get_config, reduced
    from repro.models.attention import attention, init_attention
    from repro.models.layers import KeyGen
    cfg = reduced(get_config("qwen3-4b"))
    p = jax.tree.map(lambda t: t.value if hasattr(t, "value") else t,
                     init_attention(KeyGen(KEY), cfg),
                     is_leaf=lambda t: hasattr(t, "value"))
    x = jax.random.normal(jax.random.fold_in(KEY, 30),
                          (1, 16, cfg.d_model), jnp.bfloat16)
    y, _ = attention(p, x, cfg, mode="train")
    c = dispatch_counters()
    assert any(op == "flash_attention" for (op, _, _) in c), dict(c)
    assert y.shape == (1, 16, cfg.d_model)


# --------------------------------------------------------- serving plumbing

def test_serve_engine_accepts_dispatch_ctx():
    from repro.configs import get_config, reduced
    from repro.models.model import build
    from repro.serving.engine import Request, ServeEngine
    cfg = reduced(get_config("qwen3-4b"))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))
    eng = ServeEngine(model, params, n_slots=2, max_len=64,
                      dispatch_ctx=DispatchContext(vmem_budget=0))
    st = eng.admit(Request(uid=0, tokens=[5, 6, 7], max_new=2, eos_id=-1))
    assert st is not None
    eng.step()
    rep = eng.dispatch_report()
    counters = rep["counters"]
    assert any(dec == "host" for (_, dec, _) in counters), counters
    assert not any(b == "pallas" for (_, _, b) in counters), counters
    assert rep["cache"]["cache_dtype"] == "bf16"
    assert rep["cache"]["traffic_ratio_vs_bf16"] == 1.0
