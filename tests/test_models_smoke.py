"""Per-arch reduced-config smoke tests: forward + train step on CPU.

Every assigned architecture (+ the paper's whisper-tiny.en) instantiates
a REDUCED config of the same family and runs one forward and one train
step, asserting output shapes and finiteness (brief requirement f).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.data.synthetic import batch_for_step
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, make_train_step

ARCHS = list_archs()
SEQ, BATCH = 32, 2


def _batch(cfg):
    b = batch_for_step(cfg, SEQ, BATCH, seed=0, step=0)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))
    batch = _batch(cfg)
    logits, _ = model.forward(params, batch, mode="train")
    from repro.models.layers import pad_vocab
    assert logits.shape[0] == BATCH
    assert logits.shape[-1] == pad_vocab(cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    # vocab padding masked to large negatives
    assert float(logits[..., cfg.vocab:].max()) < -1e8


# tier-1 keeps the paper's models + one dense representative; the full
# per-arch train-step sweep (~90 s) runs under the slow marker
_TRAIN_STEP_FAST = {"qwen3-4b", "whisper-tiny-en", "whisper-base"}


@pytest.mark.parametrize("arch", [
    a if a in _TRAIN_STEP_FAST else pytest.param(a, marks=pytest.mark.slow)
    for a in ARCHS])
def test_train_step_decreases_nothing_nan(arch):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3,
                                                      total_steps=10)))
    batch = _batch(cfg)
    s1, m1 = step(state, batch)
    s2, m2 = step(s1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    # same batch twice: the optimizer must make progress on it
    assert float(m2["loss"]) < float(m1["loss"]) + 0.1
    assert float(m1["grad_norm"]) > 0.0


@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma2-2b", "mixtral-8x7b",
                                  pytest.param("zamba2-7b",
                                               marks=pytest.mark.slow),
                                  "xlstm-350m", "whisper-base",
                                  "llava-next-34b"])
def test_prefill_decode_equals_forward(arch):
    """prefill(tokens[:-1]) + decode(last) ≡ full forward (family-wide).

    MoE: capacity_factor is raised so no token is capacity-dropped —
    prefill (n-1 tokens) and full forward (n) otherwise make *different*
    capacity cuts, which is correct-but-unequal routing behaviour."""
    import dataclasses
    cfg = reduced(get_config(arch))
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = build(cfg)
    params = model.init_values(jax.random.key(1))
    batch = _batch(cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape

    full_batch = dict(batch)
    logits_full, _ = model.forward(params, full_batch, mode="train")

    # prefill on tokens[:, :-1]
    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, :-1]
    cache = model.init_cache(
        b, s + cfg.n_img_tokens + 8,   # VLM: image prefix occupies cache
        enc_len=batch.get(
            "enc_frames", jnp.zeros((1, 8, 1))).shape[1]
        if cfg.enc_dec else 1500,
        dtype=jnp.float32)   # exact state carry (prod uses bf16)
    logits_pre, cache = model.forward(params, pre_batch, mode="prefill",
                                      cache=cache)
    # decode the final token at its position
    pos = s - 1
    if cfg.vlm:
        pos = cfg.n_img_tokens + s - 1
    logits_dec, _ = model.forward(params, {"tokens": tokens[:, -1:]},
                                  mode="decode", cache=cache,
                                  pos=jnp.asarray(pos, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=5e-2, atol=5e-2)


def test_ssd_chunked_equals_recurrent():
    """zamba2's chunked SSD scan ≡ step-by-step recurrence."""
    from repro.models import ssm
    from repro.models.layers import KeyGen, split_params
    cfg = reduced(get_config("zamba2-7b"))
    keys = KeyGen(jax.random.key(3))
    params, _ = split_params(ssm.init_mamba(keys, cfg))
    x = jax.random.normal(jax.random.key(4), (2, 24, cfg.d_model),
                          jnp.float32) * 0.5
    y_par, _ = ssm.mamba_block(params, x, cfg, mode="train")
    y_rec = ssm.mamba_recurrent_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_rec, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_mlstm_chunked_equals_recurrent():
    """xlstm's chunked-parallel mLSTM ≡ recurrent stepping."""
    from repro.models import xlstm
    from repro.models.layers import KeyGen, split_params
    cfg = reduced(get_config("xlstm-350m"))
    keys = KeyGen(jax.random.key(5))
    params, _ = split_params(xlstm.init_mlstm(keys, cfg))
    x = jax.random.normal(jax.random.key(6), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    y_par, _ = xlstm.mlstm_block(params, x, cfg, mode="train")
    cache = xlstm.init_mlstm_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(16):
        y, cache = xlstm.mlstm_block(params, x[:, t:t + 1], cfg,
                                     mode="decode", cache=cache, pos=t)
        ys.append(y)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_rec, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_moe_capacity_and_balance():
    """MoE: outputs finite at tight capacity; balance loss near 1 when
    router is uniform-random."""
    from repro.models import moe
    from repro.models.layers import KeyGen, split_params
    cfg = reduced(get_config("qwen3-moe-30b-a3b"))
    keys = KeyGen(jax.random.key(7))
    params, _ = split_params(moe.init_moe(keys, cfg))
    x = jax.random.normal(jax.random.key(8), (2, 16, cfg.d_model)) * 0.5
    y = moe.moe_ffn(params, x, cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    lb = moe.load_balance_loss(params, x, cfg)
    assert 0.5 < float(lb) < 3.0


def test_gqa_repeat_matches_explicit():
    from repro.models.attention import _repeat_kv
    k = jax.random.normal(jax.random.key(9), (2, 8, 2, 16))
    k4 = _repeat_kv(k, 4)
    assert k4.shape == (2, 8, 4, 16)
    np.testing.assert_array_equal(np.asarray(k4[:, :, 0]),
                                  np.asarray(k4[:, :, 1]))


def test_per_lane_decode_positions():
    """Vector pos ≡ scalar pos when all lanes share the position."""
    cfg = reduced(get_config("qwen3-4b"))
    model = build(cfg)
    params = model.init_values(jax.random.key(10))
    b, s = 3, 8
    toks = jax.random.randint(jax.random.key(11), (b, s), 0, cfg.vocab)
    cache = model.init_cache(b, 32)
    _, cache = model.forward(params, {"tokens": toks}, mode="prefill",
                             cache=cache)
    nxt = jax.random.randint(jax.random.key(12), (b, 1), 0, cfg.vocab)
    l_scalar, _ = model.forward(params, {"tokens": nxt}, mode="decode",
                                cache=cache, pos=jnp.asarray(s))
    l_vec, _ = model.forward(params, {"tokens": nxt}, mode="decode",
                             cache=cache,
                             pos=jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(l_scalar, np.float32),
                               np.asarray(l_vec, np.float32),
                               rtol=1e-4, atol=1e-4)
