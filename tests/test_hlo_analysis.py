"""HLO cost model: scan trip counts, dot flops, collectives, narrowing."""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import analyze_hlo, _shape_bytes, _shape_dims


def _compile_text(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_shape_parsing():
    assert _shape_bytes("bf16[4,8]{1,0}") == 64
    assert _shape_bytes("(f32[2,2], s8[4])") == 20
    assert _shape_bytes("f32[]") == 4
    assert _shape_dims("f32[3,5,7]{2,1,0}") == [3, 5, 7]


def test_plain_matmul_flops():
    f = lambda a, b: a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = analyze_hlo(_compile_text(f, a, b))
    assert c.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.05)


def test_scan_flops_multiplied_by_trip_count():
    def f(x, ws):
        return jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), None), x, ws)[0]
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    c = analyze_hlo(_compile_text(f, x, ws))
    want = 2 * 32 * 64 * 64 * 12
    assert want <= c.flops <= want * 1.1
    assert not c.unknown_trip_loops


def test_nested_scan_multiplies():
    def inner(h, w):
        return jax.lax.scan(lambda hh, _: (jnp.tanh(hh @ w), None), h,
                            None, length=3)[0]
    def f(x, ws):
        return jax.lax.scan(lambda h, w: (inner(h, w), None), x, ws)[0]
    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    c = analyze_hlo(_compile_text(f, x, ws))
    want = 2 * 16 * 32 * 32 * 3 * 4
    assert want * 0.9 <= c.flops <= want * 1.2, (c.flops, want)


def test_scan_weight_slice_bytes_narrowed():
    """Stacked weights read via in-loop dynamic-slice must charge one
    slice per trip, not the whole stack per trip."""
    def f(x, ws):
        return jax.lax.scan(lambda h, w: (h @ w, None), x, ws)[0]
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((100, 64, 64), jnp.float32)
    c = analyze_hlo(_compile_text(f, x, ws))
    full_stack_per_trip = 100 * (100 * 64 * 64 * 4)
    assert c.bytes < full_stack_per_trip / 5, c.bytes


def test_batched_dot_flops():
    f = lambda a, b: jnp.einsum("bik,bkj->bij", a, b)
    a = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 8), jnp.float32)
    c = analyze_hlo(_compile_text(f, a, b))
    assert c.flops == pytest.approx(2 * 4 * 16 * 32 * 8, rel=0.05)


def test_gather_bytes_sparse():
    """Embedding lookups charge output-size, not table-size."""
    f = lambda t, i: jnp.take(t, i, axis=0)
    t = jax.ShapeDtypeStruct((50_000, 64), jnp.float32)
    i = jax.ShapeDtypeStruct((8,), jnp.int32)
    c = analyze_hlo(_compile_text(f, t, i))
    assert c.bytes < 50_000 * 64 * 4 / 10, c.bytes


def test_collectives_detected_in_subprocess():
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis.hlo import analyze_hlo
mesh = jax.make_mesh((4,), ("d",))
def f(x):
    return jnp.sum(x, axis=0)
x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
comp = jax.jit(f, in_shardings=NamedSharding(mesh, P("d")),
               out_shardings=NamedSharding(mesh, P())).lower(x).compile()
c = analyze_hlo(comp.as_text())
assert "all-reduce" in c.collectives or "all-gather" in c.collectives, \\
    c.collectives
print("OK", c.collectives)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd="/root/repo", timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_roofline_terms_and_dominance():
    from repro.analysis.roofline import roofline_from_hlocost
    from repro.analysis.hlo import HloCost
    hc = HloCost(flops=1e12, bytes=1e10, collective_bytes=1e8,
                 collectives={"all-reduce": 1e8}, collective_counts={},
                 unknown_trip_loops=[], unknown_customcalls=[])
    rl = roofline_from_hlocost(hc, arch="x", shape="y", mesh="16x16",
                               chips=256, model_flops=2e14)
    assert rl.compute_s == pytest.approx(1e12 / 197e12)
    assert rl.memory_s == pytest.approx(1e10 / 819e9)
    assert rl.collective_s == pytest.approx(1e8 / 50e9)
    assert rl.dominant == "memory"
    assert rl.hlo_flops == pytest.approx(1e12 * 256)
    assert rl.useful_flops_ratio == pytest.approx(2e14 / (1e12 * 256))
