"""Paged KV/cross-KV cache subsystem (``repro.paging``): allocator /
table / prefix-store invariants, copy-on-write sharing, and paged
serving parity with the dense slot pool.

The engine-level tests pin the tentpole contract: a ``paged=True``
``ServeEngine`` is **token-identical** to the slot engine for the same
requests — one-shot (bf16 and q8_0), streaming with mid-stream cross-KV
extension, EOS inside a fused decode block, and the async gateway —
while holding per-request page extents instead of ``max_len`` slots.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import build
from repro.paging import (PageAllocError, PagePool, PageTable, PagedKV,
                          PrefixStore, SCRATCH_PAGE, pages_needed)
from repro.serving.engine import (AudioRequest, RejectCode,
                                  RejectionError, ServeEngine,
                                  StreamingAudioRequest)

P = 8


# ------------------------------------------------------------- allocator
def test_pool_alloc_free_refcount():
    pool = PagePool(8, P)
    assert pool.free_pages == 7            # page 0 is reserved scratch
    a = pool.alloc(3)
    assert len(set(a)) == 3 and SCRATCH_PAGE not in a
    assert pool.used_pages == 3 and pool.free_pages == 4
    pool.retain(a[0])
    assert pool.refcount(a[0]) == 2
    pool.free(a[0])                        # drops to 1, still allocated
    assert pool.refcount(a[0]) == 1 and pool.used_pages == 3
    pool.free_all(a)
    assert pool.used_pages == 0 and pool.free_pages == 7
    pool.check()


def test_pool_double_free_and_oom():
    pool = PagePool(4, P)
    a = pool.alloc(3)
    with pytest.raises(PageAllocError):
        pool.alloc(1)
    assert pool.try_alloc(1) is None
    pool.free(a[0])
    with pytest.raises(RuntimeError):
        pool.free(a[0])
    with pytest.raises(RuntimeError):
        pool.retain(a[0])                  # unallocated page
    pool.retain(SCRATCH_PAGE)              # scratch is a no-op
    pool.free_all(a[1:])
    pool.check()


def test_pool_seeded_random_ops_never_leak():
    """Deterministic random alloc/retain/free sequence against a shadow
    refcount model: no leak, no double-free, everything drains to zero.
    (The hypothesis-driven version lives in test_paging_properties.py.)
    """
    rng = np.random.default_rng(42)
    pool = PagePool(16, P)
    shadow: dict[int, int] = {}            # page -> refcount
    for _ in range(400):
        op = rng.integers(0, 3)
        if op == 0:
            k = int(rng.integers(1, 4))
            got = pool.try_alloc(k)
            if got is None:
                assert pool.free_pages < k
            else:
                for pg in got:
                    assert pg not in shadow
                    shadow[pg] = 1
        elif op == 1 and shadow:
            pg = int(rng.choice(list(shadow)))
            pool.retain(pg)
            shadow[pg] += 1
        elif op == 2 and shadow:
            pg = int(rng.choice(list(shadow)))
            pool.free(pg)
            shadow[pg] -= 1
            if shadow[pg] == 0:
                del shadow[pg]
        assert pool.used_pages == len(shadow)
        for pg, n in shadow.items():
            assert pool.refcount(pg) == n
        pool.check()
    for pg, n in list(shadow.items()):
        for _ in range(n):
            pool.free(pg)
    assert pool.used_pages == 0 and pool.free_pages == 15
    pool.check()


# ------------------------------------------------------------ page table
def test_table_rows_device_cache_and_adopt():
    t = PageTable(n_slots=2, max_len=32, page_size=P)
    assert t.row(0) == [SCRATCH_PAGE] * 4
    t.set_row(0, [3, 5])
    assert t.row(0) == [3, 5, SCRATCH_PAGE, SCRATCH_PAGE]
    assert t.lookup(0, 9) == (5, 1)
    d1 = t.device()
    assert d1 is t.device()                # cached between mutations
    np.testing.assert_array_equal(
        np.asarray(d1), [[3, 5, 0, 0], [0, 0, 0, 0]])
    v = t.version
    fake = d1 + 0
    t.adopt(fake, v)                       # same version: installed
    assert t.device() is fake
    t.set_entry(1, 0, 7)                   # mutation invalidates
    assert t.version != v
    t.adopt(fake, v)                       # stale adopt: ignored
    assert np.asarray(t.device())[1, 0] == 7
    with pytest.raises(ValueError):
        t.set_row(0, [1, 2, 3, 4, 5])


# ---------------------------------------------------------- prefix store
def test_prefix_store_share_and_evict_on_free():
    pool = PagePool(8, P)
    store = PrefixStore(pool)
    donor = pool.alloc(2)
    store.publish(("k",), donor)
    got = store.lookup(("k",))
    assert got == donor and pool.refcount(donor[0]) == 2
    assert store.lookup(("other",)) is None
    st = store.stats()
    assert st["entries"] == 1 and st["hits"] == 1 and st["misses"] == 1
    pool.free_all(got)                     # sharer releases
    assert store.lookup(("k",)) == donor   # still indexed
    pool.free_all(donor)                   # re-lookup's + donor's refs
    pool.free_all(donor)
    assert store.stats()["entries"] == 0   # evicted when refs hit zero
    assert store.lookup(("k",)) is None
    pool.check()


# -------------------------------------------------------------- manager
def test_manager_admit_share_cow_and_drain():
    kv = PagedKV(n_slots=4, max_len=32, enc_len=16, page_size=P,
                 n_pages=16, n_cross_pages=8)
    anchor = list(range(P))                # one full shareable page
    a = kv.admit_lane(0, anchor + [99], "dig", max_new=4, enc_s=8)
    b = kv.admit_lane(1, anchor + [55], "dig", max_new=4, enc_s=8)
    assert a.self_pages[0] == b.self_pages[0]          # anchor shared
    assert a.self_pages[1] != b.self_pages[1]          # tails private
    assert kv.self_pool.refcount(a.self_pages[0]) == 2
    assert a.cross_pages == b.cross_pages              # same audio
    c = kv.admit_lane(2, anchor + [99], "other", max_new=4, enc_s=8)
    assert c.self_pages[0] != a.self_pages[0]   # digest keys the prompt
    assert c.cross_pages != a.cross_pages

    # COW: lane 1 must clone before writing its shared anchor page
    copies = []
    res = kv.ensure_writable(1, 0, copier=lambda o, n: copies.append((o, n)))
    old, new = res
    assert copies == [(old, new)] and kv.self_table.entry(1, 0) == new
    assert kv.self_pool.refcount(old) == 1             # lane 0 only
    assert kv.ensure_writable(1, 0) is None            # now exclusive

    for slot in (0, 1, 2):
        kv.free_lane(slot)
    assert kv.self_pool.used_pages == 0
    assert kv.cross_pool.used_pages == 0
    assert kv.self_prefix.stats()["entries"] == 0      # evicted
    kv.check()


def test_manager_oom_rollback_and_stream_extend():
    kv = PagedKV(n_slots=2, max_len=64, enc_len=32, page_size=P,
                 n_pages=4, n_cross_pages=3)           # 3 self, 2 cross
    kv.admit_lane(0, [1, 2, 3], "d0", max_new=10, enc_s=8)   # 2s + 1c
    free0 = (kv.self_pool.free_pages, kv.cross_pool.free_pages)
    with pytest.raises(PageAllocError):
        kv.admit_lane(1, [1, 2, 3], "d1", max_new=10, enc_s=16)  # 2s+2c
    # full rollback: nothing retained by the failed admit
    assert (kv.self_pool.free_pages, kv.cross_pool.free_pages) == free0
    assert 1 not in kv.lanes

    ln = kv.admit_stream_lane(1)
    phys, off = kv.extend_cross(1, 0, 5)
    assert len(phys) == 5 and off == [0, 1, 2, 3, 4]
    phys2, _ = kv.extend_cross(1, 5, 3)                # same page
    assert set(phys2) <= set(ln.cross_pages)
    with pytest.raises(PageAllocError):
        kv.extend_cross(1, 8, 8)                       # pool dry
    assert ln.cross_len == 8                           # unchanged extent
    kv.free_lane(0)
    kv.free_lane(1)
    assert kv.self_pool.used_pages == kv.cross_pool.used_pages == 0
    kv.check()


def test_pages_needed():
    assert pages_needed(0, P) == 0
    assert pages_needed(1, P) == 1
    assert pages_needed(8, P) == 1
    assert pages_needed(9, P) == 2


# ----------------------------------------------------- engine parity rig
MAX_LEN = 64
ENC_LEN = 16


@pytest.fixture(scope="module")
def rig():
    cfg = dataclasses.replace(
        reduced(get_config("whisper-tiny-en")),
        d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, vocab=256,
        enc_layers=1, n_layers=1)
    model = build(cfg)
    return cfg, model, model.init_values(jax.random.key(0))


def _engines(rig, cache_dtype="bf16", **kw):
    cfg, model, params = rig
    mk = lambda paged: ServeEngine(
        model, params, n_slots=4, max_len=MAX_LEN, enc_len=ENC_LEN,
        cache_dtype=cache_dtype, paged=paged, page_size=P, **kw)
    return mk(False), mk(True)


def _frames(s, d_model=64, seed=7):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((s, d_model)).astype(np.float32) * 0.5


def _drain(eng):
    while eng.n_active:
        eng.step()


@pytest.mark.parametrize("cache_dtype", ["bf16", "q8_0"])
def test_paged_oneshot_parity(rig, cache_dtype):
    """Paged decode is token-identical to the slot pool for bf16 AND
    q8_0 caches (the paged xla backend mirrors the dense chain
    bit-for-bit over gathered pages)."""
    slot, paged = _engines(rig, cache_dtype)
    prompts = [[5, 6, 7, 8], [9, 10, 11], [3, 4, 5, 6, 7, 8, 9, 10, 2]]
    outs = {}
    for name, eng in (("slot", slot), ("paged", paged)):
        sts = [eng.admit(AudioRequest(
            uid=i, tokens=list(p), max_new=6, eos_id=-2,
            enc_frames=_frames(6 + 2 * i, seed=i)))
            for i, p in enumerate(prompts)]
        _drain(eng)
        outs[name] = [st.out for st in sts]
    assert outs["paged"] == outs["slot"]
    assert paged.pages.self_pool.used_pages == 0       # drained clean
    assert paged.pages.cross_pool.used_pages == 0
    paged.pages.check()


def test_paged_streaming_parity_midstream_extension(rig):
    """Streaming lanes extend their cross-KV pages chunk by chunk
    mid-stream; partial hypotheses and the final transcript match the
    slot engine exactly."""
    slot, paged = _engines(rig)
    chunks = [_frames(8, seed=s) for s in (1, 2)]
    res = {}
    for name, eng in (("slot", slot), ("paged", paged)):
        req = StreamingAudioRequest(uid=0, tokens=[5, 6, 7], max_new=6,
                                    eos_id=-2, chunks=chunks)
        st = eng.open_stream(req)
        for c in chunks:
            eng.stream_feed(st, c)
            eng.step()
            eng.step()
        st = eng.stream_finalize(st)
        _drain(eng)
        res[name] = (st.out, st.partials)
    assert res["paged"] == res["slot"]
    assert paged.pages.self_pool.used_pages == 0
    paged.pages.check()


def test_paged_eos_mid_block_parity(rig):
    """A lane hitting EOS inside a fused decode block freezes at the
    same token under both pool layouts (emit-mask replay parity)."""
    slot, paged = _engines(rig, decode_block=4)
    # hotter frames: the micro model's greedy output actually varies,
    # so an EOS pick strictly inside the first fused block exists
    fr = np.random.default_rng(11).standard_normal(
        (8, 64)).astype(np.float32) * 1.5
    ref = slot.admit(AudioRequest(uid=0, tokens=[5, 6, 7], max_new=8,
                                  eos_id=-2, enc_frames=fr))
    _drain(slot)
    assert len(ref.out) == 8
    # an emitted token that differs from the prefill's first token, so
    # the EOS fires inside a fused block (not at admit)
    eos = next((t for t in ref.out[1:] if t != ref.out[0]), None)
    if eos is None:
        pytest.skip("degenerate greedy output: no mid-block EOS pick")
    stop_at = ref.out.index(eos) + 1
    outs = {}
    for name, eng in (("slot", slot), ("paged", paged)):
        st = eng.admit(AudioRequest(uid=1, tokens=[5, 6, 7], max_new=8,
                                    eos_id=eos, enc_frames=fr))
        _drain(eng)
        outs[name] = st.out
    assert outs["paged"] == outs["slot"]
    assert outs["paged"][-1] == eos and len(outs["paged"]) == stop_at


def test_paged_gateway_parity(rig):
    """The async gateway over a paged engine is token-identical to the
    synchronous scheduler over a slot engine (same mixed one-shot /
    streaming workload), with one host sync per tick."""
    from repro.gateway import LoadSpec, run_load, sync_baseline, synth_load

    cfg, _, _ = rig
    slot, paged = _engines(rig, decode_block=4)
    spec = LoadSpec(rate_rps=300.0, n_requests=12, seed=0,
                    stream_fraction=0.3)
    descs = synth_load(cfg, spec)
    baseline = sync_baseline(slot, descs)
    results, summary, _ = run_load(paged, spec, shed_on_submit=False)
    assert all(r.ok for r in results), \
        [(r.uid, r.code, r.error) for r in results if not r.ok]
    for d, r in zip(descs, results):
        assert list(r.tokens) == baseline[d.idx], f"desc {d.idx}"
    assert paged._host_syncs == paged._ticks
    assert paged.pages.self_pool.used_pages == 0


def test_paged_pool_exhaustion_codes(rig):
    """Permanent page-demand overflow rejects at validate with
    POOL_EXHAUSTED; transient exhaustion returns None from admit (the
    scheduler's retry contract) and admits once pages drain."""
    cfg, model, params = rig
    eng = ServeEngine(model, params, n_slots=4, max_len=MAX_LEN,
                      enc_len=ENC_LEN, paged=True, page_size=P,
                      n_pages=4, n_cross_pages=3)   # 3 self, 2 cross
    fr = _frames(8)
    # permanent: 4 self pages demanded > 3 in the whole pool
    rej = eng.validate(AudioRequest(uid=0, tokens=[1] * 9, max_new=16,
                                    eos_id=-1, enc_frames=fr))
    assert rej is not None and rej.code == RejectCode.POOL_EXHAUSTED
    # transient: first lane takes 2 of 3 self pages; the second 2-page
    # request must wait (None), then admit after the drain
    st = eng.admit(AudioRequest(uid=1, tokens=[1, 2, 3], max_new=8,
                                eos_id=-1, enc_frames=fr))
    assert st is not None
    blocked = AudioRequest(uid=2, tokens=[4, 5, 6], max_new=8,
                           eos_id=-1, enc_frames=_frames(8, seed=9))
    assert eng.admit(blocked) is None
    assert len(eng.free) == 4 - 1          # the popped slot was returned
    _drain(eng)
    assert eng.admit(blocked) is not None
    _drain(eng)


def test_paged_midstream_pool_exhaustion(rig):
    """A stream whose next chunk cannot get cross pages sheds with
    POOL_EXHAUSTED (not a crash, not silent truncation)."""
    cfg, model, params = rig
    eng = ServeEngine(model, params, n_slots=2, max_len=32,
                      enc_len=ENC_LEN, paged=True, page_size=P,
                      n_pages=9, n_cross_pages=3)    # TWO usable pages
    # a resident one-shot lane holds one cross page, so the stream
    # passes validate (2 pages could fit an empty pool) but starves
    # mid-flight
    resident = eng.admit(AudioRequest(uid=9, tokens=[1, 2], max_new=32 - 8,
                                      eos_id=-1,
                                      enc_frames=_frames(8, seed=5)))
    assert resident is not None
    req = StreamingAudioRequest(uid=0, tokens=[5, 6], max_new=4,
                                eos_id=-2,
                                chunks=[_frames(8), _frames(8, seed=8)])
    st = eng.open_stream(req)
    eng.stream_feed(st, req.chunks[0])               # takes the last page
    with pytest.raises(RejectionError) as ei:
        eng.stream_feed(st, req.chunks[1])
    assert ei.value.rejection.code == RejectCode.POOL_EXHAUSTED
    eng.abort(st)
    _drain(eng)
    assert eng.pages.cross_pool.used_pages == 0


def test_paged_prefix_refcount_matches_lanes(rig):
    """N lanes admitted with the same anchor prompt + audio hold ONE
    physical copy of the anchor page, refcounted N times; freeing every
    lane drains both pools to zero."""
    cfg, model, params = rig
    eng = ServeEngine(model, params, n_slots=4, max_len=MAX_LEN,
                      enc_len=ENC_LEN, paged=True, page_size=P)
    fr = _frames(8)
    anchor = list(range(3, 3 + P))
    sts = [eng.admit(AudioRequest(uid=i, tokens=list(anchor), max_new=4,
                                  eos_id=-2, enc_frames=fr))
           for i in range(4)]
    pages = {eng.pages.lanes[st.slot].self_pages[0] for st in sts}
    assert len(pages) == 1
    assert eng.pages.self_pool.refcount(pages.pop()) == 4
    rep = eng.paging_report()
    assert rep["prefix"]["self"]["hits"] == 3
    assert rep["prefix"]["cross"]["hits"] == 3
    assert rep["resident_lanes"] == 4
    _drain(eng)
    outs = [st.out for st in sts]
    assert all(o == outs[0] for o in outs)   # shared pages uncorrupted
    assert eng.pages.self_pool.used_pages == 0
    assert eng.pages.cross_pool.used_pages == 0
    eng.pages.check()


def test_paged_cache_report_prices_resident_bytes(rig):
    """bytes_per_step on a paged engine counts mapped pages only — and
    an idle pool streams zero cache bytes."""
    cfg, model, params = rig
    eng = ServeEngine(model, params, n_slots=4, max_len=MAX_LEN,
                      enc_len=ENC_LEN, paged=True, page_size=P)
    assert eng.cache_report()["bytes_per_step"] == 0
    st = eng.admit(AudioRequest(uid=0, tokens=[5, 6, 7], max_new=4,
                                eos_id=-2, enc_frames=_frames(8)))
    rep = eng.cache_report()
    pg = rep["paging"]
    assert rep["bytes_per_step"] == pg["resident_kv_bytes"] > 0
    assert pg["self"]["pages_in_use"] == 1   # ceil((3+4)/8)
    assert pg["cross"]["pages_in_use"] == 1
    _drain(eng)
    assert eng.cache_report()["bytes_per_step"] == 0
    assert st.out  # request actually ran
