"""Async gateway: SLO admission, shedding, lifecycle edge cases, and
token parity with the synchronous scheduler.

One module-scoped micro-whisper engine serves every test (jits compile
once; per-lane cache isolation means engine reuse cannot leak tokens
between tests — each test drains the pool). Tests drive asyncio via
``asyncio.run`` inside plain functions (no plugin dependency).
"""

import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.gateway import (INTERACTIVE, STANDARD, AdmissionQueue, Gateway,
                           LoadSpec, SLOClass, poisson_arrivals, run_load,
                           sync_baseline, synth_load)
from repro.models.model import build
from repro.serving.engine import (AudioRequest, RejectCode, Request,
                                  ServeEngine)
from repro.serving.scheduler import BatchScheduler, SchedulerStuckError

MAX_LEN = 64
ENC_LEN = 16


@pytest.fixture(scope="module")
def rig():
    cfg = dataclasses.replace(
        reduced(get_config("whisper-tiny-en")),
        d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, vocab=256,
        enc_layers=1, n_layers=1)
    model = build(cfg)
    params = model.init_values(jax.random.key(0))
    engine = ServeEngine(model, params, n_slots=4, max_len=MAX_LEN,
                         enc_len=ENC_LEN, decode_block=4)
    return cfg, engine


def _frames(s, d_model=64, seed=7):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((s, d_model)).astype(np.float32) * 0.02


# ---------------------------------------------------------------- parity
def test_gateway_parity_32_concurrent(rig):
    """>= 32 concurrent mixed one-shot/streaming requests through the
    async gateway are token-identical to the synchronous FCFS
    BatchScheduler, with exactly one host sync per fused tick."""
    cfg, engine = rig
    spec = LoadSpec(rate_rps=500.0, n_requests=32, seed=0,
                    stream_fraction=0.3)
    descs = synth_load(cfg, spec)
    baseline = sync_baseline(engine, descs)
    assert engine.n_active == 0
    results, summary, _ = run_load(engine, spec, shed_on_submit=False)
    assert all(r.ok for r in results), \
        [(r.uid, r.code, r.error) for r in results if not r.ok]
    for d, r in zip(descs, results):
        assert list(r.tokens) == baseline[d.idx], f"desc {d.idx}"
    assert summary["completed"] == 32 and summary["shed_total"] == 0
    assert engine._host_syncs == engine._ticks
    assert engine.n_active == 0 and len(engine.free) == engine.n_slots


# ----------------------------------------------------- lifecycle edges
def test_cancel_mid_stream_frees_slot_and_reanchors(rig):
    """Cancelling a streaming session mid-flight frees its lane, and a
    subsequent request on the same engine still matches the clean
    reference (no state leaks from the aborted lane)."""
    cfg, engine = rig
    fr = _frames(8)
    # clean reference for the follow-up request
    st_ref = engine.admit(AudioRequest(uid=900, tokens=[1, 5], max_new=6,
                                       eos_id=-1, enc_frames=fr))
    while engine.n_active:
        engine.step()
    ref = list(st_ref.out)

    async def go():
        async with Gateway(engine, shed_on_submit=False) as gw:
            sess = await gw.open_session(tokens=[1], max_new=30,
                                         slo=INTERACTIVE)
            await sess.feed(_frames(4, seed=1))
            for _ in range(50):       # let the lane actually decode
                await asyncio.sleep(0.01)
                if sess.partials:
                    break
            assert sess.partials, "stream never anchored"
            r = await sess.cancel()
            assert not r.ok and r.code is RejectCode.CANCELLED
            # the freed lane serves the follow-up token-identically
            r2 = await gw.submit_audio(frames=fr, tokens=[1, 5],
                                       max_new=6, slo=STANDARD)
            assert r2.ok and list(r2.tokens) == ref
        assert engine.n_active == 0
        assert len(engine.free) == engine.n_slots

    asyncio.run(go())


def test_client_timeout_mid_flight_frees_slot(rig):
    cfg, engine = rig

    async def go():
        async with Gateway(engine, shed_on_submit=False) as gw:
            r = await gw.submit_audio(frames=_frames(8), tokens=[1],
                                      max_new=40, slo=STANDARD,
                                      timeout_s=1e-3)
            assert not r.ok and r.code is RejectCode.TIMEOUT
        assert engine.n_active == 0
        assert len(engine.free) == engine.n_slots

    asyncio.run(go())


def test_deadline_miss_sheds_before_prefill(rig):
    """A request whose deadline passes while queued is shed at pop time
    — before any prefill compute is spent on it."""
    cfg, engine = rig
    tight = SLOClass("tight", priority=0, deadline_s=1e-6)

    async def go():
        async with Gateway(engine, shed_on_submit=False) as gw:
            r = await gw.submit_audio(frames=_frames(8), tokens=[1],
                                      max_new=4, slo=tight)
            assert not r.ok and r.code is RejectCode.DEADLINE_MISSED
            assert r.record.admit_t is None      # never prefilled
        assert engine.n_active == 0

    asyncio.run(go())


def test_queue_full_backpressure_sheds(rig):
    """Bounded admission queue: with admissions frozen, the request
    past the limit is shed QUEUE_FULL instead of growing a backlog."""
    cfg, engine = rig

    async def go():
        # max_admit_per_tick=0 freezes admission: queue fills exactly
        gw = Gateway(engine, queue_limit=2, max_admit_per_tick=0,
                     shed_on_submit=False)
        await gw.start()
        try:
            t1 = asyncio.create_task(gw.submit_audio(
                frames=_frames(4), tokens=[1], max_new=2, slo=STANDARD,
                timeout_s=0.5))
            t2 = asyncio.create_task(gw.submit_audio(
                frames=_frames(4), tokens=[1], max_new=2, slo=STANDARD,
                timeout_s=0.5))
            await asyncio.sleep(0.05)            # both queued
            assert gw.n_queued == 2
            r3 = await gw.submit_audio(frames=_frames(4), tokens=[1],
                                       max_new=2, slo=STANDARD)
            assert not r3.ok and r3.code is RejectCode.QUEUE_FULL
            r1, r2 = await t1, await t2          # time out queued
            assert {r1.code, r2.code} == {RejectCode.TIMEOUT}
        finally:
            await gw.close(drain=False)

    asyncio.run(go())


def test_bad_chunk_sheds_session(rig):
    cfg, engine = rig

    async def go():
        async with Gateway(engine, shed_on_submit=False) as gw:
            sess = await gw.open_session(tokens=[1], max_new=4)
            await sess.feed(_frames(4))
            await sess.feed(np.zeros((3, 5), np.float32))   # wrong d_model
            r = await sess.finalize()
            assert not r.ok and r.code is RejectCode.BAD_ENC_SHAPE
            # overflow path: a fresh session streaming past enc_len
            s2 = await gw.open_session(tokens=[1], max_new=4)
            await s2.feed(_frames(ENC_LEN))
            await s2.feed(_frames(4))
            r2 = await s2.finalize()
            assert not r2.ok and r2.code is RejectCode.ENC_OVERFLOW
        assert engine.n_active == 0

    asyncio.run(go())


# ------------------------------------------------------- load generator
def test_poisson_loadgen_deterministic(rig):
    cfg, _ = rig
    a = poisson_arrivals(50.0, 64, seed=3)
    b = poisson_arrivals(50.0, 64, seed=3)
    c = poisson_arrivals(50.0, 64, seed=4)
    assert np.array_equal(a, b) and not np.array_equal(a, c)
    assert np.all(np.diff(a) > 0) and a.shape == (64,)
    spec = LoadSpec(rate_rps=100.0, n_requests=12, seed=5)
    d1, d2 = synth_load(cfg, spec), synth_load(cfg, spec)
    for x, y in zip(d1, d2):
        assert x.arrival_s == y.arrival_s and x.tokens == y.tokens
        assert x.kind == y.kind and x.slo is y.slo
        assert all(np.array_equal(p, q)
                   for p, q in zip(x.chunks, y.chunks))


# ------------------------------------------------------ admission queue
def test_admission_queue_edf_within_priority():
    @dataclasses.dataclass
    class T:
        slo: SLOClass
        deadline_t: float
        cancelled: bool = False

    hi = SLOClass("hi", 0, 1.0)
    lo = SLOClass("lo", 1, 1.0)
    q = AdmissionQueue(limit=4)
    late_hi = T(hi, 9.0)
    early_lo = T(lo, 1.0)
    early_hi = T(hi, 2.0)
    assert q.push(late_hi) and q.push(early_lo) and q.push(early_hi)
    cancelled = T(hi, 0.5, cancelled=True)
    assert q.push(cancelled)
    assert not q.push(T(lo, 3.0))          # full -> backpressure
    q.cancelled_dropped()
    # priority class strict; EDF within class; cancelled skipped
    assert q.pop() is early_hi
    assert q.pop() is late_hi
    assert q.pop() is early_lo
    assert q.pop() is None and len(q) == 0


# ------------------------------------------------ reject codes / drain
def test_validate_reject_codes(rig):
    cfg, engine = rig
    r = engine.validate(Request(uid=0, tokens=[1] * MAX_LEN, max_new=4,
                                eos_id=-1))
    assert r is not None and r.code is RejectCode.TOO_LONG
    r = engine.validate(Request(uid=1, tokens=[1], max_new=4, eos_id=-1))
    assert r is not None and r.code is RejectCode.MISSING_ENC_INPUT
    r = engine.validate(AudioRequest(uid=2, tokens=[1], max_new=4,
                                     eos_id=-1,
                                     enc_frames=_frames(ENC_LEN + 1)))
    assert r is not None and r.code is RejectCode.ENC_OVERFLOW
    assert engine.validate(AudioRequest(uid=3, tokens=[1], max_new=4,
                                        eos_id=-1,
                                        enc_frames=_frames(4))) is None
    # scheduler surfaces the machine-readable code on rejected results
    sched = BatchScheduler(engine)
    st = sched.submit(Request(uid=950, tokens=[1], max_new=4, eos_id=-1))
    assert st.done and st.error_code is RejectCode.MISSING_ENC_INPUT


def test_run_until_drained_raises_when_stuck(rig):
    cfg, engine = rig
    sched = BatchScheduler(engine)
    sched.submit(AudioRequest(uid=960, tokens=[1], max_new=6, eos_id=-1,
                              enc_frames=_frames(4)))
    with pytest.raises(SchedulerStuckError, match="not drained"):
        sched.run_until_drained(max_ticks=0)
    assert sched.run_until_drained(max_ticks=0, strict=False) is False
    assert sched.run_until_drained() is True
    assert sched.drained
