"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; distributed tests fork subprocesses that set their own
device counts (see tests/test_distributed.py)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
