"""Q8_0 quantization: round-trip bound, packing accounting, properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core.quantize import (QBLOCK, Q8_BYTES_PER_ELEM, Q8Tensor,
                                 dequantize_q8_0, pad_to_block,
                                 quantization_error_bound, quantize_q8_0,
                                 quantize_tree, stored_bytes)


def test_roundtrip_error_within_bound():
    x = jax.random.normal(jax.random.key(0), (8, 256), jnp.float32)
    t = quantize_q8_0(x)
    err = jnp.abs(dequantize_q8_0(t) - x)
    # bound: d/2 per element + fp16 scale representation error (~2^-11 rel)
    bound = jnp.repeat(quantization_error_bound(t), QBLOCK, axis=-1)
    bound = bound * 1.01 + 1e-6
    assert bool(jnp.all(err <= bound)), float(jnp.max(err - bound))


def test_quantize_shapes_and_dtypes():
    x = jnp.ones((4, 64), jnp.bfloat16)
    t = quantize_q8_0(x)
    assert t.q.shape == (4, 64) and t.q.dtype == jnp.int8
    assert t.scale.shape == (4, 2) and t.scale.dtype == jnp.float16


def test_quantize_along_axis():
    x = jax.random.normal(jax.random.key(1), (64, 5), jnp.float32)
    t = quantize_q8_0(x, axis=0)
    assert t.q.shape == (64, 5) and t.scale.shape == (2, 5)
    np.testing.assert_allclose(np.asarray(dequantize_q8_0(t, axis=0)),
                               np.asarray(x), atol=0.05)


def test_zero_block_is_exact():
    x = jnp.zeros((1, 32))
    t = quantize_q8_0(x)
    assert float(jnp.max(jnp.abs(dequantize_q8_0(t)))) == 0.0


def test_non_multiple_k_raises_and_pad_fixes():
    x = jnp.ones((2, 33))
    with pytest.raises(ValueError):
        quantize_q8_0(x)
    xp = pad_to_block(x)
    assert xp.shape == (2, 64)
    quantize_q8_0(xp)  # no raise


def test_packed_bytes_ratio():
    x = jnp.ones((16, 320))
    t = quantize_q8_0(x)
    assert t.nbytes_packed == int(x.size * Q8_BYTES_PER_ELEM)


def test_stored_bytes_policies():
    # baseline pads each row to 32B; optimized packs densely
    assert stored_bytes((4, 10), "f16", "baseline") == 4 * 32
    assert stored_bytes((4, 10), "f16", "optimized") == 4 * 20
    assert stored_bytes((1, 32), "q8_0", "optimized") == 34


def test_quantize_tree_selectivity():
    params = {"w": jnp.ones((64, 8)), "norm": jnp.ones((8,)),
              "odd": jnp.ones((33, 5))}
    qt = quantize_tree(params)
    assert isinstance(qt["w"], Q8Tensor)          # K=64 divisible
    assert not isinstance(qt["norm"], Q8Tensor)   # 1-D skipped
    assert not isinstance(qt["odd"], Q8Tensor)    # K=33 not divisible


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.floats(0.01, 100.0))
def test_property_error_bound(rows, blocks, scale):
    x = (np.random.RandomState(rows * 31 + blocks).randn(rows, blocks * 32)
         * scale).astype(np.float32)
    t = quantize_q8_0(jnp.asarray(x))
    err = np.abs(np.asarray(dequantize_q8_0(t)) - x)
    bound = np.repeat(np.asarray(quantization_error_bound(t)), 32, axis=-1)
    assert (err <= bound * 1.01 + 1e-6).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 6))
def test_property_idempotent(seed):
    """quantize(dequantize(quantize(x))) == quantize(x) (fixed point)."""
    x = np.random.RandomState(seed).randn(2, 64).astype(np.float32)
    t1 = quantize_q8_0(jnp.asarray(x))
    x2 = dequantize_q8_0(t1)
    t2 = quantize_q8_0(x2)
    np.testing.assert_array_equal(np.asarray(t1.q), np.asarray(t2.q))
    np.testing.assert_allclose(np.asarray(t1.scale, dtype=np.float32),
                               np.asarray(t2.scale, dtype=np.float32),
                               rtol=1e-2)
