"""Doc-drift guard: every fenced ``python`` block in README.md and
docs/*.md must actually execute.

Blocks are extracted per file, concatenated in order (a file's snippets
share one namespace, so docs can build on earlier snippets), and run in
a fresh subprocess — documented code that rots fails tier-1. Output
structure sketches use plain (language-less) fences and are not
executed."""

import os
import pathlib
import re
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))

FENCE = re.compile(r"^```python[ \t]*\n(.*?)^```[ \t]*$",
                   re.DOTALL | re.MULTILINE)


def _blocks(path: pathlib.Path) -> list[str]:
    return [m.group(1) for m in FENCE.finditer(path.read_text())]


def test_docs_exist_and_have_snippets():
    assert (ROOT / "README.md").exists(), "top-level README.md missing"
    names = {p.name for p in DOC_FILES}
    for required in ("README.md", "architecture.md", "asr_pipeline.md",
                     "reproduce.md", "serving.md", "platforms.md",
                     "kernel_api.md"):
        assert required in names, f"docs/{required} missing"
    assert sum(len(_blocks(p)) for p in DOC_FILES) >= 8


def test_readme_links_resolve():
    """Every relative markdown link in README.md points at a real file."""
    text = (ROOT / "README.md").read_text()
    for target in re.findall(r"\]\(((?!https?://)[^)#]+)\)", text):
        assert (ROOT / target).exists(), f"README links to missing {target}"


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_snippets_execute(path):
    blocks = _blocks(path)
    if not blocks:
        pytest.skip(f"{path.name}: no fenced python blocks")
    prog = "\n\n".join(
        f"# --- {path.name} :: block {i} ---\n{b}"
        for i, b in enumerate(blocks))
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run([sys.executable, "-c", prog],
                          capture_output=True, text=True, env=env,
                          cwd=ROOT, timeout=560)
    assert proc.returncode == 0, (
        f"{path.name}: documented snippet failed\n"
        f"--- stdout ---\n{proc.stdout[-2000:]}\n"
        f"--- stderr ---\n{proc.stderr[-4000:]}")
