"""Sharding-rule unit tests: per-arch fallbacks, divisibility, specs."""

import subprocess
import sys

import pytest

from repro.configs import get_config
from jax.sharding import PartitionSpec as P


class _FakeMesh:
    """Duck-typed mesh: rules_for only reads .shape."""
    def __init__(self, **axes):
        self.shape = dict(axes)


def _rules(arch, mode="train", **axes):
    from repro.parallel.sharding import rules_for
    return rules_for(get_config(arch), _FakeMesh(**axes), mode=mode)


def test_head_sharding_when_divisible():
    r = _rules("deepseek-7b", data=16, model=16)      # 32H % 16 == 0
    assert r["heads"] == "model" and r["kv_heads"] == "model"
    assert r["q_seq"] is None


def test_context_parallel_fallback():
    r = _rules("gemma2-2b", data=16, model=16)        # 8H % 16 != 0
    assert r["heads"] is None
    assert r["q_seq"] == "model"                      # CP instead


def test_serve_row_tp_for_indivisible_heads():
    r = _rules("llava-next-34b", mode="serve", data=16, model=16)  # 56H
    assert r["param_embed"] == "model"                # Megatron row/col
    r_train = _rules("llava-next-34b", mode="train", data=16, model=16)
    assert r_train["param_embed"] == "data"           # FSDP in training


def test_serve_kv_on_head_dim():
    r = _rules("whisper-base", mode="serve", data=16, model=16)  # kv=8
    assert r["head_dim"] == "model"                   # not seq-sharded
    assert r["cache_seq"] is None


def test_ep_vs_expert_tp():
    r = _rules("qwen3-moe-30b-a3b", data=16, model=16)   # 128e % 16 == 0
    assert r["experts"] == "model" and r["expert_ff"] is None
    r2 = _rules("mixtral-8x7b", data=16, model=16)       # 8e % 16 != 0
    assert r2["experts"] is None and r2["expert_ff"] == "model"


def test_multipod_batch_axes():
    r = _rules("qwen3-4b", pod=2, data=16, model=16)
    assert r["batch"] == ("pod", "data")


def test_spec_for_drops_duplicate_axis():
    from repro.parallel.sharding import spec_for
    rules = {"a": "model", "b": "model", "c": None}
    assert spec_for(("a", "b", "c"), rules) == P("model", None, None)


def test_enforce_divisibility_drops_uneven():
    # real (single-device) mesh of size 1 divides everything; use a fake
    # spec check instead via the pure helper on a 4-device forced mesh
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.parallel.sharding import enforce_divisibility
mesh = jax.make_mesh((4,), ('data',))
sh = {'a': NamedSharding(mesh, P('data')),
      'b': NamedSharding(mesh, P('data'))}
shapes = {'a': jax.ShapeDtypeStruct((8, 2), jnp.float32),
          'b': jax.ShapeDtypeStruct((1501,), jnp.float32)}
out = enforce_divisibility(sh, shapes)
assert out['a'].spec == P('data', None), out['a'].spec
assert out['b'].spec == P(None), out['b'].spec
print('DIV-OK')
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd="/root/repo", timeout=120)
    assert r.returncode == 0, r.stderr[-1500:]
    assert "DIV-OK" in r.stdout


@pytest.mark.slow
def test_train_launcher_distributed_smoke():
    """launch.train end to end on a forced 2x2 mesh."""
    code = """
import sys, tempfile; sys.path.insert(0, 'src')
from repro.launch.train import main
with tempfile.TemporaryDirectory() as d:
    res = main(['--arch', 'gemma2-2b', '--reduced', '--devices', '4',
                '--mesh', '2x2', '--steps', '6', '--batch', '4',
                '--seq', '32', '--ckpt', d])
assert res.final_step == 6 and len(res.losses) == 6
print('LAUNCH-OK')
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd="/root/repo", timeout=420)
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-2500:])
    assert "LAUNCH-OK" in r.stdout
