"""Model zoo serving: per-family decode throughput + J/token through
the one engine.

Every family the lane-state spec covers is served end-to-end on the
paper platform model — whisper (enc-dec KV), qwen3 (dense causal KV),
qwen3-MoE (KV + expert routing counters), zamba2 (hybrid KV + SSM
state), xlstm (pure recurrent mLSTM/sLSTM state) — through the *same*
``ServeEngine`` code path: spec-driven admission, fused decode tick,
one host sync per tick, spec-driven teardown.

Blocking checks are count-exact: one host sync per tick for every
family, lane-state ledger drained after every serve, recurrent
families carrying nonzero constant-size state, and the recurrent
families' per-step state stream being independent of sequence length
(the O(1)-state story next to KV's O(n)). Wall-clock tokens/s and the
modeled J/token (``energy_report`` on imax3-28nm/32k) are informative
trajectory numbers, recorded per family in ``BENCH_platforms.json``
under ``"model_zoo"``.
"""

import time

import numpy as np

import benchmarks.common  # noqa: F401  (puts src/ on the path)
import jax
from repro.configs import get_config, reduced
from repro.models.model import build
from repro.serving.engine import AudioRequest, Request, ServeEngine

ARCHS = ("whisper-tiny-en", "qwen3-4b", "qwen3-moe-30b-a3b",
         "zamba2-7b", "xlstm-350m")
N_SLOTS = 2
MAX_LEN = 64
ENC_LEN = 16
ENC_FRAMES = 12
DECODE_BLOCK = 4
MAX_NEW = 17          # 1 prefill token + 16 decode tokens per lane
PROMPTS = ([5, 6, 7], [9, 10, 11, 12])
PLATFORM = "imax3-28nm/32k"


def _requests(cfg):
    rng = np.random.default_rng(0)
    if cfg.enc_dec:
        return [AudioRequest(uid=i, tokens=list(p), max_new=MAX_NEW,
                             eos_id=-1,
                             enc_frames=rng.standard_normal(
                                 (ENC_FRAMES, cfg.d_model)).astype(
                                     np.float32) * 0.5)
                for i, p in enumerate(PROMPTS)]
    return [Request(uid=i, tokens=list(p), max_new=MAX_NEW, eos_id=-1)
            for i, p in enumerate(PROMPTS)]


def _serve(eng, cfg):
    sts = [eng.admit(r) for r in _requests(cfg)]
    g0, s0, t0 = eng._generated, eng._host_syncs, eng._ticks
    wall0 = time.monotonic()
    while eng.n_active:
        eng.step()
    wall = time.monotonic() - wall0
    toks = eng._generated - g0
    return (sts, toks, eng._host_syncs - s0, eng._ticks - t0, wall)


def run():
    rows = {}
    one_sync = True
    drained = True
    state_nonzero = True
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        model = build(cfg)
        params = model.init_values(jax.random.key(0))
        eng = ServeEngine(model, params, n_slots=N_SLOTS,
                          max_len=MAX_LEN, enc_len=ENC_LEN,
                          cache_dtype="bf16", decode_block=DECODE_BLOCK,
                          platform=PLATFORM)
        _serve(eng, cfg)                       # compile warmup
        _, toks, syncs, ticks, wall = _serve(eng, cfg)
        one_sync &= syncs == ticks
        drained &= eng.lanestate.drained and eng.n_active == 0
        spec = eng.spec
        if spec.recurrent:
            state_nonzero &= \
                eng.cache_report()["state_bytes_total"] > 0
        erep = eng.energy_report()
        crep = eng.cache_report()
        rows[arch] = {
            "family": spec.family,
            "state_kinds": list(spec.state_kinds),
            "q8_supported": spec.q8_supported,
            "tokens_per_s": round(toks / wall, 1),
            "joules_per_token": erep["joules_per_token"],
            "bytes_per_step": crep["bytes_per_step"],
            "state_bytes_per_step": crep["state_bytes_per_step"],
        }

    lines = [
        f"model zoo: {N_SLOTS} lanes x {MAX_NEW - 1} decode tokens, "
        f"decode_block={DECODE_BLOCK}, bf16 pools, platform {PLATFORM}",
        f"{'arch':20s} {'state kinds':>26s} {'tok/s':>8s} "
        f"{'J/tok':>10s} {'B/step':>8s}",
    ]
    for arch, r in rows.items():
        lines.append(
            f"{arch:20s} {'+'.join(r['state_kinds']):>26s} "
            f"{r['tokens_per_s']:8.1f} {r['joules_per_token']:10.2e} "
            f"{r['bytes_per_step']:8d}")

    checks = {
        # count-exact — blocking
        "one host sync per tick for every family": one_sync,
        "lane-state ledger drained after every serve": drained,
        "recurrent families carry nonzero O(1) state": state_nonzero,
        # wall clock / model — informative trajectory numbers
        "zoo": rows,
    }
    return "\n".join(lines), checks


if __name__ == "__main__":
    import sys
    table, checks = run()
    print(table)
    failed = [k for k, v in checks.items()
              if isinstance(v, bool) and not v]
    for k, v in checks.items():
        tag = ("PASS" if v else "FAIL") if isinstance(v, bool) else "info"
        print(f"  [{tag}] {k}" + ("" if isinstance(v, bool) else f": {v}"))
    sys.exit(1 if failed else 0)
