"""Fig 6: latency & PDP vs LMM size — the PDP minimum must sit at 32 KB.

This is the paper's headline design-space exploration: 16 KB forces CPU
fallbacks (latency up); 64+ KB buys little latency but much static power
(PDP up). Also runs the TPU binding of the same knob: the Pallas VMEM
block budget sweep (no static-power term on fixed silicon -> latency-
monotone instead of U-shaped; reported for contrast).
"""

from benchmarks.common import fmt_table, workloads
from repro.core.energy import calibrate_imax, lmm_sweep
from repro.core.footprint import select_blocks
from repro.platforms import get_platform, list_platforms


def run():
    w16, w8 = workloads()
    calib = calibrate_imax(w16, w8)
    # the swept budgets are the registered imax3-28nm LMM configurations
    # (Fig 6 plots up to 128 KB)
    budgets = tuple(sorted(
        get_platform(n).vmem_budget for n in list_platforms("imax3-28nm")
        if get_platform(n).vmem_budget <= 128 * 1024))
    out = []
    mins = {}
    for kern, work in (("fp16", w16), ("q8_0", w8)):
        pts = lmm_sweep(work, calib.model, kern, budgets=budgets)
        for p in pts:
            out.append([kern, f"{p.budget_bytes // 1024}KB",
                        f"{p.latency_s:.2f}", f"{p.power_w:.3f}",
                        f"{p.pdp_j:.1f}",
                        f"{p.breakdown.exec_share:.1%}"])
        mins[kern] = min(pts, key=lambda p: p.pdp_j).budget_bytes
    table = fmt_table(["kernel", "LMM", "latency (s)", "power (W)",
                       "PDP (J)", "EXEC share"], out,
                      "Fig 6 — latency & PDP vs LMM size")

    # TPU VMEM-budget analogue: block shapes chosen under the budget
    vm_rows = []
    for budget_kb in (128, 512, 2048, 8192):
        b = select_blocks(1024, 8192, 8192, budget_kb * 1024)
        vm_rows.append([f"{budget_kb}KB", f"({b.bm},{b.bn},{b.bk})",
                        f"{b.vmem_bytes // 1024}KB",
                        f"{2 * b.bm * b.bn * b.bk / (b.vmem_bytes):.1f}"])
    vm_table = fmt_table(
        ["VMEM budget", "block (bm,bn,bk)", "used", "FLOPs/byte"],
        vm_rows, "TPU binding — Pallas block shapes under a VMEM budget")

    checks = {
        "PDP min at 32KB (fp16)": mins["fp16"] == 32 * 1024,
        "PDP min at 32KB (q8_0)": mins["q8_0"] == 32 * 1024,
    }
    return table + "\n" + vm_table, checks


if __name__ == "__main__":
    t, c = run()
    print(t)
    print(c)
