"""Gateway load benchmark: SLO-aware serving under open-loop Poisson
arrivals (``repro.gateway``).

One micro-whisper engine (1+1 layers, d=64 — the loop-overhead regime;
jits compile once and every load point reuses them) serves three load
points:

* a **parity** point (32 mixed one-shot/streaming requests, shedding
  off) replayed through the synchronous ``BatchScheduler`` — the
  gateway must be token-identical per request (blocking check);
* a small **arrival-rate sweep** (open-loop Poisson, seeded) whose
  wall-clock serving metrics — p50/p99 TTFT and e2e seconds, goodput,
  shed counts — are the info record CI tracks in BENCH_platforms.json.

Blocking checks (CI fails loudly):
* gateway tokens == sync scheduler tokens for every parity request,
* seeded Poisson workload synthesis is deterministic,
* goodput accounting is consistent at every load point
  (completed + shed == offered; in-deadline <= completed;
  goodput <= throughput),
* the engine performed exactly one host sync per fused tick across the
  entire benchmark — the gateway adds zero device round trips.

Wall-clock latency/goodput figures are host-dependent: emitted as
[info], never asserted.

Run directly (``python -m benchmarks.serve_load``) it also merges a
``serve_load`` section into ``BENCH_platforms.json`` (path overridable
via ``SERVE_LOAD_JSON``) so the standalone CI job uploads the same
artifact shape as the full benchmark driver.
"""

import dataclasses
import json
import os
import sys

import jax
import numpy as np

import benchmarks.common  # noqa: F401  (puts src/ on the path)
from repro.configs import get_config, reduced
from repro.models.model import build
from repro.serving.engine import AudioRequest, ServeEngine

N_SLOTS = 4
MAX_LEN = 64
ENC_LEN = 16
DECODE_BLOCK = 4
PLATFORM = "imax3-28nm/32k"
PARITY_N = 32
SWEEP_RATES = (50.0, 200.0)
SWEEP_N = 16


def _micro_whisper():
    cfg = dataclasses.replace(
        reduced(get_config("whisper-tiny-en")),
        d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, vocab=256,
        enc_layers=1, n_layers=1)
    model = build(cfg)
    return cfg, model, model.init_values(jax.random.key(0))


def _accounting_ok(summary: dict, offered: int) -> bool:
    return (summary["completed"] + summary["shed_total"] == offered
            and summary["completed_in_deadline"] <= summary["completed"]
            and summary["goodput_rps"] <= summary["throughput_rps"] + 1e-9
            and summary["completed_in_deadline"] ==
            summary["completed"] - summary["deadline_misses"])


def _capacity_point(model, params, cfg) -> tuple[dict, dict]:
    """Fixed-pool-bytes capacity: the slot pool's ``N_SLOTS x MAX_LEN``
    self / ``N_SLOTS x ENC_LEN`` cross token-slots, re-spent as a paged
    pool (same usable pages, page size 8) across 4x the lanes. Every
    request is a short Whisper-style job — one shared anchor-prompt
    page, identical audio, small decode budget — so paged lanes hold
    ~2 self pages instead of a ``MAX_LEN`` slot, and the anchor page is
    stored once (COW prefix sharing), refcounted by every lane.

    Returns (blocking checks, info record)."""
    p = 8
    lanes = 4 * N_SLOTS
    rng = np.random.default_rng(7)
    frames = rng.standard_normal((p, cfg.d_model)).astype(np.float32) * 0.5
    anchor = list(range(3, 3 + p))     # one full (shareable) prompt page

    def reqs():
        return [AudioRequest(uid=i, tokens=list(anchor), max_new=4,
                             eos_id=-2, enc_frames=frames)
                for i in range(lanes)]

    slot_eng = ServeEngine(model, params, n_slots=N_SLOTS,
                           max_len=MAX_LEN, enc_len=ENC_LEN)
    slot_sts = [slot_eng.admit(r) for r in reqs()]
    slot_resident = sum(1 for s in slot_sts if s is not None)

    paged_eng = ServeEngine(
        model, params, n_slots=lanes, max_len=MAX_LEN, enc_len=ENC_LEN,
        paged=True, page_size=p,
        # usable pages == the slot pool's token capacity, exactly
        n_pages=N_SLOTS * (MAX_LEN // p) + 1,
        n_cross_pages=N_SLOTS * (ENC_LEN // p) + 1)
    paged_sts = [paged_eng.admit(r) for r in reqs()]
    paged_resident = sum(1 for s in paged_sts if s is not None)

    first_pages = {paged_eng.pages.lanes[s.slot].self_pages[0]
                   for s in paged_sts if s is not None}
    one_copy = len(first_pages) == 1
    refcount = (paged_eng.pages.self_pool.refcount(first_pages.pop())
                if one_copy else 0)

    while slot_eng.n_active:
        slot_eng.step()
    while paged_eng.n_active:
        paged_eng.step()
    slot_done = [s.out for s in slot_sts if s is not None]
    paged_done = [s.out for s in paged_sts if s is not None]

    checks = {
        "paged pool holds >= 4x resident lanes at the slot pool's "
        "byte budget":
            slot_resident > 0
            and paged_resident >= 4 * slot_resident,
        "anchor prefix pages physically shared "
        "(one copy, refcount == lanes)":
            one_copy and refcount == paged_resident,
        "capacity-point tokens identical across pool layouts":
            bool(slot_done)
            and all(o == slot_done[0] for o in slot_done + paged_done),
    }
    info = {
        "slot_resident_lanes": slot_resident,
        "paged_resident_lanes": paged_resident,
        "lane_multiplier": (paged_resident / slot_resident
                            if slot_resident else 0.0),
        "slot_goodput_requests": len(slot_done),
        "paged_goodput_requests": len(paged_done),
        "anchor_page_refcount": refcount,
    }
    return checks, info


def run():
    from repro.gateway import (LoadSpec, run_load, sync_baseline,
                               synth_load)

    cfg, model, params = _micro_whisper()
    engine = ServeEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                         enc_len=ENC_LEN, decode_block=DECODE_BLOCK,
                         platform=PLATFORM)
    checks: dict = {}
    rows = []

    # --- parity point: gateway vs synchronous scheduler, shedding off
    spec = LoadSpec(rate_rps=100.0, n_requests=PARITY_N, seed=0,
                    stream_fraction=0.3)
    descs = synth_load(cfg, spec)
    baseline = sync_baseline(engine, descs)        # warms every jit too
    results, summary, _ = run_load(engine, spec, shed_on_submit=False)
    mismatches = [d.idx for d, r in zip(descs, results)
                  if not r.ok or list(r.tokens) != baseline[d.idx]]
    checks[f"gateway token-identical to sync scheduler "
           f"({PARITY_N} mixed requests)"] = not mismatches
    checks["parity point sheds nothing"] = \
        summary["shed_total"] == 0 and summary["completed"] == PARITY_N
    rows.append(("parity", spec.rate_rps, summary))

    # --- determinism of the seeded workload
    d2 = synth_load(cfg, spec)
    checks["seeded Poisson workload is deterministic"] = all(
        a.arrival_s == b.arrival_s and a.tokens == b.tokens
        and a.slo is b.slo and len(a.chunks) == len(b.chunks)
        and all(np.array_equal(x, y)
                for x, y in zip(a.chunks, b.chunks))
        for a, b in zip(descs, d2))

    # --- arrival-rate sweep (open loop; sheds allowed)
    acct_ok = _accounting_ok(summary, PARITY_N)
    total_audio_s = summary["audio_s"]
    for rate in SWEEP_RATES:
        spec = LoadSpec(rate_rps=rate, n_requests=SWEEP_N, seed=1,
                        stream_fraction=0.25)
        _, s, _ = run_load(engine, spec)
        acct_ok = acct_ok and _accounting_ok(s, SWEEP_N)
        total_audio_s += s["audio_s"]
        rows.append((f"{rate:g} rps", rate, s))
    checks["goodput accounting consistent at every load point"] = acct_ok
    checks["exactly one host sync per fused tick under load"] = \
        engine._host_syncs == engine._ticks

    # --- info metrics (host-dependent; tracked, not asserted)
    for name, _, s in rows:
        checks[f"[{name}] goodput_rps"] = round(s["goodput_rps"], 3)
        checks[f"[{name}] ttft_s p50/p99"] = (
            round(s["ttft_s"]["p50"], 4), round(s["ttft_s"]["p99"], 4))
        checks[f"[{name}] shed"] = s["shed"]
    er = engine.energy_report("fp16")
    checks["joules_per_audio_s"] = {
        PLATFORM: er["pdp_j"] / total_audio_s if total_audio_s else 0.0}
    checks["audio_s_served"] = round(total_audio_s, 2)

    # --- paged-pool capacity at the slot pool's byte budget
    cap_checks, cap_info = _capacity_point(model, params, cfg)
    checks.update(cap_checks)
    checks["paged_capacity"] = cap_info

    hdr = (f"{'load point':>12} {'offered':>8} {'done':>5} {'in-SLO':>7} "
           f"{'shed':>5} {'goodput':>8} {'ttft p50':>9} {'ttft p99':>9} "
           f"{'e2e p99':>8}")
    lines = [hdr, "-" * len(hdr)]
    for name, _, s in rows:
        lines.append(
            f"{name:>12} {s['requests']:>8} {s['completed']:>5} "
            f"{s['completed_in_deadline']:>7} {s['shed_total']:>5} "
            f"{s['goodput_rps']:>8.2f} {s['ttft_s']['p50']:>9.4f} "
            f"{s['ttft_s']['p99']:>9.4f} {s['e2e_s']['p99']:>8.4f}")
    lines.append(
        f"paged capacity @ slot-pool bytes: "
        f"{cap_info['paged_resident_lanes']} resident lanes vs "
        f"{cap_info['slot_resident_lanes']} "
        f"({cap_info['lane_multiplier']:.0f}x), anchor page refcount "
        f"{cap_info['anchor_page_refcount']}")
    table = (f"gateway serve load: micro whisper (1+1 layers, d=64), "
             f"{N_SLOTS} slots, decode_block {DECODE_BLOCK}, "
             f"platform {PLATFORM}\n" + "\n".join(lines))
    return table, checks


def serve_load_record(checks: dict) -> dict:
    """The BENCH_platforms.json section for this module's checks."""
    info = {k: v for k, v in checks.items() if not isinstance(v, bool)}
    return {
        "gateway_token_parity": bool(checks.get(
            f"gateway token-identical to sync scheduler "
            f"({PARITY_N} mixed requests)", False)),
        "poisson_deterministic": bool(checks.get(
            "seeded Poisson workload is deterministic", False)),
        "goodput_accounting": bool(checks.get(
            "goodput accounting consistent at every load point", False)),
        "one_host_sync_per_tick": bool(checks.get(
            "exactly one host sync per fused tick under load", False)),
        "paged_capacity_4x": bool(checks.get(
            "paged pool holds >= 4x resident lanes at the slot pool's "
            "byte budget", False)),
        "paged_prefix_shared": bool(checks.get(
            "anchor prefix pages physically shared "
            "(one copy, refcount == lanes)", False)),
        "paged_capacity": checks.get("paged_capacity", {}),
        "joules_per_audio_s": checks.get("joules_per_audio_s", {}),
        "load_points": info,
    }


def main():
    table, checks = run()
    print(table)
    print("\nchecks:")
    failures = []
    for k, v in checks.items():
        if isinstance(v, bool):
            print(f"  [{'PASS' if v else 'FAIL'}] {k}")
            if not v:
                failures.append(k)
        else:
            print(f"  [info] {k}: {v}")
    # merge the serve_load section into the shared benchmark artifact
    path = os.environ.get("SERVE_LOAD_JSON", "BENCH_platforms.json")
    try:
        with open(path) as fh:
            rec = json.load(fh)
    except (FileNotFoundError, json.JSONDecodeError):
        rec = {"schema": 1}
    rec["serve_load"] = serve_load_record(checks)
    with open(path, "w") as fh:
        json.dump(rec, fh, indent=1, sort_keys=True)
    print(f"\nwrote serve_load section to {path}")
    if failures:
        print(f"{len(failures)} SERVE-LOAD CHECK FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("all serve-load checks passed")


if __name__ == "__main__":
    main()
