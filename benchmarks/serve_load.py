"""Gateway load benchmark: SLO-aware serving under open-loop Poisson
arrivals (``repro.gateway``).

One micro-whisper engine (1+1 layers, d=64 — the loop-overhead regime;
jits compile once and every load point reuses them) serves three load
points:

* a **parity** point (32 mixed one-shot/streaming requests, shedding
  off) replayed through the synchronous ``BatchScheduler`` — the
  gateway must be token-identical per request (blocking check);
* a small **arrival-rate sweep** (open-loop Poisson, seeded) whose
  wall-clock serving metrics — p50/p99 TTFT and e2e seconds, goodput,
  shed counts — are the info record CI tracks in BENCH_platforms.json.

Blocking checks (CI fails loudly):
* gateway tokens == sync scheduler tokens for every parity request,
* seeded Poisson workload synthesis is deterministic,
* goodput accounting is consistent at every load point
  (completed + shed == offered; in-deadline <= completed;
  goodput <= throughput),
* the engine performed exactly one host sync per fused tick across the
  entire benchmark — the gateway adds zero device round trips.

Wall-clock latency/goodput figures are host-dependent: emitted as
[info], never asserted.

Run directly (``python -m benchmarks.serve_load``) it also merges a
``serve_load`` section into ``BENCH_platforms.json`` (path overridable
via ``SERVE_LOAD_JSON``) so the standalone CI job uploads the same
artifact shape as the full benchmark driver.
"""

import dataclasses
import json
import os
import sys

import jax
import numpy as np

import benchmarks.common  # noqa: F401  (puts src/ on the path)
from repro.configs import get_config, reduced
from repro.models.model import build
from repro.serving.engine import ServeEngine

N_SLOTS = 4
MAX_LEN = 64
ENC_LEN = 16
DECODE_BLOCK = 4
PLATFORM = "imax3-28nm/32k"
PARITY_N = 32
SWEEP_RATES = (50.0, 200.0)
SWEEP_N = 16


def _micro_whisper():
    cfg = dataclasses.replace(
        reduced(get_config("whisper-tiny-en")),
        d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, vocab=256,
        enc_layers=1, n_layers=1)
    model = build(cfg)
    return cfg, model, model.init_values(jax.random.key(0))


def _accounting_ok(summary: dict, offered: int) -> bool:
    return (summary["completed"] + summary["shed_total"] == offered
            and summary["completed_in_deadline"] <= summary["completed"]
            and summary["goodput_rps"] <= summary["throughput_rps"] + 1e-9
            and summary["completed_in_deadline"] ==
            summary["completed"] - summary["deadline_misses"])


def run():
    from repro.gateway import (LoadSpec, run_load, sync_baseline,
                               synth_load)

    cfg, model, params = _micro_whisper()
    engine = ServeEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                         enc_len=ENC_LEN, decode_block=DECODE_BLOCK,
                         platform=PLATFORM)
    checks: dict = {}
    rows = []

    # --- parity point: gateway vs synchronous scheduler, shedding off
    spec = LoadSpec(rate_rps=100.0, n_requests=PARITY_N, seed=0,
                    stream_fraction=0.3)
    descs = synth_load(cfg, spec)
    baseline = sync_baseline(engine, descs)        # warms every jit too
    results, summary, _ = run_load(engine, spec, shed_on_submit=False)
    mismatches = [d.idx for d, r in zip(descs, results)
                  if not r.ok or list(r.tokens) != baseline[d.idx]]
    checks[f"gateway token-identical to sync scheduler "
           f"({PARITY_N} mixed requests)"] = not mismatches
    checks["parity point sheds nothing"] = \
        summary["shed_total"] == 0 and summary["completed"] == PARITY_N
    rows.append(("parity", spec.rate_rps, summary))

    # --- determinism of the seeded workload
    d2 = synth_load(cfg, spec)
    checks["seeded Poisson workload is deterministic"] = all(
        a.arrival_s == b.arrival_s and a.tokens == b.tokens
        and a.slo is b.slo and len(a.chunks) == len(b.chunks)
        and all(np.array_equal(x, y)
                for x, y in zip(a.chunks, b.chunks))
        for a, b in zip(descs, d2))

    # --- arrival-rate sweep (open loop; sheds allowed)
    acct_ok = _accounting_ok(summary, PARITY_N)
    total_audio_s = summary["audio_s"]
    for rate in SWEEP_RATES:
        spec = LoadSpec(rate_rps=rate, n_requests=SWEEP_N, seed=1,
                        stream_fraction=0.25)
        _, s, _ = run_load(engine, spec)
        acct_ok = acct_ok and _accounting_ok(s, SWEEP_N)
        total_audio_s += s["audio_s"]
        rows.append((f"{rate:g} rps", rate, s))
    checks["goodput accounting consistent at every load point"] = acct_ok
    checks["exactly one host sync per fused tick under load"] = \
        engine._host_syncs == engine._ticks

    # --- info metrics (host-dependent; tracked, not asserted)
    for name, _, s in rows:
        checks[f"[{name}] goodput_rps"] = round(s["goodput_rps"], 3)
        checks[f"[{name}] ttft_s p50/p99"] = (
            round(s["ttft_s"]["p50"], 4), round(s["ttft_s"]["p99"], 4))
        checks[f"[{name}] shed"] = s["shed"]
    er = engine.energy_report("fp16")
    checks["joules_per_audio_s"] = {
        PLATFORM: er["pdp_j"] / total_audio_s if total_audio_s else 0.0}
    checks["audio_s_served"] = round(total_audio_s, 2)

    hdr = (f"{'load point':>12} {'offered':>8} {'done':>5} {'in-SLO':>7} "
           f"{'shed':>5} {'goodput':>8} {'ttft p50':>9} {'ttft p99':>9} "
           f"{'e2e p99':>8}")
    lines = [hdr, "-" * len(hdr)]
    for name, _, s in rows:
        lines.append(
            f"{name:>12} {s['requests']:>8} {s['completed']:>5} "
            f"{s['completed_in_deadline']:>7} {s['shed_total']:>5} "
            f"{s['goodput_rps']:>8.2f} {s['ttft_s']['p50']:>9.4f} "
            f"{s['ttft_s']['p99']:>9.4f} {s['e2e_s']['p99']:>8.4f}")
    table = (f"gateway serve load: micro whisper (1+1 layers, d=64), "
             f"{N_SLOTS} slots, decode_block {DECODE_BLOCK}, "
             f"platform {PLATFORM}\n" + "\n".join(lines))
    return table, checks


def serve_load_record(checks: dict) -> dict:
    """The BENCH_platforms.json section for this module's checks."""
    info = {k: v for k, v in checks.items() if not isinstance(v, bool)}
    return {
        "gateway_token_parity": bool(checks.get(
            f"gateway token-identical to sync scheduler "
            f"({PARITY_N} mixed requests)", False)),
        "poisson_deterministic": bool(checks.get(
            "seeded Poisson workload is deterministic", False)),
        "goodput_accounting": bool(checks.get(
            "goodput accounting consistent at every load point", False)),
        "one_host_sync_per_tick": bool(checks.get(
            "exactly one host sync per fused tick under load", False)),
        "joules_per_audio_s": checks.get("joules_per_audio_s", {}),
        "load_points": info,
    }


def main():
    table, checks = run()
    print(table)
    print("\nchecks:")
    failures = []
    for k, v in checks.items():
        if isinstance(v, bool):
            print(f"  [{'PASS' if v else 'FAIL'}] {k}")
            if not v:
                failures.append(k)
        else:
            print(f"  [info] {k}: {v}")
    # merge the serve_load section into the shared benchmark artifact
    path = os.environ.get("SERVE_LOAD_JSON", "BENCH_platforms.json")
    try:
        with open(path) as fh:
            rec = json.load(fh)
    except (FileNotFoundError, json.JSONDecodeError):
        rec = {"schema": 1}
    rec["serve_load"] = serve_load_record(checks)
    with open(path, "w") as fh:
        json.dump(rec, fh, indent=1, sort_keys=True)
    print(f"\nwrote serve_load section to {path}")
    if failures:
        print(f"{len(failures)} SERVE-LOAD CHECK FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("all serve-load checks passed")


if __name__ == "__main__":
    main()
