"""Fig 7: EXEC / LOAD+DRAIN / CONF execution-time breakdown on the
calibrated accelerator model; checks the compute-bound claim (EXEC 60.89%
FP16, 74.70% Q8_0 — the Q8 row is a *prediction*, see energy.py)."""

from benchmarks.common import fmt_table, workloads
from repro import hw
from repro.core.energy import calibrate_imax
from repro.core.offload import execution_breakdown


def run():
    w16, w8 = workloads()
    calib = calibrate_imax(w16, w8)
    rows = []
    shares = {}
    for kern, work in (("fp16", w16), ("q8_0", w8)):
        bd = execution_breakdown(work, calib.model, 32 * 1024)
        shares[kern] = bd.exec_share
        rows.append([kern, f"{bd.exec_s:.2f}", f"{bd.load_s:.2f}",
                     f"{bd.conf_s:.2f}", f"{bd.host_s:.2f}",
                     f"{bd.exec_share:.2%}",
                     f"{hw.PAPER_EXEC_SHARE[kern]:.2%}"])
    table = fmt_table(
        ["kernel", "EXEC (s)", "LOAD (s)", "CONF (s)", "host (s)",
         "EXEC share (ours)", "(paper)"],
        rows, "Fig 7 — execution-time breakdown (32 KB LMM)")
    checks = {
        "fp16 EXEC share ~60.9% (fit)":
            abs(shares["fp16"] - hw.PAPER_EXEC_SHARE["fp16"]) < 0.02,
        "q8 EXEC share ~74.7% (prediction within 10pp)":
            abs(shares["q8_0"] - hw.PAPER_EXEC_SHARE["q8_0"]) < 0.10,
        "q8 more compute-bound than fp16":
            shares["q8_0"] > shares["fp16"],
    }
    return table, checks


if __name__ == "__main__":
    t, c = run()
    print(t)
    print(c)
