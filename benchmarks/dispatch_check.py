"""Dispatch-layer check: the analytic offload plan (core.offload) and the
executable dispatch layer (repro.kernels.api) must take the SAME
ACCEL/HOST decision for every kernel in the Whisper workload — the
paper's control law is one predicate, exercised two ways.

Also routes a real Q8 GEMM through ``dispatch`` under a loose and a
zero budget and checks the backends actually diverge (Pallas vs host)
while the numerics agree.
"""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, pct, workloads
from repro.core.offload import plan_offload
from repro.core.quantize import quantize_q8_0
from repro.kernels.api import (DispatchContext, decide, dispatch,
                               dispatch_counters, reset_dispatch_log,
                               use_context)

BUDGETS_KB = (16, 32, 64)


def _plan_agreement(work, budget):
    ctx = DispatchContext(vmem_budget=budget, allow_pallas=True)
    plan = plan_offload(work, budget)
    accel = set(map(id, plan.accel))
    agree = 0
    for spec in work:
        decision, _ = decide("q8_matmul", spec, ctx)
        planned = "accel" if id(spec) in accel else "host"
        agree += decision == planned
    return agree, len(work), plan.coverage_calls


def _executed_routing():
    """Route one GEMM at two budgets; report the backends taken."""
    x = jax.random.normal(jax.random.key(0), (8, 256), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (256, 128), jnp.float32)
    wq = quantize_q8_0(w, axis=0)
    outs, backends = {}, {}
    for tag, budget in (("loose", 64 * 2 ** 20), ("zero", 0)):
        reset_dispatch_log()
        with use_context(DispatchContext(vmem_budget=budget,
                                         allow_pallas=True,
                                         interpret=True)):
            outs[tag] = np.asarray(dispatch("q8_matmul", x, wq))
        ((_, decision, backend),) = {k for k in dispatch_counters()}
        backends[tag] = (decision, backend)
    reset_dispatch_log()
    close = np.allclose(outs["loose"], outs["zero"], rtol=1e-4, atol=1e-3)
    return backends, close


def run():
    w16, _ = workloads()
    rows = []
    all_agree = True
    for kb in BUDGETS_KB:
        agree, total, cov = _plan_agreement(w16, kb * 1024)
        all_agree &= agree == total
        rows.append([f"{kb} KB", f"{agree}/{total}", pct(100 * cov)])
    backends, close = _executed_routing()
    table = fmt_table(
        ["LMM budget", "plan==dispatch", "call coverage"],
        rows, "Dispatch check — analytic plan vs executable routing")
    checks = {
        "plan and dispatch agree on every kernel": all_agree,
        "loose budget routes ACCEL->pallas":
            backends["loose"] == ("accel", "pallas"),
        "zero budget routes HOST->xla":
            backends["zero"] == ("host", "xla"),
        "routed outputs allclose across budgets": bool(close),
    }
    return table, checks


if __name__ == "__main__":
    t, c = run()
    print(t)
    print(c)
