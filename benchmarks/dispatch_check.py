"""Dispatch-layer check: the analytic offload plan (core.offload) and the
executable dispatch layer (repro.kernels.api) must take the SAME
ACCEL/HOST decision for every kernel in the Whisper workload — the
paper's control law is one predicate, exercised two ways.

Budgets come from the platform registry: one plan-agreement row per
registered ``imax3-28nm/*`` LMM configuration, each exercised through
``DispatchContext.for_platform`` so the routing context (and the
platform stamp in every trace record) is derived the way serving
derives it.

Also routes a real Q8 GEMM through ``dispatch`` under a loose and a
zero budget and checks the backends actually diverge (Pallas vs host)
while the numerics agree.
"""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, pct, workloads
from repro.core.offload import plan_offload
from repro.core.quantize import quantize_q8_0
from repro.kernels.api import (DispatchContext, decide, dispatch,
                               dispatch_counters, dispatch_trace,
                               reset_dispatch_log, use_context)
from repro.platforms import get_platform, list_platforms


def _plan_agreement(work, platform_name):
    ctx = DispatchContext.for_platform(platform_name, allow_pallas=True)
    plan = plan_offload(work, ctx.vmem_budget, ctx.policy)
    accel = set(map(id, plan.accel))
    agree = 0
    for spec in work:
        decision, _ = decide("q8_matmul", spec, ctx)
        planned = "accel" if id(spec) in accel else "host"
        agree += decision == planned
    return agree, len(work), plan.coverage_calls


def _executed_routing():
    """Route one GEMM at two budgets; report the backends taken and the
    platform stamp carried by the trace records."""
    x = jax.random.normal(jax.random.key(0), (8, 256), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (256, 128), jnp.float32)
    wq = quantize_q8_0(w, axis=0)
    outs, backends, stamps = {}, {}, {}
    for tag, ctx in (
            ("loose", DispatchContext.for_platform(
                "tpu-v5e", allow_pallas=True, interpret=True)),
            ("zero", DispatchContext(vmem_budget=0, allow_pallas=True,
                                     interpret=True))):
        reset_dispatch_log()
        with use_context(ctx):
            outs[tag] = np.asarray(dispatch("q8_matmul", x, wq))
        ((_, decision, backend),) = {k for k in dispatch_counters()}
        backends[tag] = (decision, backend)
        stamps[tag] = {r.platform for r in dispatch_trace()}
    reset_dispatch_log()
    close = np.allclose(outs["loose"], outs["zero"], rtol=1e-4, atol=1e-3)
    return backends, stamps, close


def run():
    w16, _ = workloads()
    imax_names = [n for n in list_platforms("imax3-28nm")
                  if get_platform(n).vmem_budget <= 64 * 1024]
    rows = []
    all_agree = True
    for name in sorted(imax_names,
                       key=lambda n: get_platform(n).vmem_budget):
        agree, total, cov = _plan_agreement(w16, name)
        all_agree &= agree == total
        rows.append([name, f"{get_platform(name).vmem_budget // 1024} KB",
                     f"{agree}/{total}", pct(100 * cov)])
    backends, stamps, close = _executed_routing()
    table = fmt_table(
        ["platform", "LMM budget", "plan==dispatch", "call coverage"],
        rows, "Dispatch check — analytic plan vs executable routing")
    checks = {
        "plan and dispatch agree on every kernel": all_agree,
        "loose budget routes ACCEL->pallas":
            backends["loose"] == ("accel", "pallas"),
        "zero budget routes HOST->xla":
            backends["zero"] == ("host", "xla"),
        "routed outputs allclose across budgets": bool(close),
        "platform-derived context stamps its records":
            stamps["loose"] == {"tpu-v5e"} and stamps["zero"] == {""},
    }
    return table, checks


if __name__ == "__main__":
    t, c = run()
    print(t)
    print(c)
