"""bf16 vs Q8_0 KV-cache decode traffic — the paper's C1 LOAD saving
applied to the serving decode bottleneck.

Every decode tick streams the full cache pool through the attention
matvec, so cache bytes/step — not weight bytes — dominate the decode
memory term (§Roofline decode rows). Serving the same whisper workload
through a ``cache_dtype="q8_0"`` pool must cut that stream to
``kernels.q8_attention.ops.cache_traffic_ratio()`` ≈ 0.53x of bf16
(int8 planes + one f16 scale per 32-element block), while routing the
cache matvec through the dispatched ``q8_decode_attention`` op.
"""

import time

import jax
import numpy as np

import benchmarks.common  # noqa: F401  (puts src/ on the path)
from repro.configs import get_config, reduced
from repro.kernels.api import reset_dispatch_log
from repro.kernels.q8_attention.ops import cache_traffic_ratio
from repro.models.model import build
from repro.serving.engine import AudioRequest, ServeEngine
from repro.serving.scheduler import BatchScheduler

N_REQUESTS = 8
MAX_NEW = 8
ENC_FRAMES = 12


def _serve(model, params, cfg, cache_dtype: str) -> dict:
    reset_dispatch_log()
    engine = ServeEngine(model, params, n_slots=4, max_len=64,
                         enc_len=16, cache_dtype=cache_dtype)
    sched = BatchScheduler(engine)
    rng = np.random.default_rng(0)
    for uid in range(N_REQUESTS):
        n = int(rng.integers(4, 24))
        frames = rng.standard_normal(
            (ENC_FRAMES, cfg.d_model)).astype(np.float32) * 0.5
        sched.submit(AudioRequest(
            uid=uid, tokens=rng.integers(3, cfg.vocab, n).tolist(),
            max_new=MAX_NEW, eos_id=-1, enc_frames=frames))
    t0 = time.monotonic()
    sched.run_until_drained()
    dt = time.monotonic() - t0
    rep = engine.dispatch_report()
    toks = sum(len(st.out) for st in sched.results.values())
    return {
        "cache": rep["cache"],
        "counters": rep["counters"],
        "ticks": sched.metrics.ticks,
        "tokens": toks,
        "tok_per_s": toks / max(dt, 1e-9),
        "out": {uid: st.out for uid, st in sched.results.items()},
    }


def run():
    cfg = reduced(get_config("whisper-tiny-en"))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))

    res = {dt: _serve(model, params, cfg, dt) for dt in ("bf16", "q8_0")}
    rb, rq = res["bf16"]["cache"], res["q8_0"]["cache"]
    ratio = rq["bytes_per_step"] / rb["bytes_per_step"]
    q8_calls = sum(n for (op, _, _), n in res["q8_0"]["counters"].items()
                   if op == "q8_decode_attention")
    agree = sum(a == b for a, b in zip(res["bf16"]["out"].values(),
                                       res["q8_0"]["out"].values()))

    lines = [
        "decode cache traffic: whisper-tiny.en (reduced), "
        f"{N_REQUESTS} audio requests x {MAX_NEW} new tokens",
        f"{'cache':8s} {'KV bytes/step':>14s} {'KV B/tok':>9s} "
        f"{'ticks':>6s} {'tok/s':>8s}",
    ]
    for dt in ("bf16", "q8_0"):
        c = res[dt]["cache"]
        lines.append(
            f"{dt:8s} {c['bytes_per_step']:14d} "
            f"{c['self_kv_bytes_per_token']:9d} "
            f"{res[dt]['ticks']:6d} {res[dt]['tok_per_s']:8.1f}")
    lines.append(f"q8_0 / bf16 cache bytes/step: {ratio:.4f}x "
                 f"(paper C1 LOAD: {cache_traffic_ratio():.4f}x)")
    lines.append(f"greedy outputs identical for {agree}/{N_REQUESTS} "
                 "requests (Q8 rounding can flip near-ties)")

    checks = {
        "q8 cache stream ~0.53x of bf16":
            abs(ratio - cache_traffic_ratio()) < 1e-6,
        "decode ticks route q8_decode_attention": q8_calls > 0,
        "all requests served under both cache dtypes":
            len(res["bf16"]["out"]) == N_REQUESTS
            and len(res["q8_0"]["out"]) == N_REQUESTS,
        "q8/bf16 greedy agreement": f"{agree}/{N_REQUESTS}",
        "q8 tok/s": f"{res['q8_0']['tok_per_s']:.1f}",
    }
    return "\n".join(lines), checks


if __name__ == "__main__":
    table, checks = run()
    print(table)
    print(checks)
