"""bf16 vs Q8_0 vs Q4_0 KV-cache decode traffic — the paper's C1 LOAD
saving applied to the serving decode bottleneck.

Every decode tick streams the full cache pool through the attention
matvec, so cache bytes/step — not weight bytes — dominate the decode
memory term (§Roofline decode rows). Serving the same whisper workload
through a ``cache_dtype="q8_0"`` pool must cut that stream to
``kernels.q8_attention.ops.cache_traffic_ratio()`` ≈ 0.53x of bf16
(int8 planes + one f16 scale per 32-element block), while routing the
cache matvec through the dispatched ``q8_decode_attention`` op; a
``"q4_0"`` pool (packed nibble planes) cuts it again to
``kernels.q4_attention.ops.cache_traffic_ratio_q4()`` ≈ 0.28x via
``q4_decode_attention``.

The paged section serves the same workload through a ``paged=True``
engine (``repro.paging``): per-lane cache bytes are then the lane's
*mapped pages* — actual request extents, not ``n_slots x max_len``
pool padding — so ``bytes_per_step`` (and the energy model's decode
LOAD term) prices resident bytes. The mid-serve snapshot records pages
in use, fragmentation (allocated-but-unfilled page tail fraction), and
the copy-on-write prefix-share hit rate for the shared anchor prompt +
repeated audio.
"""

import time

import jax
import numpy as np

import benchmarks.common  # noqa: F401  (puts src/ on the path)
from repro.configs import get_config, reduced
from repro.kernels.api import reset_dispatch_log
from repro.kernels.q4_attention.ops import cache_traffic_ratio_q4
from repro.kernels.q8_attention.ops import cache_traffic_ratio
from repro.models.model import build
from repro.serving.engine import AudioRequest, ServeEngine
from repro.serving.scheduler import BatchScheduler

N_REQUESTS = 8
MAX_NEW = 8
ENC_FRAMES = 12
PAGE_SIZE = 8
# the Whisper-style anchor prompt every request starts with — one full
# page, so paged lanes with the same audio physically share it (COW)
ANCHOR = [11, 12, 13, 14, 15, 16, 17, 18]


def _workload(cfg):
    """(tokens, frames) per request: shared anchor prefix + distinct
    tails; two distinct audio contents repeated across requests so the
    paged engine's prefix store sees cross-KV (and anchor-page) hits."""
    rng = np.random.default_rng(0)
    audio = [rng.standard_normal(
        (ENC_FRAMES, cfg.d_model)).astype(np.float32) * 0.5
        for _ in range(2)]
    reqs = []
    for uid in range(N_REQUESTS):
        n_tail = int(rng.integers(2, 12))
        toks = ANCHOR + rng.integers(3, cfg.vocab, n_tail).tolist()
        reqs.append((toks, audio[uid % 2]))
    return reqs


def _serve(model, params, cfg, cache_dtype: str,
           paged: bool = False) -> dict:
    reset_dispatch_log()
    engine = ServeEngine(model, params, n_slots=4, max_len=64,
                         enc_len=16, cache_dtype=cache_dtype,
                         paged=paged, page_size=PAGE_SIZE)
    sched = BatchScheduler(engine)
    for uid, (toks, frames) in enumerate(_workload(cfg)):
        sched.submit(AudioRequest(uid=uid, tokens=toks, max_new=MAX_NEW,
                                  eos_id=-1, enc_frames=frames))
    t0 = time.monotonic()
    # a few hand ticks first: the mid-serve cache snapshot must see
    # resident lanes (after the drain every page is back on the free
    # list and bytes_per_step would read 0)
    for _ in range(2):
        sched.tick()
    mid = engine.cache_report()
    sched.run_until_drained()
    dt = time.monotonic() - t0
    rep = engine.dispatch_report()
    toks = sum(len(st.out) for st in sched.results.values())
    return {
        "cache": mid,
        "counters": rep["counters"],
        "ticks": sched.metrics.ticks,
        "tokens": toks,
        "tok_per_s": toks / max(dt, 1e-9),
        "out": {uid: st.out for uid, st in sched.results.items()},
    }


def run():
    cfg = reduced(get_config("whisper-tiny-en"))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))

    res = {dt: _serve(model, params, cfg, dt)
           for dt in ("bf16", "q8_0", "q4_0")}
    paged = _serve(model, params, cfg, "bf16", paged=True)
    rb, rq = res["bf16"]["cache"], res["q8_0"]["cache"]
    ratio = rq["bytes_per_step"] / rb["bytes_per_step"]
    ratio4 = (res["q4_0"]["cache"]["bytes_per_step"]
              / rb["bytes_per_step"])
    q8_calls = sum(n for (op, _, _), n in res["q8_0"]["counters"].items()
                   if op == "q8_decode_attention")
    q4_calls = sum(n for (op, _, _), n in res["q4_0"]["counters"].items()
                   if op == "q4_decode_attention")
    agree = sum(a == b for a, b in zip(res["bf16"]["out"].values(),
                                       res["q8_0"]["out"].values()))
    agree4 = sum(a == b for a, b in zip(res["bf16"]["out"].values(),
                                        res["q4_0"]["out"].values()))
    paged_calls = sum(n for (op, _, _), n in paged["counters"].items()
                      if op == "paged_decode_attention")
    paged_agree = sum(a == b for a, b in zip(res["bf16"]["out"].values(),
                                             paged["out"].values()))
    pg = paged["cache"]["paging"]
    paged_ratio = (paged["cache"]["bytes_per_step"]
                   / rb["bytes_per_step"])

    lines = [
        "decode cache traffic: whisper-tiny.en (reduced), "
        f"{N_REQUESTS} audio requests x {MAX_NEW} new tokens",
        f"{'cache':10s} {'KV bytes/step':>14s} {'KV B/tok':>9s} "
        f"{'ticks':>6s} {'tok/s':>8s}",
    ]
    for dt in ("bf16", "q8_0", "q4_0"):
        c = res[dt]["cache"]
        lines.append(
            f"{dt:10s} {c['bytes_per_step']:14d} "
            f"{c['self_kv_bytes_per_token']:9d} "
            f"{res[dt]['ticks']:6d} {res[dt]['tok_per_s']:8.1f}")
    c = paged["cache"]
    lines.append(
        f"{'bf16/paged':10s} {c['bytes_per_step']:14d} "
        f"{c['self_kv_bytes_per_token']:9d} "
        f"{paged['ticks']:6d} {paged['tok_per_s']:8.1f}")
    lines.append(f"q8_0 / bf16 cache bytes/step: {ratio:.4f}x "
                 f"(paper C1 LOAD: {cache_traffic_ratio():.4f}x)")
    lines.append(f"q4_0 / bf16 cache bytes/step: {ratio4:.4f}x "
                 f"(analytic: {cache_traffic_ratio_q4():.4f}x)")
    lines.append(f"paged / slot cache bytes/step: {paged_ratio:.4f}x "
                 f"(resident pages only, mid-serve)")
    lines.append(f"greedy outputs identical for {agree}/{N_REQUESTS} "
                 "requests (Q8 rounding can flip near-ties)")
    lines.append(
        f"paging: self {pg['self']['pages_in_use']}/"
        f"{pg['self']['n_pages'] - 1} pages "
        f"({pg['self']['fragmentation']:.1%} frag), cross "
        f"{pg['cross']['pages_in_use']}/{pg['cross']['n_pages'] - 1} "
        f"({pg['cross']['fragmentation']:.1%} frag), prefix hit rate "
        f"self {pg['prefix']['self']['hit_rate']:.2f} / cross "
        f"{pg['prefix']['cross']['hit_rate']:.2f}")

    checks = {
        "q8 cache stream ~0.53x of bf16":
            abs(ratio - cache_traffic_ratio()) < 1e-6,
        "q4 cache stream ~0.28x of bf16":
            abs(ratio4 - cache_traffic_ratio_q4()) < 1e-6,
        "decode ticks route q8_decode_attention": q8_calls > 0,
        "decode ticks route q4_decode_attention": q4_calls > 0,
        "all requests served under every cache dtype":
            all(len(res[dt]["out"]) == N_REQUESTS for dt in res),
        "q8/bf16 greedy agreement": f"{agree}/{N_REQUESTS}",
        "q4/bf16 greedy agreement": f"{agree4}/{N_REQUESTS}",
        "q8 tok/s": f"{res['q8_0']['tok_per_s']:.1f}",
        "q4 tok/s": f"{res['q4_0']['tok_per_s']:.1f}",
        # ---- paged pool (repro.paging) -------------------------------
        "paged tokens identical to slot pool":
            paged_agree == N_REQUESTS,
        "paged decode routes paged_decode_attention": paged_calls > 0,
        "paged bytes/step prices resident pages only":
            0 < paged["cache"]["bytes_per_step"]
            < rb["bytes_per_step"],
        "paged prefix sharing observed":
            pg["prefix"]["self"]["hits"] > 0
            and pg["prefix"]["cross"]["hits"] > 0,
        "paged_bytes_per_step_ratio": f"{paged_ratio:.4f}",
        "paging": {
            "self_pages_in_use": pg["self"]["pages_in_use"],
            "cross_pages_in_use": pg["cross"]["pages_in_use"],
            "self_fragmentation": round(pg["self"]["fragmentation"], 4),
            "cross_fragmentation": round(pg["cross"]["fragmentation"], 4),
            "prefix_hit_rate_self": pg["prefix"]["self"]["hit_rate"],
            "prefix_hit_rate_cross": pg["prefix"]["cross"]["hit_rate"],
            "resident_kv_bytes": pg["resident_kv_bytes"],
        },
    }
    return "\n".join(lines), checks


if __name__ == "__main__":
    table, checks = run()
    print(table)
    print(checks)
