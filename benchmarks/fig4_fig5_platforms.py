"""Figs 4 & 5: E2E latency and PDP by platform — every hardware constant
sourced through the ``repro.platforms`` registry.

Paper rows carry the paper's published latency (Fig 4) and PDP (Fig 5),
read from each registered platform's ``paper`` observables. Note the
paper's Fig-5 PDP values embed *measured phase-wise average power*, not
nominal-TDP × latency (their §IV-A caveat): e.g. Q8_0 IMAX
11.1 s × 1.32 W = 14.65 J (Eq 1 with nominal power) vs the published
12.6 J. We report both: ``pdp_eq1`` (latency × nominal power, our Eq-1
derivation) and ``pdp_paper`` (their figure). Headline ratio checks run
on the paper's own numbers; our calibrated model's Eq-1 PDP must land
within 15 % of Eq-1 with the platform's nominal constants.

'imax3-28nm(model)' rows are OUR calibrated accelerator model's
predictions; 'tpu-v5e(projection)' places the brief's target chip on the
same axes (uncalibrated roofline constants).
"""

from benchmarks.common import fmt_table, workloads
from repro.core.energy import calibrate_imax, platform_pdp_table
from repro.platforms import get_platform


def run():
    w16, w8 = workloads()
    calib = calibrate_imax(w16, w8)
    rows_all = platform_pdp_table(w16, w8, calib)
    rows = []
    for r in rows_all:
        phase = r.get("pdp_phase_j")
        paper_pdp = r.get("pdp_paper_j")
        rows.append([r["device"], r["kernel"], f"{r['latency_s']:.2f}",
                     f"{r['power_w']:.3f}", f"{r['pdp_j']:.1f}",
                     f"{phase:.1f}" if phase else "-",
                     f"{paper_pdp:.1f}" if paper_pdp else "-",
                     r["source"]])
    table = fmt_table(["device", "kernel", "latency (s)", "power (W)",
                       "PDP eq1 (J)", "PDP phase (J)", "PDP paper (J)",
                       "source"], rows,
                      "Figs 4+5 — E2E latency & PDP by platform "
                      "(registry-sourced)")

    imax = get_platform("imax3-28nm")
    orin = get_platform("jetson-agx-orin")
    rtx = get_platform("rtx-4090")
    imax8 = imax.paper_observable("pdp_j", "q8_0")
    orin8 = orin.paper_observable("pdp_j", "q8_0")
    rtx8 = rtx.paper_observable("pdp_j", "q8_0")
    imax_lat8 = imax.paper_observable("latency_s", "q8_0")
    by = {(r["device"], r["kernel"]): r for r in rows_all}
    model8 = by[("imax3-28nm(model)", "q8_0")]
    checks = {
        "paper headline: 1.90x vs Orin (Q8_0)":
            abs(orin8 / imax8 - 1.90) < 0.02,
        "paper headline: 9.83x vs RTX4090 (Q8_0)":
            abs(rtx8 / imax8 - 9.83) < 0.02,
        "model latency within 15% of paper (q8)":
            abs(model8["latency_s"] / imax_lat8 - 1.0) < 0.15,
        # Eq 1 with the platform's own nominal constants gives
        # 11.1 x 1.32 = 14.65 J; our calibrated model must land within
        # 15% of that.
        "model Eq1-PDP within 15% of paper-constants Eq1 (q8)":
            abs(model8["pdp_j"]
                / (imax_lat8 * imax.platform_power("q8_0")) - 1.0) < 0.15,
        "published Fig5 (measured power) vs Eq1-nominal — info":
            (f"published {imax8}J implies IMAX duty factor "
             f"{(imax8 - get_platform('cortex-a72').power.nominal_w * imax_lat8) / (imax.platform_power('q8_0') * imax_lat8):.2f}; "
             f"our Eq1 model: {model8['pdp_j']:.1f}J, "
             f"phase-wise: {model8['pdp_phase_j']:.1f}J"),
        "IMAX slower than GPUs but beats host CPU (Fig 4 ordering)":
            rtx.paper_observable("latency_s", "q8_0")
            < orin.paper_observable("latency_s", "q8_0")
            < model8["latency_s"]
            < get_platform("cortex-a72").paper_observable("latency_s",
                                                          "q8_0"),
        "calibration residuals": calib.residuals,
    }
    return table, checks


if __name__ == "__main__":
    t, c = run()
    print(t)
    print(c)
