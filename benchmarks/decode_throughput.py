"""Decode throughput: tokens/s and host syncs per token across
``decode_block x cache_dtype x {plain, speculative}`` — the serving
engine's measured-perf trajectory.

The fused decode loop (``ServeEngine.step``) runs ``decode_block``
decode steps inside one donated jit and syncs to host once per tick, so
the per-token host cost (jit dispatch, device round trip, Python
bookkeeping) is amortized ``decode_block``-fold. This benchmark pins
that down three ways:

* **counts** (deterministic): host syncs per token drop exactly
  ``1/decode_block``-fold, one sync per tick, and the emitted tokens
  are identical across every block size and vs the pre-PR ``seed_loop``
  reference (host-resident state re-uploaded per step, undonated
  decode) — these are the blocking checks;
* **wall clock** (hardware-dependent): tokens/s per grid cell, measured
  with compile-warmup + interleaved passes + best-of (so scheduler
  noise and cgroup throttling hit all cells equally); the
  ``block16 >= 3x block1`` throughput target is enforced only under
  ``REPRO_BENCH_STRICT_THROUGHPUT=1`` (the non-blocking CI smoke job)
  because wall-clock ratios on tiny shared-CPU runners are load-bound;
* the model is a micro whisper config (1 enc / 1 dec layer, d=64):
  the point is the loop overhead around a decode step, not the step
  itself — ``decode_traffic``/``e2e_asr`` cover the reduced config.

The q4_0 tier and self-speculative cells add two more blocking,
deterministic properties:

* the q4_0 pool's cache stream per decode step measures below
  0.5312x the q8_0 pool's (0.28125 / 0.53125 ~= 0.529 of it — the
  nibble planes beat q8 by almost 2x on the LOAD term);
* the speculative tick beats plain q8_0 serving by > 1.3x on the
  platform-roofline *modeled* tokens/s, computed from the MEASURED
  acceptance rate of this very serve (``energy_report``), at
  token-identical outputs. Wall-clock speculative tok/s is reported
  but, like every wall-clock figure here, is not gated on shared-CPU
  runners.
"""

import dataclasses
import gc
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import benchmarks.common  # noqa: F401  (puts src/ on the path)
from repro.configs import get_config, reduced
from repro.models.model import build
from repro.serving.engine import AudioRequest, ServeEngine

BLOCKS = (1, 4, 16)
CACHE_DTYPES = ("bf16", "q8_0", "q4_0")
N_SLOTS = 2
MAX_LEN = 64
ENC_FRAMES = 12
MAX_NEW = 49          # 1 prefill token + 48 decode tokens; 48 % 16 == 0
PROMPTS = ([5, 6, 7], [9, 10, 11, 12])
PASSES = 6            # timed passes per cell (interleaved, best-of)
SPEC_K = 4            # draft 3 + verify 1 per round; 16 % 4 == 0
SPEC_BLOCK = 16
PLATFORM = "imax3-28nm/32k"


def _micro_whisper():
    """Whisper shrunk to the loop-overhead regime (q8-compatible:
    head_dim 32, plain softmax)."""
    cfg = dataclasses.replace(
        reduced(get_config("whisper-tiny-en")),
        d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, vocab=256,
        enc_layers=1, n_layers=1)
    model = build(cfg)
    return cfg, model, model.init_values(jax.random.key(0))


def _requests(cfg):
    rng = np.random.default_rng(0)
    return [AudioRequest(uid=i, tokens=list(p), max_new=MAX_NEW,
                         eos_id=-1,
                         enc_frames=rng.standard_normal(
                             (ENC_FRAMES, cfg.d_model)).astype(
                                 np.float32) * 0.5)
            for i, p in enumerate(PROMPTS)]


class _SeedLoop:
    """The pre-PR decode loop, reproduced as a reference: per-lane state
    lives in host NumPy and is re-uploaded every step, the decode jit is
    undonated (the KV pool is copied per step), and every token costs a
    host round trip. Serves the lanes an engine has just admitted."""

    def __init__(self, eng: ServeEngine):
        self.eng = eng
        model = eng.model

        @jax.jit
        def decode(params, cache, tokens, pos, enc_lens):
            logits, new_cache = model.forward(
                params, {"tokens": tokens, "enc_lens": enc_lens},
                mode="decode", cache=cache, pos=pos)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, new_cache

        self.decode = decode

    def serve(self, sts) -> int:
        eng = self.eng
        tokens = np.array(eng._tokens)
        pos = np.array(eng._pos)
        enc = np.array(eng._enc_lens)
        cache = eng.cache
        active = {st.slot: st for st in sts if not st.done}
        n = 0
        while active:
            nxt, cache = self.decode(
                eng.params, cache, jnp.asarray(tokens), jnp.asarray(pos),
                jnp.asarray(enc))
            nxt = np.asarray(nxt)
            for slot, st in list(active.items()):
                tok = int(nxt[slot])
                st.out.append(tok)
                st.pos += 1
                n += 1
                tokens[slot, 0] = tok
                pos[slot] = st.pos
                if tok == st.req.eos_id or len(st.out) >= st.req.max_new \
                        or st.pos >= eng.max_len - 1:
                    del active[slot]
        return n


def _fused_pass(eng, cfg):
    sts = [eng.admit(r) for r in _requests(cfg)]
    g0, s0 = eng._generated, eng._host_syncs
    t0 = time.monotonic()
    while eng.n_active:
        eng.step()
    dt = time.monotonic() - t0
    return ([st.out for st in sts], eng._generated - g0,
            eng._host_syncs - s0, eng._ticks, dt)


def _seed_pass(eng, loop, cfg):
    sts = [eng.admit(r) for r in _requests(cfg)]
    eng.active.clear()            # the reference loop takes over
    t0 = time.monotonic()
    n = loop.serve(sts)
    dt = time.monotonic() - t0
    eng.free = list(range(eng.n_slots))
    for slot in range(eng.n_slots):     # bypassed retire(): drop the
        if eng.lanestate.holds(slot):   # lane-state reservations too
            eng.lanestate.release(slot)
    return [st.out for st in sts], n, dt


def run():
    cfg, model, params = _micro_whisper()

    def engine(cache_dtype, block, spec_k=0):
        return ServeEngine(model, params, n_slots=N_SLOTS,
                           max_len=MAX_LEN, enc_len=16,
                           cache_dtype=cache_dtype, decode_block=block,
                           spec_k=spec_k, platform=PLATFORM)

    cells = {}          # (dtype, block) -> dict
    seed = {}           # dtype -> dict
    spec = {}           # dtype -> dict (speculative tick, SPEC_BLOCK)
    for dt in CACHE_DTYPES:
        for b in BLOCKS:
            cells[(dt, b)] = {"eng": engine(dt, b), "best": float("inf")}
        e = engine(dt, 1)
        seed[dt] = {"eng": e, "loop": _SeedLoop(e), "best": float("inf")}
        spec[dt] = {"eng": engine(dt, SPEC_BLOCK, spec_k=SPEC_K),
                    "best": float("inf")}

    # compile warmup, then interleaved timed passes: contention and
    # throttle phases hit every cell, best-of filters the spikes
    for dt in CACHE_DTYPES:
        for b in BLOCKS:
            _fused_pass(cells[(dt, b)]["eng"], cfg)
        _fused_pass(spec[dt]["eng"], cfg)
        _seed_pass(seed[dt]["eng"], seed[dt]["loop"], cfg)
    gc.disable()
    try:
        for _ in range(PASSES):
            for dt in CACHE_DTYPES:
                for b in BLOCKS:
                    c = cells[(dt, b)]
                    outs, toks, syncs, ticks, wall = _fused_pass(
                        c["eng"], cfg)
                    c["sum_toks"] = c.get("sum_toks", 0) + toks
                    c["sum_syncs"] = c.get("sum_syncs", 0) + syncs
                    c.update(outs=outs, toks=toks, best=min(c["best"], wall))
                sp = spec[dt]
                outs, toks, syncs, ticks, wall = _fused_pass(sp["eng"], cfg)
                sp["sum_toks"] = sp.get("sum_toks", 0) + toks
                sp["sum_syncs"] = sp.get("sum_syncs", 0) + syncs
                sp.update(outs=outs, toks=toks, best=min(sp["best"], wall))
                s = seed[dt]
                outs, toks, wall = _seed_pass(s["eng"], s["loop"], cfg)
                s.update(outs=outs, toks=toks, best=min(s["best"], wall))
    finally:
        gc.enable()

    tok_s, syncs_per_tok = {}, {}
    one_sync_per_tick = True
    parity = {dt: True for dt in CACHE_DTYPES}
    for (dt, b), c in cells.items():
        eng = c["eng"]
        tok_s[f"{dt}/block{b}"] = round(c["toks"] / c["best"], 1)
        # count-exact: decode-tick syncs over decode tokens (timed passes)
        syncs_per_tok[f"{dt}/block{b}"] = round(
            c["sum_syncs"] / max(c["sum_toks"], 1), 5)
        one_sync_per_tick &= eng._host_syncs == eng._ticks
        parity[dt] &= c["outs"] == cells[(dt, 1)]["outs"]
    spec_parity, acceptance = {}, {}
    for dt, sp in spec.items():
        tok_s[f"{dt}/spec{SPEC_K}"] = round(sp["toks"] / sp["best"], 1)
        syncs_per_tok[f"{dt}/spec{SPEC_K}"] = round(
            sp["sum_syncs"] / max(sp["sum_toks"], 1), 5)
        one_sync_per_tick &= sp["eng"]._host_syncs == sp["eng"]._ticks
        spec_parity[dt] = sp["outs"] == cells[(dt, 1)]["outs"]
        acceptance[dt] = round(sp["eng"].acceptance_rate, 4)
    seed_tok_s = {dt: round(s["toks"] / s["best"], 1)
                  for dt, s in seed.items()}
    seed_parity = {dt: seed[dt]["outs"] == cells[(dt, 1)]["outs"]
                   for dt in CACHE_DTYPES}
    speedup_16v1 = {dt: tok_s[f"{dt}/block16"] / tok_s[f"{dt}/block1"]
                    for dt in CACHE_DTYPES}
    speedup_16vseed = {dt: tok_s[f"{dt}/block16"] / seed_tok_s[dt]
                       for dt in CACHE_DTYPES}

    # cache-stream LOAD term per decode step, straight off the pools
    bytes_per_step = {dt: cells[(dt, 16)]["eng"].cache_report()
                      ["bytes_per_step"] for dt in CACHE_DTYPES}
    q4_stream_vs_q8 = bytes_per_step["q4_0"] / bytes_per_step["q8_0"]

    # roofline tokens/s with the acceptance rate MEASURED on this very
    # serve — deterministic (the greedy token stream is), unlike the
    # wall-clock columns
    modeled_tok_s = {}
    for dt in CACHE_DTYPES:
        modeled_tok_s[f"{dt}/plain16"] = \
            cells[(dt, 16)]["eng"].energy_report()["modeled_tokens_per_s"]
        modeled_tok_s[f"{dt}/spec{SPEC_K}"] = \
            spec[dt]["eng"].energy_report()["modeled_tokens_per_s"]
    spec_modeled_gain = (modeled_tok_s[f"q4_0/spec{SPEC_K}"]
                         / modeled_tok_s["q8_0/plain16"])

    lines = [
        f"decode throughput: micro whisper (1+1 layers, d=64), "
        f"{N_SLOTS} lanes x {MAX_NEW - 1} decode tokens, best of "
        f"{PASSES} interleaved passes",
        f"{'cache':6s} {'block':>5s} {'tok/s':>8s} {'syncs/tok':>10s}",
    ]
    for dt in CACHE_DTYPES:
        for b in BLOCKS:
            lines.append(f"{dt:6s} {b:5d} {tok_s[f'{dt}/block{b}']:8.1f} "
                         f"{syncs_per_tok[f'{dt}/block{b}']:10.4f}")
        lines.append(f"{dt:6s} {'spec':>5s} "
                     f"{tok_s[f'{dt}/spec{SPEC_K}']:8.1f} "
                     f"{syncs_per_tok[f'{dt}/spec{SPEC_K}']:10.4f}   "
                     f"(spec_k={SPEC_K}, acceptance "
                     f"{acceptance[dt]:.2f})")
        lines.append(f"{dt:6s} {'seed':>5s} {seed_tok_s[dt]:8.1f} "
                     f"{1.0:10.4f}   (pre-PR host-resident loop)")
    for dt in CACHE_DTYPES:
        lines.append(
            f"{dt}: block16 = {speedup_16v1[dt]:.2f}x block1, "
            f"{speedup_16vseed[dt]:.2f}x seed loop")
    lines.append(
        f"q4_0 cache stream/step = {q4_stream_vs_q8:.4f}x q8_0 "
        f"({bytes_per_step['q4_0']} vs {bytes_per_step['q8_0']} B)")
    lines.append(
        f"spec{SPEC_K}[q4_0] modeled roofline = "
        f"{spec_modeled_gain:.2f}x plain q8_0/block16 "
        f"(measured acceptance {acceptance['q4_0']:.2f})")

    checks = {
        # deterministic properties — blocking
        "fused blocks token-identical to block1 (bf16)": parity["bf16"],
        "fused blocks token-identical to block1 (q8_0)": parity["q8_0"],
        "fused blocks token-identical to block1 (q4_0)": parity["q4_0"],
        "speculative ticks token-identical to plain decode":
            all(spec_parity.values()),
        "fused tokens match the seed host loop":
            all(seed_parity.values()),
        "exactly one host sync per tick": one_sync_per_tick,
        "block16 syncs/token == block1/16":
            abs(syncs_per_tok["bf16/block1"]
                - 16 * syncs_per_tok["bf16/block16"]) < 1e-9,
        "q4_0 cache stream/step < 0.5312x q8_0":
            q4_stream_vs_q8 < 0.5312,
        f"spec{SPEC_K}[q4_0] > 1.3x plain q8_0 modeled tok/s":
            spec_modeled_gain > 1.3,
        # wall clock — informative here, enforced in the strict CI job
        "tokens_per_s": tok_s,
        "seed_loop_tokens_per_s": seed_tok_s,
        "host_syncs_per_token": syncs_per_tok,
        "speedup_block16_vs_block1":
            {dt: round(v, 2) for dt, v in speedup_16v1.items()},
        "speedup_block16_vs_seed_loop":
            {dt: round(v, 2) for dt, v in speedup_16vseed.items()},
        "acceptance_rate": acceptance,
        "q4_cache_stream_vs_q8": round(q4_stream_vs_q8, 4),
        "modeled_tokens_per_s":
            {k: round(v, 1) for k, v in modeled_tok_s.items()},
        "spec_modeled_speedup_vs_q8_plain": round(spec_modeled_gain, 2),
    }
    if os.environ.get("REPRO_BENCH_STRICT_THROUGHPUT"):
        checks["block16 >= 3x block1 tok/s (bf16, strict)"] = \
            speedup_16v1["bf16"] >= 3.0
    return "\n".join(lines), checks


if __name__ == "__main__":
    import sys
    table, checks = run()
    print(table)
    failed = [k for k, v in checks.items()
              if isinstance(v, bool) and not v]
    for k, v in checks.items():
        print(f"  [{('PASS' if v else 'FAIL') if isinstance(v, bool) else 'info'}] {k}"
              + ("" if isinstance(v, bool) else f": {v}"))
    sys.exit(1 if failed else 0)
