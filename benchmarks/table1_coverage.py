"""Table I: cumulative kernel coverage by LMM limit, baseline vs optimized.

Reproduces the paper's central co-design observation: without padding
removal, essentially nothing fits a 32 KB LMM; with packing, >90 % does —
and the optimized column is dtype-independent (IMAX computes in f32 after
inline conversion, so the resident tile is the same for FP16 and Q8_0).
"""

from benchmarks.common import fmt_table, pct, workloads
from repro import hw
from repro.core.footprint import LMM_LIMITS, coverage_cdf


def run():
    w16, w8 = workloads()
    cols = {}
    for name, work, policy in (
            ("f16_base", w16, "baseline"), ("f16_opt", w16, "optimized"),
            ("q8_base", w8, "baseline"), ("q8_opt", w8, "optimized")):
        cols[name] = {r.limit_bytes: r.coverage_pct
                      for r in coverage_cdf(work, policy)}

    rows = []
    for limit in LMM_LIMITS:
        p = hw.PAPER_TABLE1[limit]
        rows.append([
            f"{limit // 1024}KB",
            pct(cols["f16_base"][limit]), pct(p[0]),
            pct(cols["f16_opt"][limit]), pct(p[1]),
            pct(cols["q8_base"][limit]), pct(p[2]),
            pct(cols["q8_opt"][limit]), pct(p[3]),
        ])
    table = fmt_table(
        ["LMM", "F16 base (ours)", "(paper)", "F16 opt (ours)", "(paper)",
         "Q8 base (ours)", "(paper)", "Q8 opt (ours)", "(paper)"],
        rows, "Table I — kernel coverage CDF by LMM limit")
    checks = {
        "optimized@32KB > 90%": cols["f16_opt"][32 * 1024] > 90.0,
        "baseline@32KB < 35%": cols["f16_base"][32 * 1024] < 35.0,
        "opt col dtype-independent":
            all(abs(cols["f16_opt"][l] - cols["q8_opt"][l]) < 1e-6
                for l in LMM_LIMITS),
        "q8 baseline fits more than f16 baseline @256KB":
            cols["q8_base"][256 * 1024] >= cols["f16_base"][256 * 1024],
        "baseline@32KB within 5pp of paper 1.39%":
            abs(cols["f16_base"][32 * 1024] - 1.39) < 5.0,
    }
    return table, checks


if __name__ == "__main__":
    t, c = run()
    print(t)
    print(c)
