"""Table IV + Sec V-C: scaling to base/small — coverage and dot-op counts.

The paper's scalability claim: a modest LMM bump (32->64 KB) recovers
>94 % coverage for base/small; dot-product counts grow 477k -> 645k ->
1.92M (tiny -> base -> small).
"""

from benchmarks.common import fmt_table, pct
from repro import hw
from repro.core.footprint import coverage_cdf
from repro.core.workload import (WHISPER_TINY, WHISPER_BASE, WHISPER_SMALL,
                                 total_calls, whisper_workload)


def run():
    rows = []
    counts = {}
    for dims, paper_key in ((WHISPER_TINY, "tiny"), (WHISPER_BASE, "base"),
                            (WHISPER_SMALL, "small")):
        work = whisper_workload(dims)
        cov = {r.limit_bytes // 1024: r.coverage_pct
               for r in coverage_cdf(work, "optimized")}
        counts[paper_key] = total_calls(work)
        paper = hw.PAPER_TABLE4[paper_key]
        rows.append([paper_key] +
                    [f"{pct(cov[k])} / {paper[k]:.2f}%"
                     for k in (16, 32, 64, 128, 256)])
    table = fmt_table(
        ["model", "16KB ours/paper", "32KB", "64KB", "128KB", "256KB"],
        rows, "Table IV — optimized coverage by LMM (tiny/base/small)")

    dot_rows = [[k, f"{counts[k]:,}", f"{hw.PAPER_DOT_COUNTS[k]:,}",
                 f"{counts[k] / counts['tiny']:.2f}x",
                 f"{hw.PAPER_DOT_COUNTS[k] / hw.PAPER_DOT_COUNTS['tiny']:.2f}x"]
                for k in ("tiny", "base", "small")]
    dot_table = fmt_table(["model", "kernel calls (ours)", "paper dot-ops",
                           "scaling (ours)", "scaling (paper)"], dot_rows,
                          "Sec V-C — dot-product workload scaling per run")

    cov = {}
    for dims, key in ((WHISPER_TINY, "tiny"), (WHISPER_BASE, "base"),
                      (WHISPER_SMALL, "small")):
        cov[key] = {r.limit_bytes // 1024: r.coverage_pct
                    for r in coverage_cdf(whisper_workload(dims),
                                          "optimized")}
    # Paper Table IV signature (exact call-weighting differs from
    # whisper.cpp's internal counter; the *structure* is the claim):
    checks = {
        "tiny jumps 16->32KB (d_ff=1536 fits at 32)":
            cov["tiny"][32] - cov["tiny"][16] > 3.0,
        "base flat 16->32KB (d_ff=2048 doesn't fit)":
            cov["base"][32] - cov["base"][16] < 2.0,
        "small flat 16->32KB (d_ff=3072 doesn't fit)":
            cov["small"][32] - cov["small"][16] < 2.0,
        "base 64KB recovers (>94% like paper)":
            cov["base"][64] - cov["base"][32] > 3.0 and cov["base"][64] > 94,
        "small 64KB recovers": cov["small"][64] > 94,
        "counts ordered tiny<base<small":
            counts["tiny"] < counts["base"] < counts["small"],
        "count scaling small/tiny in paper band (~4x)":
            2.5 < counts["small"] / counts["tiny"] < 6.5,
        "note": ("our counter = per-B-row kernel invocations; whisper.cpp's"
                 " printed totals include beam/windowing internals"),
    }
    return table + "\n" + dot_table, checks


if __name__ == "__main__":
    t, c = run()
    print(t)
    print(c)
