"""Shared helpers for the paper-reproduction benchmarks."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.workload import WHISPER_TINY, whisper_workload  # noqa: E402


def fmt_table(headers, rows, title=""):
    widths = [max([len(str(h))] + [len(str(r[i])) for r in rows])
              for i, h in enumerate(headers)]
    out = []
    if title:
        out.append(f"\n## {title}")
    out.append("| " + " | ".join(str(h).ljust(w)
                                 for h, w in zip(headers, widths)) + " |")
    out.append("|" + "|".join("-" * (w + 2) for w in widths) + "|")
    for r in rows:
        out.append("| " + " | ".join(str(c).ljust(w)
                                     for c, w in zip(r, widths)) + " |")
    return "\n".join(out)


def pct(x):
    return f"{x:.2f}%"


def workloads():
    return (whisper_workload(WHISPER_TINY, dtype="f16"),
            whisper_workload(WHISPER_TINY, dtype="q8_0"))
