"""Beyond-paper: render the 40-cell roofline table from results/dryrun."""

import glob
import json
import os

from benchmarks.common import fmt_table

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str = "16x16", results_dir: str = RESULTS):
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, f"*__{mesh}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    skips = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*__na.json"))):
        with open(f) as fh:
            skips.append(json.load(fh))
    return recs, skips


def run(mesh: str = "16x16", results_dir: str = RESULTS):
    recs, skips = load(mesh, results_dir)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    rows = []
    for r in recs:
        rows.append([
            r["arch"], r["shape"],
            f"{r['compute_s'] * 1e3:.1f}",
            f"{r['memory_s'] * 1e3:.1f}",
            f"{r['collective_s'] * 1e3:.1f}",
            r["dominant"],
            f"{r['useful_ratio']:.3f}",
            f"{r['roofline_frac']:.2%}",
        ])
    for s in skips:
        rows.append([s["arch"], s["shape"], "-", "-", "-", "skip", "-", "-"])
    table = fmt_table(
        ["arch", "shape", "compute (ms)", "memory (ms)",
         "collective (ms)", "dominant", "useful 6ND/HLO", "roofline frac"],
        rows, f"Roofline baseline — {mesh} mesh "
              f"({len(recs)} compiled cells + {len(skips)} documented skips)")
    checks = {"cells_compiled": len(recs), "cells_skipped": len(skips)}
    return table, checks


if __name__ == "__main__":
    t, c = run()
    print(t)
    print(c)
