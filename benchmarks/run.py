"""Benchmark driver: one module per paper table/figure + the roofline
table. ``python -m benchmarks.run`` prints every table and a check
summary; non-zero exit if a reproduction check fails.
"""

import importlib
import sys
import traceback

MODULES = [
    "benchmarks.table1_coverage",
    "benchmarks.table2_power",
    "benchmarks.table4_scaling",
    "benchmarks.secIIIB_burst_dse",
    "benchmarks.fig4_fig5_platforms",
    "benchmarks.fig6_lmm_sweep",
    "benchmarks.fig7_breakdown",
    "benchmarks.roofline_table",
    "benchmarks.dispatch_check",
    "benchmarks.decode_traffic",
]


def main():
    failures = []
    for name in MODULES:
        try:
            mod = importlib.import_module(name)
            table, checks = mod.run()
            print(table)
            print("\nchecks:")
            for k, v in checks.items():
                if isinstance(v, bool):
                    print(f"  [{'PASS' if v else 'FAIL'}] {k}")
                    if not v:
                        failures.append(f"{name}: {k}")
                else:
                    print(f"  [info] {k}: {v}")
        except Exception:
            traceback.print_exc()
            failures.append(f"{name}: exception")
        print()
    if failures:
        print(f"{len(failures)} BENCHMARK CHECK FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("all benchmark checks passed")


if __name__ == "__main__":
    main()
