"""Benchmark driver: one module per paper table/figure + the roofline
table. ``python -m benchmarks.run`` prints every table and a check
summary; non-zero exit if a reproduction check fails.

Also emits ``BENCH_platforms.json`` — a machine-readable per-platform
summary (latency/PDP rows from the registry-driven Fig-4/5 table,
headline paper ratios, dispatch plan/execute agreement, calibration
residuals). CI uploads it as an artifact on every run, so the file's
history is the perf-trajectory baseline.
"""

import importlib
import json
import os
import sys
import traceback

MODULES = [
    "benchmarks.table1_coverage",
    "benchmarks.table2_power",
    "benchmarks.table4_scaling",
    "benchmarks.secIIIB_burst_dse",
    "benchmarks.fig4_fig5_platforms",
    "benchmarks.fig6_lmm_sweep",
    "benchmarks.fig7_breakdown",
    "benchmarks.roofline_table",
    "benchmarks.dispatch_check",
    "benchmarks.decode_traffic",
    "benchmarks.decode_throughput",
    "benchmarks.model_zoo",
    "benchmarks.e2e_asr",
    "benchmarks.serve_load",
]

BENCH_JSON = os.environ.get("BENCH_PLATFORMS_JSON", "BENCH_platforms.json")


def platforms_record(module_checks: dict) -> dict:
    """The machine-readable per-platform record: every registry target's
    latency/PDP (paper rows + our model rows), the paper's headline Q8_0
    PDP ratios, and the dispatch-layer agreement result."""
    from benchmarks.common import workloads
    from repro.core.energy import calibrate_imax, platform_pdp_table
    from repro.platforms import get_platform, list_platforms

    from benchmarks.serve_load import serve_load_record

    w16, w8 = workloads()
    calib = calibrate_imax(w16, w8)
    rows = platform_pdp_table(w16, w8, calib)
    # static hot-path invariants (repro.staticcheck): per-function
    # donation / sync-free / dtype-plane verdicts — the cheap static
    # slice; the full gate (recompile + footprint) is the CI
    # `staticcheck` job. Kept non-fatal so a checker crash still
    # leaves a benchmark record (with ok=False) behind.
    try:
        from repro.staticcheck import bench_record
        staticcheck_rec = bench_record()
    except Exception as e:
        traceback.print_exc()
        staticcheck_rec = {"ok": False, "error": repr(e)}
    imax8 = get_platform("imax3-28nm").paper_observable("pdp_j", "q8_0")
    dispatch_checks = module_checks.get("benchmarks.dispatch_check", {})
    asr_checks = module_checks.get("benchmarks.e2e_asr", {})
    tp_checks = module_checks.get("benchmarks.decode_throughput", {})
    zoo_checks = module_checks.get("benchmarks.model_zoo", {})
    sl_checks = module_checks.get("benchmarks.serve_load", {})
    dt_checks = module_checks.get("benchmarks.decode_traffic", {})
    return {
        "schema": 1,
        "platforms": list_platforms(),
        "pdp_table": rows,
        # end-to-end ASR: modeled joules per audio-second per platform
        # (benchmarks/e2e_asr.py — frontend + chunked encode + decode)
        "e2e_asr": {
            "joules_per_audio_s": asr_checks.get("joules_per_audio_s", {}),
            "steady_state_compute_ms_per_audio_s": asr_checks.get(
                "steady_state_compute_ms_per_audio_s"),
            "streaming_matches_one_shot": bool(asr_checks.get(
                "streaming chunked encode == one-shot tokens", False)),
        },
        "paper_ratios": {
            "q8_pdp_vs_jetson-agx-orin":
                get_platform("jetson-agx-orin").paper_observable(
                    "pdp_j", "q8_0") / imax8,
            "q8_pdp_vs_rtx-4090":
                get_platform("rtx-4090").paper_observable(
                    "pdp_j", "q8_0") / imax8,
        },
        # fused decode loop: tokens/s + host syncs per token across the
        # decode_block x cache_dtype grid (benchmarks/decode_throughput)
        # — the perf-trajectory record for the serving hot path
        "decode_throughput": {
            "tokens_per_s": tp_checks.get("tokens_per_s", {}),
            "seed_loop_tokens_per_s":
                tp_checks.get("seed_loop_tokens_per_s", {}),
            "host_syncs_per_token":
                tp_checks.get("host_syncs_per_token", {}),
            "speedup_block16_vs_block1":
                tp_checks.get("speedup_block16_vs_block1", {}),
            "speedup_block16_vs_seed_loop":
                tp_checks.get("speedup_block16_vs_seed_loop", {}),
            "fused_matches_sequential": bool(
                tp_checks.get(
                    "fused blocks token-identical to block1 (bf16)", False)
                and tp_checks.get(
                    "fused blocks token-identical to block1 (q8_0)",
                    False)
                and tp_checks.get(
                    "fused blocks token-identical to block1 (q4_0)",
                    False)),
            "one_host_sync_per_tick": bool(tp_checks.get(
                "exactly one host sync per tick", False)),
            # q4_0 tier + self-speculative decode (this PR's headline):
            # measured cache-stream ratio, measured acceptance, and the
            # roofline tokens/s built from them — all deterministic
            "q4_cache_stream_vs_q8":
                tp_checks.get("q4_cache_stream_vs_q8"),
            "acceptance_rate": tp_checks.get("acceptance_rate", {}),
            "modeled_tokens_per_s":
                tp_checks.get("modeled_tokens_per_s", {}),
            "spec_modeled_speedup_vs_q8_plain":
                tp_checks.get("spec_modeled_speedup_vs_q8_plain"),
            "spec_matches_plain": bool(tp_checks.get(
                "speculative ticks token-identical to plain decode",
                False)),
        },
        # model zoo: every lane-state family served through the one
        # engine — per-family tokens/s, modeled J/token, bytes/step
        # (benchmarks/model_zoo)
        "model_zoo": {
            "families": zoo_checks.get("zoo", {}),
            "one_host_sync_per_tick": bool(zoo_checks.get(
                "one host sync per tick for every family", False)),
            "lanestate_drained": bool(zoo_checks.get(
                "lane-state ledger drained after every serve", False)),
        },
        # async gateway under Poisson load: token parity vs the sync
        # scheduler, goodput accounting, J/audio-s (benchmarks/serve_load)
        "serve_load": serve_load_record(sl_checks),
        # paged KV/cross-KV pool (repro.paging): mid-serve occupancy,
        # fragmentation, COW prefix-share hit rates, and the resident-
        # bytes decode stream vs the padded slot pool
        # (benchmarks/decode_traffic + benchmarks/serve_load capacity)
        "paging": {
            **dt_checks.get("paging", {}),
            "tokens_match_slot_pool": bool(dt_checks.get(
                "paged tokens identical to slot pool", False)),
            "bytes_per_step_ratio_vs_slot": dt_checks.get(
                "paged_bytes_per_step_ratio"),
            "capacity": sl_checks.get("paged_capacity", {}),
        },
        # hot-path invariant verdicts (repro.staticcheck)
        "staticcheck": staticcheck_rec,
        "dispatch_agreement": bool(dispatch_checks.get(
            "plan and dispatch agree on every kernel", False)),
        "calibration_residuals": calib.residuals,
    }


def main():
    failures = []
    module_checks: dict = {}
    for name in MODULES:
        try:
            mod = importlib.import_module(name)
            table, checks = mod.run()
            module_checks[name] = checks
            print(table)
            print("\nchecks:")
            for k, v in checks.items():
                if isinstance(v, bool):
                    print(f"  [{'PASS' if v else 'FAIL'}] {k}")
                    if not v:
                        failures.append(f"{name}: {k}")
                else:
                    print(f"  [info] {k}: {v}")
        except Exception:
            traceback.print_exc()
            failures.append(f"{name}: exception")
        print()
    try:
        rec = platforms_record(module_checks)
        with open(BENCH_JSON, "w") as fh:
            json.dump(rec, fh, indent=1, sort_keys=True)
        print(f"wrote {BENCH_JSON} ({len(rec['pdp_table'])} platform rows)")
    except Exception:
        traceback.print_exc()
        failures.append("BENCH_platforms.json: exception")
    if failures:
        print(f"{len(failures)} BENCHMARK CHECK FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("all benchmark checks passed")


if __name__ == "__main__":
    main()
