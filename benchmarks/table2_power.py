"""Table II: power by LMM size (paper synthesis values + interpolation).

Also derives the TPU analogue: VMEM is fixed silicon on v5e, so the
'budget' knob costs no static power — the table contrasts the two
hardware models' power-vs-local-memory curves.
"""

from benchmarks.common import fmt_table
from repro import hw
from repro.core.energy import imax_power


def run():
    rows = []
    for kb in (16, 32, 64, 128, 256):
        b = kb * 1024
        rows.append([
            f"{kb}KB",
            f"{imax_power(b, 'fp16'):.3f} W",
            f"{hw.IMAX_POWER_FP16_W[b]:.3f} W",
            f"{imax_power(b, 'q8_0'):.2f} W",
            f"{hw.IMAX_POWER_Q8_W[b]:.2f} W",
        ])
    table = fmt_table(
        ["LMM", "FP16 (model)", "(paper)", "Q8_0 (model)", "(paper)"],
        rows, "Table II — IMAX 28nm power by LMM size (per lane)")
    checks = {
        "32KB fp16 = 0.647W": abs(imax_power(32 * 1024, "fp16") - 0.647) < 1e-9,
        "32KB->64KB jump is the PDP cliff":
            imax_power(64 * 1024, "fp16") / imax_power(32 * 1024, "fp16") > 3.0,
    }
    return table, checks


if __name__ == "__main__":
    t, c = run()
    print(t)
    print(c)
