"""Table II: power by LMM size — one row per registered ``imax3-28nm/*``
platform, interpolation checked against each platform's own curve.

Also derives the TPU analogue: VMEM is fixed silicon on v5e, so the
'budget' knob costs no static power — the table contrasts the two
hardware models' power-vs-local-memory curves.
"""

from benchmarks.common import fmt_table
from repro.core.energy import imax_power
from repro.platforms import get_platform, list_platforms


def run():
    rows = []
    for name in list_platforms(family="imax3-28nm"):
        p = get_platform(name)
        b = p.vmem_budget
        rows.append([
            p.name,
            f"{b // 1024}KB",
            f"{imax_power(b, 'fp16'):.3f} W",
            f"{p.power.curves['fp16'][b]:.3f} W",
            f"{imax_power(b, 'q8_0'):.2f} W",
            f"{p.power.curves['q8_0'][b]:.2f} W",
        ])
    rows.sort(key=lambda r: int(r[1][:-2]))
    table = fmt_table(
        ["platform", "LMM", "FP16 (model)", "(paper)", "Q8_0 (model)",
         "(paper)"],
        rows, "Table II — IMAX 28nm power by LMM size (per lane)")
    p32 = get_platform("imax3-28nm/32k")
    p64 = get_platform("imax3-28nm/64k")
    checks = {
        "32KB fp16 = 0.647W":
            abs(p32.platform_power("fp16") - 0.647) < 1e-9,
        "32KB->64KB jump is the PDP cliff":
            p64.platform_power("fp16") / p32.platform_power("fp16") > 3.0,
        "every registered LMM size hits its curve point exactly":
            all(abs(get_platform(n).platform_power("fp16")
                    - get_platform(n).power.curves["fp16"][
                        get_platform(n).vmem_budget]) < 1e-12
                for n in list_platforms(family="imax3-28nm")),
    }
    return table, checks


if __name__ == "__main__":
    t, c = run()
    print(t)
    print(c)
