"""End-to-end ASR benchmark: seconds of audio in, tokens out, energy per
audio-second per platform.

This closes the loop the paper actually measures — full Whisper ASR —
on top of the repro stack: synthetic waveform -> log-mel frontend
(dispatched GEMMs) -> chunked encoder -> continuous-batching decode.
For every registered platform it reports the modeled
**joules per audio-second** (the serving energy report scaled by the
utterance length) and checks that the streaming chunked-encode path is
token-identical to one-shot serving.
"""

import time

import jax

import benchmarks.common  # noqa: F401  (puts src/ on the path)
from repro.audio.stream import synth_waveform
from repro.audio.transcribe import transcribe
from repro.configs import get_config, reduced
from repro.models.model import build
from repro.platforms import list_platforms

AUDIO_SECONDS = 0.5
MAX_NEW = 8
CHUNK_FRAMES = 8


def run():
    wave = synth_waveform(AUDIO_SECONDS)
    # one model/params for every run below (each platform still gets
    # its own engine, so dispatch contexts stay isolated)
    model = build(reduced(get_config("whisper-tiny-en")))
    params = model.init_values(jax.random.key(0))

    def go(**kw):
        return transcribe(wave, 16_000, model=model, params=params,
                          max_new=MAX_NEW, chunk_frames=CHUNK_FRAMES,
                          **kw)

    # one-shot vs streaming parity (platform-free, shared jits)
    one = go()
    streamed = go(stream=True, engine=one.engine)
    # steady-state compute cost: re-run on the already-compiled engine
    t0 = time.monotonic()
    go(engine=one.engine)
    warm_ms = (time.monotonic() - t0) / AUDIO_SECONDS * 1e3

    rows = []
    energy = {}
    for name in list_platforms():
        r = go(platform=name)
        e = r.energy
        energy[name] = e["joules_per_audio_s"]
        rows.append((name, f"{e['joules_per_audio_s']:.3e}",
                     f"{e['joules_per_token']:.3e}",
                     f"{e['power_w']:.3f}", e["bound"],
                     f"{e['accel_flops_share']:.0%}"))

    # q8_0 cache pool: the C1 LOAD saving must show up as cache energy
    q8 = go(platform="imax3-28nm", cache_dtype="q8_0")
    bf16_imax = go(platform="imax3-28nm")

    lines = [
        f"end-to-end ASR: {AUDIO_SECONDS}s synthetic audio, "
        f"whisper-tiny.en (reduced), {one.n_frames} encoder frames, "
        f"chunk={CHUNK_FRAMES}, {MAX_NEW} new tokens",
        f"steady-state compute: {warm_ms:.0f} ms per audio-second "
        f"(compiled engine, CPU wall clock)",
        f"{'platform':18s} {'J/audio-s':>11s} {'J/token':>11s} "
        f"{'W':>8s} {'bound':>7s} {'accel':>6s}",
    ]
    for r in rows:
        lines.append(f"{r[0]:18s} {r[1]:>11s} {r[2]:>11s} {r[3]:>8s} "
                     f"{r[4]:>7s} {r[5]:>6s}")
    lines.append(
        f"imax3-28nm cache energy: q8_0 {q8.energy['cache_energy_j']:.3e} J"
        f" vs bf16 {bf16_imax.energy['cache_energy_j']:.3e} J")

    checks = {
        "streaming chunked encode == one-shot tokens":
            streamed.tokens == one.tokens,
        "streaming emitted partial hypotheses":
            len(streamed.partials) >= 2,
        "every platform reports finite joules/audio-second":
            all(v > 0.0 and v == v and v != float("inf")
                for v in energy.values()),
        "q8_0 cache energy <= bf16 on imax3-28nm":
            q8.energy["cache_energy_j"]
            <= bf16_imax.energy["cache_energy_j"] + 1e-12,
        "joules_per_audio_s": energy,
        "steady_state_compute_ms_per_audio_s": round(warm_ms, 1),
    }
    return "\n".join(lines), checks


if __name__ == "__main__":
    table, checks = run()
    print(table)
    print(checks)
