"""Sec III-B: burst-length design-space exploration.

The paper partitions each K-vector into a burst-aligned main segment
(offloaded) and a residual (host CPU), and reports burst=16 optimal over
Whisper's vector-length distribution with ~5 % residual compute. This
benchmark reproduces the sweep with the calibrated cost ratios and also
reports the TPU binding (K-tile alignment of the Pallas GEMM wrappers).
"""

from benchmarks.common import fmt_table
from repro.core.burst import burst_cost, offload_rate, optimal_burst
from repro.core.workload import (WHISPER_TINY, k_length_histogram,
                                 whisper_workload)
from repro.kernels.fp16_matmul.ops import offload_info


def run():
    hist = k_length_histogram(whisper_workload(WHISPER_TINY))
    rows = []
    for b in (4, 8, 16, 32, 64, 128):
        c = burst_cost(hist, b, t_mac_accel=1.0, t_mac_host=2.76,
                       t_burst_overhead=0.065)
        rows.append([b, f"{c.offload:.2%}",
                     f"{c.accel_time / 1e9:.2f}",
                     f"{c.host_time / 1e9:.2f}",
                     f"{c.total_time / 1e9:.2f}"])
    table = fmt_table(
        ["burst", "offload rate", "accel (norm)", "host (norm)", "total"],
        rows, "Sec III-B — burst-length DSE (whisper-tiny K distribution)")

    tpu_rows = []
    for m, n, k in ((1, 1536, 384), (1500, 1536, 384), (64, 51865, 384),
                    (16, 4096, 1000)):
        info = offload_info(m, n, k)
        tpu_rows.append([f"({m},{k})x({k},{n})", info["bk"],
                         info["k_main"], info["k_residual"],
                         f"{info['offload_fraction']:.2%}"])
    tpu_table = fmt_table(
        ["GEMM", "K-tile", "K main", "K residual", "offload"],
        tpu_rows, "TPU binding — Pallas K-tile split (C2) per GEMM shape")

    best = optimal_burst(hist)
    checks = {
        "burst=16 optimal (paper Sec III-B)": best.burst == 16,
        "residual ~5% at burst 16 (paper: ~5%)":
            1 - offload_rate(hist, 16) < 0.10,
        "hardware-aligned K fully offloads on TPU":
            offload_info(16, 4096, 384)["offload_fraction"] == 1.0,
    }
    return table + "\n" + tpu_table, checks


if __name__ == "__main__":
    t, c = run()
    print(t)
    print(c)
