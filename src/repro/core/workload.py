"""GEMM/dot-product workload extraction (paper Secs III-A, V-C).

The paper's unit of offload is the ggml ``mul_mat`` dot-product kernel:
``C[m, n] = sum_k A[n, k] * B[m, k]`` — every output element is one
K-length dot product. We enumerate those kernels for a whole model run
(Whisper: one encoder pass + T decoder steps; decoder-only LMs: prefill
and/or decode) so that the coverage/offload/energy analyses can reason
about the real kernel-size *distribution*, exactly as Sec III-B does for
burst-length selection and Sec III-C/V-C do for LMM sizing.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One mul_mat call site: A is (n, k) [weights or cached tensor],
    B is (m, k) [activations]; invoked ``count`` times per run."""

    name: str
    m: int            # rows of B (tokens/queries in this call)
    n: int            # rows of A (output features / kv positions)
    k: int            # dot-product length
    dtype: str        # storage dtype of A: 'f16' | 'q8_0' | 'f32'
    count: int = 1    # invocations per run
    tag: str = "proj"  # proj | attn_qk | attn_av | mlp | logits | conv |
    #                    ssm | frontend (audio log-mel/projection GEMMs)

    @property
    def flops(self) -> int:
        return 2 * self.m * self.n * self.k * self.count

    @property
    def dot_products(self) -> int:
        """Number of K-length dot products (output elements) per run."""
        return self.m * self.n * self.count

    @property
    def calls(self) -> int:
        """Per-B-row kernel invocations (the offload granularity)."""
        return self.m * self.count


@dataclasses.dataclass(frozen=True)
class WhisperDims:
    name: str
    d_model: int
    n_heads: int
    enc_layers: int
    dec_layers: int
    d_ff: int
    vocab: int
    enc_frames: int = 1500   # 30s window after conv stride-2
    n_mels: int = 80


WHISPER_TINY = WhisperDims("tiny", 384, 6, 4, 4, 1536, 51865)
WHISPER_BASE = WhisperDims("base", 512, 8, 6, 6, 2048, 51865)
WHISPER_SMALL = WhisperDims("small", 768, 12, 12, 12, 3072, 51865)


def whisper_workload(dims: WhisperDims, dec_steps: int = 28,
                     dtype: str = "f16") -> list[KernelSpec]:
    """Kernel inventory for one transcription (jfk.wav ≈ 10 s → ~28 tokens).

    Weight-bearing GEMMs use ``dtype`` storage; attention score/value
    kernels read the fp16 KV cache in both model variants (as whisper.cpp
    does — Q8_0 quantizes weights only).
    """
    d, h, ff, v = dims.d_model, dims.n_heads, dims.d_ff, dims.vocab
    dh = d // h
    S = dims.enc_frames
    out: list[KernelSpec] = []
    add = out.append

    # --- encoder (one pass over S frames) ---
    L = dims.enc_layers
    add(KernelSpec("enc.conv1", S, d, dims.n_mels * 3, dtype, 1, "conv"))
    add(KernelSpec("enc.conv2", S, d, d * 3, dtype, 1, "conv"))
    add(KernelSpec("enc.attn.qkv", S, 3 * d, d, dtype, L, "proj"))
    add(KernelSpec("enc.attn.out", S, d, d, dtype, L, "proj"))
    add(KernelSpec("enc.attn.qk", S, S, dh, "f16", L * h, "attn_qk"))
    add(KernelSpec("enc.attn.av", S, dh, S, "f16", L * h, "attn_av"))
    add(KernelSpec("enc.mlp.up", S, ff, d, dtype, L, "mlp"))
    add(KernelSpec("enc.mlp.down", S, d, ff, dtype, L, "mlp"))

    # --- decoder cross-KV precompute (once) ---
    Ld = dims.dec_layers
    add(KernelSpec("dec.cross.kv", S, 2 * d, d, dtype, Ld, "proj"))

    # --- decoder steps (m=1 incremental) ---
    for t in range(1, dec_steps + 1):
        add(KernelSpec("dec.attn.qkv", 1, 3 * d, d, dtype, Ld, "proj"))
        add(KernelSpec("dec.attn.out", 1, d, d, dtype, Ld, "proj"))
        add(KernelSpec("dec.attn.qk", 1, t, dh, "f16", Ld * h, "attn_qk"))
        add(KernelSpec("dec.attn.av", 1, dh, t, "f16", Ld * h, "attn_av"))
        add(KernelSpec("dec.cross.q", 1, d, d, dtype, Ld, "proj"))
        add(KernelSpec("dec.cross.out", 1, d, d, dtype, Ld, "proj"))
        add(KernelSpec("dec.cross.qk", 1, S, dh, "f16", Ld * h, "attn_qk"))
        add(KernelSpec("dec.cross.av", 1, dh, S, "f16", Ld * h, "attn_av"))
        add(KernelSpec("dec.mlp.up", 1, ff, d, dtype, Ld, "mlp"))
        add(KernelSpec("dec.mlp.down", 1, d, ff, dtype, Ld, "mlp"))
        add(KernelSpec("dec.logits", 1, v, d, dtype, 1, "logits"))
    return out


# ----------------------------------------------------------------------------
# Generic decoder-only LM workloads (ties the paper's analysis to every
# assigned architecture; used by the offload planner and benchmarks).
# ----------------------------------------------------------------------------

def lm_workload(*, name: str, n_layers: int, d_model: int, n_heads: int,
                n_kv_heads: int, d_ff: int, vocab: int, seq: int,
                mode: str = "decode", dtype: str = "f16",
                n_experts: int = 0, top_k: int = 0,
                steps: int = 1) -> list[KernelSpec]:
    """Kernel inventory for a decoder-only LM.

    ``mode='decode'``: ``steps`` incremental steps against a KV cache of
    length ``seq``. ``mode='prefill'``: one pass over ``seq`` tokens.
    MoE layers contribute top_k active expert GEMMs per token.
    """
    d, h, hk, ff, v = d_model, n_heads, n_kv_heads, d_ff, vocab
    dh = d // h
    m = 1 if mode == "decode" else seq
    S = seq
    out: list[KernelSpec] = []
    add = out.append
    L = n_layers
    c = steps if mode == "decode" else 1

    add(KernelSpec(f"{name}.attn.q", m, h * dh, d, dtype, L * c, "proj"))
    add(KernelSpec(f"{name}.attn.kv", m, 2 * hk * dh, d, dtype, L * c, "proj"))
    add(KernelSpec(f"{name}.attn.out", m, d, h * dh, dtype, L * c, "proj"))
    add(KernelSpec(f"{name}.attn.qk", m, S, dh, "f16", L * h * c, "attn_qk"))
    add(KernelSpec(f"{name}.attn.av", m, dh, S, "f16", L * h * c, "attn_av"))
    if n_experts and top_k:
        add(KernelSpec(f"{name}.moe.router", m, n_experts, d, dtype, L * c, "proj"))
        # top_k active experts per token; gate+up+down per expert.
        add(KernelSpec(f"{name}.moe.gate", m, ff, d, dtype, L * top_k * c, "mlp"))
        add(KernelSpec(f"{name}.moe.up", m, ff, d, dtype, L * top_k * c, "mlp"))
        add(KernelSpec(f"{name}.moe.down", m, d, ff, dtype, L * top_k * c, "mlp"))
    elif ff:
        add(KernelSpec(f"{name}.mlp.gate", m, ff, d, dtype, L * c, "mlp"))
        add(KernelSpec(f"{name}.mlp.up", m, ff, d, dtype, L * c, "mlp"))
        add(KernelSpec(f"{name}.mlp.down", m, d, ff, dtype, L * c, "mlp"))
    add(KernelSpec(f"{name}.logits", m, v, d, dtype, c, "logits"))
    return out


# ----------------------------------------------------------------------------


def total_flops(work: list[KernelSpec]) -> int:
    return sum(k.flops for k in work)


def total_dot_products(work: list[KernelSpec]) -> int:
    return sum(k.dot_products for k in work)


def total_calls(work: list[KernelSpec]) -> int:
    return sum(k.calls for k in work)


def k_length_histogram(work: list[KernelSpec]) -> dict[int, int]:
    """Histogram of dot-product lengths weighted by dot-product count —
    the distribution behind the paper's burst-length selection (Sec III-B)."""
    hist: dict[int, int] = {}
    for spec in work:
        hist[spec.k] = hist.get(spec.k, 0) + spec.dot_products
    return hist


def iter_unique_gemms(work: list[KernelSpec]) -> Iterator[KernelSpec]:
    seen = set()
    for spec in work:
        key = (spec.m, spec.n, spec.k, spec.dtype)
        if key not in seen:
            seen.add(key)
            yield spec
