"""Working-set / LMM-coverage model (paper contribution C3/C4, Tables I & IV).

The paper's central co-design axis: for each dot-product kernel, how many
bytes must be resident in local memory (LMM on IMAX, a VMEM block budget on
TPU), under two data-handling policies:

* ``baseline``  — whisper.cpp's native layout: the kernel's A-operand is
  staged as stored, i.e. the full padded tensor plane (32-byte row
  alignment, storage dtype). This models the paper's observation that
  without packing, DMA moves padding and whole planes, so almost nothing
  fits a small LMM (Table I: 1.39 % at 32 KB for FP16).
* ``optimized`` — the paper's dense packing + inline conversion: only the
  working tile is resident, already converted to f32 (IMAX PEs compute in
  f32 after inline FP16→FP32 conversion; hence the optimized column of
  Table I is *identical* for the FP16 and Q8_0 models). Tile = N_TILE rows
  of A × K, plus the B row, plus N_TILE accumulators.

``N_TILE = 4`` models IMAX's 4-way column multithreading (Sec III-B).

Exact per-kernel byte counts inside whisper.cpp are not published; this
module reproduces the *structure* of Tables I/IV (near-zero baseline
coverage at small LMM, >90 % optimized coverage at 32 KB for tiny,
dtype-independent optimized column, 64 KB requirement for base/small) and
EXPERIMENTS.md reports our CDF side-by-side with the paper's.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.quantize import bytes_per_elem, stored_bytes
from repro.core.workload import KernelSpec

N_TILE = 4  # column-wise multithreading depth (Sec III-B)

LMM_LIMITS = tuple(kb * 1024 for kb in (8, 16, 32, 64, 128, 256))


def elem_bytes(dtype: str) -> float:
    return bytes_per_elem(dtype)


def kernel_footprint(spec: KernelSpec, policy: str = "optimized",
                     n_tile: int = N_TILE) -> int:
    """Resident LMM bytes for one kernel call under a policy.

    Optimized (packed) residency: n_tile A-rows + one B-row + accumulators.
    Weight operands are inline-converted to f32 in the LMM (paper C1);
    **cache operands (attention QK/AV) stay in their f16 storage dtype** —
    this is what makes the paper's Table IV signature work out: the
    1500-frame attention kernels fit 16 KB for every model size, so
    base/small are flat from 16→32 KB and only the d_ff GEMMs (f32,
    20 bytes/K: tiny 1536 ≤ 32 KB < base 2048 ≤ 64 KB ≥ small 3072)
    produce the coverage jumps."""
    if policy == "optimized":
        elem = 2.0 if spec.tag in ("attn_qk", "attn_av") else 4.0
        return int(elem * (n_tile * spec.k + spec.k) + 4 * n_tile)
    if policy == "baseline":
        # Whole padded A plane in storage dtype + padded B row.
        a_bytes = stored_bytes((spec.n, spec.k), spec.dtype, "baseline")
        b_bytes = stored_bytes((spec.k,), "f16", "baseline")
        return a_bytes + b_bytes
    raise ValueError(f"unknown policy {policy!r}")


@dataclasses.dataclass(frozen=True)
class CoverageRow:
    limit_bytes: int
    coverage_pct: float      # % of kernel calls that fit
    flops_pct: float         # % of FLOPs covered (energy-relevant weighting)


def coverage_cdf(work: Sequence[KernelSpec], policy: str = "optimized",
                 limits: Sequence[int] = LMM_LIMITS,
                 n_tile: int = N_TILE) -> list[CoverageRow]:
    """Cumulative % of kernel calls whose footprint fits each LMM limit
    (paper Tables I & IV)."""
    total_calls = sum(s.calls for s in work)
    total_flops = sum(s.flops for s in work)
    rows = []
    for limit in limits:
        calls = sum(s.calls for s in work
                    if kernel_footprint(s, policy, n_tile) <= limit)
        flops = sum(s.flops for s in work
                    if kernel_footprint(s, policy, n_tile) <= limit)
        rows.append(CoverageRow(
            limit_bytes=limit,
            coverage_pct=100.0 * calls / max(total_calls, 1),
            flops_pct=100.0 * flops / max(total_flops, 1),
        ))
    return rows


# ----------------------------------------------------------------------------
# TPU adaptation: VMEM block-budget selection for the Pallas kernels.
# ----------------------------------------------------------------------------

MXU_LANE = 128   # last-dim tile multiple
MXU_SUBLANE = 8  # second-minor tile multiple (f32)


@dataclasses.dataclass(frozen=True)
class BlockShape:
    bm: int
    bn: int
    bk: int
    vmem_bytes: int

    def fits(self, budget: int) -> bool:
        return self.vmem_bytes <= budget


def block_vmem_bytes(bm: int, bn: int, bk: int, a_dtype: str,
                     b_dtype: str = "f32") -> int:
    """VMEM bytes for one (bm×bk)·(bk×bn) step: A tile + B tile + f32 acc.
    Double-buffered input tiles (Pallas pipelines the next block)."""
    a = bm * bk * elem_bytes(a_dtype)
    b = bk * bn * elem_bytes(b_dtype)
    acc = bm * bn * 4
    return int(2 * (a + b) + acc)


def select_blocks(m: int, n: int, k: int, budget_bytes: int,
                  a_dtype: str = "bf16", b_dtype: str = "bf16") -> BlockShape:
    """Choose MXU-aligned block shapes under a VMEM byte budget — the TPU
    binding of the paper's LMM-size knob. Greedy: grow bk (reuse), then
    bn/bm (MXU utilization), staying under budget."""
    def rdown(x: int, mult: int) -> int:
        return max(mult, (x // mult) * mult)

    m_c = rdown(min(m, 256), MXU_SUBLANE)
    n_c = rdown(min(n, 256), MXU_LANE)
    k_c = rdown(min(k, 2048), MXU_LANE if k >= MXU_LANE else 32)

    best = None
    bk = k_c
    while bk >= 32:
        bn = n_c
        while bn >= MXU_LANE or bn == n_c:
            bm = m_c
            while bm >= MXU_SUBLANE:
                vb = block_vmem_bytes(bm, bn, bk, a_dtype, b_dtype)
                if vb <= budget_bytes:
                    cand = BlockShape(bm, bn, bk, vb)
                    # prefer larger MXU tiles, then larger K reuse
                    key = (bm * bn, bk)
                    if best is None or key > (best.bm * best.bn, best.bk):
                        best = cand
                    break
                bm //= 2
                bm = rdown(bm, MXU_SUBLANE) if bm >= MXU_SUBLANE else 0
                if bm == 0:
                    break
            if bn <= MXU_LANE:
                break
            bn = rdown(bn // 2, MXU_LANE)
        if bk <= 32:
            break
        bk = max(32, rdown(bk // 2, 32))
    if best is None:
        raise ValueError(
            f"no MXU-aligned block fits budget={budget_bytes}B for "
            f"gemm ({m}x{k})@({k}x{n})")
    return best
