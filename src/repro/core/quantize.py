"""Q8_0 / Q4_0 blockwise quantization (paper contribution C1/C3).

The paper reuses ggml's Q8_0 format: the innermost dimension is split into
blocks of 32 elements; each block stores 32 int8 values plus one fp16 scale
``d = max(|x|)/127`` (1.0625 bytes/element vs 2 for fp16).

Q4_0 is the tier below: the same 32-element blocks store symmetric 4-bit
codes ``q = round(x / d) in [-7, 7]`` with ``d = max(|x|)/7``, packed two
codes per byte (0.5625 bytes/element) — the CGLA follow-up's headline
low-bit dot-product tier.

On TPU we keep the exact formats but store the code plane and the scale
plane as two dense arrays (the paper's "padding removal": no interleaved
headers, no row-alignment padding), which is what the Pallas kernels
consume.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

QBLOCK = 32  # ggml Q8_0/Q4_0 block size (elements)
Q8_BYTES_PER_BLOCK = QBLOCK + 2  # 32 int8 + fp16 scale
Q8_BYTES_PER_ELEM = Q8_BYTES_PER_BLOCK / QBLOCK  # 1.0625
Q4_BYTES_PER_BLOCK = QBLOCK // 2 + 2  # 32 packed nibbles + fp16 scale
Q4_BYTES_PER_ELEM = Q4_BYTES_PER_BLOCK / QBLOCK  # 0.5625

#: Storage bytes per element for every supported tier — the one place the
#: rest of the stack (``stored_bytes``, ``core.footprint.elem_bytes``, the
#: serving cache pricing) reads element sizes from.
BYTES_PER_ELEM = {
    "f32": 4.0,
    "f16": 2.0,
    "bf16": 2.0,
    "q8_0": Q8_BYTES_PER_ELEM,
    "q4_0": Q4_BYTES_PER_ELEM,
}


def bytes_per_elem(dtype: str) -> float:
    """Element size of a storage tier; raises a ``ValueError`` naming the
    supported tiers on an unknown dtype string (not an opaque KeyError)."""
    try:
        return BYTES_PER_ELEM[dtype]
    except KeyError:
        raise ValueError(
            f"unknown storage dtype {dtype!r}; supported tiers: "
            f"{sorted(BYTES_PER_ELEM)}"
        ) from None


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Q8Tensor:
    """A Q8_0-quantized tensor. ``q``: int8 of the original shape.
    ``scale``: float16/float32, original shape with last dim // QBLOCK."""

    q: jax.Array
    scale: jax.Array

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        return self.q.shape

    @property
    def nbytes_packed(self) -> int:
        """Dense-packed storage bytes (optimized policy, C3)."""
        return int(self.q.size) + 2 * int(self.scale.size)


def _check_last_dim(k: int) -> None:
    if k % QBLOCK != 0:
        raise ValueError(
            f"Q8_0 requires the last dim ({k}) to be a multiple of {QBLOCK}; "
            "pad with pad_to_block() first."
        )


def pad_to_block(x: jax.Array, block: int = QBLOCK) -> jax.Array:
    """Zero-pad the last dim up to a multiple of ``block``."""
    k = x.shape[-1]
    rem = (-k) % block
    if rem == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
    return jnp.pad(x, pad)


def quantize_q8_0(x: jax.Array, scale_dtype=jnp.float16,
                  axis: int = -1) -> Q8Tensor:
    """Quantize to Q8_0 with 32-element blocks along ``axis`` (the
    contraction dim for weights consumed by the Pallas kernel, which stores
    W as (K, N) and quantizes along K). ``axis`` dim must be a multiple of
    QBLOCK."""
    axis = axis % x.ndim
    xm = jnp.moveaxis(x, axis, -1)
    _check_last_dim(xm.shape[-1])
    blocks = xm.astype(jnp.float32).reshape(*xm.shape[:-1], -1, QBLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=-1)
    d = (amax / 127.0).astype(scale_dtype)
    # ggml: inverse scale with zero guard.
    inv = jnp.where(d > 0, 1.0 / d.astype(jnp.float32), 0.0)
    q = jnp.clip(jnp.round(blocks * inv[..., None]), -127, 127).astype(jnp.int8)
    q = jnp.moveaxis(q.reshape(xm.shape), -1, axis)
    scale = jnp.moveaxis(d, -1, axis)
    return Q8Tensor(q=q, scale=scale)


def dequantize_q8_0(t: Q8Tensor, dtype=jnp.float32, axis: int = -1) -> jax.Array:
    """Exact inverse of the storage transform (not of quantize: lossy)."""
    axis = axis % t.q.ndim
    qm = jnp.moveaxis(t.q, axis, -1)
    sm = jnp.moveaxis(t.scale, axis, -1)
    q = qm.reshape(*qm.shape[:-1], -1, QBLOCK).astype(jnp.float32)
    x = q * sm.astype(jnp.float32)[..., None]
    return jnp.moveaxis(x.reshape(qm.shape), -1, axis).astype(dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Q4Tensor:
    """A Q4_0-quantized tensor. ``q``: uint8 plane with the quantized axis
    halved — each byte packs two consecutive 4-bit codes (low nibble =
    even index, high nibble = odd index), biased by +8 so codes occupy
    [1, 15]. ``scale``: float16/float32, quantized axis // QBLOCK."""

    q: jax.Array
    scale: jax.Array

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        """Packed-plane shape (the quantized axis is halved)."""
        return self.q.shape

    @property
    def nbytes_packed(self) -> int:
        """Dense-packed storage bytes (optimized policy, C3)."""
        return int(self.q.size) + 2 * int(self.scale.size)


def pack_q4(codes: jax.Array, axis: int = -1) -> jax.Array:
    """Pack int8 codes in [-8, 7] two-per-byte along ``axis`` (length must
    be even): byte i = (codes[2i] + 8) | ((codes[2i+1] + 8) << 4)."""
    axis = axis % codes.ndim
    cm = jnp.moveaxis(codes, axis, -1)
    k = cm.shape[-1]
    if k % 2 != 0:
        raise ValueError(f"pack_q4 needs an even axis length, got {k}")
    pairs = (cm.astype(jnp.int32) + 8).astype(jnp.uint8)
    pairs = pairs.reshape(*cm.shape[:-1], k // 2, 2)
    packed = pairs[..., 0] | (pairs[..., 1] << 4)
    return jnp.moveaxis(packed, -1, axis)


def unpack_q4(packed: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse of :func:`pack_q4`: uint8 bytes -> int8 codes in [-8, 7],
    ``axis`` doubled."""
    axis = axis % packed.ndim
    pm = jnp.moveaxis(packed, axis, -1)
    lo = (pm & jnp.uint8(0xF)).astype(jnp.int8) - 8
    hi = (pm >> 4).astype(jnp.int8) - 8
    codes = jnp.stack([lo, hi], axis=-1).reshape(*pm.shape[:-1],
                                                 2 * pm.shape[-1])
    return jnp.moveaxis(codes, -1, axis)


def quantize_q4_0(x: jax.Array, scale_dtype=jnp.float16,
                  axis: int = -1) -> Q4Tensor:
    """Quantize to Q4_0 with 32-element blocks along ``axis``; symmetric
    codes in [-7, 7] with ``d = max(|x|)/7``, packed two per byte along
    the same axis. ``axis`` dim must be a multiple of QBLOCK."""
    axis = axis % x.ndim
    xm = jnp.moveaxis(x, axis, -1)
    _check_last_dim(xm.shape[-1])
    blocks = xm.astype(jnp.float32).reshape(*xm.shape[:-1], -1, QBLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=-1)
    d = (amax / 7.0).astype(scale_dtype)
    inv = jnp.where(d > 0, 1.0 / d.astype(jnp.float32), 0.0)
    q = jnp.clip(jnp.round(blocks * inv[..., None]), -7, 7).astype(jnp.int8)
    codes = jnp.moveaxis(q.reshape(xm.shape), -1, axis)
    scale = jnp.moveaxis(d, -1, axis)
    return Q4Tensor(q=pack_q4(codes, axis=axis), scale=scale)


def dequantize_q4_0(t: Q4Tensor, dtype=jnp.float32, axis: int = -1) -> jax.Array:
    """Exact inverse of the storage transform (not of quantize: lossy)."""
    axis = axis % t.q.ndim
    codes = unpack_q4(t.q, axis=axis)
    qm = jnp.moveaxis(codes, axis, -1)
    sm = jnp.moveaxis(t.scale, axis, -1)
    q = qm.reshape(*qm.shape[:-1], -1, QBLOCK).astype(jnp.float32)
    x = q * sm.astype(jnp.float32)[..., None]
    return jnp.moveaxis(x.reshape(qm.shape), -1, axis).astype(dtype)


def quantization_error_bound(t) -> jax.Array:
    """Per-block worst-case absolute error: d/2 (round-to-nearest).
    Accepts either a :class:`Q8Tensor` or a :class:`Q4Tensor`."""
    return t.scale.astype(jnp.float32) / 2.0


def as_array(leaf: Any, dtype=jnp.bfloat16, axis: int = -2) -> jax.Array:
    """Dequantize a Q8Tensor/Q4Tensor (blocked along ``axis``, the
    quantize_tree convention) or cast a plain array — for params consumed
    outside the quant-aware ``mm`` path (positional tables, frontends)."""
    if isinstance(leaf, Q8Tensor):
        return dequantize_q8_0(leaf, dtype, axis=axis)
    if isinstance(leaf, Q4Tensor):
        return dequantize_q4_0(leaf, dtype, axis=axis)
    return leaf.astype(dtype)


def quantize_tree(params: Any, predicate=None, tier: str = "q8_0") -> Any:
    """Quantize every float leaf (matching ``predicate(path, leaf)``) of a
    param pytree to Q8Tensor/Q4Tensor; other leaves pass through. Used to
    build the Q8_0/Q4_0 serving variants of any architecture (paper Sec
    III-A; ``tier="q4_0"`` builds the self-speculative draft weights)."""
    if tier not in ("q8_0", "q4_0"):
        raise ValueError(
            f"unknown weight tier {tier!r}; supported: ['q4_0', 'q8_0']")
    qfn = quantize_q8_0 if tier == "q8_0" else quantize_q4_0

    def _q(path, leaf):
        if not isinstance(leaf, jax.Array) and not hasattr(leaf, "dtype"):
            return leaf
        if leaf.ndim < 2 or leaf.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
            return leaf
        # weights are stored (…, K, N); quantize along the contraction dim
        if leaf.shape[-2] % QBLOCK != 0:
            return leaf
        if predicate is not None and not predicate(path, leaf):
            return leaf
        return qfn(leaf, axis=-2)

    return jax.tree_util.tree_map_with_path(_q, params)


# ----------------------------------------------------------------------------
# Storage accounting (paper C3: padding removal)
# ----------------------------------------------------------------------------

def stored_bytes(shape, dtype: str, policy: str = "optimized",
                 align_bytes: int = 32) -> int:
    """Bytes occupied by a tensor under a packing policy.

    ``baseline`` models whisper.cpp's row layout where each row (last dim) is
    padded up to ``align_bytes`` alignment; ``optimized`` is the paper's dense
    packing (C3).
    """
    elem = bytes_per_elem(dtype)
    *lead, k = shape
    rows = 1
    for d in lead:
        rows *= d
    row_bytes = k * elem
    if policy == "baseline":
        row_bytes = -(-row_bytes // align_bytes) * align_bytes
    return int(rows * row_bytes)
