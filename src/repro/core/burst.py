"""Mixed-execution burst partitioning (paper contribution C2).

IMAX processes fixed-length bursts efficiently; variable-length dot products
are split into a burst-aligned *main* segment (offloaded) and a small
*residual* tail (host CPU). On TPU the same split applies between the Pallas
kernel (tile-aligned K) and a plain-XLA residual; the planner below also
reproduces the paper's burst-length design-space exploration (burst=16 was
found optimal for Whisper's vector-length distribution).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

DEFAULT_BURST = 16


@dataclasses.dataclass(frozen=True)
class BurstSplit:
    k: int
    burst: int
    k_main: int      # burst-aligned prefix, offloaded
    k_residual: int  # tail, host/XLA path

    @property
    def offload_fraction(self) -> float:
        return self.k_main / self.k if self.k else 0.0


def split_burst(k: int, burst: int = DEFAULT_BURST) -> BurstSplit:
    if k < 0 or burst <= 0:
        raise ValueError(f"invalid split: k={k}, burst={burst}")
    k_main = (k // burst) * burst
    return BurstSplit(k=k, burst=burst, k_main=k_main, k_residual=k - k_main)


def offload_rate(lengths: Mapping[int, int] | Sequence[int],
                 burst: int = DEFAULT_BURST) -> float:
    """MAC-weighted fraction of work on the accelerator for a vector-length
    distribution. ``lengths`` is either a {K: count} histogram or a sequence
    of Ks. The paper reports ~95% offload (5% residual) at burst=16."""
    hist = dict(lengths) if isinstance(lengths, Mapping) else None
    if hist is None:
        hist = {}
        for k in lengths:
            hist[k] = hist.get(k, 0) + 1
    total = sum(k * c for k, c in hist.items())
    if total == 0:
        return 0.0
    main = sum(split_burst(k, burst).k_main * c for k, c in hist.items())
    return main / total


@dataclasses.dataclass(frozen=True)
class BurstCost:
    burst: int
    offload: float          # MAC fraction on accelerator
    accel_time: float       # modeled seconds on the accelerator
    host_time: float        # modeled seconds for the residual tail
    total_time: float       # accel + host (residual only partially hides)


def burst_cost(lengths: Mapping[int, int], burst: int, *,
               t_mac_accel: float, t_mac_host: float,
               t_burst_overhead: float) -> BurstCost:
    """Latency model behind the paper's burst-length trade-off: a larger
    burst amortizes per-burst overhead but lowers the offload rate (more
    residual work lands on the slow host path)."""
    accel = 0.0
    host = 0.0
    for k, count in lengths.items():
        s = split_burst(k, burst)
        n_bursts = s.k_main // burst
        accel += count * (s.k_main * t_mac_accel + n_bursts * t_burst_overhead)
        host += count * (s.k_residual * t_mac_host)
    return BurstCost(
        burst=burst,
        offload=offload_rate(lengths, burst),
        accel_time=accel,
        host_time=host,
        total_time=accel + host,
    )


def optimal_burst(lengths: Mapping[int, int],
                  candidates: Iterable[int] = (4, 8, 16, 32, 64, 128), *,
                  t_mac_accel: float = 1.0,
                  t_mac_host: float = 2.76,
                  t_burst_overhead: float = 0.065) -> BurstCost:
    """Sweep burst lengths and return the latency-minimizing one.

    Default cost ratios are derived from the paper-calibrated accelerator
    model (repro.core.energy.calibrate_imax): the A72 host path is ~2.76x
    slower per MAC than IMAX; the per-burst setup cost (in units of one
    accelerator MAC) is bounded to [0.05, 0.08] by requiring burst=16 to
    minimize total latency over Whisper's K-length distribution — i.e. the
    paper's Sec III-B DSE outcome pins the one free parameter (larger
    bursts amortize overhead but push more residual MACs to the slow host
    path; at ov>=0.12 burst 64 would win, at ov<=0.02 burst 8 would).
    """
    best = None
    for b in candidates:
        c = burst_cost(lengths, b, t_mac_accel=t_mac_accel,
                       t_mac_host=t_mac_host, t_burst_overhead=t_burst_overhead)
        if best is None or c.total_time < best.total_time:
            best = c
    assert best is not None
    return best
