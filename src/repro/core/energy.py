"""Energy model: power, PDP, calibration, LMM/VMEM sweeps (paper C5).

Reproduces the paper's evaluation methodology, sourcing every hardware
fact through the platform registry (``repro.platforms``):

* ``imax_power`` / ``interp_power`` — Table II power-vs-LMM curves
  (log-linear interpolation) read from the ``imax3-28nm`` platforms.
* ``calibrate_imax`` — closed-form fit of the 4-parameter AccelModel to
  the paper's published observables carried on the platform (FP16/Q8_0
  E2E latency 13.5 s / 11.1 s, EXEC shares 60.89 % / 74.70 %, host-only
  latency 24.4 s / 19.6 s). The paper's numbers over-determine the
  model; the residual mismatch is reported by the benchmark as a
  reproduction check.
* ``pdp`` and ``lmm_sweep`` — Figs 4/5/6: latency & PDP vs LMM size,
  with the PDP minimum expected at 32 KB.
* ``platform_pdp_table`` — Figs 4+5 over the whole registry: every
  platform with published observables, our calibrated IMAX model, and
  the TPU v5e projection on one axis.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

from repro.core.burst import split_burst
from repro.core.offload import AccelModel, Breakdown, execution_breakdown, staged_bytes, plan_offload
from repro.core.workload import KernelSpec, total_flops
from repro.platforms import Platform, get_platform, list_platforms
from repro.platforms.base import interp_power_log

PlatformLike = Union[str, Platform]


def interp_power(table: dict[int, float], size_bytes: int) -> float:
    """Log-linear interpolation of a power-vs-size table (Table II):
    linear in log(size), so the geometric-mean size maps to the
    arithmetic-mean power."""
    return interp_power_log(table, size_bytes)


def imax_power(lmm_bytes: int, kernel: str = "fp16", lanes: int = 1,
               platform: PlatformLike = "imax3-28nm") -> float:
    """Table-II power at an arbitrary LMM size, interpolated on the
    platform's power curves."""
    return get_platform(platform).power.power(kernel, lmm_bytes,
                                              lanes=lanes)


def pdp(latency_s: float, power_w: float) -> float:
    """Power-Delay Product (paper Eq. 1), in joules."""
    return latency_s * power_w


def phase_pdp(breakdown, accel_power_w: float,
              host_power_w: Optional[float] = None) -> float:
    """Phase-wise energy: the accelerator draws power only while a kernel
    is resident (EXEC+LOAD+CONF); the host CPU draws power for the whole
    run (orchestration + residual + fallback). This is the accounting
    that reproduces the paper's published Fig-5 Q8_0 PDP (12.6 J), which
    nominal-power x latency (Eq 1: 11.1 x 1.32 = 14.7 J) does not — their
    §IV-A notes power was measured per phase."""
    if host_power_w is None:
        host_power_w = get_platform("cortex-a72").power.nominal_w
    return (accel_power_w * breakdown.accel_s
            + host_power_w * breakdown.total_s)


# ----------------------------------------------------------------------------
# Calibration to the paper's observables
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Calibration:
    model: AccelModel
    residuals: dict[str, float]   # relative errors vs paper observables
    platform: Optional[Platform] = None   # target carrying the model


def calibrate_imax(work_fp16: Sequence[KernelSpec],
                   work_q8: Sequence[KernelSpec],
                   budget_bytes: Optional[int] = None,
                   conf_share: float = 0.04,
                   platform: PlatformLike = "imax3-28nm/32k",
                   host: PlatformLike = "cortex-a72") -> Calibration:
    """Closed-form fit of (flops_rate, mem_bw, conf_time, host_rate) to
    ``platform``'s *FP16* observables only; the Q8_0 observables are then
    **predictions** and their residuals are the cross-validation of the
    model (reported by benchmarks/fig7_breakdown.py).

    FP16 observables used: E2E latency 13.5 s, EXEC share 60.89 %, host-
    only latency 24.4 s — all read from the platform registry entries.
    ``conf_share`` apportions the paper's unlabeled CONF/REGV/RANGE/
    REFILL sliver of Fig 7 (~4 % of accel time)."""
    plat = get_platform(platform)
    hostp = get_platform(host)
    if budget_bytes is None:
        budget_bytes = plat.vmem_budget
    t16 = plat.paper_observable("latency_s", "fp16")
    t8 = plat.paper_observable("latency_s", "q8_0")
    s16 = plat.paper_observable("exec_share", "fp16")
    s8 = plat.paper_observable("exec_share", "q8_0")
    host16 = hostp.paper_observable("latency_s", "fp16")
    host8 = hostp.paper_observable("latency_s", "q8_0")
    missing = [k for k, v in [("latency fp16", t16), ("latency q8", t8),
                              ("exec_share fp16", s16),
                              ("exec_share q8", s8),
                              ("host latency fp16", host16),
                              ("host latency q8", host8)] if v is None]
    if missing:
        raise ValueError(
            f"platform {plat.name!r}/{hostp.name!r} lacks the paper "
            f"observables needed for calibration: {missing}")

    f_total = total_flops(list(work_fp16))
    host_rate16 = f_total / host16
    host_rate8 = total_flops(list(work_q8)) / host8

    plan16 = plan_offload(work_fp16, budget_bytes)
    b16 = sum(staged_bytes(s) * s.calls for s in plan16.accel)
    calls16 = sum(s.calls for s in plan16.accel)
    f_off16 = sum(s.flops * split_burst(s.k).offload_fraction
                  for s in plan16.accel)
    f_host16 = f_total - f_off16
    host_s16 = f_host16 / host_rate16

    accel16 = max(t16 - host_s16, 1e-9)        # EXEC + LOAD + CONF
    exec_s = accel16 * s16
    conf_total = accel16 * conf_share
    load16 = accel16 - exec_s - conf_total

    model = AccelModel(
        name=f"{plat.name}(calibrated)",
        flops_rate=f_off16 / exec_s,
        mem_bw=b16 / load16,
        conf_time=conf_total / max(calls16, 1),
        host_flops_rate=(host_rate16 + host_rate8) / 2,
    )
    # fp16 residuals close by construction; q8 rows are predictions.
    bd16 = execution_breakdown(work_fp16, model, budget_bytes)
    bd8 = execution_breakdown(work_q8, model, budget_bytes)
    residuals = {
        "latency_fp16(fit)": bd16.total_s / t16 - 1.0,
        "exec_share_fp16(fit)": bd16.exec_share / s16 - 1.0,
        "latency_q8(pred)": bd8.total_s / t8 - 1.0,
        "exec_share_q8(pred)": bd8.exec_share / s8 - 1.0,
    }
    return Calibration(model=model, residuals=residuals,
                       platform=plat.with_accel_model(model))


# ----------------------------------------------------------------------------
# LMM / VMEM-budget sweep (Fig 6)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepPoint:
    budget_bytes: int
    latency_s: float
    power_w: float
    pdp_j: float
    breakdown: Breakdown


def lmm_sweep(work: Sequence[KernelSpec], model: AccelModel, kernel: str,
              budgets: Sequence[int] = tuple(k * 1024 for k in (16, 32, 64, 128)),
              lanes: int = 1,
              platform: PlatformLike = "imax3-28nm") -> list[SweepPoint]:
    """Latency/power/PDP vs local-memory budget (Fig 6). Larger budgets
    admit more kernels (less host fallback) but cost static power
    (the platform's Table-II curves); the paper's minimum is at 32 KB."""
    plat = get_platform(platform)
    out = []
    for budget in budgets:
        bd = execution_breakdown(work, model, budget)
        p = plat.power.power(kernel, budget, lanes=lanes)
        out.append(SweepPoint(budget, bd.total_s, p, pdp(bd.total_s, p), bd))
    return out


# ----------------------------------------------------------------------------
# TPU projection (beyond-paper platform row; honest v5e constants)
# ----------------------------------------------------------------------------

def tpu_accel_model(platform: PlatformLike = "tpu-v5e",
                    mxu_efficiency: float = 0.5,
                    conf_time: float = 2e-6) -> AccelModel:
    """The TPU platform as the 'accelerator': matvec-dominated decode is
    HBM-bound, so mem_bw is the binding constant; mxu_efficiency derates
    peak for the small-GEMM regime. The 'host' fallback is the same
    chip's VPU at a scalar-ish rate (kernels that skip the MXU path)."""
    plat = get_platform(platform)
    return AccelModel(
        name=plat.name,
        flops_rate=plat.peak_flops("bf16") * mxu_efficiency,
        mem_bw=plat.memory.main_bw,
        conf_time=conf_time,
        host_flops_rate=2e12,   # VPU-path effective rate
    )


def platform_pdp_table(work_fp16, work_q8, calib: Calibration,
                       budget_bytes: int = 32 * 1024) -> list[dict]:
    """Fig 4 + Fig 5 in one table, iterating the platform registry:
    every platform carrying published observables (paper rows) + our
    calibrated IMAX model + the TPU v5e projection."""
    rows = []
    for name in list_platforms():
        plat = get_platform(name)
        lat = plat.paper.get("latency_s", {})
        for kern in sorted(lat):
            power = plat.platform_power(kern)
            rows.append(dict(
                device=plat.family, platform=plat.name, kernel=kern,
                latency_s=lat[kern], power_w=power,
                pdp_j=pdp(lat[kern], power),
                pdp_paper_j=plat.paper_observable("pdp_j", kern),
                source="paper"))
    imax = get_platform("imax3-28nm")
    for kern, work in (("fp16", work_fp16), ("q8_0", work_q8)):
        bd = execution_breakdown(work, calib.model, budget_bytes)
        power = imax.power.power(kern, budget_bytes)
        rows.append(dict(device=f"{imax.family}(model)",
                         platform=imax.name, kernel=kern,
                         latency_s=bd.total_s, power_w=power,
                         pdp_j=pdp(bd.total_s, power),
                         pdp_phase_j=phase_pdp(bd, power), source="model"))
    tpu_plat = get_platform("tpu-v5e")
    tpu = tpu_plat.accel_model or tpu_accel_model(tpu_plat)
    for kern, work in (("fp16", work_fp16), ("q8_0", work_q8)):
        bd = execution_breakdown(work, tpu, tpu_plat.vmem_budget)
        # utilization-scaled power
        util = bd.exec_s / max(bd.total_s, 1e-12)
        power = tpu_plat.power.power(kern, util=util)
        rows.append(dict(device=f"{tpu_plat.name}(projection)",
                         platform=tpu_plat.name, kernel=kern,
                         latency_s=bd.total_s, power_w=power,
                         pdp_j=pdp(bd.total_s, power), source="model"))
    return rows
