"""Energy model: power, PDP, calibration, LMM/VMEM sweeps (paper C5).

Reproduces the paper's evaluation methodology:

* ``imax_power`` / ``vmem_static_power`` — Table II power-vs-LMM curves.
* ``calibrate_imax`` — closed-form fit of the 4-parameter AccelModel to the
  paper's published observables (FP16/Q8_0 E2E latency 13.5 s / 11.1 s,
  EXEC shares 60.89 % / 74.70 %, host-only latency 24.4 s / 19.6 s). The
  paper's numbers over-determine the model; the residual mismatch is
  reported by the benchmark as a reproduction check.
* ``pdp`` and ``lmm_sweep`` — Figs 4/5/6: latency & PDP vs LMM size, with
  the PDP minimum expected at 32 KB.

The same machinery runs against TPU v5e constants (uncalibrated, honest
roofline) to place a TPU projection on the paper's axes and to drive the
VMEM-block-budget sweep of the Pallas kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro import hw
from repro.core.burst import split_burst
from repro.core.offload import AccelModel, Breakdown, execution_breakdown, staged_bytes, plan_offload
from repro.core.workload import KernelSpec, total_flops


def interp_power(table: dict[int, float], size_bytes: int) -> float:
    """Log-linear interpolation of a power-vs-size table (Table II)."""
    pts = sorted(table.items())
    if size_bytes <= pts[0][0]:
        return pts[0][1]
    if size_bytes >= pts[-1][0]:
        return pts[-1][1]
    for (s0, p0), (s1, p1) in zip(pts, pts[1:]):
        if s0 <= size_bytes <= s1:
            t = (size_bytes - s0) / (s1 - s0)
            return p0 + t * (p1 - p0)
    raise AssertionError


def imax_power(lmm_bytes: int, kernel: str = "fp16", lanes: int = 1) -> float:
    table = hw.IMAX_POWER_FP16_W if kernel == "fp16" else hw.IMAX_POWER_Q8_W
    return lanes * interp_power(table, lmm_bytes)


def pdp(latency_s: float, power_w: float) -> float:
    """Power-Delay Product (paper Eq. 1), in joules."""
    return latency_s * power_w


def phase_pdp(breakdown, accel_power_w: float,
              host_power_w: float = hw.PLATFORM_POWER_W["cortex-a72"]) -> float:
    """Phase-wise energy: the accelerator draws power only while a kernel
    is resident (EXEC+LOAD+CONF); the host CPU draws power for the whole
    run (orchestration + residual + fallback). This is the accounting
    that reproduces the paper's published Fig-5 Q8_0 PDP (12.6 J), which
    nominal-power x latency (Eq 1: 11.1 x 1.32 = 14.7 J) does not — their
    §IV-A notes power was measured per phase."""
    return (accel_power_w * breakdown.accel_s
            + host_power_w * breakdown.total_s)


# ----------------------------------------------------------------------------
# Calibration to the paper's observables
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Calibration:
    model: AccelModel
    residuals: dict[str, float]   # relative errors vs paper observables


def calibrate_imax(work_fp16: Sequence[KernelSpec],
                   work_q8: Sequence[KernelSpec],
                   budget_bytes: int = 32 * 1024,
                   conf_share: float = 0.04) -> Calibration:
    """Closed-form fit of (flops_rate, mem_bw, conf_time, host_rate) to the
    paper's *FP16* observables only; the Q8_0 observables are then
    **predictions** and their residuals are the cross-validation of the
    model (reported by benchmarks/fig7_breakdown.py).

    FP16 observables used: E2E latency 13.5 s, EXEC share 60.89 %, host-only
    latency 24.4 s. ``conf_share`` apportions the paper's unlabeled
    CONF/REGV/RANGE/REFILL sliver of Fig 7 (~4 % of accel time).
    """
    t16 = hw.PAPER_LATENCY_S[("imax3-28nm", "fp16")]
    t8 = hw.PAPER_LATENCY_S[("imax3-28nm", "q8_0")]
    s16, s8 = hw.PAPER_EXEC_SHARE["fp16"], hw.PAPER_EXEC_SHARE["q8_0"]
    host16 = hw.PAPER_LATENCY_S[("cortex-a72", "fp16")]
    host8 = hw.PAPER_LATENCY_S[("cortex-a72", "q8_0")]

    f_total = total_flops(list(work_fp16))
    host_rate16 = f_total / host16
    host_rate8 = total_flops(list(work_q8)) / host8

    plan16 = plan_offload(work_fp16, budget_bytes)
    b16 = sum(staged_bytes(s) * s.calls for s in plan16.accel)
    calls16 = sum(s.calls for s in plan16.accel)
    f_off16 = sum(s.flops * split_burst(s.k).offload_fraction
                  for s in plan16.accel)
    f_host16 = f_total - f_off16
    host_s16 = f_host16 / host_rate16

    accel16 = max(t16 - host_s16, 1e-9)        # EXEC + LOAD + CONF
    exec_s = accel16 * s16
    conf_total = accel16 * conf_share
    load16 = accel16 - exec_s - conf_total

    model = AccelModel(
        name="imax3-28nm(calibrated)",
        flops_rate=f_off16 / exec_s,
        mem_bw=b16 / load16,
        conf_time=conf_total / max(calls16, 1),
        host_flops_rate=(host_rate16 + host_rate8) / 2,
    )
    # fp16 residuals close by construction; q8 rows are predictions.
    bd16 = execution_breakdown(work_fp16, model, budget_bytes)
    bd8 = execution_breakdown(work_q8, model, budget_bytes)
    residuals = {
        "latency_fp16(fit)": bd16.total_s / t16 - 1.0,
        "exec_share_fp16(fit)": bd16.exec_share / s16 - 1.0,
        "latency_q8(pred)": bd8.total_s / t8 - 1.0,
        "exec_share_q8(pred)": bd8.exec_share / s8 - 1.0,
    }
    return Calibration(model=model, residuals=residuals)


# ----------------------------------------------------------------------------
# LMM / VMEM-budget sweep (Fig 6)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepPoint:
    budget_bytes: int
    latency_s: float
    power_w: float
    pdp_j: float
    breakdown: Breakdown


def lmm_sweep(work: Sequence[KernelSpec], model: AccelModel, kernel: str,
              budgets: Sequence[int] = tuple(k * 1024 for k in (16, 32, 64, 128)),
              lanes: int = 1) -> list[SweepPoint]:
    """Latency/power/PDP vs local-memory budget (Fig 6). Larger budgets
    admit more kernels (less host fallback) but cost static power
    (Table II); the paper's minimum is at 32 KB."""
    out = []
    for budget in budgets:
        bd = execution_breakdown(work, model, budget)
        p = imax_power(budget, kernel, lanes)
        out.append(SweepPoint(budget, bd.total_s, p, pdp(bd.total_s, p), bd))
    return out


# ----------------------------------------------------------------------------
# TPU projection (beyond-paper platform row; honest v5e constants)
# ----------------------------------------------------------------------------

def tpu_accel_model(chip: hw.ChipSpec = hw.TPU_V5E,
                    mxu_efficiency: float = 0.5,
                    conf_time: float = 2e-6) -> AccelModel:
    """v5e as the 'accelerator': matvec-dominated decode is HBM-bound, so
    mem_bw is the binding constant; mxu_efficiency derates peak for the
    small-GEMM regime. The 'host' fallback is the same chip's VPU at a
    scalar-ish rate (kernels that skip the MXU path)."""
    return AccelModel(
        name=chip.name,
        flops_rate=chip.peak_flops_bf16 * mxu_efficiency,
        mem_bw=chip.hbm_bandwidth,
        conf_time=conf_time,
        host_flops_rate=2e12,   # VPU-path effective rate
    )


def platform_pdp_table(work_fp16, work_q8, calib: Calibration,
                       budget_bytes: int = 32 * 1024) -> list[dict]:
    """Fig 4 + Fig 5 in one table: paper platforms (paper numbers) + our
    calibrated IMAX model + the TPU v5e projection."""
    rows = []
    for (dev, kern), lat in sorted(hw.PAPER_LATENCY_S.items()):
        if dev == "imax3-28nm":
            power = imax_power(budget_bytes, "fp16" if kern == "fp16" else "q8_0")
        else:
            power = hw.PLATFORM_POWER_W.get(dev, float("nan"))
        rows.append(dict(device=dev, kernel=kern, latency_s=lat,
                         power_w=power, pdp_j=pdp(lat, power),
                         source="paper"))
    for kern, work in (("fp16", work_fp16), ("q8_0", work_q8)):
        bd = execution_breakdown(work, calib.model, budget_bytes)
        power = imax_power(budget_bytes, kern)
        rows.append(dict(device="imax3-28nm(model)", kernel=kern,
                         latency_s=bd.total_s, power_w=power,
                         pdp_j=pdp(bd.total_s, power),
                         pdp_phase_j=phase_pdp(bd, power), source="model"))
    tpu = tpu_accel_model()
    for kern, work in (("fp16", work_fp16), ("q8_0", work_q8)):
        bd = execution_breakdown(work, tpu, hw.TPU_V5E.vmem_bytes)
        # utilization-scaled power
        util = bd.exec_s / max(bd.total_s, 1e-12)
        power = hw.TPU_V5E.idle_power_w + util * (
            hw.TPU_V5E.power_w - hw.TPU_V5E.idle_power_w)
        rows.append(dict(device="tpu-v5e(projection)", kernel=kern,
                         latency_s=bd.total_s, power_w=power,
                         pdp_j=pdp(bd.total_s, power), source="model"))
    return rows
