"""ACCEL/HOST offload planning + execution-time breakdown (paper C5, Fig 7).

The paper's control law: a kernel is offloaded to IMAX iff its (optimized)
working set fits the LMM; everything else — plus the burst residual — runs
on the host CPU. Execution time on the accelerator decomposes into

* ``EXEC``        — pure PE compute,
* ``LOAD/DRAIN``  — DRAM↔LMM traffic,
* ``CONF``        — per-call configuration (CONF/REGV/RANGE/REFILL).

We keep the same decomposition; on TPU the analogues are MXU compute,
HBM↔VMEM traffic, and per-kernel launch/config overhead.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.burst import DEFAULT_BURST, split_burst
from repro.core.footprint import elem_bytes, kernel_footprint
from repro.core.workload import KernelSpec


@dataclasses.dataclass(frozen=True)
class AccelModel:
    """Calibratable accelerator latency model."""
    name: str
    flops_rate: float        # effective FLOP/s on the accelerator
    mem_bw: float            # DRAM<->LMM (HBM<->VMEM) bytes/s
    conf_time: float         # seconds per kernel call (CONF/launch)
    host_flops_rate: float   # effective FLOP/s of the host/fallback path
    burst: int = DEFAULT_BURST


@dataclasses.dataclass(frozen=True)
class Plan:
    budget_bytes: int
    policy: str
    accel: tuple[KernelSpec, ...]
    host: tuple[KernelSpec, ...]

    @property
    def coverage_calls(self) -> float:
        a = sum(s.calls for s in self.accel)
        h = sum(s.calls for s in self.host)
        return a / max(a + h, 1)

    @property
    def coverage_flops(self) -> float:
        a = sum(s.flops for s in self.accel)
        h = sum(s.flops for s in self.host)
        return a / max(a + h, 1)


def offload_decision(spec: KernelSpec, budget_bytes: int,
                     policy: str = "optimized") -> str:
    """The paper's per-kernel control law: ``"accel"`` iff the (policy)
    working set fits the LMM/VMEM budget, else ``"host"``. This single
    predicate backs both the analytic planner below and the executable
    dispatch layer (``repro.kernels.api``)."""
    fits = kernel_footprint(spec, policy) <= budget_bytes
    return "accel" if fits else "host"


def plan_offload(work: Sequence[KernelSpec], budget_bytes: int,
                 policy: str = "optimized") -> Plan:
    accel, host = [], []
    for spec in work:
        (accel if offload_decision(spec, budget_bytes, policy) == "accel"
         else host).append(spec)
    return Plan(budget_bytes, policy, tuple(accel), tuple(host))


@dataclasses.dataclass(frozen=True)
class Breakdown:
    exec_s: float
    load_s: float
    conf_s: float
    host_s: float            # non-offloaded kernels + burst residual

    @property
    def accel_s(self) -> float:
        return self.exec_s + self.load_s + self.conf_s

    @property
    def total_s(self) -> float:
        # Residual overlaps the accelerator (Sec III-B) but whole fallback
        # kernels serialize; we fold both into host_s and serialize — the
        # paper's Fig 6 shows the 16 KB case degrading exactly this way.
        return self.accel_s + self.host_s

    @property
    def exec_share(self) -> float:
        a = self.accel_s
        return self.exec_s / a if a else 0.0


def staged_bytes(spec: KernelSpec) -> int:
    """DRAM->LMM traffic for one kernel call under the optimized (packed)
    policy: the A tile stream (storage dtype — this is where Q8_0 wins),
    the B row, and the drained output."""
    a = spec.n * spec.k * elem_bytes(spec.dtype)
    b = spec.k * elem_bytes("f16")
    out = spec.n * 4
    return int(a + b + out)


def execution_breakdown(work: Sequence[KernelSpec], model: AccelModel,
                        budget_bytes: int,
                        policy: str = "optimized") -> Breakdown:
    plan = plan_offload(work, budget_bytes, policy)
    exec_s = load_s = conf_s = host_s = 0.0
    for spec in plan.accel:
        s = split_burst(spec.k, model.burst)
        frac_main = s.offload_fraction
        exec_s += spec.flops * frac_main / model.flops_rate
        load_s += staged_bytes(spec) * spec.calls / model.mem_bw
        conf_s += spec.calls * model.conf_time
        host_s += spec.flops * (1.0 - frac_main) / model.host_flops_rate
    for spec in plan.host:
        host_s += spec.flops / model.host_flops_rate
    return Breakdown(exec_s, load_s, conf_s, host_s)
