"""Core library: the paper's contribution as reusable components.

C1 quantized kernels      -> repro.core.quantize (+ repro.kernels.*)
C2 mixed execution        -> repro.core.burst
C3 packing / footprints   -> repro.core.footprint, repro.core.quantize
C4 LMM/VMEM sizing DSE    -> repro.core.footprint, repro.core.energy
C5 energy methodology     -> repro.core.energy, repro.core.offload
workload extraction       -> repro.core.workload
"""

from repro.core.burst import (BurstSplit, burst_cost, offload_rate,
                              optimal_burst, split_burst)
from repro.core.footprint import (BlockShape, coverage_cdf, kernel_footprint,
                                  select_blocks)
from repro.core.offload import (AccelModel, Breakdown, Plan,
                                execution_breakdown, offload_decision,
                                plan_offload)
from repro.core.quantize import (QBLOCK, Q8Tensor, dequantize_q8_0,
                                 pad_to_block, quantize_q8_0, quantize_tree)
from repro.core.workload import (KernelSpec, WhisperDims, k_length_histogram,
                                 lm_workload, whisper_workload)

__all__ = [
    "AccelModel", "BlockShape", "Breakdown", "BurstSplit", "KernelSpec",
    "Plan", "QBLOCK", "Q8Tensor", "WhisperDims", "burst_cost",
    "coverage_cdf", "dequantize_q8_0", "execution_breakdown",
    "k_length_histogram", "kernel_footprint", "lm_workload",
    "offload_decision", "offload_rate",
    "optimal_burst", "pad_to_block", "plan_offload", "quantize_q8_0",
    "quantize_tree", "select_blocks", "split_burst", "whisper_workload",
]
