from repro.kernels.q4_matmul.ops import *  # noqa
