"""Pure-jnp oracle for the Q4_0 GEMM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QBLOCK, unpack_q4


def dequant_ref(wp: jax.Array, ws: jax.Array) -> jax.Array:
    """wp: (K//2, N) packed uint8, ws: (K//QBLOCK, N) -> (K, N) f32."""
    codes = unpack_q4(wp, axis=0).astype(jnp.float32)
    scales = jnp.repeat(ws.astype(jnp.float32), QBLOCK, axis=0)
    return codes * scales


def q4_matmul_ref(x: jax.Array, wp: jax.Array, ws: jax.Array,
                  out_dtype=jnp.float32) -> jax.Array:
    """y = x @ dequant(wp, ws), f32 accumulation."""
    w = dequant_ref(wp, ws)
    return jnp.dot(x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32).astype(out_dtype)
