"""Public jit'd wrapper for the Q4_0 GEMM — mixed execution + budgets.

Same co-design stack as ``q8_matmul`` one tier lower:

* C1 inline conversion: nibbles are unpacked and scaled in VMEM right
  before the MXU dot — the HBM stream stays at 0.5625 bytes/element.
* C2 mixed execution: K split into a block-aligned main segment (Pallas)
  and a residual tail on the plain-XLA path, summed.
* C4 VMEM budget: block shapes from ``select_blocks(b_dtype="q4_0")``.

The XLA backend (``q4_matmul_xla``) deliberately widens the int4 codes to
**bf16, never f32**: unlike the q8 weight path, q4 planes are live inside
the traced draft-verify decode program, so a full-plane f32 dequant here
would be a real HBM regression (and an SC-DTYPE finding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.footprint import select_blocks
from repro.core.quantize import QBLOCK, Q4Tensor, unpack_q4
from repro.kernels.common import pad_dim
from repro.kernels.q4_matmul.q4_matmul import q4_matmul_pallas
from repro.kernels.q4_matmul.ref import q4_matmul_ref


@functools.partial(jax.jit, static_argnames=("vmem_budget", "interpret",
                                             "out_dtype"))
def q4_matmul(x: jax.Array, w: Q4Tensor, *,
              vmem_budget: int = 4 * 1024 * 1024,
              out_dtype=jnp.float32,
              interpret: bool = True) -> jax.Array:
    """y = x @ dequant(w), w stored as Q4Tensor packed along K.

    ``w.q`` is (K//2, N) uint8 (two codes/byte), ``w.scale`` (K//QBLOCK, N).
    """
    if x.ndim != 2:
        lead = x.shape[:-1]
        y = q4_matmul(x.reshape(-1, x.shape[-1]), w,
                      vmem_budget=vmem_budget, out_dtype=out_dtype,
                      interpret=interpret)
        return y.reshape(*lead, y.shape[-1])

    m, k = x.shape
    k2, n = w.q.shape
    assert k == 2 * k2, (x.shape, w.q.shape)

    blocks = select_blocks(m, n, k, vmem_budget, a_dtype="bf16",
                           b_dtype="q4_0")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    bk = max(QBLOCK, (bk // QBLOCK) * QBLOCK)

    # --- C2: burst/tile-aligned main segment vs residual tail ---
    k_main = (k // bk) * bk
    x_main, x_res = x[:, :k_main], x[:, k_main:]
    wp_main, wp_res = w.q[:k_main // 2], w.q[k_main // 2:]
    ws_main, ws_res = w.scale[:k_main // QBLOCK], w.scale[k_main // QBLOCK:]

    xp = pad_dim(x_main, 0, bm)
    wpp = pad_dim(wp_main, 1, bn)
    wsp = pad_dim(ws_main, 1, bn)

    if k_main > 0:
        y = q4_matmul_pallas(xp, wpp, wsp, bm=bm, bn=bn, bk=bk,
                             out_dtype=jnp.float32, interpret=interpret)
        y = y[:m, :n]
    else:
        y = jnp.zeros((m, n), jnp.float32)

    if k_main < k:  # residual on the XLA ("host") path, then summed
        y = y + q4_matmul_ref(x_res, wp_res, ws_res)
    return y.astype(out_dtype)


def q4_matmul_xla(x: jax.Array, w: Q4Tensor, out_dtype=jnp.float32) -> jax.Array:
    """XLA fallback (the HOST decision) with **bf16-widened** dequant.

    Codes go uint8 -> int8 -> bf16 (exact: |q| <= 8) and the dot runs
    blockwise so per-group scales fold in at f32 *after* the contraction —
    the int4 plane never materializes in f32 (SC-DTYPE clean even when the
    draft weights live inside the fused decode scan).
    """
    if x.ndim != 2:
        lead = x.shape[:-1]
        y = q4_matmul_xla(x.reshape(-1, x.shape[-1]), w, out_dtype)
        return y.reshape(*lead, y.shape[-1])
    m, k = x.shape
    assert k == 2 * w.q.shape[0], (x.shape, w.q.shape)
    n = w.q.shape[-1]
    codes = unpack_q4(w.q, axis=0).astype(jnp.bfloat16)       # (K, N)
    xb = x.astype(jnp.bfloat16).reshape(m, k // QBLOCK, QBLOCK)
    cb = codes.reshape(k // QBLOCK, QBLOCK, n)
    part = jnp.einsum("mbk,bkn->mbn", xb, cb,
                      preferred_element_type=jnp.float32)      # (M, K/32, N)
    y = (part * w.scale.astype(jnp.float32)[None, :, :]).sum(axis=1)
    return y.astype(out_dtype)
