"""Pallas TPU kernel: Q4_0 dequant-in-kernel GEMM (paper C1, int4 tier).

``y[M, N] = x[M, K] @ dequant(wp[K/2, N], ws[K/32, N])``

The int4 tier below Q8_0: two 4-bit codes per byte along K plus one f16
scale per 32-element block — 0.5625 bytes/element streamed from HBM, the
CGLA follow-up's headline low-bit dot-product saving. The nibbles are
unpacked and scaled *in VMEM* immediately before the MXU dot, so the
weight plane never exists in HBM above 4 bits/elem.

Block shapes come from ``repro.core.footprint.select_blocks`` under a
VMEM byte budget (C4), with bk rounded to the QBLOCK multiple so scale
blocks never straddle a tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import QBLOCK


def _unpack_rows(p: jax.Array) -> jax.Array:
    """(bk//2, bn) packed uint8 -> (bk, bn) f32 codes in [-8, 7]."""
    lo = (p & jnp.uint8(0xF)).astype(jnp.int8) - 8
    hi = (p >> 4).astype(jnp.int8) - 8
    half, bn = p.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * half, bn).astype(jnp.float32)


def _q4_matmul_kernel(x_ref, wp_ref, ws_ref, o_ref, acc_ref, *, n_k_blocks):
    """One (bm, bn) output tile; grid dim 2 walks K in bk steps."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                  # (bm, bk)
    q = _unpack_rows(wp_ref[...])                       # (bk, bn) in VMEM (C1)
    s = ws_ref[...].astype(jnp.float32)                 # (bk // 32, bn)
    bk, bn = q.shape
    scales = jnp.broadcast_to(s[:, None, :], (bk // QBLOCK, QBLOCK, bn))
    w = q * scales.reshape(bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k_blocks - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def q4_matmul_pallas(x: jax.Array, wp: jax.Array, ws: jax.Array, *,
                     bm: int = 128, bn: int = 128, bk: int = 512,
                     out_dtype=jnp.float32,
                     interpret: bool = False) -> jax.Array:
    """x: (M, K) float; wp: (K//2, N) packed uint8; ws: (K//QBLOCK, N).

    M % bm == 0, N % bn == 0, K % bk == 0, bk % QBLOCK == 0 — the burst-
    aligned "main segment"; ragged shapes are handled by the mixed-execution
    wrapper in ops.py (paper C2).
    """
    m, k = x.shape
    k2, n = wp.shape
    assert k == 2 * k2 and ws.shape == (k // QBLOCK, n), (
        x.shape, wp.shape, ws.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % QBLOCK == 0, (
        (m, n, k), (bm, bn, bk))
    n_k_blocks = k // bk
    grid = (m // bm, n // bn, n_k_blocks)
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels.common import tpu_compiler_params
    return pl.pallas_call(
        functools.partial(_q4_matmul_kernel, n_k_blocks=n_k_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // QBLOCK, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wp, ws)
