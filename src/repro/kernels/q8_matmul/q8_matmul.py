"""Pallas TPU kernel: Q8_0 dequant-in-kernel GEMM (paper C1, TPU binding).

``y[M, N] = x[M, K] @ dequant(wq[K, N], ws[K/32, N])``

The IMAX kernel converts Q8_0 blocks to f32 inline on the PE's bit-
manipulation units as data streams from the LMM; the TPU analogue is
dequantizing the int8 tile *in VMEM* immediately before the MXU dot, so
HBM→VMEM traffic stays at ~1.06 bytes/element (the paper's Q8_0 LOAD
saving) while the MXU still sees a dense f32/bf16 operand.

Block shapes come from ``repro.core.footprint.select_blocks`` under a VMEM
byte budget — the TPU binding of the paper's LMM-size knob (C4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import QBLOCK


def _q8_matmul_kernel(x_ref, wq_ref, ws_ref, o_ref, acc_ref, *, n_k_blocks):
    """One (bm, bn) output tile; grid dim 2 walks K in bk steps."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                  # (bm, bk)
    q = wq_ref[...].astype(jnp.float32)                 # (bk, bn)
    s = ws_ref[...].astype(jnp.float32)                 # (bk // 32, bn)
    bk, bn = q.shape
    # inline dequant: expand per-32-block scales along K (C1)
    scales = jnp.broadcast_to(s[:, None, :], (bk // QBLOCK, QBLOCK, bn))
    w = q * scales.reshape(bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k_blocks - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def q8_matmul_pallas(x: jax.Array, wq: jax.Array, ws: jax.Array, *,
                     bm: int = 128, bn: int = 128, bk: int = 512,
                     out_dtype=jnp.float32,
                     interpret: bool = False) -> jax.Array:
    """x: (M, K) float; wq: (K, N) int8; ws: (K//QBLOCK, N) scales.

    M % bm == 0, N % bn == 0, K % bk == 0, bk % QBLOCK == 0 — the burst-
    aligned "main segment"; ragged shapes are handled by the mixed-execution
    wrapper in ops.py (paper C2).
    """
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2 and ws.shape == (k // QBLOCK, n), (x.shape, wq.shape, ws.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % QBLOCK == 0, (
        (m, n, k), (bm, bn, bk))
    n_k_blocks = k // bk
    grid = (m // bm, n // bn, n_k_blocks)
    return pl.pallas_call(
        functools.partial(_q8_matmul_kernel, n_k_blocks=n_k_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // QBLOCK, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pl.ANY if False else _vmem((bm, bn), jnp.float32)],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(x, wq, ws)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _tpu_params():
    from repro.kernels.common import tpu_compiler_params
    return tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "arbitrary"))
