from repro.kernels.q8_matmul.ops import *  # noqa
