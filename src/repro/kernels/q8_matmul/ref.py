"""Pure-jnp oracle for the Q8_0 GEMM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QBLOCK


def dequant_ref(wq: jax.Array, ws: jax.Array) -> jax.Array:
    """wq: (K, N) int8, ws: (K//QBLOCK, N) -> (K, N) f32."""
    k, n = wq.shape
    scales = jnp.repeat(ws.astype(jnp.float32), QBLOCK, axis=0)
    return wq.astype(jnp.float32) * scales


def q8_matmul_ref(x: jax.Array, wq: jax.Array, ws: jax.Array,
                  out_dtype=jnp.float32) -> jax.Array:
    """y = x @ dequant(wq, ws), f32 accumulation."""
    w = dequant_ref(wq, ws)
    return jnp.dot(x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32).astype(out_dtype)
