"""Public jit'd wrapper for the Q8_0 GEMM — mixed execution + budgets.

Implements the paper's co-design stack on top of the raw kernel:

* C2 mixed execution: K is split into a block-aligned main segment (Pallas)
  and a residual tail computed on the plain-XLA path and summed.
* C3 dense packing: operands are the packed (q, scale) planes — no row
  padding is ever materialized.
* C4 VMEM budget: block shapes are selected by
  ``repro.core.footprint.select_blocks`` under a byte budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.footprint import select_blocks
from repro.core.quantize import QBLOCK, Q8Tensor
from repro.kernels.common import pad_dim
from repro.kernels.q8_matmul.q8_matmul import q8_matmul_pallas
from repro.kernels.q8_matmul.ref import q8_matmul_ref


@functools.partial(jax.jit, static_argnames=("vmem_budget", "interpret",
                                             "out_dtype"))
def q8_matmul(x: jax.Array, w: Q8Tensor, *,
              vmem_budget: int = 4 * 1024 * 1024,
              out_dtype=jnp.float32,
              interpret: bool = True) -> jax.Array:
    """y = x @ dequant(w), w stored as Q8Tensor with shape (K, N).

    ``interpret=True`` runs the kernel body on CPU (this container);
    on real TPU pass ``interpret=False``.
    """
    if x.ndim != 2:
        lead = x.shape[:-1]
        y = q8_matmul(x.reshape(-1, x.shape[-1]), w,
                      vmem_budget=vmem_budget, out_dtype=out_dtype,
                      interpret=interpret)
        return y.reshape(*lead, y.shape[-1])

    m, k = x.shape
    k2, n = w.q.shape
    assert k == k2, (x.shape, w.q.shape)

    blocks = select_blocks(m, n, k, vmem_budget, a_dtype="bf16",
                           b_dtype="q8_0")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk
    bk = max(QBLOCK, (bk // QBLOCK) * QBLOCK)

    # --- C2: burst/tile-aligned main segment vs residual tail ---
    k_main = (k // bk) * bk
    x_main, x_res = x[:, :k_main], x[:, k_main:]
    wq_main, wq_res = w.q[:k_main], w.q[k_main:]
    ws_main, ws_res = w.scale[:k_main // QBLOCK], w.scale[k_main // QBLOCK:]

    # pad M/N up to block multiples (packed operands, C3 — padding exists
    # only transiently in VMEM-tile space, never in HBM layout)
    xp = pad_dim(x_main, 0, bm)
    wqp = pad_dim(wq_main, 1, bn)
    wsp = pad_dim(ws_main, 1, bn)

    if k_main > 0:
        y = q8_matmul_pallas(xp, wqp, wsp, bm=bm, bn=bn, bk=bk,
                             out_dtype=jnp.float32, interpret=interpret)
        y = y[:m, :n]
    else:
        y = jnp.zeros((m, n), jnp.float32)

    if k_main < k:  # residual on the XLA ("host") path, then summed
        y = y + q8_matmul_ref(x_res, wq_res, ws_res)
    return y.astype(out_dtype)


def q8_matmul_xla(x: jax.Array, w: Q8Tensor, out_dtype=jnp.float32) -> jax.Array:
    """XLA fallback path (the offload planner's HOST decision): dequant in
    HLO + dense dot. Also what the multi-pod dry-run lowers, since TPU
    Pallas cannot be lowered on the CPU backend."""
    if x.ndim != 2:
        lead = x.shape[:-1]
        y = q8_matmul_xla(x.reshape(-1, x.shape[-1]), w, out_dtype)
        return y.reshape(*lead, y.shape[-1])
    return q8_matmul_ref(x, w.q, w.scale, out_dtype=out_dtype)
