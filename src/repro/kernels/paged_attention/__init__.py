from repro.kernels.paged_attention.ref import paged_decode_attention_ref
from repro.kernels.paged_attention.xla import (gather_pages,
                                               paged_decode_attention_xla)

__all__ = sorted([
    "gather_pages",
    "paged_decode_attention_ref",
    "paged_decode_attention_xla",
])
