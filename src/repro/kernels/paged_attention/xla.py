"""XLA host backend: decode attention gathered over per-lane page tables.

The cache planes live in a shared page pool ``(n_pages, P, Hkv, ·)``;
lane ``b``'s logical sequence is reassembled by one ``jnp.take`` over
its page-table row — ``table[b]`` lists physical pages in logical order,
so the gathered ``(B, n_lp * P, Hkv, ·)`` planes have exactly the layout
of the slot engine's per-lane cache rows. Everything after the gather
mirrors the dense decode chain in ``models.attention`` operation for
operation (bf16 operands, f32-accumulated einsums, -1e30 masking), so a
paged lane is bit-identical to its slot-pool reference whenever the
gathered values match — which the paging parity tests assert.

Q8_0 planes gather the int8 codes + f16 scales the same way and then
reuse ``q8_decode_attention_xla`` verbatim (codes widened to bf16,
scales folded after the f32 accumulation), so the paged q8 path inherits
the slot path's math exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import flags
from repro.kernels.common import lens_mask
from repro.kernels.q4_attention.xla import q4_decode_attention_xla
from repro.kernels.q8_attention.xla import q8_decode_attention_xla

NEG_INF = -1e30


def gather_pages(plane: jax.Array, table: jax.Array) -> jax.Array:
    """plane (n_pages, P, Hkv, ·) + table (B, n_lp) int32 ->
    (B, n_lp * P, Hkv, ·) per-lane logical planes."""
    b, n_lp = table.shape
    g = jnp.take(plane, table, axis=0)          # (B, n_lp, P, Hkv, ·)
    return g.reshape(b, n_lp * plane.shape[1], *plane.shape[2:])


def _repeat_heads(k: jax.Array, n_heads: int) -> jax.Array:
    hk = k.shape[2]
    if hk == n_heads:
        return k
    return jnp.repeat(k, n_heads // hk, axis=2)


def paged_decode_attention_xla(q, kc, vc, table, lens) -> jax.Array:
    """q: (B, Q, H, D); kc/vc: pool planes — arrays (bf16 cache),
    ``{"q": int8, "s": f16}`` dicts (q8_0), or ``{"p": uint8, "s": f16}``
    dicts (q4_0 packed nibbles); table: (B, n_lp) int32; lens: (B,) or
    (B, Q) int32 attend depths (the (B, Q) form is the speculative
    verify's per-draft-position mask). Returns (B, Q, H, D) in q.dtype."""
    b, nq, h, d = q.shape
    if isinstance(kc, dict):                    # quantized planes
        def flat(plane):
            g = _repeat_heads(gather_pages(plane, table), h)
            return g.transpose(0, 2, 1, 3).reshape(b * h, g.shape[1], -1)
        qf = q.transpose(0, 2, 1, 3).reshape(b * h, nq, d)
        lens_f = jnp.repeat(jnp.asarray(lens, jnp.int32), h, axis=0)
        fn = q4_decode_attention_xla if "p" in kc else q8_decode_attention_xla
        key = "p" if "p" in kc else "q"
        out = fn(qf, flat(kc[key]), flat(kc["s"]),
                 flat(vc[key]), flat(vc["s"]), lens_f)
        return out.reshape(b, h, nq, d).transpose(0, 2, 1, 3)

    k = _repeat_heads(gather_pages(kc, table), h)
    v = _repeat_heads(gather_pages(vc, table), h)
    s_len = k.shape[1]
    ddt = jnp.float32 if flags.BASELINE else jnp.bfloat16
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q.astype(ddt), k.astype(ddt),
                    preferred_element_type=jnp.float32) * (d ** -0.5)
    mask = lens_mask(lens, b, s_len)            # (B, Q|1, S)
    s_ = jnp.where(mask[:, None, :, :], s_, NEG_INF)
    w = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(ddt), v.astype(ddt),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
