"""XLA host backend: decode attention gathered over per-lane page tables.

The cache planes live in a shared page pool ``(n_pages, P, Hkv, ·)``;
lane ``b``'s logical sequence is reassembled by one ``jnp.take`` over
its page-table row — ``table[b]`` lists physical pages in logical order,
so the gathered ``(B, n_lp * P, Hkv, ·)`` planes have exactly the layout
of the slot engine's per-lane cache rows. Everything after the gather
mirrors the dense decode chain in ``models.attention`` operation for
operation (bf16 operands, f32-accumulated einsums, -1e30 masking), so a
paged lane is bit-identical to its slot-pool reference whenever the
gathered values match — which the paging parity tests assert.

Q8_0 planes gather the int8 codes + f16 scales the same way and then
reuse ``q8_decode_attention_xla`` verbatim (codes widened to bf16,
scales folded after the f32 accumulation), so the paged q8 path inherits
the slot path's math exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import flags
from repro.kernels.q8_attention.xla import q8_decode_attention_xla

NEG_INF = -1e30


def gather_pages(plane: jax.Array, table: jax.Array) -> jax.Array:
    """plane (n_pages, P, Hkv, ·) + table (B, n_lp) int32 ->
    (B, n_lp * P, Hkv, ·) per-lane logical planes."""
    b, n_lp = table.shape
    g = jnp.take(plane, table, axis=0)          # (B, n_lp, P, Hkv, ·)
    return g.reshape(b, n_lp * plane.shape[1], *plane.shape[2:])


def _repeat_heads(k: jax.Array, n_heads: int) -> jax.Array:
    hk = k.shape[2]
    if hk == n_heads:
        return k
    return jnp.repeat(k, n_heads // hk, axis=2)


def paged_decode_attention_xla(q, kc, vc, table, lens) -> jax.Array:
    """q: (B, 1, H, D); kc/vc: pool planes — arrays (bf16 cache) or
    ``{"q": int8, "s": f16}`` dicts (q8_0); table: (B, n_lp) int32;
    lens: (B,) int32, lane b attends logical positions [0, lens[b]).
    Returns (B, 1, H, D) in q's dtype."""
    b, _, h, d = q.shape
    if isinstance(kc, dict):                    # Q8_0 planes
        def flat(plane):
            g = _repeat_heads(gather_pages(plane, table), h)
            return g.transpose(0, 2, 1, 3).reshape(b * h, g.shape[1], -1)
        qf = q.transpose(0, 2, 1, 3).reshape(b * h, 1, d)
        lens_f = jnp.repeat(jnp.asarray(lens, jnp.int32), h)
        out = q8_decode_attention_xla(qf, flat(kc["q"]), flat(kc["s"]),
                                      flat(vc["q"]), flat(vc["s"]), lens_f)
        return out.reshape(b, h, 1, d).transpose(0, 2, 1, 3)

    k = _repeat_heads(gather_pages(kc, table), h)
    v = _repeat_heads(gather_pages(vc, table), h)
    s_len = k.shape[1]
    ddt = jnp.float32 if flags.BASELINE else jnp.bfloat16
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q.astype(ddt), k.astype(ddt),
                    preferred_element_type=jnp.float32) * (d ** -0.5)
    mask = (jnp.arange(s_len)[None, :]
            < jnp.asarray(lens, jnp.int32)[:, None])
    s_ = jnp.where(mask[:, None, None, :], s_, NEG_INF)
    w = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(ddt), v.astype(ddt),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
