"""Pure-jnp oracle: f32 dense attention over gathered page-table planes.

Parity anchor for the paged op — dequantizes/upcasts the gathered
per-lane planes to f32 and runs the textbook masked softmax chain.
Never routed on the hot path (``host_order`` prefers the xla binding);
exists for backend cross-checks and forced-``ref`` runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QBLOCK, unpack_q4
from repro.kernels.common import lens_mask
from repro.kernels.paged_attention.xla import _repeat_heads, gather_pages

NEG_INF = -1e30


def _dequant(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return (codes.astype(jnp.float32)
            * jnp.repeat(scale.astype(jnp.float32), QBLOCK, axis=-1))


def paged_decode_attention_ref(q, kc, vc, table, lens) -> jax.Array:
    """Same contract as ``paged_decode_attention_xla``."""
    b, _, h, d = q.shape
    if isinstance(kc, dict) and "p" in kc:      # q4_0 packed nibbles
        k = _dequant(unpack_q4(gather_pages(kc["p"], table), axis=-1),
                     gather_pages(kc["s"], table))
        v = _dequant(unpack_q4(gather_pages(vc["p"], table), axis=-1),
                     gather_pages(vc["s"], table))
    elif isinstance(kc, dict):
        k = _dequant(gather_pages(kc["q"], table),
                     gather_pages(kc["s"], table))
        v = _dequant(gather_pages(vc["q"], table),
                     gather_pages(vc["s"], table))
    else:
        k = gather_pages(kc, table).astype(jnp.float32)
        v = gather_pages(vc, table).astype(jnp.float32)
    k = _repeat_heads(k, h)
    v = _repeat_heads(v, h)
    s_len = k.shape[1]
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k,
                    preferred_element_type=jnp.float32) * (d ** -0.5)
    s_ = jnp.where(lens_mask(lens, b, s_len)[:, None, :, :], s_, NEG_INF)
    w = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
