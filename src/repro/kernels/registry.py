"""Kernel-op registry: the single registration point for every compute
hot-spot the offload control law can route.

A ``KernelOp`` bundles, per op:

* ``spec``      — an analytic footprint builder: maps the call's concrete
  operands to a ``core.workload.KernelSpec`` so the ACCEL/HOST decision
  can reuse ``core.footprint.kernel_footprint`` (the paper's LMM model);
* ``backends``  — implementations keyed ``"pallas"`` / ``"xla"`` /
  ``"ref"``.  Each takes ``(ctx, *args, **kwargs)`` where ``ctx`` is the
  active ``repro.kernels.api.DispatchContext`` (budget, interpret flag);
* ``accel_order`` / ``host_order`` — backend preference for each side of
  the offload decision.  ACCEL prefers the Pallas kernel; HOST prefers
  the plain-XLA binding with the jnp oracle as last resort.

Future backends (real-TPU lowering, a CGLA cost-model backend) register
here; nothing else in the stack needs to change.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Tuple

from repro.core.workload import KernelSpec

BACKENDS = ("pallas", "xla", "ref")


@dataclasses.dataclass(frozen=True)
class KernelOp:
    name: str
    spec: Callable[..., KernelSpec]
    backends: Mapping[str, Callable]
    accel_order: Tuple[str, ...] = ("pallas", "xla", "ref")
    host_order: Tuple[str, ...] = ("xla", "ref")
    doc: str = ""

    def __post_init__(self):
        unknown = set(self.backends) - set(BACKENDS)
        if unknown:
            raise ValueError(f"{self.name}: unknown backends {sorted(unknown)}")
        if not self.backends:
            raise ValueError(f"{self.name}: at least one backend required")


_REGISTRY: dict[str, KernelOp] = {}


def register(op: KernelOp) -> KernelOp:
    """Register (or re-register) an op; returns it for chaining."""
    _REGISTRY[op.name] = op
    return op


def get_op(name: str) -> KernelOp:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel op {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def list_ops() -> list[str]:
    return sorted(_REGISTRY)
