"""Shared padding helpers for the kernel wrappers (paper C3: padding is
a transient VMEM-tile artifact, never an HBM layout property).

Every per-kernel ``ops.py`` used to carry its own copy of ``_pad_dim``;
they all route here now so the registry's padding policy has one
implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tpu_compiler_params(**kwargs):
    """Version-tolerant ``pltpu.CompilerParams`` (named ``TPUCompilerParams``
    before jax 0.5)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def pad_dim(x: jax.Array, axis: int, mult: int) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to the next multiple of ``mult``."""
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def lens_mask(length, bh: int, s_len: int) -> jax.Array:
    """Normalize a decode-attention ``length`` of shape (), (BH,), or
    (BH, Q) into a (BH, Q|1, S) bool attend mask. The (BH, Q) form gives
    every query row its own depth — how the speculative verify forward
    masks draft position j to [0, pos + j + 1)."""
    lens = jnp.asarray(length, jnp.int32)
    if lens.ndim <= 1:
        lens = jnp.broadcast_to(lens.reshape(-1), (bh,))[:, None]
    return jnp.arange(s_len)[None, None, :] < lens[:, :, None]
