"""Public wrapper: Q8_0 KV-cache decode attention (+ its traffic model).

``quantize_kv`` builds the Q8 cache planes from bf16 K/V (per-token,
per-head 32-blocks along head_dim — the ggml layout transposed to the
cache's natural axes). ``q8_decode_attention`` pads S to the block
multiple and dispatches the kernel.

Traffic: the per-step cache stream drops from 2·S·D bf16 bytes to
2·S·D·(1 + 2/QBLOCK)/2 ≈ 1.06·S·D — the paper's Q8_0 LOAD saving applied
to the decode bottleneck (≈1.88x on the §Roofline decode memory terms'
cache component).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantize import QBLOCK, quantize_q8_0
from repro.kernels.common import pad_dim
from repro.kernels.q8_attention.q8_attention import q8_decode_attention_pallas


def quantize_kv(k: jax.Array):
    """k: (..., S, D) float -> (int8 plane, (…, S, D//QBLOCK) scales)."""
    t = quantize_q8_0(k, axis=-1)
    return t.q, t.scale


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def q8_decode_attention(q, kq, ks, vq, vs, length, *, bk: int = 128,
                        interpret: bool = True) -> jax.Array:
    """q: (BH, 1, D); kq/vq: (BH, S, D) int8; ks/vs scales; attend
    [0, length). ``length`` is a scalar (lockstep decode) or a (BH,)
    vector (continuous batching: every serving lane at its own depth).
    Handles S not divisible by bk via zero padding (masked by
    ``length``). Single-query only: the speculative verify's (BH, Q)
    case raises ``ValueError`` so dispatch falls back to the XLA
    backend."""
    bh, _, d = q.shape
    length = jnp.asarray(length)
    if q.shape[1] != 1 or length.ndim > 1:
        raise ValueError(
            "q8_decode_attention (Pallas) is single-query: got "
            f"q {q.shape}, length {length.shape}; multi-query verify "
            "routes to the XLA backend via dispatch fallback")
    kq, vq, ks, vs = (pad_dim(t, 1, bk) for t in (kq, vq, ks, vs))
    # scalar-vs-(BH,) length normalization happens in the pallas wrapper
    return q8_decode_attention_pallas(q, kq, ks, vq, vs,
                                      jnp.asarray(length), bk=bk,
                                      interpret=interpret)


def cache_traffic_ratio() -> float:
    """Q8 cache bytes per element vs bf16 (paper C1 LOAD saving)."""
    q8 = 1.0 + 2.0 / QBLOCK
    return q8 / 2.0
