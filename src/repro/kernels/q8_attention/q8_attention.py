"""Pallas TPU kernel: decode attention over a Q8_0-quantized KV cache.

The paper's C1 (inline dequantization next to the compute unit) applied
to the *decode bottleneck*: every decode step streams the full KV cache,
so cache bytes — not weight bytes — dominate the serving memory term
(§Roofline decode rows). Quantizing the cache to Q8_0 (int8 + one f16
scale per 32-element block along head_dim) cuts the stream to ~0.53x of
bf16; this kernel dequantizes blocks **in VMEM right before the MXU dot**
— the cache never exists in HBM at bf16/f32.

Online-softmax over KV blocks (one grid step per (head, kv-block)), with
a masked tail for cache positions beyond the current decode position.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import QBLOCK

NEG_INF = -1e30


def _q8_attn_kernel(len_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref,
                    o_ref, m_ref, l_ref, acc_ref, *,
                    scale, n_k_blocks, bk):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (1, D)

    def dequant(qref, sref):
        raw = qref[0].astype(jnp.float32)                # (bk, D)
        sc = sref[0].astype(jnp.float32)                 # (bk, D//32)
        sc_full = jnp.repeat(sc, QBLOCK, axis=1)         # C1: in-VMEM
        return raw * sc_full

    k = dequant(kq_ref, ks_ref)
    v = dequant(vq_ref, vs_ref)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bk)
    s = s * scale
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(kpos < len_ref[0, 0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_k_blocks - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def q8_decode_attention_pallas(q: jax.Array, kq: jax.Array, ks: jax.Array,
                               vq: jax.Array, vs: jax.Array,
                               length: jax.Array, *,
                               bk: int = 128,
                               interpret: bool = False) -> jax.Array:
    """q: (BH, 1, D); kq/vq: (BH, S, D) int8; ks/vs: (BH, S, D//QBLOCK)
    scales; length: () or (BH,) int32 — lane h attends positions
    [0, length[h]) (per-lane depths under continuous batching).
    S % bk == 0. Returns (BH, 1, D) in q.dtype."""
    bh, one, d = q.shape
    s = kq.shape[1]
    assert one == 1 and kq.shape == (bh, s, d) and s % bk == 0
    assert ks.shape == (bh, s, d // QBLOCK), ks.shape
    n_k_blocks = s // bk
    scale = 1.0 / (d ** 0.5)
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels.common import tpu_compiler_params
    kernel = functools.partial(_q8_attn_kernel, scale=scale,
                               n_k_blocks=n_k_blocks, bk=bk)
    grid = (bh, n_k_blocks)
    lens = jnp.broadcast_to(
        jnp.asarray(length, jnp.int32).reshape(-1), (bh,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda h, j: (h, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d // QBLOCK), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d // QBLOCK), lambda h, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens.reshape(bh, 1), q, kq, ks, vq, vs)
