"""XLA host backend: Q8_0 decode attention without f32 plane
materialization.

The ref oracle dequantizes whole cache planes to f32 — 4 bytes/elem
through HBM, defeating the Q8_0 cache-stream saving on any host-routed
platform. Here the int8 codes are widened to bf16 (codes are integers
in [-127, 127], exact in bf16's 8-bit mantissa) and the per-block
scales are folded in *after* the f32-accumulated contraction, which is
algebraically identical to dequantize-then-dot (the scale is constant
within each QBLOCK slice of the contraction). The widest materialized
plane is therefore 2 bytes/elem, and ``repro.staticcheck``'s SC-DTYPE
pass verifies no f32 plane convert exists in the lowered program.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QBLOCK
from repro.kernels.common import lens_mask


def q8_decode_attention_xla(q, kq, ks, vq, vs, length) -> jax.Array:
    """q: (BH, Q, D); int8 code planes + (BH, S, D//QBLOCK) scales;
    attend positions [0, length) with ``length`` (), (BH,), or (BH, Q)
    per-query depths. Same contract as the ref oracle."""
    bh, nq, d = q.shape
    s_len = kq.shape[1]
    nb = d // QBLOCK
    qb = q.astype(jnp.bfloat16).reshape(bh, nq, nb, QBLOCK)
    k8 = kq.astype(jnp.bfloat16).reshape(bh, s_len, nb, QBLOCK)
    v8 = vq.astype(jnp.bfloat16).reshape(bh, s_len, nb, QBLOCK)
    # per-block partial dots, f32 accumulation; scales fold in afterward
    s = jnp.einsum("bqnd,bknd->bqkn", qb, k8,
                   preferred_element_type=jnp.float32)
    s = (s * ks.astype(jnp.float32)[:, None, :, :]).sum(-1) * (d ** -0.5)
    s = jnp.where(lens_mask(length, bh, s_len), s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    # out_d = sum_k w_k * code_kd * scale_k,blk: fold the scale into the
    # f32 weights (per (k, block)), contract against bf16 codes
    wv = w[:, :, :, None] * vs.astype(jnp.float32)[:, None, :, :]
    out = jnp.einsum("bqkn,bknd->bqnd", wv.astype(jnp.bfloat16), v8,
                     preferred_element_type=jnp.float32)
    return out.reshape(bh, nq, d).astype(q.dtype)
