"""Pure-jnp oracle: dense decode attention over a dequantized Q8 cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QBLOCK


def dequant(q8: jax.Array, scale: jax.Array) -> jax.Array:
    """q8: (..., S, D) int8; scale: (..., S, D//QBLOCK) -> f32."""
    return (q8.astype(jnp.float32)
            * jnp.repeat(scale.astype(jnp.float32), QBLOCK, axis=-1))


def q8_decode_attention_ref(q, kq, ks, vq, vs, length) -> jax.Array:
    """q: (BH, 1, D); int8 caches + scales; attend [0, length).
    ``length``: scalar or (BH,) per-lane depths."""
    k = dequant(kq, ks)
    v = dequant(vq, vs)
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k) * (d ** -0.5)
    lens = jnp.broadcast_to(
        jnp.asarray(length, jnp.int32).reshape(-1), (q.shape[0],))
    mask = jnp.arange(k.shape[1])[None, None, :] < lens[:, None, None]
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v).astype(q.dtype)
