"""Pure-jnp oracle: dense decode attention over a dequantized Q8 cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QBLOCK
from repro.kernels.common import lens_mask


def dequant(q8: jax.Array, scale: jax.Array) -> jax.Array:
    """q8: (..., S, D) int8; scale: (..., S, D//QBLOCK) -> f32."""
    return (q8.astype(jnp.float32)
            * jnp.repeat(scale.astype(jnp.float32), QBLOCK, axis=-1))


def q8_decode_attention_ref(q, kq, ks, vq, vs, length) -> jax.Array:
    """q: (BH, Q, D); int8 caches + scales; attend [0, length).
    ``length``: scalar, (BH,), or (BH, Q) per-query depths."""
    k = dequant(kq, ks)
    v = dequant(vq, vs)
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k) * (d ** -0.5)
    s = jnp.where(lens_mask(length, q.shape[0], k.shape[1]), s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v).astype(q.dtype)
