from repro.kernels.q8_attention.ops import *  # noqa
