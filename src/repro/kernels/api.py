"""Unified kernel-dispatch API: the paper's ACCEL/HOST control law as an
executable router.

``core.offload.plan_offload`` decides *analytically* which kernels fit
the LMM/VMEM budget; this module makes the same decision at call time
and routes execution accordingly:

1. every op registers a ``KernelOp`` (``repro.kernels.registry``) with
   its analytic footprint builder and its ``pallas`` / ``xla`` / ``ref``
   backends;
2. a ``DispatchContext`` carries the budget, the packing policy, the
   Pallas ``interpret`` flag, and any backend override (programmatic or
   via the ``REPRO_*`` env knobs in ``repro.flags``);
3. ``dispatch(op, *args, **kwargs)`` builds the op's ``KernelSpec``,
   applies ``core.offload.offload_decision`` (footprint <= budget ->
   ACCEL, else HOST), binds the decision to the preferred available
   backend, runs it, and records the routing in an inspectable trace.

Decisions happen at **trace time** (shapes are static under jit), so a
jitted forward bakes in the routing that was active when it was first
traced — wrap jit entry points in ``use_context`` (see serving/engine).

On CPU the ACCEL decision binds to the plain-XLA binding by default
(Pallas interpreter mode is a correctness tool, not a fast path); set
``allow_pallas=True`` (or ``REPRO_ALLOW_PALLAS=1``) to bind ACCEL to the
Pallas wrappers, as on real TPU.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
from typing import Mapping, Optional

import jax.numpy as jnp

from repro import flags
from repro.core.workload import KernelSpec
from repro.kernels.registry import BACKENDS, KernelOp, get_op, register

__all__ = [
    "DispatchContext", "DispatchRecord", "dispatch", "dispatch_counters",
    "dispatch_trace", "grad_safe_context", "reset_dispatch_log",
    "use_context", "current_context",
]


# ----------------------------------------------------------------------------
# Context
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DispatchContext:
    """Everything the control law needs to route one kernel call.

    ``vmem_budget`` is the paper's LMM-size knob: the offload decision
    compares each op's analytic footprint against it, and the Pallas
    wrappers also use it for block selection (C4).
    ``force_backend`` bypasses the control law globally; ``backends``
    does so per-op (``{"q8_matmul": "ref"}``).
    ``platform`` names the registered hardware target this context was
    derived from (``for_platform``); it is stamped into every
    ``DispatchRecord`` so traces are attributable per target. ``tag``
    is a free-form observability label stamped alongside it — e.g. one
    per ServeEngine, so two engines on the same platform can tell their
    trace records apart.
    """

    vmem_budget: int
    policy: str = "optimized"
    interpret: bool = True
    allow_pallas: bool = False
    force_backend: Optional[str] = None
    backends: Mapping[str, str] = dataclasses.field(default_factory=dict)
    platform: Optional[str] = None
    tag: Optional[str] = None

    @classmethod
    def for_platform(cls, platform, **overrides) -> "DispatchContext":
        """Derive a context from a registered ``repro.platforms`` target
        (by name or ``Platform`` object): the LMM/VMEM budget, the
        packing policy, and pallas-eligibility all come from the
        platform. The platform says whether its accel path *may* bind to
        Pallas; the environment says whether this process *can* run it
        (``flags.allow_pallas_default()`` — real TPU, or an explicit
        ``REPRO_ALLOW_PALLAS=1``). Keyword ``overrides`` win over both.
        """
        from repro.platforms import get_platform
        p = get_platform(platform)
        kw = dict(
            vmem_budget=p.vmem_budget,
            policy=p.policy,
            interpret=flags.interpret_default(),
            allow_pallas=p.allow_pallas and flags.allow_pallas_default(),
            force_backend=flags.kernel_backend_override(),
            platform=p.name,
        )
        kw.update(overrides)
        return cls(**kw)

    @classmethod
    def from_env(cls) -> "DispatchContext":
        name = flags.platform_default()
        if name:
            over = {}
            budget = flags.vmem_budget_override()
            if budget is not None:
                over["vmem_budget"] = budget
            if flags._env_bool("REPRO_ALLOW_PALLAS") is not None:
                over["allow_pallas"] = flags.allow_pallas_default()
            return cls.for_platform(name, **over)
        return cls(
            vmem_budget=flags.vmem_budget_default(),
            interpret=flags.interpret_default(),
            allow_pallas=flags.allow_pallas_default(),
            force_backend=flags.kernel_backend_override(),
        )


_CTX: Optional[DispatchContext] = None


def current_context() -> DispatchContext:
    """The active context: the innermost ``use_context``, else env/defaults."""
    return _CTX if _CTX is not None else DispatchContext.from_env()


def grad_safe_context(ctx: Optional[DispatchContext] = None
                      ) -> DispatchContext:
    """A variant of ``ctx`` that never binds to Pallas. The Pallas
    kernels define no VJP yet, so differentiated forwards (training)
    must stay on the XLA/ref bindings whatever the platform or env
    routing says."""
    ctx = ctx or current_context()
    force = None if ctx.force_backend == "pallas" else ctx.force_backend
    backends = {k: v for k, v in ctx.backends.items() if v != "pallas"}
    return dataclasses.replace(ctx, allow_pallas=False,
                               force_backend=force, backends=backends)


@contextlib.contextmanager
def use_context(ctx: Optional[DispatchContext]):
    """Install ``ctx`` as the dispatch context for the enclosed block.
    ``None`` is a no-op (convenient for optional plumbing)."""
    global _CTX
    if ctx is None:
        yield
        return
    prev = _CTX
    _CTX = ctx
    try:
        yield ctx
    finally:
        _CTX = prev


# ----------------------------------------------------------------------------
# Trace / counters
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DispatchRecord:
    op: str
    decision: str        # "accel" | "host" | "forced" | "accel->host"
    backend: str         # "pallas" | "xla" | "ref"
    footprint: int
    budget: int
    spec: KernelSpec
    platform: str = ""   # registered platform the context was derived from
    tag: str = ""        # caller-scoped label (e.g. one per ServeEngine)


_TRACE_MAX = 1024
_trace: collections.deque = collections.deque(maxlen=_TRACE_MAX)
_counters: collections.Counter = collections.Counter()


def dispatch_trace() -> list[DispatchRecord]:
    return list(_trace)


def dispatch_counters() -> collections.Counter:
    """Counter keyed ``(op, decision, backend)`` — trace-time events."""
    return collections.Counter(_counters)


def reset_dispatch_log() -> None:
    _trace.clear()
    _counters.clear()


# ----------------------------------------------------------------------------
# Dispatch
# ----------------------------------------------------------------------------

def _first_allowed(op: KernelOp, order, ctx: DispatchContext) -> str:
    for b in order:
        if b not in op.backends:
            continue
        if b == "pallas" and not ctx.allow_pallas:
            continue
        return b
    # nothing allowed in the preferred order: take anything registered,
    # honoring the order but ignoring allow_pallas (an op may be
    # pallas-only; correctness beats the platform preference).
    for b in order:
        if b in op.backends:
            return b
    return next(iter(op.backends))


def _decide(op: KernelOp, spec: KernelSpec,
            ctx: DispatchContext) -> tuple[str, str, int]:
    """(decision, backend, footprint) — one footprint evaluation."""
    from repro.core.footprint import kernel_footprint
    footprint = kernel_footprint(spec, ctx.policy)
    forced = ctx.force_backend or ctx.backends.get(op.name)
    if forced:
        if forced not in BACKENDS:
            raise ValueError(
                f"forced backend {forced!r} for {op.name}: expected one "
                f"of {BACKENDS}")
        if forced in op.backends:
            return "forced", forced, footprint
        # a valid backend the op never registered (e.g. a global
        # REPRO_KERNEL_BACKEND=xla hitting a pallas/ref-only op):
        # land it on the op's host chain rather than crashing.
        return "forced", _first_allowed(op, op.host_order, ctx), footprint
    decision = "accel" if footprint <= ctx.vmem_budget else "host"
    order = op.accel_order if decision == "accel" else op.host_order
    return decision, _first_allowed(op, order, ctx), footprint


def decide(op_name: str, spec: KernelSpec,
           ctx: Optional[DispatchContext] = None) -> tuple[str, str]:
    """(decision, backend) the control law would take for ``spec`` —
    the pure half of ``dispatch``, used by the plan-agreement benchmark."""
    decision, backend, _ = _decide(get_op(op_name), spec,
                                   ctx or current_context())
    return decision, backend


def dispatch(op_name: str, *args, ctx: Optional[DispatchContext] = None,
             tag: Optional[str] = None, **kwargs):
    """Route one kernel call through the registered backend the control
    law selects. Returns whatever the backend returns.

    ``tag`` (reserved — never forwarded to the backend) overrides the
    ``KernelSpec.tag`` the op's spec builder stamps, so call sites
    outside the transformer proper (e.g. the audio frontend's mel/
    projection GEMMs, tagged ``"frontend"``) stay distinguishable in the
    dispatch trace and the workload accounting."""
    op = get_op(op_name)
    ctx = ctx or current_context()
    spec = op.spec(*args, **kwargs)
    if tag is not None:
        spec = dataclasses.replace(spec, tag=tag)
    decision, backend, footprint = _decide(op, spec, ctx)
    try:
        out = op.backends[backend](ctx, *args, **kwargs)
    except ValueError:
        if backend != "pallas" or decision == "forced":
            raise
        # the budget admitted the analytic footprint but the kernel
        # can't take the call (no MXU-aligned block fits, or an
        # unsupported shape class): land it on the host path, as the
        # paper's residual machinery does.
        backend = _first_allowed(op, op.host_order, ctx)
        out = op.backends[backend](ctx, *args, **kwargs)
        decision = "accel->host"
    _trace.append(DispatchRecord(op_name, decision, backend, footprint,
                                 ctx.vmem_budget, spec,
                                 platform=ctx.platform or "",
                                 tag=ctx.tag or ""))
    _counters[(op_name, decision, backend)] += 1
    return out


# ----------------------------------------------------------------------------
# Built-in op registrations
# ----------------------------------------------------------------------------

def _flat_m(x) -> int:
    m = 1
    for d in x.shape[:-1]:
        m *= d
    return m


def _register_builtin_ops() -> None:
    from repro.kernels.fp16_matmul.ops import fp16_matmul
    from repro.kernels.fp16_matmul.ref import fp16_matmul_ref
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.paged_attention.ref import paged_decode_attention_ref
    from repro.kernels.paged_attention.xla import paged_decode_attention_xla
    from repro.kernels.q4_attention.ops import q4_decode_attention
    from repro.kernels.q4_attention.ref import q4_decode_attention_ref
    from repro.kernels.q4_attention.xla import q4_decode_attention_xla
    from repro.kernels.q4_matmul.ops import q4_matmul, q4_matmul_xla
    from repro.kernels.q4_matmul.ref import q4_matmul_ref
    from repro.kernels.q8_attention.ops import q8_decode_attention
    from repro.kernels.q8_attention.ref import q8_decode_attention_ref
    from repro.kernels.q8_attention.xla import q8_decode_attention_xla
    from repro.kernels.q8_matmul.ops import q8_matmul, q8_matmul_xla
    from repro.kernels.q8_matmul.ref import q8_matmul_ref
    from repro.kernels.slstm_scan.ops import slstm_scan
    from repro.kernels.slstm_scan.ref import slstm_scan_ref

    # ---- q8_matmul: y = x @ dequant(w), w a (K, N) Q8Tensor ----
    register(KernelOp(
        name="q8_matmul",
        doc="Q8_0 GEMM (weights quantized along K).",
        spec=lambda x, w, **kw: KernelSpec(
            "q8_matmul", m=_flat_m(x), n=w.q.shape[-1], k=x.shape[-1],
            dtype="q8_0", tag="proj"),
        backends={
            "pallas": lambda ctx, x, w, out_dtype=jnp.float32: q8_matmul(
                x, w, vmem_budget=ctx.vmem_budget, out_dtype=out_dtype,
                interpret=ctx.interpret),
            "xla": lambda ctx, x, w, out_dtype=jnp.float32: q8_matmul_xla(
                x, w, out_dtype=out_dtype),
            "ref": lambda ctx, x, w, out_dtype=jnp.float32: q8_matmul_ref(
                x, w.q, w.scale, out_dtype=out_dtype),
        },
    ))

    # ---- q4_matmul: y = x @ dequant(w), w a packed-K Q4Tensor ----
    # One tier below q8_matmul: spec.k is the *logical* K (2x the packed
    # plane rows) so the SC-FOOT bytes band prices the 0.5625 B/elem
    # stream against the same m/n/k as the q8 op.
    register(KernelOp(
        name="q4_matmul",
        doc="Q4_0 GEMM (nibble-packed weights quantized along K).",
        spec=lambda x, w, **kw: KernelSpec(
            "q4_matmul", m=_flat_m(x), n=w.q.shape[-1], k=x.shape[-1],
            dtype="q4_0", tag="proj"),
        backends={
            "pallas": lambda ctx, x, w, out_dtype=jnp.float32: q4_matmul(
                x, w, vmem_budget=ctx.vmem_budget, out_dtype=out_dtype,
                interpret=ctx.interpret),
            "xla": lambda ctx, x, w, out_dtype=jnp.float32: q4_matmul_xla(
                x, w, out_dtype=out_dtype),
            "ref": lambda ctx, x, w, out_dtype=jnp.float32: q4_matmul_ref(
                x, w.q, w.scale, out_dtype=out_dtype),
        },
    ))

    # ---- fp16_matmul: y = x @ w, dense fp16/bf16 operands ----
    # The "xla" binding reproduces models.layers.mm's historical einsum
    # exactly (operands stay in compute dtype; no forced f32 upcast) so
    # host-routed model forwards are bit-identical to the pre-API stack.
    register(KernelOp(
        name="fp16_matmul",
        doc="Dense fp16/bf16 GEMM.",
        spec=lambda x, w, **kw: KernelSpec(
            "fp16_matmul", m=_flat_m(x), n=w.shape[-1], k=x.shape[-1],
            dtype="f16", tag="proj"),
        backends={
            "pallas": lambda ctx, x, w, out_dtype=None: fp16_matmul(
                x, w, vmem_budget=ctx.vmem_budget,
                out_dtype=out_dtype or jnp.float32,
                interpret=ctx.interpret),
            "xla": lambda ctx, x, w, out_dtype=None: (
                jnp.einsum("...k,kn->...n", x, w).astype(out_dtype)
                if out_dtype is not None
                else jnp.einsum("...k,kn->...n", x, w)),
            "ref": lambda ctx, x, w, out_dtype=None: fp16_matmul_ref(
                x, w, out_dtype=out_dtype or jnp.float32),
        },
    ))

    # ---- flash_attention: (B,S,H,D) GQA attention ----
    def _flash_pallas(ctx, q, k, v, *, causal=True, window=None,
                      softcap=None):
        if q.shape[1] != k.shape[1]:
            # the Pallas kernel assumes square S; cross-attention
            # (sq != skv) lands on the host chunked path via dispatch's
            # accel->host fallback.
            raise ValueError(
                f"flash_attention pallas kernel requires sq == skv, got "
                f"{q.shape[1]} vs {k.shape[1]}")
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, interpret=ctx.interpret)

    def _flash_xla(ctx, q, k, v, *, causal=True, window=None, softcap=None):
        # deferred import: models.attention itself dispatches through
        # this module (call-time import breaks the cycle).
        from repro.models.attention import _repeat_kv, chunked_attention
        h = q.shape[2]
        return chunked_attention(q, _repeat_kv(k, h), _repeat_kv(v, h),
                                 causal=causal, window=window,
                                 softcap=softcap)

    def _flash_ref(ctx, q, k, v, *, causal=True, window=None, softcap=None):
        from repro.models.attention import _repeat_kv
        b, s, h, d = q.shape
        k = _repeat_kv(k, h)
        v = _repeat_kv(v, h)
        sk = k.shape[1]
        out = attention_ref(
            q.transpose(0, 2, 1, 3).reshape(b * h, s, d),
            k.transpose(0, 2, 1, 3).reshape(b * h, sk, d),
            v.transpose(0, 2, 1, 3).reshape(b * h, sk, d),
            causal=causal, window=window, softcap=softcap)
        return out.reshape(b, h, s, d).transpose(0, 2, 1, 3).astype(q.dtype)

    register(KernelOp(
        name="flash_attention",
        doc="GQA flash attention over (B,S,H,D).",
        # count = 2 * B * H: QK^T and AV (equal 2*m*n*k flops) over every
        # batch*query-head plane — one KernelSpec per dispatched call.
        spec=lambda q, k, v, **kw: KernelSpec(
            "flash_attention", m=q.shape[1], n=k.shape[1], k=q.shape[-1],
            dtype="f16", count=2 * q.shape[0] * q.shape[2],
            tag="attn_qk"),
        backends={
            "pallas": _flash_pallas,
            "xla": _flash_xla,
            "ref": _flash_ref,
        },
    ))

    # ---- q8_decode_attention: decode matvec over the Q8_0 KV cache ----
    # count = 2 * BH: the QK^T and AV contractions (same 2*m*n*k flops
    # each) across every batch*head lane in the flattened plane.
    # The "xla" host backend dequantizes into bf16 (never f32 planes) —
    # the ref oracle's full-plane f32 dequant is for parity tests only.
    register(KernelOp(
        name="q8_decode_attention",
        doc="Decode attention reading the Q8_0-quantized KV cache.",
        spec=lambda q, kq, ks, vq, vs, length, **kw: KernelSpec(
            "q8_decode_attention", m=q.shape[1], n=kq.shape[1],
            k=q.shape[-1], dtype="q8_0", count=2 * q.shape[0],
            tag="attn_qk"),
        backends={
            "pallas": lambda ctx, q, kq, ks, vq, vs, length, bk=128:
                q8_decode_attention(q, kq, ks, vq, vs, length, bk=bk,
                                    interpret=ctx.interpret),
            "xla": lambda ctx, q, kq, ks, vq, vs, length, bk=128:
                q8_decode_attention_xla(q, kq, ks, vq, vs, length),
            "ref": lambda ctx, q, kq, ks, vq, vs, length, bk=128:
                q8_decode_attention_ref(q, kq, ks, vq, vs, length),
        },
    ))

    # ---- q4_decode_attention: decode matvec over the Q4_0 KV cache ----
    # Same shape/count conventions as the q8 op; the Pallas binding is
    # single-query (speculative multi-query verify raises ValueError and
    # lands on the bf16-widened xla backend via accel->host fallback).
    register(KernelOp(
        name="q4_decode_attention",
        doc="Decode attention reading the Q4_0 nibble-packed KV cache.",
        spec=lambda q, kp, ks, vp, vs, length, **kw: KernelSpec(
            "q4_decode_attention", m=q.shape[1], n=kp.shape[1],
            k=q.shape[-1], dtype="q4_0", count=2 * q.shape[0],
            tag="attn_qk"),
        backends={
            "pallas": lambda ctx, q, kp, ks, vp, vs, length, bk=128:
                q4_decode_attention(q, kp, ks, vp, vs, length, bk=bk,
                                    interpret=ctx.interpret),
            "xla": lambda ctx, q, kp, ks, vp, vs, length, bk=128:
                q4_decode_attention_xla(q, kp, ks, vp, vs, length),
            "ref": lambda ctx, q, kp, ks, vp, vs, length, bk=128:
                q4_decode_attention_ref(q, kp, ks, vp, vs, length),
        },
    ))

    # ---- paged_decode_attention: decode matvec over a paged KV pool ----
    # Planes live in a shared (n_pages, P, Hkv, ·) pool; ``table``
    # (B, n_lp) reassembles each lane's logical sequence by gather, so
    # n = n_lp * P plays the role the slot pool's max_len/enc_len played.
    # ``kc``/``vc`` are arrays (bf16 cache) or {"q", "s"} dicts (q8_0).
    # count = 2 * B * H as in the slot-pool decode ops; the page-table
    # gather roughly doubles the K/V byte stream (pool read + gathered
    # copy), which stays inside the SC-FOOT bytes band.
    register(KernelOp(
        name="paged_decode_attention",
        doc="Decode attention gathered over per-lane page tables.",
        spec=lambda q, kc, vc, table, lens, **kw: KernelSpec(
            "paged_decode_attention", m=q.shape[1],
            n=table.shape[1] * (kc["p" if "p" in kc else "q"]
                                if isinstance(kc, dict) else kc).shape[1],
            k=q.shape[-1],
            dtype=(("q4_0" if "p" in kc else "q8_0")
                   if isinstance(kc, dict) else "bf16"),
            count=2 * q.shape[0] * q.shape[2], tag="attn_qk"),
        backends={
            "xla": lambda ctx, q, kc, vc, table, lens:
                paged_decode_attention_xla(q, kc, vc, table, lens),
            "ref": lambda ctx, q, kc, vc, table, lens:
                paged_decode_attention_ref(q, kc, vc, table, lens),
        },
    ))

    # ---- slstm_scan: time-chunked sLSTM recurrence ----
    register(KernelOp(
        name="slstm_scan",
        doc="Chunked sLSTM scan, state resident in VMEM.",
        # count = 4 * T: four gate recurrence matmuls (B*H, hd) @ (hd, hd)
        # per scanned time step.
        spec=lambda wx, r_all, state0, **kw: KernelSpec(
            "slstm_scan", m=wx.shape[2] * wx.shape[3], n=wx.shape[-1],
            k=wx.shape[-1], dtype="f32", count=4 * wx.shape[0],
            tag="ssm"),
        backends={
            "pallas": lambda ctx, wx, r_all, state0, t_chunk=64:
                slstm_scan(wx, r_all, state0, t_chunk=t_chunk,
                           interpret=ctx.interpret),
            "ref": lambda ctx, wx, r_all, state0, t_chunk=64:
                slstm_scan_ref(wx, r_all, state0),
        },
    ))


_register_builtin_ops()
