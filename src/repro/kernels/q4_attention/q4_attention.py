"""Pallas TPU kernel: decode attention over a Q4_0-quantized KV cache.

One tier below ``q8_attention``: the cache stream drops to
(0.5 + 2/QBLOCK)/2 = 0.28125x of bf16 — nibble codes plus one f16 scale
per 32-element block along head_dim. Nibbles are unpacked and scaled
**in VMEM right before the MXU dot** (paper C1); the cache never exists
in HBM above 4 bits/element.

Online-softmax over KV blocks, one grid step per (head, kv-block), with
a masked tail for cache positions beyond the current decode position.
Single-query only: the speculative multi-query verify path routes to the
XLA backend via the dispatch fallback.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import QBLOCK

NEG_INF = -1e30


def _q4_attn_kernel(len_ref, q_ref, kp_ref, ks_ref, vp_ref, vs_ref,
                    o_ref, m_ref, l_ref, acc_ref, *,
                    scale, n_k_blocks, bk):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (1, D)

    def dequant(pref, sref):
        raw = pref[0]                                    # (bk, D//2) uint8
        lo = (raw & jnp.uint8(0xF)).astype(jnp.int8) - 8
        hi = (raw >> 4).astype(jnp.int8) - 8
        rows, half = raw.shape
        codes = jnp.stack([lo, hi], axis=2).reshape(rows, 2 * half)
        sc = sref[0].astype(jnp.float32)                 # (bk, D//32)
        sc_full = jnp.repeat(sc, QBLOCK, axis=1)         # C1: in-VMEM
        return codes.astype(jnp.float32) * sc_full

    k = dequant(kp_ref, ks_ref)
    v = dequant(vp_ref, vs_ref)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bk)
    s = s * scale
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(kpos < len_ref[0, 0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_k_blocks - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def q4_decode_attention_pallas(q: jax.Array, kp: jax.Array, ks: jax.Array,
                               vp: jax.Array, vs: jax.Array,
                               length: jax.Array, *,
                               bk: int = 128,
                               interpret: bool = False) -> jax.Array:
    """q: (BH, 1, D); kp/vp: (BH, S, D//2) packed uint8; ks/vs:
    (BH, S, D//QBLOCK) scales; length: () or (BH,) int32 — lane h attends
    positions [0, length[h]). S % bk == 0. Returns (BH, 1, D) in q.dtype."""
    bh, one, d = q.shape
    s = kp.shape[1]
    assert one == 1 and kp.shape == (bh, s, d // 2) and s % bk == 0
    assert ks.shape == (bh, s, d // QBLOCK), ks.shape
    n_k_blocks = s // bk
    scale = 1.0 / (d ** 0.5)
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels.common import tpu_compiler_params
    kernel = functools.partial(_q4_attn_kernel, scale=scale,
                               n_k_blocks=n_k_blocks, bk=bk)
    grid = (bh, n_k_blocks)
    lens = jnp.broadcast_to(
        jnp.asarray(length, jnp.int32).reshape(-1), (bh,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda h, j: (h, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, bk, d // 2), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d // QBLOCK), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d // 2), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d // QBLOCK), lambda h, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens.reshape(bh, 1), q, kp, ks, vp, vs)
