"""Pure-jnp oracle: dense decode attention over a dequantized Q4 cache.

``length`` may be (), (BH,), or (BH, Q) — the last gives every query row
its own attend-depth, which is how the speculative verify forward masks
draft position j to [0, pos + j + 1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QBLOCK, unpack_q4
from repro.kernels.common import lens_mask


def dequant(p: jax.Array, scale: jax.Array) -> jax.Array:
    """p: (..., S, D//2) packed uint8; scale: (..., S, D//QBLOCK) -> f32."""
    codes = unpack_q4(p, axis=-1).astype(jnp.float32)
    return codes * jnp.repeat(scale.astype(jnp.float32), QBLOCK, axis=-1)


def q4_decode_attention_ref(q, kp, ks, vp, vs, length) -> jax.Array:
    """q: (BH, Q, D); packed caches + scales; attend [0, length)."""
    k = dequant(kp, ks)
    v = dequant(vp, vs)
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k) * (d ** -0.5)
    mask = lens_mask(length, q.shape[0], k.shape[1])
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v).astype(q.dtype)
