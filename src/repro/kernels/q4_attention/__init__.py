from repro.kernels.q4_attention.ops import *  # noqa
