"""XLA host backend: Q4_0 decode attention without f32 plane
materialization.

Nibble codes are unpacked uint8 -> int8 -> bf16 (integers in [-8, 7],
exact in bf16) and the per-block scales fold in *after* the
f32-accumulated contraction — algebraically identical to
dequantize-then-dot, with the widest materialized plane at 2 bytes/elem.
``repro.staticcheck``'s SC-DTYPE pass verifies no f32 plane convert
exists in the lowered program.

Supports the speculative multi-query verify: ``q`` is (BH, Q, D) and
``length`` may be (BH, Q) per-query attend-depths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QBLOCK, unpack_q4
from repro.kernels.common import lens_mask


def q4_decode_attention_xla(q, kp, ks, vp, vs, length) -> jax.Array:
    """q: (BH, Q, D); kp/vp (BH, S, D//2) packed uint8 + scales; attend
    positions [0, length). Same contract as the ref oracle."""
    bh, nq, d = q.shape
    s_len = kp.shape[1]
    nb = d // QBLOCK
    qb = q.astype(jnp.bfloat16).reshape(bh, nq, nb, QBLOCK)
    k4 = unpack_q4(kp, axis=-1).astype(jnp.bfloat16).reshape(
        bh, s_len, nb, QBLOCK)
    v4 = unpack_q4(vp, axis=-1).astype(jnp.bfloat16).reshape(
        bh, s_len, nb, QBLOCK)
    s = jnp.einsum("bqnd,bknd->bqkn", qb, k4,
                   preferred_element_type=jnp.float32)
    s = (s * ks.astype(jnp.float32)[:, None, :, :]).sum(-1) * (d ** -0.5)
    s = jnp.where(lens_mask(length, bh, s_len), s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    wv = w[:, :, :, None] * vs.astype(jnp.float32)[:, None, :, :]
    out = jnp.einsum("bqkn,bknd->bqnd", wv.astype(jnp.bfloat16), v4,
                     preferred_element_type=jnp.float32)
    return out.reshape(bh, nq, d).astype(q.dtype)
