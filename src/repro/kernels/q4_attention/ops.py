"""Public wrapper: Q4_0 KV-cache decode attention (+ its traffic model).

``quantize_kv_q4`` builds the packed nibble planes from bf16 K/V
(per-token, per-head 32-blocks along head_dim). ``q4_decode_attention``
pads S to the block multiple and dispatches the Pallas kernel; it is
single-query only — the speculative verify's (BH, Q) case raises
``ValueError`` so the kernel registry's accel->host fallback routes it
to the XLA backend.

Traffic: the per-step cache stream drops from 2·S·D bf16 bytes to
2·S·D·(0.5 + 2/QBLOCK)/2 ≈ 0.56·S·D — 0.28125x of bf16 and 0.53x of the
Q8_0 tier, the int4 LOAD saving the CGLA follow-up headlines.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantize import QBLOCK, quantize_q4_0
from repro.kernels.common import pad_dim
from repro.kernels.q4_attention.q4_attention import q4_decode_attention_pallas


def quantize_kv_q4(k: jax.Array):
    """k: (..., S, D) float -> (packed uint8 plane (…, S, D//2),
    (…, S, D//QBLOCK) scales)."""
    t = quantize_q4_0(k, axis=-1)
    return t.q, t.scale


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def q4_decode_attention(q, kp, ks, vp, vs, length, *, bk: int = 128,
                        interpret: bool = True) -> jax.Array:
    """q: (BH, 1, D); kp/vp: (BH, S, D//2) packed uint8; ks/vs scales;
    attend [0, length) with ``length`` a scalar or (BH,) vector. Handles
    S not divisible by bk via zero padding (masked by ``length``)."""
    length = jnp.asarray(length)
    if q.shape[1] != 1 or length.ndim > 1:
        raise ValueError(
            "q4_decode_attention (Pallas) is single-query: got "
            f"q {q.shape}, length {length.shape}; multi-query verify "
            "routes to the XLA backend via dispatch fallback")
    kp, vp, ks, vs = (pad_dim(t, 1, bk) for t in (kp, vp, ks, vs))
    return q4_decode_attention_pallas(q, kp, ks, vp, vs, length, bk=bk,
                                      interpret=interpret)


def cache_traffic_ratio_q4() -> float:
    """Q4 cache bytes per element vs bf16 (paper C1 LOAD saving,
    int4 tier): (0.5 + 2/QBLOCK) / 2 = 0.28125."""
    q4 = 0.5 + 2.0 / QBLOCK
    return q4 / 2.0
