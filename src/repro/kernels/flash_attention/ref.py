"""Pure-jnp oracle for flash attention (dense softmax, same masks)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: int | None = None,
                  softcap: float | None = None) -> jax.Array:
    """q, k, v: (BH, S, D). Dense reference with identical masking."""
    bh, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bqk,bkd->bqd", p / l, v.astype(jnp.float32))
    return out.astype(q.dtype)
