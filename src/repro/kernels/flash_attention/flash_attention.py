"""Pallas TPU kernel: online-softmax (flash) attention.

Needed by the long-context cells (32k prefill / 500k hybrid decode): the
scores matrix must never materialize in HBM. Online softmax over KV blocks
with running (m, l) statistics; causal, sliding-window (Mixtral), and
logit-softcap (Gemma-2) variants are folded into the mask/logits path so
one kernel serves every assigned architecture.

VMEM residency per grid step = q-block + k-block + v-block + accumulators —
chosen against the same VMEM budget machinery as the GEMM kernels (C4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, softcap, n_k_blocks, bq, bk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)                    # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                 # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                              # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                     # (bq, 1)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_k_blocks - 1)
    def _done():
        # fully-masked rows (can happen with sliding windows) get l == 0
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           window: int | None = None,
                           softcap: float | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, S, D) — heads pre-flattened (GQA handled by ops.py).
    S must divide by bq and bk."""
    bh, s, d = q.shape
    assert k.shape == (bh, s, d) and v.shape == (bh, s, d)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_k_blocks = s // bk
    scale = 1.0 / (d ** 0.5)
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels.common import tpu_compiler_params
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, n_k_blocks=n_k_blocks, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // bq, n_k_blocks),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
