"""Public wrapper: GQA-aware flash attention over (B, S, H, D) layouts."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _choose_block(s: int, pref: int = 128) -> int:
    b = min(pref, s)
    while s % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: int | None = None,
                    softcap: float | None = None,
                    interpret: bool = True) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, Hkv, D) with H % Hkv == 0 (GQA).
    Returns (B, S, H, D)."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    bq = _choose_block(s)
    bk = _choose_block(s)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                 softcap=softcap, bq=bq, bk=bk,
                                 interpret=interpret)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
