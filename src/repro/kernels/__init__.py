"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel ships as <name>/<name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper incl. the C2 mixed-execution split), and
ref.py (pure-jnp oracle used by the allclose test sweeps).

``repro.kernels.api`` is the dispatch seam: every op registers in
``repro.kernels.registry`` and consumers route through ``dispatch``,
which applies the paper's ACCEL/HOST control law per call.
"""
from repro.kernels.q8_matmul.ops import q8_matmul, q8_matmul_xla
from repro.kernels.fp16_matmul.ops import fp16_matmul, offload_info
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.paged_attention.xla import (gather_pages,
                                               paged_decode_attention_xla)
from repro.kernels.q8_attention.ops import (cache_traffic_ratio,
                                            q8_decode_attention, quantize_kv)
from repro.kernels.slstm_scan.ops import slstm_scan
from repro.kernels.registry import KernelOp, get_op, list_ops, register
from repro.kernels.api import (DispatchContext, dispatch, dispatch_counters,
                               dispatch_trace, reset_dispatch_log,
                               use_context, current_context)

__all__ = [
    "DispatchContext", "KernelOp", "cache_traffic_ratio", "current_context",
    "dispatch", "dispatch_counters", "dispatch_trace", "fp16_matmul",
    "flash_attention", "gather_pages", "get_op", "list_ops", "offload_info",
    "paged_decode_attention_xla", "q8_matmul", "q8_matmul_xla",
    "q8_decode_attention", "quantize_kv", "register",
    "reset_dispatch_log", "slstm_scan", "use_context",
]
