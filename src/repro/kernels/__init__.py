"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel ships as <name>/<name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper incl. the C2 mixed-execution split), and
ref.py (pure-jnp oracle used by the allclose test sweeps).
"""
from repro.kernels.q8_matmul.ops import q8_matmul, q8_matmul_xla
from repro.kernels.fp16_matmul.ops import fp16_matmul, offload_info
from repro.kernels.flash_attention.ops import flash_attention
