"""Pure-jnp oracle for the sLSTM time-chunk kernel: plain lax.scan over
timesteps with the model's stabilized gate math (xlstm._slstm_step)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def slstm_scan_ref(wx: jax.Array, r_all: jax.Array, state0: jax.Array):
    """wx: (S, 4, B, H, hd); r_all: (4, H, hd, hd); state0: (4, B, H, hd).
    Returns (hs: (S, B, H, hd) f32, state_final: (4, B, H, hd))."""
    def step(st, wx_t):
        c, n, h, m = st[0], st[1], st[2], st[3]
        pre = wx_t + jnp.einsum("bhe,ghef->gbhf", h,
                                r_all.astype(jnp.float32))
        i_r, f_r, z_r, o_r = pre[0], pre[1], pre[2], pre[3]
        logf = jax.nn.log_sigmoid(f_r)
        m_new = jnp.maximum(logf + m, i_r)
        i_g = jnp.exp(i_r - m_new)
        f_g = jnp.exp(logf + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(z_r)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(o_r) * c_new / jnp.maximum(n_new, 1e-6)
        st = jnp.stack([c_new, n_new, h_new, m_new])
        return st, h_new

    state, hs = jax.lax.scan(step, state0.astype(jnp.float32),
                             wx.astype(jnp.float32))
    return hs, state
