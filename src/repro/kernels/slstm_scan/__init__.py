from repro.kernels.slstm_scan.ops import *  # noqa
