"""Pallas TPU kernel: time-chunked sLSTM scan (§Perf xlstm iteration 3).

The XLA formulation of the sLSTM recurrence round-trips the (4, B, H, hd)
state and every per-timestep intermediate through HBM 4096 times per
segment — the worst memory term of the whole 40-cell table. The TPU-native
fix keeps the recurrence resident:

* the stacked recurrent weights R (4, H, hd, hd) and the running state
  (c, n, h, m) live in VMEM for the whole sequence;
* the precomputed input pre-activations ``wx`` stream in T-step chunks
  (one grid step = T timesteps), and only the h outputs stream back;
* HBM traffic per chunk = wx-in + h-out (+ R and state once per
  sequence) — ~50x less than the per-step XLA loop.

Grid dim 0 walks the sequence chunks sequentially ("arbitrary"
semantics); VMEM scratch persists across grid steps, carrying the state.
Numerics match the model's stabilized formulation exactly (log-sigmoid
forget, m-state max-stabilizer).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _slstm_chunk_kernel(wx_ref, r_ref, s0_ref, hs_ref, sout_ref, state_ref,
                        *, t_chunk, n_chunks):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        state_ref[...] = s0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)            # (4, H, hd, hd)

    def step(t, _):
        st = state_ref[...]
        c, n, h, m = st[0], st[1], st[2], st[3]
        wx_t = wx_ref[t].astype(jnp.float32)      # (4, B, H, hd)
        rh = jax.lax.dot_general(                 # (B,H,e)x(4,H,e,f)
            h, r, (((2,), (2,)), ((1,), (1,))),
            preferred_element_type=jnp.float32)   # -> (H, B, 4, f)
        pre = wx_t + rh.transpose(2, 1, 0, 3)     # (4, B, H, hd)
        i_r, f_r, z_r, o_r = pre[0], pre[1], pre[2], pre[3]
        logf = jax.nn.log_sigmoid(f_r)
        m_new = jnp.maximum(logf + m, i_r)
        i_g = jnp.exp(i_r - m_new)
        f_g = jnp.exp(logf + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(z_r)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(o_r) * c_new / jnp.maximum(n_new, 1e-6)
        state_ref[...] = jnp.stack([c_new, n_new, h_new, m_new])
        hs_ref[t] = h_new.astype(hs_ref.dtype)
        return 0

    jax.lax.fori_loop(0, t_chunk, step, 0)

    @pl.when(i == n_chunks - 1)
    def _done():
        sout_ref[...] = state_ref[...].astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("t_chunk", "interpret"))
def slstm_scan_pallas(wx: jax.Array, r_all: jax.Array, state0: jax.Array, *,
                      t_chunk: int = 64,
                      interpret: bool = False):
    """wx: (S, 4, B, H, hd) input pre-activations (Wx+b, precomputed);
    r_all: (4, H, hd, hd); state0: (4, B, H, hd) stacked (c, n, h, m).
    Returns (hs: (S, B, H, hd) f32, state_final: (4, B, H, hd)).
    S must divide by t_chunk (ops.py pads)."""
    s, four, b, h, hd = wx.shape
    assert four == 4 and r_all.shape == (4, h, hd, hd), (wx.shape,
                                                         r_all.shape)
    assert s % t_chunk == 0, (s, t_chunk)
    n_chunks = s // t_chunk
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels.common import tpu_compiler_params
    kernel = functools.partial(_slstm_chunk_kernel, t_chunk=t_chunk,
                               n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((t_chunk, 4, b, h, hd), lambda i: (i, 0, 0, 0, 0)),
            pl.BlockSpec((4, h, hd, hd), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((4, b, h, hd), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t_chunk, b, h, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((4, b, h, hd), lambda i: (0, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, b, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((4, b, h, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((4, b, h, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(wx, r_all, state0)
