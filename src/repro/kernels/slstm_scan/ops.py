"""Public wrapper for the sLSTM time-chunk kernel (+ its roofline model).

``slstm_scan`` pads S to the chunk multiple and dispatches the Pallas
kernel (interpret=True on CPU). ``kernel_traffic_model`` is the analytic
HBM-traffic model used by EXPERIMENTS.md §Perf (the kernel cannot be
lowered by the CPU backend, so its roofline term is derived, not parsed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.slstm_scan.slstm_scan import slstm_scan_pallas


@functools.partial(jax.jit, static_argnames=("t_chunk", "interpret"))
def slstm_scan(wx: jax.Array, r_all: jax.Array, state0: jax.Array, *,
               t_chunk: int = 64, interpret: bool = True):
    """wx: (S, 4, B, H, hd); returns (hs (S,B,H,hd), state (4,B,H,hd))."""
    s = wx.shape[0]
    pad = (-s) % t_chunk
    if pad:
        # state-preserving padding: i-gate -> -inf (add nothing),
        # f-gate -> +large (log-sigmoid ~ 0: keep everything); the padded
        # h outputs are sliced off below.
        _, four, b, h, hd = wx.shape
        pad_row = jnp.stack([
            jnp.full((b, h, hd), -1e30, wx.dtype),   # i
            jnp.full((b, h, hd), 40.0, wx.dtype),    # f
            jnp.zeros((b, h, hd), wx.dtype),         # z
            jnp.zeros((b, h, hd), wx.dtype),         # o
        ])
        wx = jnp.concatenate(
            [wx, jnp.broadcast_to(pad_row, (pad,) + pad_row.shape)], 0)
    hs, state = slstm_scan_pallas(wx, r_all, state0, t_chunk=t_chunk,
                                  interpret=interpret)
    if pad:
        # c/n/m are pad-invariant; h drifts on padded steps — restore the
        # last valid output
        state = jnp.concatenate([state[:2], hs[s - 1][None], state[3:]])
    return hs[:s], state


def kernel_traffic_model(s: int, b: int, h: int, hd: int,
                         n_segments: int, n_micro: int = 1,
                         bwd_factor: float = 3.0) -> dict:
    """Per-device HBM bytes for the kernelized sLSTM pass.

    Streams: wx in (4·S·B·H·hd f32 — written once by the projection GEMM,
    read once by the kernel), h out (S·B·H·hd f32), R + state resident in
    VMEM (R: 4·H·hd² ≈ 4 MB; state: 4·B·H·hd ≈ 256 KB — both fit v5e's
    128 MB VMEM with the wx chunk double-buffered). ``bwd_factor``
    models the backward kernel (re-reads wx + h, writes dwx, accumulates
    dR in VMEM) at ~2x forward plus the recompute read.
    """
    wx_bytes = 4 * s * b * h * hd * 4
    h_bytes = s * b * h * hd * 4
    r_bytes = 4 * h * hd * hd * 4
    fwd = 2 * wx_bytes + 2 * h_bytes + r_bytes   # write+read each stream
    total = fwd * (1 + bwd_factor) * n_segments * n_micro
    return {"fwd_bytes": fwd, "total_bytes": total,
            "vmem_resident": r_bytes + 4 * b * h * hd * 4}
