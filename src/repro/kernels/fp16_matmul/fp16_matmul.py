"""Pallas TPU kernel: FP16 GEMM with inline FP16→FP32 upconversion (C1).

IMAX performs FP16→FP32 conversion inline on PE bit-manipulation units to
avoid dedicated hardware; the TPU analogue is storing/streaming fp16 and
upcasting in VMEM right before the MXU dot (the MXU natively consumes
bf16/f32 — fp16 inputs would otherwise be upcast in HBM, doubling traffic).

The paper's SIMD pairing (two 32-bit ops on a 64-bit datapath) and 4-way
column multithreading map onto the MXU's native 8x128 lane structure and
the grid pipeline — reflected here by MXU-aligned block shapes and the
k-grid accumulation pipeline rather than emulated literally (DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fp16_matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k_blocks):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # inline fp16 -> fp32 conversion in VMEM (C1)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k_blocks - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def fp16_matmul_pallas(x: jax.Array, w: jax.Array, *,
                       bm: int = 128, bn: int = 128, bk: int = 512,
                       out_dtype=jnp.float32,
                       interpret: bool = False) -> jax.Array:
    """x: (M, K); w: (K, N) float16. Shapes must be block-aligned (the
    mixed-execution wrapper in ops.py handles ragged K/M/N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, ((m, n, k), (bm, bn, bk))
    n_k_blocks = k // bk
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels.common import tpu_compiler_params
    return pl.pallas_call(
        functools.partial(_fp16_matmul_kernel, n_k_blocks=n_k_blocks),
        grid=(m // bm, n // bn, n_k_blocks),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
