from repro.kernels.fp16_matmul.ops import *  # noqa
