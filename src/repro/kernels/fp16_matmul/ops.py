"""Public wrapper for the FP16 GEMM: the literal C2 mixed-execution split.

``K`` is partitioned into a burst-aligned main segment (Pallas kernel, the
"IMAX" path) and a residual tail (plain XLA, the "host" path), executed
concurrently under jit and summed — exactly Sec III-B's strategy. The
``burst`` parameter is the kernel's K-block; ``offload_info`` reports the
achieved offload rate (paper: ~95 % of MACs at burst=16 on Whisper).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.burst import split_burst
from repro.core.footprint import select_blocks
from repro.kernels.common import pad_dim
from repro.kernels.fp16_matmul.fp16_matmul import fp16_matmul_pallas
from repro.kernels.fp16_matmul.ref import fp16_matmul_ref


@functools.partial(jax.jit, static_argnames=("vmem_budget", "interpret",
                                             "out_dtype"))
def fp16_matmul(x: jax.Array, w: jax.Array, *,
                vmem_budget: int = 4 * 1024 * 1024,
                out_dtype=jnp.float32,
                interpret: bool = True) -> jax.Array:
    """y = x @ w for fp16/bf16 operands of any shape; C2 split on K."""
    if x.ndim != 2:
        lead = x.shape[:-1]
        y = fp16_matmul(x.reshape(-1, x.shape[-1]), w,
                        vmem_budget=vmem_budget, out_dtype=out_dtype,
                        interpret=interpret)
        return y.reshape(*lead, y.shape[-1])
    m, k = x.shape
    k2, n = w.shape
    assert k == k2

    blocks = select_blocks(m, n, k, vmem_budget, a_dtype="f16", b_dtype="f16")
    bm, bn, bk = blocks.bm, blocks.bn, blocks.bk

    split = split_burst(k, bk)
    x_main, x_res = x[:, :split.k_main], x[:, split.k_main:]
    w_main, w_res = w[:split.k_main], w[split.k_main:]

    xp = pad_dim(x_main, 0, bm)
    wp = pad_dim(w_main, 1, bn)

    if split.k_main > 0:
        y = fp16_matmul_pallas(xp, wp, bm=bm, bn=bn, bk=bk,
                               out_dtype=jnp.float32, interpret=interpret)
        y = y[:m, :n]
    else:
        y = jnp.zeros((m, n), jnp.float32)
    if split.k_residual > 0:
        y = y + fp16_matmul_ref(x_res, w_res)
    return y.astype(out_dtype)


def offload_info(m: int, n: int, k: int,
                 vmem_budget: int = 4 * 1024 * 1024) -> dict:
    """Report the C2 split this wrapper would use for a GEMM shape."""
    blocks = select_blocks(m, n, k, vmem_budget, a_dtype="f16", b_dtype="f16")
    s = split_burst(k, blocks.bk)
    return dict(bm=blocks.bm, bn=blocks.bn, bk=blocks.bk,
                k_main=s.k_main, k_residual=s.k_residual,
                offload_fraction=s.offload_fraction,
                vmem_bytes=blocks.vmem_bytes)
