"""Pure-jnp oracle for the FP16 GEMM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fp16_matmul_ref(x: jax.Array, w: jax.Array,
                    out_dtype=jnp.float32) -> jax.Array:
    """y = f32(x) @ f32(w) with f32 accumulation (IMAX computes f32 after
    inline conversion)."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)
