"""Paper-faithful-baseline switch for §Perf A/B measurements.

``REPRO_BASELINE=1`` re-enables every pre-hillclimb code path so the
baseline can be re-measured under the *final* analyzer convention
(before/after numbers must share one accounting):

* attention: f32 HBM upcasts of Q/K/V/P before the dots (vs C1-inline
  bf16-into-MXU);
* decode: ys-stacked cache re-materialization (vs stacked-carry in-place
  token writes);
* sharding: seq-sharded serve KV when kv%tp != 0 (vs head_dim-sharded);
* MoE: global-token dispatch (vs GShard grouped);
* sLSTM: gate projections inside the timestep scan (vs hoisted Wx).
"""

import os

BASELINE = os.environ.get("REPRO_BASELINE", "") == "1"
