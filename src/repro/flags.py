"""Process-wide switches: the paper-faithful-baseline A/B toggle plus the
kernel-dispatch environment knobs consumed by ``repro.kernels.api``.

``REPRO_BASELINE=1`` re-enables every pre-hillclimb code path so the
baseline can be re-measured under the *final* analyzer convention
(before/after numbers must share one accounting):

* attention: f32 HBM upcasts of Q/K/V/P before the dots (vs C1-inline
  bf16-into-MXU);
* decode: ys-stacked cache re-materialization (vs stacked-carry in-place
  token writes);
* sharding: seq-sharded serve KV when kv%tp != 0 (vs head_dim-sharded);
* MoE: global-token dispatch (vs GShard grouped);
* sLSTM: gate projections inside the timestep scan (vs hoisted Wx).

Dispatch knobs (read at dispatch time, not import time, so tests can
monkeypatch ``os.environ``):

* ``REPRO_KERNEL_BACKEND`` — force every op onto one backend
  (``pallas`` | ``xla`` | ``ref``), bypassing the ACCEL/HOST control law;
* ``REPRO_VMEM_BUDGET``    — default LMM/VMEM byte budget for the
  offload decision and the Pallas block selection;
* ``REPRO_ALLOW_PALLAS``   — ``1``/``0``: whether the ACCEL decision may
  bind to the Pallas backend (default: only on real TPU — on CPU the
  interpreter is a correctness tool, not a fast path);
* ``REPRO_INTERPRET``      — ``1``/``0``: run Pallas kernels in
  interpreter mode (default: on unless running on TPU);
* ``REPRO_PLATFORM``       — name of a registered hardware platform
  (``repro.platforms``); ``DispatchContext.from_env`` derives its
  budget/policy/pallas-eligibility from the platform, with the explicit
  knobs above still winning where set.
"""

import os

BASELINE = os.environ.get("REPRO_BASELINE", "") == "1"

DEFAULT_VMEM_BUDGET = 4 * 1024 * 1024

_VALID_BACKENDS = ("pallas", "xla", "ref")


def _env_bool(name: str):
    v = os.environ.get(name, "").strip().lower()
    if v == "":
        return None
    return v not in ("0", "false", "no")


def _on_tpu() -> bool:
    import jax
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def kernel_backend_override():
    """Global backend force from REPRO_KERNEL_BACKEND, or None."""
    v = os.environ.get("REPRO_KERNEL_BACKEND", "").strip().lower()
    if not v:
        return None
    if v not in _VALID_BACKENDS:
        raise ValueError(
            f"REPRO_KERNEL_BACKEND={v!r}: expected one of {_VALID_BACKENDS}")
    return v


def vmem_budget_override():
    """Explicit REPRO_VMEM_BUDGET byte count, or None when unset."""
    v = os.environ.get("REPRO_VMEM_BUDGET", "")
    if not v:
        return None
    try:
        return int(v)
    except ValueError:
        raise ValueError(
            f"REPRO_VMEM_BUDGET={v!r}: expected an integer byte count"
        ) from None


def vmem_budget_default() -> int:
    v = vmem_budget_override()
    return DEFAULT_VMEM_BUDGET if v is None else v


def platform_default():
    """Platform name from REPRO_PLATFORM, or None. Resolved against the
    ``repro.platforms`` registry by ``DispatchContext.from_env``."""
    return os.environ.get("REPRO_PLATFORM", "").strip() or None


def allow_pallas_default() -> bool:
    v = _env_bool("REPRO_ALLOW_PALLAS")
    return _on_tpu() if v is None else v


def interpret_default() -> bool:
    v = _env_bool("REPRO_INTERPRET")
    return (not _on_tpu()) if v is None else v
