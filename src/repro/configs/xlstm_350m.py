"""xlstm-350m [arXiv:2405.04517]: alternating mLSTM/sLSTM blocks.

d_ff=0 per the assignment: blocks carry their own 2x up/down projections
(proj_factor). 4 heads; 24 blocks = 12 (mLSTM, sLSTM) pairs.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, xlstm=True, proj_factor=2.0,
    source="arXiv:2405.04517 (unverified tier)",
)
