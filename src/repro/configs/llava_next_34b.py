"""llava-next-34b [hf:llava-hf/llava-v1.6]: VLM, anyres tiling stubbed.

Yi-34B-style backbone: 60L, d_model=7168, 56H (kv=8), d_ff=20480. The
vision frontend is a stub per the brief: input_specs() provides
precomputed patch embeddings (n_img_tokens=2880 for anyres 2x2+base).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm", vlm=True, n_img_tokens=2880,
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab=64000, rope_theta=5e6,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (unverified tier)",
)
