"""Architecture configs: the 10 assigned archs + the paper's Whisper models.

Each assigned architecture has its own ``<id>.py`` exporting ``CONFIG``;
``get_config(name)`` resolves ids with dashes or underscores. ``reduced()``
produces the CPU-smoke-test shrink of any config (same family/block
pattern, tiny dims).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 -> d_model // n_heads

    # attention features
    qk_norm: bool = False
    attn_softcap: Optional[float] = None    # gemma2: 50.0 on attn logits
    final_softcap: Optional[float] = None   # gemma2: 30.0 on lm logits
    sliding_window: Optional[int] = None    # mixtral SWA
    local_global: bool = False              # gemma2 alternating local/global
    local_window: int = 4096
    rope_theta: float = 10000.0
    attn_bias: bool = False                 # qwen1.5-family qkv bias

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid (zamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0          # hybrid: one shared attn block every N

    # xLSTM
    xlstm: bool = False
    proj_factor: float = 2.0

    # encoder-decoder (whisper)
    enc_dec: bool = False
    enc_layers: int = 0

    # VLM (llava)
    vlm: bool = False
    n_img_tokens: int = 0

    # general
    norm_eps: float = 1e-6
    act: str = "silu"            # silu | gelu
    tie_embeddings: bool = False
    remat: bool = True
    dtype: str = "bf16"          # activation/compute dtype
    source: str = ""             # provenance note

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def scan_unit(self) -> int:
        """Layers per scanned segment (heterogeneous stacks scan groups)."""
        if self.family == "hybrid" and self.attn_every:
            return self.attn_every
        if self.xlstm or self.local_global:
            return 2
        return 1

    @property
    def sub_quadratic(self) -> bool:
        """Whether long_500k decode is runnable (state-based memory)."""
        return self.family in ("ssm", "hybrid")


_REGISTRY = [
    "whisper_base", "qwen3_moe_30b_a3b", "mixtral_8x7b", "gemma2_2b",
    "qwen3_4b", "deepseek_7b", "codeqwen15_7b", "xlstm_350m", "zamba2_7b",
    "llava_next_34b", "whisper_tiny_en",
]


def list_archs() -> list[str]:
    return [n.replace("_", "-") for n in _REGISTRY]


def get_config(name: str) -> ArchConfig:
    mod_name = name.replace("-", "_").replace(".", "")
    if mod_name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test shrink: same family and block pattern, tiny dims."""
    unit = cfg.scan_unit
    kv = min(cfg.n_kv_heads, 2)
    heads = max(4, kv * max(1, min(2, cfg.n_heads // max(cfg.n_kv_heads, 1))))
    heads = (heads // kv) * kv or kv
    return dataclasses.replace(
        cfg,
        n_layers=2 * unit,
        enc_layers=2 if cfg.enc_dec else 0,
        d_model=128,
        n_heads=heads,
        n_kv_heads=kv,
        d_head=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        sliding_window=64 if cfg.sliding_window else None,
        local_window=32 if cfg.local_global else cfg.local_window,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else cfg.ssm_head_dim,
        n_img_tokens=16 if cfg.vlm else 0,
        remat=False,
    )
