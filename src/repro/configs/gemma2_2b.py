"""gemma2-2b [arXiv:2408.00118]: alternating local/global attn, softcaps."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=9216, vocab=256000,
    local_global=True, local_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    act="gelu", tie_embeddings=True,
    source="arXiv:2408.00118 (hf tier)",
)
