"""zamba2-7b [arXiv:2411.15242]: Mamba2 backbone + shared attention blocks.

81 layers, ssm_state=64; one attention block every 6 blocks. The model
scans 13 segments of (5 mamba + 1 attn) plus a tail scan of 3 mamba-only
blocks, preserving exactly 81 layers.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, ssm_state=64, ssm_conv=4, ssm_head_dim=64, ssm_expand=2,
    attn_every=6,
    source="arXiv:2411.15242 (unverified tier)",
)
