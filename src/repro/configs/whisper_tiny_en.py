"""whisper-tiny.en — the paper's own evaluation model (Sec IV-A)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny.en", family="audio",
    n_layers=4, enc_layers=4, enc_dec=True,
    d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865,
    act="gelu", tie_embeddings=True,
    source="whisper.cpp / arXiv:2212.04356",
)
