"""whisper-base [arXiv:2212.04356]: enc-dec, conv frontend stubbed.

6L encoder + 6L decoder, d_model=512, 8H (kv=8), d_ff=2048, vocab=51865.
GELU MLP, LayerNorm, learned decoder positions (modeled), tied embeddings.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, enc_layers=6, enc_dec=True,
    d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865,
    act="gelu", tie_embeddings=True,
    source="arXiv:2212.04356 (unverified tier)",
)
