"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B]: qwen1.5-arch (qkv bias), MHA."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
    vocab=92416, attn_bias=True, rope_theta=1e6,
    source="hf:Qwen/CodeQwen1.5-7B (hf tier)",
)
