"""Atomic, async, *elastic* checkpointing.

Fault-tolerance contract (DESIGN.md §4):

* **atomic** — a step directory is written under ``<root>/tmp-<step>`` and
  ``os.rename``d into place only after every leaf + manifest is on disk;
  a crash mid-write never corrupts the latest checkpoint.
* **async** — ``CheckpointManager.save`` snapshots device arrays to host
  (blocking only for the copy) and writes in a background thread; training
  proceeds during serialization. ``wait()`` joins the writer.
* **elastic** — arrays are stored *unsharded* (global view) with their
  pytree paths; ``restore_checkpoint`` re-shards onto whatever mesh the
  restoring job brings (different DP/TP degree, different host count),
  which is the mesh-reshape restore path the tests exercise.
* **retention** — keeps the last ``keep`` checkpoints; GC never touches
  the newest.

Layout::

    <root>/step-000123/
        manifest.json          # step, leaf index, shapes/dtypes, config note
        arr-00000.npy ...      # one .npy per leaf (np.save, mmap-able)
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize ml_dtypes (bfloat16, fp8) natively: store them as
# same-width uint views and record the logical dtype in the manifest.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step-{step:09d}")


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("-")[1]) for d in os.listdir(root)
             if d.startswith("step-") and
             os.path.exists(os.path.join(root, d, "manifest.json"))]
    return max(steps) if steps else None


def save_checkpoint(root: str, step: int, tree: Any,
                    note: str = "") -> str:
    """Synchronous atomic save of a (host-resident) pytree."""
    os.makedirs(root, exist_ok=True)
    tmp = os.path.join(root, f"tmp-{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten_with_names(tree)
    index = []
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        if dtype_name in _EXOTIC:
            arr = arr.view(_EXOTIC[dtype_name][1])
        fname = f"arr-{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        index.append({"name": name, "file": fname,
                      "shape": list(arr.shape), "dtype": dtype_name})
    manifest = {"step": step, "note": note, "leaves": index}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = _step_dir(root, step)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)   # atomic publish
    return final


def restore_checkpoint(root: str, like: Any, step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``. ``shardings`` (same
    structure, optional) re-shards each global array onto the restoring
    job's mesh — the elastic path. Returns (tree, step)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = _step_dir(root, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {e["name"]: e for e in manifest["leaves"]}

    names = [n for n, _ in _flatten_with_names(like)]
    like_leaves = jax.tree_util.tree_leaves(like)
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(like_leaves))
    assert len(names) == len(like_leaves)

    out = []
    for name, proto, shard in zip(names, like_leaves, shard_leaves):
        entry = by_name.get(name)
        if entry is None:
            raise KeyError(f"checkpoint {d} missing leaf {name!r}")
        arr = np.load(os.path.join(d, entry["file"]))
        if entry["dtype"] in _EXOTIC:
            arr = arr.view(_EXOTIC[entry["dtype"]][0])
        want = tuple(proto.shape) if hasattr(proto, "shape") else None
        if want is not None and tuple(arr.shape) != want:
            raise ValueError(
                f"leaf {name!r}: checkpoint shape {arr.shape} != {want}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


def _gc(root: str, keep: int) -> None:
    if keep <= 0 or not os.path.isdir(root):
        return
    steps = sorted(int(d.split("-")[1]) for d in os.listdir(root)
                   if d.startswith("step-"))
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)


class CheckpointManager:
    """Async writer with retention. One in-flight save at a time (a newer
    save waits for the previous write to land, preserving ordering)."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, note: str = "") -> None:
        self.wait()
        # snapshot to host *before* returning so training can mutate state
        host = jax.tree.map(lambda x: np.asarray(x), tree)

        def write():
            try:
                save_checkpoint(self.root, step, host, note)
                _gc(self.root, self.keep)
            except BaseException as e:     # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, int]:
        self.wait()
        return restore_checkpoint(self.root, like, step, shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.root)
