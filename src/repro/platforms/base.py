"""Platform model: one object per hardware target.

A ``Platform`` bundles everything the repo knows about one target —
identity, the memory hierarchy (including the LMM/VMEM budget that
drives the paper's ACCEL/HOST control law), per-dtype compute rates, a
``PowerModel`` (flat nominal power and/or the Table-II power-vs-LMM
curves), an optional calibratable ``AccelModel`` latency model, the
paper's published observables for the target, and the dispatch defaults
(``allow_pallas`` / packing ``policy``) that ``DispatchContext
.for_platform`` derives its routing from.

The registry (``repro.platforms.registry``) maps names like
``"imax3-28nm/32k"`` to these objects; consumers (dispatch, serving
energy accounting, ``core.energy``, the roofline, the benchmarks) take a
``Platform`` instead of reaching into module-level constant tables.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional

from repro.core.offload import AccelModel

__all__ = ["MemoryHierarchy", "PowerModel", "Platform", "interp_power_log"]


def interp_power_log(table: Mapping[int, float], size_bytes: int) -> float:
    """Log-linear interpolation of a power-vs-size table (Table II):
    linear in ``log(size)``, so the geometric-mean size maps to the
    arithmetic-mean power. Clamps outside the table's span."""
    if size_bytes <= 0:
        raise ValueError(f"size_bytes must be positive, got {size_bytes}")
    pts = sorted(table.items())
    if size_bytes <= pts[0][0]:
        return pts[0][1]
    if size_bytes >= pts[-1][0]:
        return pts[-1][1]
    for (s0, p0), (s1, p1) in zip(pts, pts[1:]):
        if s0 <= size_bytes <= s1:
            t = (math.log(size_bytes) - math.log(s0)) \
                / (math.log(s1) - math.log(s0))
            return p0 + t * (p1 - p0)
    raise AssertionError


@dataclasses.dataclass(frozen=True)
class MemoryHierarchy:
    """The two levels the offload control law cares about.

    ``local_bytes`` is the LMM/VMEM budget — the paper's design knob and
    the default ``DispatchContext.vmem_budget``. 0 means the target has
    no kernel-offload surface (a plain host: every op routes HOST)."""
    local_bytes: int
    main_bytes: int = 0        # DRAM/HBM capacity
    main_bw: float = 0.0       # DRAM<->local stream, bytes/s
    link_bw: float = 0.0       # chip-to-chip interconnect, bytes/s


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Flat nominal power and/or power-vs-local-memory curves.

    ``curves`` maps a kernel family (``"fp16"`` / ``"q8_0"``) to a
    {local_bytes: watts} table (paper Table II). Targets without curves
    (fixed silicon) fall back to utilization-scaled nominal power."""
    nominal_w: float
    idle_w: float = 0.0
    curves: Mapping[str, Mapping[int, float]] = \
        dataclasses.field(default_factory=dict)

    def power(self, kernel: str = "fp16", local_bytes: Optional[int] = None,
              lanes: int = 1, util: float = 1.0) -> float:
        """Watts for one configuration. Curve targets interpolate
        (log-linearly) at ``local_bytes`` for the ``kernel`` family and
        scale by ``lanes``; flat targets return idle + util*(nominal-idle)."""
        curve = self.curves.get(kernel)
        if curve is not None and local_bytes is not None:
            return lanes * interp_power_log(curve, local_bytes)
        return self.idle_w + util * (self.nominal_w - self.idle_w)


# dtype fallback chains for peak_flops lookups
_DTYPE_FALLBACK = {
    "q8_0": ("q8_0", "int8", "f16", "bf16", "f32"),
    "int8": ("int8", "q8_0", "f16", "bf16", "f32"),
    "f16": ("f16", "bf16", "f32"),
    "bf16": ("bf16", "f16", "f32"),
    "f32": ("f32", "bf16", "f16"),
}


@dataclasses.dataclass(frozen=True)
class Platform:
    """One hardware target, registry-addressable by ``name``."""
    name: str                  # registry key, e.g. "imax3-28nm/32k"
    family: str                # device family, e.g. "imax3-28nm"
    kind: str                  # "cgla" | "cpu" | "gpu" | "tpu"
    memory: MemoryHierarchy
    power: PowerModel
    # dtype -> effective FLOP/s ("f32", "bf16", "f16", "int8", "q8_0")
    compute: Mapping[str, float] = dataclasses.field(default_factory=dict)
    freq_hz: float = 0.0
    # optional calibratable latency model (core.offload.AccelModel)
    accel_model: Optional[AccelModel] = None
    # paper reference observables: {"latency_s": {"fp16": ...}, "pdp_j": ...}
    paper: Mapping[str, Mapping[str, float]] = \
        dataclasses.field(default_factory=dict)
    # dispatch defaults consumed by DispatchContext.for_platform
    allow_pallas: bool = False
    policy: str = "optimized"
    aliases: tuple = ()
    notes: str = ""

    @property
    def vmem_budget(self) -> int:
        """The LMM/VMEM budget the offload control law compares against."""
        return self.memory.local_bytes

    def peak_flops(self, dtype: str = "bf16") -> float:
        """Effective FLOP/s for ``dtype``, following the fallback chain
        (e.g. a target without an int8 rate serves q8_0 at its f16 rate)."""
        for d in _DTYPE_FALLBACK.get(dtype, (dtype, "f32", "bf16", "f16")):
            if d in self.compute:
                return self.compute[d]
        raise KeyError(f"platform {self.name!r} has no compute rate for "
                       f"{dtype!r} (has {sorted(self.compute)})")

    def platform_power(self, kernel: str = "fp16", lanes: int = 1,
                       util: float = 1.0) -> float:
        """Watts at this platform's own local-memory size."""
        return self.power.power(kernel, self.memory.local_bytes or None,
                                lanes=lanes, util=util)

    def with_accel_model(self, model: AccelModel) -> "Platform":
        """A copy carrying a (e.g. freshly calibrated) latency model."""
        return dataclasses.replace(self, accel_model=model)

    def paper_observable(self, key: str, kernel: str) -> Optional[float]:
        """A published observable (``key`` in {"latency_s","pdp_j",
        "exec_share"}) for a kernel family, or None if unpublished."""
        return self.paper.get(key, {}).get(kernel)
