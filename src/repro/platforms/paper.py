"""The paper's measured/nominal hardware constants (Ando et al. 2025,
Tables I–IV, Figs 4/5/7) plus the brief's TPU v5e target constants.

This module is pure data — the single source of truth the builtin
platform registry (``repro.platforms.builtin``) is seeded from. The old
``repro.hw`` module re-exports every name here as a compatibility shim;
new code should reach hardware facts through ``repro.platforms``
(``get_platform(...)``) instead of reading these tables directly.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float     # FLOP/s per chip
    hbm_bandwidth: float       # bytes/s per chip
    ici_bandwidth: float       # bytes/s per link
    hbm_bytes: int             # capacity per chip
    vmem_bytes: int            # on-chip scratch (the LMM analogue)
    power_w: float             # board power estimate (active)
    idle_power_w: float        # idle power estimate


# Brief-specified v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
    power_w=200.0,             # board-level estimate (not officially published)
    idle_power_w=60.0,
)

# int8 matmuls on the MXU run at ~2x bf16 throughput.
TPU_V5E_PEAK_FLOPS_INT8 = 394e12

# ----------------------------------------------------------------------------
# Paper constants (Ando et al. 2025)
# ----------------------------------------------------------------------------

# Table II: IMAX ASIC (28nm) power by LMM size, per one-lane configuration.
# Keys are LMM bytes. (Sec III-C quotes 0.665/0.675 W for FP16 16/32KB; Table II
# and Sec IV-A quote 0.637/0.647 W — we follow Table II / Sec IV-A.)
IMAX_POWER_FP16_W = {
    16 * 1024: 0.637,
    32 * 1024: 0.647,
    64 * 1024: 2.16,
    128 * 1024: 5.18,
    256 * 1024: 11.2,
}
IMAX_POWER_Q8_W = {
    16 * 1024: 1.28,   # not printed for 16KB; extrapolated from the 32KB ratio
    32 * 1024: 1.32,
    64 * 1024: 4.41,
    128 * 1024: 10.6,
    256 * 1024: 22.9,
}

IMAX_ASIC_FREQ_HZ = 840e6
IMAX_FPGA_FREQ_HZ = 140e6
IMAX_PES_PER_LANE = 64

# Table III / Sec IV platform power (W).
PLATFORM_POWER_W = {
    "cortex-a72": 0.6485,
    "imax3-fpga": 180.0,
    "jetson-agx-orin": 15.0,
    "rtx-4090": 450.0,
}

# Fig 4: end-to-end latency (seconds), two-thread execution, jfk.wav (~10s).
PAPER_LATENCY_S = {
    ("cortex-a72", "fp16"): 24.4,
    ("cortex-a72", "q8_0"): 19.6,
    ("imax3-28nm", "fp16"): 13.5,
    ("imax3-28nm", "q8_0"): 11.1,
    ("jetson-agx-orin", "fp16"): 1.6,
    ("jetson-agx-orin", "q8_0"): 1.6,
    ("rtx-4090", "fp16"): 0.49,
    ("rtx-4090", "q8_0"): 0.50,
}

# Fig 5: PDP (J), two-thread execution.
PAPER_PDP_J = {
    ("imax3-28nm", "fp16"): 13.6,
    ("imax3-28nm", "q8_0"): 12.6,
    ("jetson-agx-orin", "fp16"): 24.0,
    ("jetson-agx-orin", "q8_0"): 24.0,   # paper quotes 1.90x vs 12.6 -> 23.9
    ("rtx-4090", "fp16"): 120.1,
    ("rtx-4090", "q8_0"): 123.9,         # 9.83x vs 12.6
}

# Sec V-C: dot-product operation counts per transcription run.
PAPER_DOT_COUNTS = {"tiny": 477_153, "base": 644_690, "small": 1_920_955}

# Table I (paper): cumulative kernel coverage (%) by LMM limit.
PAPER_TABLE1 = {
    # limit_bytes: (fp16_baseline, fp16_opt, q8_baseline, q8_opt)
    8 * 1024: (0.00, 64.96, 0.00, 64.96),
    16 * 1024: (1.39, 66.35, 1.39, 66.35),
    32 * 1024: (1.39, 93.80, 28.83, 93.80),
    64 * 1024: (93.81, 93.80, 93.81, 93.81),
    128 * 1024: (94.49, 100.00, 97.24, 100.00),
    256 * 1024: (100.00, 100.00, 100.00, 100.00),
}

# Table IV (paper): optimized coverage by LMM for tiny/base/small.
PAPER_TABLE4 = {
    "tiny": {16: 66.35, 32: 93.80, 64: 93.80, 128: 100.00, 256: 100.00},
    "base": {16: 66.55, 32: 66.54, 64: 94.17, 128: 97.08, 256: 99.89},
    "small": {16: 66.53, 32: 66.52, 64: 94.36, 128: 96.89, 256: 99.89},
}

# Fig 7: EXEC share of IMAX kernel time.
PAPER_EXEC_SHARE = {"fp16": 0.6089, "q8_0": 0.7470}
