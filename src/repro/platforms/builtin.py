"""Builtin platform definitions — every target this repo knows.

Seeded on package import:

* ``imax3-28nm/{16k,32k,64k,128k,256k}`` — the paper's 28nm-ASIC CGLA at
  each Table-II LMM size (``imax3-28nm`` aliases the 32 KB PDP-optimum);
* ``imax3-fpga``    — the measured FPGA prototype (140 MHz, board power);
* ``tpu-v5e``       — the brief's target chip (VMEM plays the LMM role);
* ``cortex-a72``    — the paper's host CPU (no offload surface);
* ``jetson-agx-orin`` / ``rtx-4090`` — the paper's GPU comparison points.

Measured numbers come from ``repro.platforms.paper`` (kept verbatim);
compute/bandwidth rates for the non-IMAX targets are nominal datasheet
figures — they only feed the roofline-style serving energy estimates,
never the paper-reproduction checks.
"""

from __future__ import annotations

from repro.core.offload import AccelModel
from repro.platforms import paper
from repro.platforms.base import MemoryHierarchy, Platform, PowerModel
from repro.platforms.registry import register_platform

__all__ = ["register_builtin_platforms", "IMAX_LMM_SIZES"]

IMAX_LMM_SIZES = tuple(sorted(paper.IMAX_POWER_FP16_W))   # 16k..256k bytes

# one IMAX lane: 64 PEs x 2 FLOP/cycle (mul+acc) at the clock
_IMAX_LANE_FLOPS = paper.IMAX_PES_PER_LANE * 2.0


def _paper_obs(device: str) -> dict:
    """Published Fig-4/Fig-5 observables for one device, keyed by
    kernel family."""
    obs: dict = {}
    for (dev, kern), v in paper.PAPER_LATENCY_S.items():
        if dev == device:
            obs.setdefault("latency_s", {})[kern] = v
    for (dev, kern), v in paper.PAPER_PDP_J.items():
        if dev == device:
            obs.setdefault("pdp_j", {})[kern] = v
    return obs


def _imax_asic(lmm_bytes: int) -> Platform:
    kb = lmm_bytes // 1024
    # Figs 4/5 were measured on the 32 KB PDP-optimum configuration; the
    # other LMM sizes carry only the Fig-7 EXEC shares (size-independent).
    obs = _paper_obs("imax3-28nm") if lmm_bytes == 32 * 1024 else {}
    obs["exec_share"] = dict(paper.PAPER_EXEC_SHARE)
    return Platform(
        name=f"imax3-28nm/{kb}k",
        family="imax3-28nm",
        kind="cgla",
        memory=MemoryHierarchy(
            local_bytes=lmm_bytes,
            main_bytes=4 * 1024**3,
            main_bw=19.2e9,            # DDR4-2400 channel feeding the lanes
        ),
        power=PowerModel(
            nominal_w=paper.IMAX_POWER_FP16_W[lmm_bytes],
            curves={"fp16": paper.IMAX_POWER_FP16_W,
                    "q8_0": paper.IMAX_POWER_Q8_W},
        ),
        compute={"f32": _IMAX_LANE_FLOPS * paper.IMAX_ASIC_FREQ_HZ},
        freq_hz=paper.IMAX_ASIC_FREQ_HZ,
        paper=obs,
        allow_pallas=True,             # CGLA = programmable-kernel target
        # the paper's PDP optimum; every other size is an explicit opt-in
        aliases=("imax3-28nm",) if lmm_bytes == 32 * 1024 else (),
        notes="paper Table II synthesis point (per-lane power)",
    )


def register_builtin_platforms() -> None:
    for lmm in IMAX_LMM_SIZES:
        register_platform(_imax_asic(lmm))

    register_platform(Platform(
        name="imax3-fpga",
        family="imax3-fpga",
        kind="cgla",
        memory=MemoryHierarchy(local_bytes=32 * 1024,
                               main_bytes=4 * 1024**3, main_bw=19.2e9),
        power=PowerModel(nominal_w=paper.PLATFORM_POWER_W["imax3-fpga"]),
        compute={"f32": _IMAX_LANE_FLOPS * paper.IMAX_FPGA_FREQ_HZ},
        freq_hz=paper.IMAX_FPGA_FREQ_HZ,
        allow_pallas=True,
        notes="measured prototype; board-level power (Sec IV)",
    ))

    register_platform(Platform(
        name="tpu-v5e",
        family="tpu-v5e",
        kind="tpu",
        memory=MemoryHierarchy(
            local_bytes=paper.TPU_V5E.vmem_bytes,
            main_bytes=paper.TPU_V5E.hbm_bytes,
            main_bw=paper.TPU_V5E.hbm_bandwidth,
            link_bw=paper.TPU_V5E.ici_bandwidth,
        ),
        power=PowerModel(nominal_w=paper.TPU_V5E.power_w,
                         idle_w=paper.TPU_V5E.idle_power_w),
        compute={"bf16": paper.TPU_V5E.peak_flops_bf16,
                 "int8": paper.TPU_V5E_PEAK_FLOPS_INT8},
        accel_model=AccelModel(
            name="tpu-v5e",
            flops_rate=paper.TPU_V5E.peak_flops_bf16 * 0.5,  # small-GEMM derate
            mem_bw=paper.TPU_V5E.hbm_bandwidth,
            conf_time=2e-6,
            host_flops_rate=2e12,      # VPU-path effective rate
        ),
        allow_pallas=True,
        notes="brief-specified constants; VMEM budget plays the LMM role",
    ))

    register_platform(Platform(
        name="cortex-a72",
        family="cortex-a72",
        kind="cpu",
        memory=MemoryHierarchy(local_bytes=0,    # host: no offload surface
                               main_bytes=4 * 1024**3, main_bw=12.8e9),
        power=PowerModel(nominal_w=paper.PLATFORM_POWER_W["cortex-a72"]),
        compute={"f32": 48e9, "f16": 48e9},      # 4 cores x NEON, ~1.5 GHz
        paper=_paper_obs("cortex-a72"),
        notes="the paper's host CPU (whisper.cpp two-thread baseline)",
    ))

    register_platform(Platform(
        name="jetson-agx-orin",
        family="jetson-agx-orin",
        kind="gpu",
        memory=MemoryHierarchy(local_bytes=0,
                               main_bytes=32 * 1024**3, main_bw=204.8e9),
        power=PowerModel(nominal_w=paper.PLATFORM_POWER_W["jetson-agx-orin"]),
        compute={"f32": 5.3e12, "f16": 10.6e12, "int8": 85e12},
        paper=_paper_obs("jetson-agx-orin"),
        notes="15 W power mode (paper Sec IV)",
    ))

    register_platform(Platform(
        name="rtx-4090",
        family="rtx-4090",
        kind="gpu",
        memory=MemoryHierarchy(local_bytes=0,
                               main_bytes=24 * 1024**3, main_bw=1008e9),
        power=PowerModel(nominal_w=paper.PLATFORM_POWER_W["rtx-4090"]),
        compute={"f32": 82.6e12, "f16": 165.2e12, "int8": 660.6e12},
        paper=_paper_obs("rtx-4090"),
        notes="450 W TDP (paper Sec IV)",
    ))
