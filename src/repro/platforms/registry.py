"""Name -> Platform registry.

``register_platform`` installs a platform (and its aliases);
``get_platform("imax3-28nm/32k")`` resolves one; ``list_platforms()``
enumerates canonical names. The builtin targets (``builtin.py``) are
registered on package import, so ``repro.platforms.get_platform`` works
out of the box; out-of-tree code can register additional targets the
same way.
"""

from __future__ import annotations

from typing import Iterable, Optional

from repro.platforms.base import Platform

__all__ = ["register_platform", "get_platform", "list_platforms",
           "platform_families", "platforms_in_family"]

_REGISTRY: dict[str, Platform] = {}
_ALIASES: dict[str, str] = {}


def register_platform(platform: Platform, *,
                      overwrite: bool = False) -> Platform:
    """Install ``platform`` under its name and aliases. Re-registering a
    name raises unless ``overwrite=True`` (aliases may not shadow a
    canonical name)."""
    names = (platform.name,) + tuple(platform.aliases)
    for n in names:
        taken = n in _REGISTRY or n in _ALIASES
        if taken and not overwrite:
            raise ValueError(f"platform name {n!r} already registered "
                             f"(pass overwrite=True to replace)")
    if platform.name in _ALIASES and not overwrite:
        raise ValueError(f"{platform.name!r} is an alias of "
                         f"{_ALIASES[platform.name]!r}")
    _REGISTRY[platform.name] = platform
    for a in platform.aliases:
        _ALIASES[a] = platform.name
    return platform


def get_platform(name: str) -> Platform:
    """Resolve a platform by canonical name or alias; raises KeyError
    naming the known platforms on a miss."""
    if isinstance(name, Platform):
        return name
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name in _ALIASES:
        return _REGISTRY[_ALIASES[name]]
    raise KeyError(
        f"unknown platform {name!r}; known platforms: "
        f"{', '.join(list_platforms())}")


def list_platforms(family: Optional[str] = None) -> list[str]:
    """Sorted canonical platform names, optionally one family only."""
    return sorted(n for n, p in _REGISTRY.items()
                  if family is None or p.family == family)


def platform_families() -> list[str]:
    return sorted({p.family for p in _REGISTRY.values()})


def platforms_in_family(family: str) -> Iterable[Platform]:
    for n in list_platforms(family):
        yield _REGISTRY[n]
