"""First-class platform API: one registry for hardware targets.

>>> from repro.platforms import get_platform, list_platforms
>>> p = get_platform("imax3-28nm/32k")
>>> p.vmem_budget, p.platform_power("q8_0")
(32768, 1.32)

The ``Platform`` object drives kernel dispatch
(``DispatchContext.for_platform``), serving energy accounting
(``ServeEngine(platform=...).energy_report()``), the analytic energy
model (``core.energy``), and the roofline (``analysis.roofline``).
``repro.hw`` remains as a compatibility shim over ``platforms.paper``.
"""

from repro.platforms.base import (MemoryHierarchy, Platform, PowerModel,
                                  interp_power_log)
from repro.platforms.builtin import (IMAX_LMM_SIZES,
                                     register_builtin_platforms)
from repro.platforms.registry import (get_platform, list_platforms,
                                      platform_families, platforms_in_family,
                                      register_platform)

__all__ = [
    "MemoryHierarchy", "Platform", "PowerModel", "interp_power_log",
    "IMAX_LMM_SIZES", "get_platform", "list_platforms",
    "platform_families", "platforms_in_family", "register_platform",
]

register_builtin_platforms()
