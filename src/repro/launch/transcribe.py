"""End-to-end ASR launcher: synthetic waveform -> log-mel frontend ->
chunked encoder -> tokens, through the serving engine.

Usage::

    PYTHONPATH=src python -m repro.launch.transcribe \
        --platform imax3-28nm --cache-dtype q8_0 [--stream] \
        [--decode-block 16] [--seconds 1.0] [--arch whisper-tiny-en] \
        [--full]

``--stream`` serves through the chunk-at-a-time streaming path (one
audio chunk per scheduler tick, partial hypotheses printed as they
form); the final transcript is token-identical to the one-shot path.
``--platform`` routes every kernel through that target's dispatch
context and ends with the modeled energy report (joules/audio-second).
"""

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="whisper-tiny-en")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced smoke size)")
    ap.add_argument("--seconds", type=float, default=1.0,
                    help="synthetic waveform length")
    ap.add_argument("--chunk-frames", type=int, default=16,
                    help="encoder chunk size (frame embeddings)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--stream", action="store_true",
                    help="serve via the streaming chunked-encode path")
    ap.add_argument("--cache-dtype", choices=["bf16", "q8_0", "q4_0"],
                    default="bf16")
    ap.add_argument("--decode-block", type=int, default=1,
                    help="decode steps fused per tick (one host sync "
                         "per tick; tokens identical for any value)")
    ap.add_argument("--platform", default=None,
                    help="registered hardware target (repro.platforms); "
                         "enables the energy report")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.audio.stream import synth_waveform
    from repro.audio.transcribe import transcribe

    wave = synth_waveform(args.seconds, seed=args.seed)
    print(f"transcribing {args.seconds:.2f}s synthetic waveform "
          f"({len(wave)} samples) with {args.arch}"
          f"{'' if args.full else ' (reduced)'}"
          f"{', streaming' if args.stream else ''}, "
          f"cache {args.cache_dtype}"
          + (f", platform {args.platform}" if args.platform else ""))
    r = transcribe(wave, 16_000, arch=args.arch, reduced=not args.full,
                   platform=args.platform, cache_dtype=args.cache_dtype,
                   decode_block=args.decode_block,
                   chunk_frames=args.chunk_frames, max_new=args.max_new,
                   stream=args.stream, seed=args.seed)
    if args.stream:
        for i, p in enumerate(r.partials):
            print(f"  partial[{i}]: {p}")
    print(f"tokens: {r.tokens}")
    print(f"{r.n_frames} encoder frames, {r.ticks} decode ticks "
          f"x block {r.decode_block} = {r.decode_steps} decode steps, "
          f"{r.host_syncs} decode host syncs, {r.wall_s:.2f}s wall "
          f"({r.compute_ms_per_audio_s:.0f} ms compute per audio-second, "
          f"includes jit)")
    if r.energy:
        e = r.energy
        print(f"energy[{e['platform']}]: "
              f"{e['joules_per_audio_s']:.3e} J/audio-s, "
              f"{e['joules_per_token']:.3e} J/token "
              f"(power {e['power_w']:.3f} W, {e['bound']}-bound, "
              f"accel share {e['accel_flops_share']:.0%})")
    return r


if __name__ == "__main__":
    main()
