import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the real step function (train_step for train
shapes; prefill/decode steps for serving shapes), binds in/out shardings
from the arch's logical-axis rules, lowers against ShapeDtypeStruct
inputs (zero allocation), compiles, and records:

* ``memory_analysis()``  — proves the cell fits per-device HBM,
* ``cost_analysis()``    — FLOPs / bytes for the roofline,
* parsed collective bytes from the compiled HLO text.

Results land in ``results/dryrun/<arch>__<shape>__<mesh>.json``; the
roofline table (EXPERIMENTS.md §Roofline) and the perf loop read them.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
        --shape train_4k [--multi-pod] [--all]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis.roofline import model_flops, roofline_from_compiled
from repro.configs import get_config, list_archs
from repro.launch.mesh import make_production_mesh, mesh_chips, mesh_label
from repro.models.model import SHAPES, build, input_specs, shape_applicable
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import rules_for
from repro.train import step as step_mod

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _param_shardings(model, mesh, rules):
    from repro.parallel.sharding import enforce_divisibility, tree_shardings
    return enforce_divisibility(
        tree_shardings(model.param_axes(), mesh, rules),
        model.param_shapes())


def _eval_state_specs(model, mesh, rules):
    """ShapeDtypeStructs + shardings for the train state (no allocation)."""
    state_shapes = jax.eval_shape(
        lambda k: step_mod.init_train_state(model, k), jax.random.key(0))
    shardings = step_mod.state_shardings(model, mesh, rules)
    return state_shapes, shardings


DEFAULT_N_MICRO = 4   # grad-accum for train cells: fits 16 GB/chip HBM


def dryrun_cell(arch: str, shape: str, *, multi_pod: bool = False,
                verbose: bool = True, opt_overrides: dict | None = None,
                n_micro: int | None = None):
    """Lower+compile one cell. Returns the result record (dict)."""
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "status": "skipped",
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    rules = rules_for(cfg, mesh, mode="train" if shape.startswith("train")
                      else "serve")
    model = build(cfg)
    seq, gbatch, kind = SHAPES[shape]
    specs = input_specs(cfg, shape)
    batch_sh = step_mod.batch_shardings(cfg, shape, mesh, rules)

    t0 = time.monotonic()
    if kind == "train":
        opt_cfg = AdamWConfig(**(opt_overrides or {}))
        nm = DEFAULT_N_MICRO if n_micro is None else n_micro
        fn = step_mod.make_train_step(model, opt_cfg, mesh=mesh,
                                      rules=rules, n_micro=nm)
        state_shapes, state_sh = _eval_state_specs(model, mesh, rules)
        jitted = jax.jit(fn,
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None))
        lowered = jitted.lower(state_shapes, specs)
        tokens = gbatch * seq
    elif kind == "prefill":
        fn = step_mod.make_prefill_step(model, mesh=mesh, rules=rules)
        param_sh = _param_shardings(model, mesh, rules)
        param_shapes = model.param_shapes(jnp.bfloat16)   # serving weights
        cache_sh = step_mod.cache_shardings(
            model, gbatch, step_mod.prefill_cache_len(seq), mesh, rules)
        jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh),
                         out_shardings=(None, cache_sh))
        lowered = jitted.lower(param_shapes, specs)
        tokens = gbatch * seq
    else:  # decode
        fn = step_mod.make_decode_step(model, mesh=mesh, rules=rules)
        param_sh = _param_shardings(model, mesh, rules)
        param_shapes = model.param_shapes(jnp.bfloat16)   # serving weights
        cache_shapes = model.cache_specs(gbatch, seq)
        cache_sh = step_mod.cache_shardings(model, gbatch, seq, mesh, rules)
        tok_spec = jax.ShapeDtypeStruct((gbatch, 1), jnp.int32)
        pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(fn, in_shardings=(param_sh, cache_sh,
                                           batch_sh["tokens"], None),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(1,))   # in-place cache updates
        lowered = jitted.lower(param_shapes, cache_shapes, tok_spec,
                               pos_spec)
        tokens = gbatch  # one new token per sequence

    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    mem_rec = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                        None),
    }
    mflops = model_flops(cfg, model.n_params(), model.n_active_params(),
                         tokens, kind)
    hlo_text = compiled.as_text()
    rl = roofline_from_compiled(
        compiled, arch=arch, shape=shape, mesh=mesh_label(mesh),
        chips=chips, model_flops=mflops, hlo_text=hlo_text)

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_label(mesh),
        "chips": chips, "kind": kind, "status": "ok",
        "compile_s": t_compile,
        "memory": mem_rec,
        "hlo_flops": rl.hlo_flops,
        "hlo_bytes": rl.hlo_bytes,
        "collective_bytes": rl.collective_bytes,
        "collectives": rl.collectives,
        "model_flops": mflops,
        "compute_s": rl.compute_s,
        "memory_s": rl.memory_s,
        "collective_s": rl.collective_s,
        "dominant": rl.dominant,
        "useful_ratio": rl.useful_flops_ratio,
        "roofline_frac": rl.roofline_fraction,
    }
    if verbose:
        print(f"[{arch} × {shape} × {mesh_label(mesh)}] compile "
              f"{t_compile:.1f}s | mem {mem_rec} | "
              f"compute {rl.compute_s*1e3:.2f}ms memory "
              f"{rl.memory_s*1e3:.2f}ms collective "
              f"{rl.collective_s*1e3:.2f}ms -> {rl.dominant}-bound, "
              f"useful {rl.useful_flops_ratio:.2f}, "
              f"roofline {rl.roofline_fraction:.2%}")
    return rec


def save_record(rec: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec.get('mesh', 'na')}.json"
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell")
    args = ap.parse_args()

    cells = []
    archs = [a for a in list_archs() if a != "whisper-tiny-en"] \
        if args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    failures = []
    for arch, shape in cells:
        try:
            rec = dryrun_cell(arch, shape, multi_pod=args.multi_pod,
                              n_micro=args.n_micro)
            rec["multi_pod"] = args.multi_pod
            save_record(rec)
            if rec["status"] == "skipped":
                print(f"[{arch} × {shape}] SKIP: {rec['reason']}")
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nall {len(cells)} cells passed "
          f"({'multi-pod 2x16x16' if args.multi_pod else 'single-pod 16x16'})")


if __name__ == "__main__":
    main()
