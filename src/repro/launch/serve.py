"""Serving launcher: continuous-batching engine over synthetic requests.

Enc-dec archs (whisper-*) get synthetic encoder frames per request and
serve through the same scheduler as decoder-only models.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --requests 16 --slots 4 [--q8] [--cache-dtype q8_0] \
        [--decode-block 16] [--platform imax3-28nm/32k]

``--decode-block K`` fuses K decode steps per scheduler tick (one host
sync per tick; tokens identical for any K).

``--platform`` serves against a registered hardware target
(``repro.platforms``): the kernel-dispatch context is derived from the
platform (LMM/VMEM budget, packing policy, pallas-eligibility) and the
run ends with the platform's energy report (joules/token, PDP).
"""

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--q8", action="store_true",
                    help="serve Q8_0-quantized weights (paper variant)")
    ap.add_argument("--cache-dtype", choices=["bf16", "q8_0", "q4_0"],
                    default="bf16",
                    help="KV-cache storage: q8_0 streams ~0.53x the "
                         "bytes/step via the q8_decode_attention "
                         "kernel, q4_0 ~0.28x via q4_decode_attention")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="self-speculative decoding: draft spec_k-1 "
                         "tokens with q4_0-quantized weights and verify "
                         "all spec_k in one forward per round "
                         "(decode-block must be a multiple; greedy "
                         "token parity with plain decode)")
    ap.add_argument("--enc-len", type=int, default=64,
                    help="encoder-state pool length (enc-dec models)")
    ap.add_argument("--decode-block", type=int, default=1,
                    help="decode steps fused per tick (device-resident "
                         "loop; one host sync per tick)")
    ap.add_argument("--platform", default=None,
                    help="registered hardware target (repro.platforms; "
                         "e.g. imax3-28nm/32k, tpu-v5e); drives dispatch "
                         "and enables the energy report")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.models.model import build
    from repro.serving.engine import AudioRequest, Request, ServeEngine
    from repro.serving.scheduler import BatchScheduler

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build(cfg)
    params = model.init_values(jax.random.key(args.seed))
    if args.q8:
        from repro.core.quantize import quantize_tree
        params = quantize_tree(params)
        print("serving Q8_0-quantized weights")
    if args.cache_dtype in ("q8_0", "q4_0"):
        print(f"serving a {args.cache_dtype.upper()}-quantized KV cache")
    if args.spec_k:
        print(f"self-speculative decoding: spec_k={args.spec_k}")

    if args.platform:
        from repro.platforms import get_platform
        plat = get_platform(args.platform)   # fail fast on unknown names
        print(f"serving on platform {plat.name} "
              f"(LMM/VMEM budget {plat.vmem_budget} B)")
    engine = ServeEngine(model, params, n_slots=args.slots,
                         max_len=args.max_len, enc_len=args.enc_len,
                         cache_dtype=args.cache_dtype,
                         decode_block=args.decode_block,
                         spec_k=args.spec_k,
                         platform=args.platform)
    sched = BatchScheduler(engine)

    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        n = int(rng.integers(4, min(64, args.max_len - args.max_new - 1)))
        toks = rng.integers(3, cfg.vocab, size=n).tolist()
        if cfg.enc_dec:
            frames = rng.standard_normal(
                (int(rng.integers(4, args.enc_len + 1)), cfg.d_model)
            ).astype(np.float32) * 0.5
            sched.submit(AudioRequest(uid=uid, tokens=toks,
                                      max_new=args.max_new, eos_id=-1,
                                      enc_frames=frames))
        else:
            sched.submit(Request(uid=uid, tokens=toks,
                                 max_new=args.max_new, eos_id=-1))

    t0 = time.monotonic()
    sched.run_until_drained()
    dt = time.monotonic() - t0
    m = sched.metrics
    total_tokens = sum(len(st.out) for st in sched.results.values())
    print(f"{m.completed}/{args.requests} requests in {m.ticks} ticks "
          f"({dt:.1f}s), {total_tokens} tokens, "
          f"occupancy {m.mean_occupancy:.2f}, mean TTFT {m.mean_ttft:.1f} "
          f"ticks, {total_tokens/dt:.1f} tok/s, "
          f"decode block {args.decode_block} "
          f"({engine._host_syncs} decode host syncs)")
    if args.platform:
        er = engine.energy_report("q8_0" if args.q8 else "fp16")
        print(f"energy[{er['platform']}]: {er['joules_per_token']:.3e} "
              f"J/token, PDP {er['pdp_j']:.3e} J "
              f"(power {er['power_w']:.3f} W, {er['bound']}-bound, "
              f"cache stream {er['cache_energy_j']:.3e} J, "
              f"accel share {er['accel_flops_share']:.0%})")
    return m


if __name__ == "__main__":
    main()
