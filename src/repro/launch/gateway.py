"""Gateway launcher: async SLO-aware serving under a seeded Poisson load.

Spins up the asyncio ``Gateway`` over a ``ServeEngine`` and offers an
open-loop Poisson workload (mixed one-shot audio and streaming
sessions, SLO mix across interactive/standard/batch), then prints the
wall-clock serving summary: p50/p99 TTFT and end-to-end latency in
seconds, streaming chunk lag, **goodput** (completed-within-deadline
requests/s), shed counts by reason code, and — with ``--platform`` —
J/audio-s from the platform energy model.

Usage::

    PYTHONPATH=src python -m repro.launch.gateway --arch whisper-tiny-en \
        --reduced --rate 20 --requests 32 --slots 4 [--decode-block 8] \
        [--stream-fraction 0.25] [--queue-limit 64] [--no-shed] \
        [--platform imax3-28nm/32k] [--seed 0]

Same request set, any arrival rate or admission order → identical
tokens (``repro.gateway.loadgen.sync_baseline`` is the oracle;
``benchmarks/serve_load.py`` pins the parity in CI).
"""

import argparse
import json


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean Poisson arrival rate, requests/s")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--enc-len", type=int, default=64)
    ap.add_argument("--decode-block", type=int, default=4,
                    help="decode steps fused per tick (one host sync)")
    ap.add_argument("--stream-fraction", type=float, default=0.25,
                    help="fraction of requests served as streaming "
                         "sessions")
    ap.add_argument("--queue-limit", type=int, default=64,
                    help="admission-queue bound (backpressure sheds)")
    ap.add_argument("--max-admit", type=int, default=2,
                    help="prefills per tick boundary")
    ap.add_argument("--no-shed", action="store_true",
                    help="disable the unmeetable-deadline submit shed")
    ap.add_argument("--cache-dtype", choices=["bf16", "q8_0"],
                    default="bf16")
    ap.add_argument("--platform", default=None,
                    help="registered hardware target (enables the "
                         "J/audio-s energy report)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true",
                    help="print the full metrics summary as JSON")
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_config, reduced
    from repro.gateway import LoadSpec, run_load
    from repro.models.model import build
    from repro.serving.engine import ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if not cfg.enc_dec:
        ap.error(f"--arch {args.arch}: the gateway load generator "
                 f"synthesizes audio workloads; pick an enc-dec "
                 f"(whisper-*) arch")
    model = build(cfg)
    params = model.init_values(jax.random.key(args.seed))
    engine = ServeEngine(model, params, n_slots=args.slots,
                         max_len=args.max_len, enc_len=args.enc_len,
                         cache_dtype=args.cache_dtype,
                         decode_block=args.decode_block,
                         platform=args.platform)
    spec = LoadSpec(rate_rps=args.rate, n_requests=args.requests,
                    seed=args.seed, stream_fraction=args.stream_fraction,
                    max_new=args.max_new)
    print(f"offering {args.requests} requests at {args.rate:.1f} rps "
          f"(Poisson, seed {args.seed}, "
          f"{args.stream_fraction:.0%} streaming) to "
          f"{args.slots} slots x decode_block {args.decode_block}")
    results, summary, gw = run_load(
        engine, spec, queue_limit=args.queue_limit,
        max_admit_per_tick=args.max_admit,
        shed_on_submit=not args.no_shed)

    if args.json:
        print(json.dumps(summary, indent=1, sort_keys=True))
        return summary
    t, e = summary["ttft_s"], summary["e2e_s"]
    print(f"{summary['completed']}/{summary['requests']} completed "
          f"({summary['completed_in_deadline']} in deadline, "
          f"{summary['shed_total']} shed {summary['shed'] or '{}'}) "
          f"in {summary['wall_s']:.2f}s over {summary['ticks']} ticks")
    print(f"goodput {summary['goodput_rps']:.2f} req/s "
          f"(throughput {summary['throughput_rps']:.2f}), "
          f"{summary['tokens']} tokens, "
          f"{summary['audio_s']:.1f}s audio served")
    print(f"TTFT p50/p99 {t['p50']:.3f}/{t['p99']:.3f}s, "
          f"e2e p50/p99 {e['p50']:.3f}/{e['p99']:.3f}s, "
          f"stream lag mean {summary['stream_lag_s']['mean']:.3f}s "
          f"({summary['stream_lag_s']['chunks']} chunks)")
    print(f"one host sync per tick: "
          f"{engine._host_syncs == engine._ticks} "
          f"({engine._host_syncs} syncs / {engine._ticks} ticks)")
    if "energy" in summary:
        en = summary["energy"]
        print(f"energy[{en['platform']}]: "
              f"{en['joules_per_audio_s']:.3e} J/audio-s, "
              f"{en['joules_per_token']:.3e} J/token, "
              f"PDP {en['pdp_j']:.3e} J")
    return summary


if __name__ == "__main__":
    main()
