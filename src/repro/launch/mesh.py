"""Production mesh construction (brief-mandated shapes).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun.py) set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU subprocess tests (forced host devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


def mesh_label(mesh) -> str:
    return "x".join(str(v) for v in mesh.shape.values())
