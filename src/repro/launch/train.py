"""End-to-end training launcher.

Builds the mesh (or runs single-device for CPU smokes), binds shardings,
and drives the fault-tolerant TrainLoop over the synthetic pipeline.

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
        --reduced --steps 200 --batch 8 --seq 256 --ckpt /tmp/ckpt
    # forced-device distributed smoke:
    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
        --reduced --devices 4 --mesh 2x2 --steps 10
"""

import argparse
import os


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (set before jax init)")
    ap.add_argument("--mesh", default="",
                    help="DxM data×model mesh (requires --devices)")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    from repro.checkpoint.store import CheckpointManager
    from repro.configs import get_config, reduced
    from repro.data.synthetic import SyntheticDataset
    from repro.models.model import build
    from repro.optim.adamw import AdamWConfig
    from repro.parallel.sharding import rules_for
    from repro.train.loop import LoopConfig, TrainLoop
    from repro.train import step as step_mod

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps)

    mesh = rules = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        rules = rules_for(cfg, mesh, mode="train")

    fn = step_mod.make_train_step(model, opt_cfg, mesh=mesh, rules=rules,
                                  n_micro=args.n_micro)
    state = step_mod.init_train_state(model, jax.random.key(args.seed))
    state_sh = None
    put_batch = None
    if mesh is not None:
        state_sh = step_mod.state_shardings(model, mesh, rules)
        state = jax.device_put(state, state_sh)
        from jax.sharding import NamedSharding, PartitionSpec as P

        def put_batch(b):
            return {k: jax.device_put(v, NamedSharding(
                mesh, P(*( ("data",) + (None,) * (v.ndim - 1) ))))
                for k, v in b.items()}

        step_fn = jax.jit(fn, in_shardings=(state_sh, None),
                          out_shardings=(state_sh, None), donate_argnums=0)
    else:
        step_fn = jax.jit(fn, donate_argnums=0)

    ds = SyntheticDataset(cfg, seq_len=args.seq, global_batch=args.batch,
                          seed=args.seed)
    ckpt_dir = args.ckpt or os.path.join("/tmp", f"ckpt-{args.arch}")
    ckpt = CheckpointManager(ckpt_dir, keep=3)
    loop_cfg = LoopConfig(total_steps=args.steps,
                          save_every=args.save_every,
                          handle_signals=True)

    def on_step(step, loss):
        if step % 10 == 0 or step == 1:
            print(f"step {step:5d}  loss {loss:.4f}", flush=True)

    loop = TrainLoop(step_fn, ds, ckpt, loop_cfg, put_batch=put_batch,
                     on_step=on_step)
    state, result = loop.run(state, state_shardings=state_sh)
    last = f"{result.losses[-1]:.4f}" if result.losses else "n/a (resumed)"
    print(f"done: {result.final_step} steps, final loss "
          f"{last}, stragglers={len(result.straggler_events)}"
          f"{', PREEMPTED' if result.preempted else ''}")
    return result


if __name__ == "__main__":
    main()
