import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Perf-loop profiler: lower one cell and print the heaviest HLO
instructions (trip-multiplied HBM bytes) and collectives, each with its
JAX-source op_name — the 'profile' the hypothesis loop reads.

Usage::

    PYTHONPATH=src python -m repro.launch.profile_cell \
        --arch mixtral-8x7b --shape train_4k [--top 25]
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    args = ap.parse_args()

    from repro.analysis.hlo import HloAnalyzer

    # reuse dryrun's cell builder but keep the compiled text
    import repro.launch.dryrun as dr
    rec_holder = {}

    orig = dr.roofline_from_compiled

    def capture(compiled, **kw):
        rec_holder["text"] = kw.get("hlo_text") or compiled.as_text()
        return orig(compiled, **kw)

    dr.roofline_from_compiled = capture
    try:
        rec = dr.dryrun_cell(args.arch, args.shape,
                             multi_pod=args.multi_pod,
                             n_micro=args.n_micro, verbose=True)
    finally:
        dr.roofline_from_compiled = orig
    if rec.get("status") != "ok":
        print(rec)
        return

    an = HloAnalyzer(rec_holder["text"])
    print(f"\n== top {args.top} instructions by effective HBM bytes "
          "(per device) ==")
    for b, op, shape, name in an.top_instructions(args.top):
        print(f"  {b / 1e9:9.3f} GB  {op:20s} {shape:34.34s} {name[:90]}")
    print("\n== top collectives by effective payload ==")
    for b, op, shape, name in an.top_collectives(15):
        print(f"  {b / 1e9:9.3f} GB  {op:20s} {shape:34.34s} {name[:90]}")


if __name__ == "__main__":
    main()
