from repro.train.step import (cross_entropy, make_train_step,
                              make_prefill_step, make_decode_step,
                              TrainState, init_train_state)
from repro.train.loop import TrainLoop, LoopConfig
