"""Fault-tolerant training loop.

Responsibilities beyond "call train_step in a loop":

* **checkpoint/restart** — resumes from the newest checkpoint (elastic:
  restore re-shards onto the current mesh); saves every ``save_every``
  steps through the async CheckpointManager.
* **preemption handling** — SIGTERM/SIGINT installs a save-and-exit flag;
  the loop checkpoints at the next step boundary (the TPU-preemption
  grace-period pattern).
* **straggler/step-time monitoring** — EWMA of step wall time; a step
  slower than ``straggler_factor``× the EWMA is logged as a straggler
  event (on real pods this feeds the reshard/evict decision; here it is
  observable behaviour the tests assert on).
* **data determinism** — batches come from the counter-based synthetic
  pipeline keyed by (seed, step), so a restart replays the identical
  stream with no data-state in the checkpoint.
* **NaN guard** — a non-finite loss aborts with a diagnostic rather than
  silently corrupting later checkpoints.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.checkpoint.store import CheckpointManager
from repro.data.synthetic import SyntheticDataset


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    save_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    handle_signals: bool = False   # opt-in (tests run in-process)


@dataclasses.dataclass
class LoopResult:
    final_step: int
    losses: list
    straggler_events: list
    preempted: bool


class TrainLoop:
    def __init__(self, step_fn: Callable, dataset: SyntheticDataset,
                 ckpt: CheckpointManager, cfg: LoopConfig,
                 put_batch: Optional[Callable] = None,
                 on_step: Optional[Callable] = None):
        """``step_fn(state, batch) -> (state, metrics)`` (jitted outside).
        ``put_batch(host_batch) -> device_batch`` applies input shardings."""
        self.step_fn = step_fn
        self.dataset = dataset
        self.ckpt = ckpt
        self.cfg = cfg
        self.put_batch = put_batch or (lambda b: b)
        self.on_step = on_step
        self._preempt = False

    def _install_signals(self):
        def handler(signum, frame):
            self._preempt = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def request_preempt(self):
        """Programmatic preemption trigger (tests)."""
        self._preempt = True

    def run(self, state: Any, start_step: Optional[int] = None,
            state_shardings: Any = None) -> tuple[Any, LoopResult]:
        cfg = self.cfg
        if cfg.handle_signals:
            self._install_signals()

        step = 0
        if start_step is not None:
            step = start_step
        else:
            latest = self.ckpt.latest_step()
            if latest is not None:
                state, step = self.ckpt.restore(state, latest,
                                                state_shardings)

        losses, stragglers = [], []
        ewma = None
        preempted = False
        while step < cfg.total_steps:
            t0 = time.monotonic()
            batch = self.put_batch(self.dataset.global_batch_at(step))
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0

            if not np.isfinite(loss):
                self.ckpt.wait()
                raise FloatingPointError(
                    f"non-finite loss {loss} at step {step}")
            losses.append(loss)
            if ewma is not None and dt > cfg.straggler_factor * ewma:
                stragglers.append({"step": step, "dt": dt, "ewma": ewma})
            ewma = dt if ewma is None else (
                cfg.ewma_alpha * dt + (1 - cfg.ewma_alpha) * ewma)

            step += 1
            if self.on_step is not None:
                self.on_step(step, loss)
            if step % cfg.save_every == 0 or step == cfg.total_steps:
                self.ckpt.save(step, state, note=f"loss={loss:.4f}")
            if self._preempt:
                self.ckpt.save(step, state, note="preempt")
                preempted = True
                break

        self.ckpt.wait()
        return state, LoopResult(step, losses, stragglers, preempted)
