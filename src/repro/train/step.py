"""Step builders: train (value_and_grad + AdamW), prefill, decode.

These are the functions the launcher jits and the multi-pod dry-run lowers.
Sharding is carried two ways at once:

* **in/out shardings** for the jit boundary, derived from each model's
  logical parameter axes via ``parallel.sharding.tree_shardings``;
* **internal constraints** via ``logical_context`` so every
  ``constrain(...)`` call inside the model binds to the active mesh.

Gradient accumulation is a ``lax.scan`` over microbatches (keeps the HLO
compact and the peak activation memory at 1/n_micro). The optional
``dp_compressed`` variant swaps the DP gradient mean for the int8
error-feedback compressed all-reduce (parallel.collectives) inside a
``shard_map`` — the paper-era "gradient compression" distributed trick.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig
from repro.kernels.api import grad_safe_context, use_context
from repro.models.model import Model, input_specs
from repro.optim import adamw
from repro.parallel.sharding import (enforce_divisibility, logical_context,
                                     spec_for, tree_shardings)

TrainState = dict  # {"params": tree, "opt": {m, v, step}}

PREFILL_CACHE_PAD = 16   # decode headroom; keeps cache_seq TP-divisible


def prefill_cache_len(seq: int) -> int:
    return seq + PREFILL_CACHE_PAD


# ----------------------------------------------------------------------------
# Loss
# ----------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, targets: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """Token-mean CE. logits: (B, S, V) any float; targets: (B, S) int32.
    Stays in f32; the vocab axis may be model-sharded (GSPMD reduces)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.clip(targets, 0)
    picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = lse - picked
    mask = (targets != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _loss_fn(model: Model, params, batch) -> tuple[jax.Array, dict]:
    # this forward sits under value_and_grad; the Pallas kernels define
    # no VJP, so pin the dispatch routing to the XLA/ref bindings here.
    with use_context(grad_safe_context()):
        logits, _ = model.forward(params, batch, mode="train")
    tgt = batch["targets"]
    # VLM: logits cover img-prefix + text; targets already full-seq length.
    if logits.shape[1] != tgt.shape[1]:
        tgt = tgt[:, :logits.shape[1]]
    loss = cross_entropy(logits, tgt)
    return loss, {"loss": loss}


# ----------------------------------------------------------------------------
# Train step
# ----------------------------------------------------------------------------

def init_train_state(model: Model, key) -> TrainState:
    params = model.init_values(key)
    return {"params": params, "opt": adamw.init_state(params)}


def state_axes(model: Model) -> dict:
    """Logical-axes tree matching init_train_state's structure."""
    axes = model.param_axes()
    return {"params": axes, "opt": {"m": axes, "v": axes, "step": ()}}


def state_shardings(model: Model, mesh: Mesh, rules: dict):
    import jax as _jax
    shapes = _jax.eval_shape(lambda k: init_train_state(model, k),
                             _jax.random.key(0))
    return enforce_divisibility(
        tree_shardings(state_axes(model), mesh, rules), shapes)


def batch_shardings(cfg: ArchConfig, shape: str, mesh: Mesh, rules: dict):
    """NamedShardings for the input batch of a (arch, shape) cell."""
    specs = input_specs(cfg, shape)

    def spec_of(name, leaf):
        if leaf.ndim == 0:
            return P()
        axes = ("batch",) + (None,) * (leaf.ndim - 1)
        return spec_for(axes, rules)

    out = {k: NamedSharding(mesh, spec_of(k, v)) for k, v in specs.items()}
    return enforce_divisibility(out, specs)


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig, *,
                    mesh: Optional[Mesh] = None,
                    rules: Optional[dict] = None,
                    n_micro: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``n_micro > 1`` accumulates gradients over microbatches with lax.scan
    (batch must divide evenly)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: _loss_fn(model, p, batch), has_aux=True)(params)

    def train_step(state: TrainState, batch: dict):
        params = state["params"]
        if n_micro == 1:
            (loss, aux), grads = grads_of(params, batch)
        else:
            def micro(carry, mb):
                (l, a), g = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, carry, g)
                return acc, l

            def reshape(x):
                b = x.shape[0]
                assert b % n_micro == 0, (b, n_micro)
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            mbs = jax.tree.map(reshape, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            grads, losses = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = jnp.mean(losses)
            aux = {"loss": loss}
        new_params, new_opt, metrics = adamw.apply_updates(
            params, grads, state["opt"], opt_cfg)
        metrics.update(aux)
        return {"params": new_params, "opt": new_opt}, metrics

    if mesh is None:
        return train_step

    def train_step_meshed(state, batch):
        with logical_context(mesh, rules):
            return train_step(state, batch)

    return train_step_meshed


def make_compressed_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                               mesh: Mesh, rules: dict) -> Callable:
    """DP-compressed variant: per-shard gradients are reduced over the
    data axes with int8 error-feedback compression
    (parallel.collectives.compressed_psum) instead of the implicit f32
    all-reduce — ~3.9x less DP wire traffic, bias-free over steps via
    error feedback. State gains an 'err' tree (f32 residuals).

    Layout contract: params are REPLICATED over the data axes inside the
    shard_map (batch is sharded); TP axes are not mapped here, so this
    variant composes with pure-DP/multi-pod meshes (the cross-pod DCN
    all-reduce is exactly where compression pays).
    """
    from jax.experimental.shard_map import shard_map
    from repro.parallel.collectives import compressed_psum

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def local_grads(params, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: _loss_fn(model, p, batch), has_aux=True)(params)
        return loss, grads

    def step(state, batch):
        params, err = state["params"], state["err"]

        def shard_fn(params, err, batch):
            loss, grads = local_grads(params, batch)
            # err leaves carry a leading per-shard dim: (n_dp, *shape)
            err_local = jax.tree.map(lambda e: e[0], err)
            mean, new_err = compressed_psum(grads, err_local, dp_axes)
            loss = jax.lax.pmean(loss, dp_axes)
            return loss, mean, jax.tree.map(lambda e: e[None], new_err)

        loss, grads, new_err = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), P(dp_axes), P(dp_axes)),
            out_specs=(P(), P(), P(dp_axes)),
            check_rep=False,
        )(params, err, batch)
        new_params, new_opt, metrics = adamw.apply_updates(
            params, grads, state["opt"], opt_cfg)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt,
                "err": new_err}, metrics

    return step


def init_compressed_state(model: Model, key, mesh: Mesh) -> TrainState:
    """Train state + per-DP-shard error-feedback residuals."""
    state = init_train_state(model, key)
    n_dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n_dp *= mesh.shape[a]
    state["err"] = jax.tree.map(
        lambda p: jnp.zeros((n_dp,) + p.shape, jnp.float32),
        state["params"])
    return state


# ----------------------------------------------------------------------------
# Serving steps (prefill / decode) — these are what decode_* shapes lower
# ----------------------------------------------------------------------------

def make_prefill_step(model: Model, *, mesh: Optional[Mesh] = None,
                      rules: Optional[dict] = None) -> Callable:
    """prefill_step(params, batch) -> (last_logits, cache)."""

    def prefill(params, batch):
        seq = batch["tokens"].shape[1]
        b = batch["tokens"].shape[0]
        cache = model.init_cache(b, prefill_cache_len(seq))
        logits, cache = model.forward(params, batch, mode="prefill",
                                      cache=cache)
        return logits[:, -1], cache

    if mesh is None:
        return prefill

    def prefill_meshed(params, batch):
        with logical_context(mesh, rules):
            return prefill(params, batch)

    return prefill_meshed


def make_decode_step(model: Model, *, mesh: Optional[Mesh] = None,
                     rules: Optional[dict] = None,
                     sample: bool = False) -> Callable:
    """decode_step(params, cache, tokens, pos[, rng]) ->
    (next_tokens|logits, new_cache). ``tokens``: (B, 1); ``pos``: scalar."""

    def decode(params, cache, tokens, pos):
        logits, new_cache = model.forward(
            params, {"tokens": tokens}, mode="decode", cache=cache, pos=pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return (nxt if sample else logits[:, -1]), new_cache

    if mesh is None:
        return decode

    def decode_meshed(params, cache, tokens, pos):
        with logical_context(mesh, rules):
            return decode(params, cache, tokens, pos)

    return decode_meshed


# ----------------------------------------------------------------------------
# Cache sharding (decode cells)
# ----------------------------------------------------------------------------

# (family, leaf) -> logical axes; family = enclosing cache-kind key written
# by transformer._block_cache / encdec.init_encdec_cache.
_CACHE_AXES = {
    ("kv", "k"): ("batch", "cache_seq", "kv_heads", "head_dim"),
    ("kv", "v"): ("batch", "cache_seq", "kv_heads", "head_dim"),
    ("ssm", "conv"): ("batch", None, "inner"),
    ("ssm", "h"): ("batch", "ssm_heads", None, None),
    ("mstate", "C"): ("batch", "heads", None, None),
    ("mstate", "n"): ("batch", "heads", None),
    ("mstate", "m"): ("batch", "heads"),
    ("sstate", "c"): ("batch", "heads", None),
    ("sstate", "n"): ("batch", "heads", None),
    ("sstate", "h"): ("batch", "heads", None),
    ("sstate", "m"): ("batch", "heads"),
}
_FAMILIES = {"kv", "ssm", "mstate", "sstate", "self", "cross"}


def cache_shardings(model: Model, batch: int, max_len: int, mesh: Mesh,
                    rules: dict, enc_len: int = 1500):
    """NamedShardings for the KV/state cache pytree. Leading stacked-layer
    dims (scan segments) stay unsharded."""
    specs = model.cache_specs(batch, max_len, enc_len)

    def shard_one(path, leaf):
        keys = [str(getattr(k, "key", k)) for k in path]
        fam = next((k for k in reversed(keys[:-1]) if k in _FAMILIES), None)
        if fam in ("self", "cross"):   # encdec caches hold raw k/v dicts
            fam = "kv"
        axes = _CACHE_AXES.get((fam, keys[-1]))
        if axes is None:
            full = (None,) * leaf.ndim
        else:
            full = (None,) * (leaf.ndim - len(axes)) + axes
        return NamedSharding(mesh, spec_for(full, rules))

    out = jax.tree_util.tree_map_with_path(shard_one, specs)
    return enforce_divisibility(out, specs)
