"""Three-term roofline from a compiled dry-run artifact.

Per the brief::

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

FLOPs/bytes/collective-bytes come from the while-aware HLO cost model
(analysis.hlo) over ``compiled.as_text()`` — raw ``cost_analysis()``
counts scan bodies once, so it is kept only as a reference field
(``xla_flops`` / ``xla_bytes``). The compiled module is the
SPMD-partitioned per-device program, so analyzer outputs are per-device;
globals scale by the chip count, which cancels back out in the terms.

Hardware constants come from a registered ``repro.platforms`` target
(default ``tpu-v5e``) — pass ``platform=`` as a name, a ``Platform``, or
(legacy) a ``hw.ChipSpec``.

The dominant term is the modeled step-latency bound;
MODEL_FLOPS/HLO_FLOPs measures how much compiled compute is "useful"
(catches remat recompute and sharding-induced redundancy).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.analysis.hlo import HloCost, analyze_hlo
from repro.configs import ArchConfig
from repro.platforms import MemoryHierarchy, Platform, PowerModel, get_platform
from repro.platforms.paper import ChipSpec

PlatformLike = Union[str, Platform, ChipSpec, None]


def _as_platform(target: PlatformLike) -> Platform:
    """Resolve the roofline's hardware target; ChipSpec is accepted for
    backward compatibility and wrapped into an unregistered Platform."""
    if target is None:
        return get_platform("tpu-v5e")
    if isinstance(target, (str, Platform)):
        return get_platform(target)
    if isinstance(target, ChipSpec):
        return Platform(
            name=target.name, family=target.name, kind="tpu",
            memory=MemoryHierarchy(
                local_bytes=target.vmem_bytes, main_bytes=target.hbm_bytes,
                main_bw=target.hbm_bandwidth, link_bw=target.ici_bandwidth),
            power=PowerModel(nominal_w=target.power_w,
                             idle_w=target.idle_power_w),
            compute={"bf16": target.peak_flops_bf16},
        )
    raise TypeError(f"platform: expected name/Platform/ChipSpec, "
                    f"got {type(target).__name__}")


@dataclasses.dataclass(frozen=True)
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # global (= per-device × chips)
    hlo_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    collectives: dict            # opcode -> per-device bytes
    collective_counts: dict
    xla_flops: float = 0.0       # raw cost_analysis (scan-undercounted)
    xla_bytes: float = 0.0
    notes: tuple = ()
    platform: str = "tpu-v5e"    # registry name the constants came from
    peak_flops: float = 0.0      # per-chip peak used for the score axis

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline latency bound = max of the three terms (resources
        overlap on real hardware; the slowest one binds)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs time at peak / modeled bound. 1.0 = perfectly
        compute-bound with zero waste (the score axis)."""
        if self.bound_s <= 0 or self.peak_flops <= 0:
            return 0.0
        ideal = self.model_flops / (self.chips * self.peak_flops)
        return ideal / self.bound_s

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_ms": 1e3 * self.compute_s,
            "memory_ms": 1e3 * self.memory_s,
            "collective_ms": 1e3 * self.collective_s,
            "dominant": self.dominant,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_frac": self.roofline_fraction,
        }


def roofline_from_compiled(compiled, *, arch: str, shape: str, mesh: str,
                           chips: int, model_flops: float,
                           platform: PlatformLike = None,
                           hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = analyze_hlo(text)
    return roofline_from_hlocost(
        hc, arch=arch, shape=shape, mesh=mesh, chips=chips,
        model_flops=model_flops, platform=platform,
        xla_flops=float(cost.get("flops", 0.0)),
        xla_bytes=float(cost.get("bytes accessed", 0.0)))


def roofline_from_hlocost(hc: HloCost, *, arch: str, shape: str, mesh: str,
                          chips: int, model_flops: float,
                          platform: PlatformLike = None,
                          xla_flops: float = 0.0,
                          xla_bytes: float = 0.0) -> Roofline:
    plat = _as_platform(platform)
    peak = plat.peak_flops("bf16")
    notes = []
    if hc.unknown_trip_loops:
        notes.append(f"{len(hc.unknown_trip_loops)} loops with unresolved "
                     "trip counts (counted once)")
    if hc.unknown_customcalls:
        notes.append("custom-calls not costed: "
                     + ",".join(hc.unknown_customcalls))
    g_flops = hc.flops * chips
    g_bytes = hc.bytes * chips
    g_coll = hc.collective_bytes * chips
    return Roofline(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops=g_flops, hlo_bytes=g_bytes, collective_bytes=g_coll,
        compute_s=g_flops / (chips * peak),
        memory_s=g_bytes / (chips * plat.memory.main_bw),
        collective_s=g_coll / (chips * plat.memory.link_bw),
        model_flops=model_flops,
        collectives=dict(hc.collectives),
        collective_counts=dict(hc.collective_counts),
        xla_flops=xla_flops, xla_bytes=xla_bytes,
        notes=tuple(notes),
        platform=plat.name, peak_flops=peak,
    )


def model_flops(cfg: ArchConfig, n_params: int, n_active: int,
                tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd-only) with N = active
    params for MoE. ``tokens`` = global tokens in the step (decode: one
    per sequence)."""
    n = n_active if cfg.is_moe else n_params
    per_tok = 6 * n if kind == "train" else 2 * n
    return float(per_tok) * tokens
