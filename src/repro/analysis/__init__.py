from repro.analysis.hlo import analyze_hlo, collective_bytes, HloCost
from repro.analysis.roofline import (Roofline, roofline_from_compiled,
                                     roofline_from_hlocost, model_flops)
