"""While-aware HLO cost model (FLOPs / HBM bytes / collective bytes).

``compiled.cost_analysis()`` counts each while-loop body ONCE — a
structural undercount for scanned layer stacks (a 36-layer scan reads as
1/36th of its true cost). This module re-derives costs from
``compiled.as_text()`` with loop trip-count multiplication:

* **FLOPs** — ``dot`` instructions contribute 2·|out|·|contracted|
  (batch dims included via the output shape); elementwise ops inside
  fusions contribute |out| each (transcendentals approximated at 1).
* **HBM bytes** — summed operand+output sizes of *top-level* (post-fusion)
  instructions: fusion boundaries in scheduled HLO are exactly XLA's
  materialization points, so this matches the compiler's own
  bytes-accessed convention. Collective payloads are kept out of the
  memory total (they're the third roofline term).
* **collective bytes** — per the brief: summed operand sizes of every
  all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute (async ``-start`` forms counted once).
* **while** — body+cond costs multiply by the trip count parsed from the
  condition region (scan/fori emit ``compare(counter, constant(N))``);
  loops whose bound can't be resolved count once and are recorded in
  ``unknown_trip_loops``.

All quantities are PER-DEVICE (the compiled module is the SPMD-partitioned
per-device program); the roofline layer scales by chip count.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_METADATA_RE = re.compile(r'op_name="([^"]*)"')

# opcodes that move no data / are bookkeeping. Bare `copy` (layout-
# preserving) is counted free: TPU buffer assignment aliases loop-carry
# copies away (donated/double-buffered); layout-CHANGING copies appear as
# transpose/fusion instructions and stay charged.
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "copy"}
# ~flops-per-element for fused elementwise ops (coarse, XLA-style)
_ELEMENTWISE_FLOP = {
    "add": 1, "subtract": 1, "multiply": 1, "divide": 1, "maximum": 1,
    "minimum": 1, "exponential": 1, "tanh": 1, "rsqrt": 1, "sqrt": 1,
    "log": 1, "negate": 1, "abs": 1, "compare": 1, "select": 1,
    "and": 1, "or": 1, "not": 1, "power": 1, "floor": 1, "ceil": 1,
    "round-nearest-afz": 1, "round-nearest-even": 1, "sign": 1,
    "cosine": 1, "sine": 1, "logistic": 1, "atan2": 1, "clamp": 1,
    "expm1": 1, "log1p": 1, "cbrt": 1, "erf": 1,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if m is None:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str            # output type string
    opcode: str
    rest: str             # operand list + attrs (raw tail)
    operands: list


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    bytes_by_op: dict = dataclasses.field(default_factory=dict)
    flops_by_op: dict = dataclasses.field(default_factory=dict)

    def add_bytes(self, op: str, b: float):
        self.bytes += b
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0) + b

    def add_flops(self, op: str, f: float):
        self.flops += f
        self.flops_by_op[op] = self.flops_by_op.get(op, 0) + f

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0) + v
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v
        for k, v in o.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0) + v
        for k, v in o.flops_by_op.items():
            self.flops_by_op[k] = self.flops_by_op.get(k, 0) + v
        return self

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.bytes * t,
                    {k: v * t for k, v in self.coll.items()},
                    {k: v * t for k, v in self.coll_counts.items()},
                    {k: v * t for k, v in self.bytes_by_op.items()},
                    {k: v * t for k, v in self.flops_by_op.items()})


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collective_bytes: float
    collectives: dict
    collective_counts: dict
    unknown_trip_loops: list
    unknown_customcalls: list
    bytes_by_op: dict = dataclasses.field(default_factory=dict)
    flops_by_op: dict = dataclasses.field(default_factory=dict)

    def top_bytes(self, n: int = 8) -> list:
        return sorted(self.bytes_by_op.items(), key=lambda kv: -kv[1])[:n]

    def top_flops(self, n: int = 8) -> list:
        return sorted(self.flops_by_op.items(), key=lambda kv: -kv[1])[:n]


def _parse_computations(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: Optional[list] = None
    for line in text.splitlines():
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_RE.match(line.strip().removeprefix("ENTRY ").strip())
            name = None
            m2 = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line.strip())
            if m2:
                name = m2.group(1)
            if name:
                cur = []
                comps[name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m is None:
            continue
        name, shape, opcode, rest = m.groups()
        # operands: %names up to the closing paren of the operand list
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        ops = _OPERAND_RE.findall(rest[:end])
        cur.append(Instr(name, shape, opcode, rest, ops))
    return comps


def _dot_flops(instr: Instr, shapes: dict) -> float:
    out_elems = _shape_elems(instr.shape)
    m = _CONTRACT_RE.search(instr.rest)
    if m is None or not instr.operands:
        return 2.0 * out_elems  # degenerate
    lhs_shape = shapes.get(instr.operands[0], "")
    dims = _shape_dims(lhs_shape)
    contracted = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(dims):
            contracted *= dims[int(idx)]
    return 2.0 * out_elems * contracted


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = _parse_computations(text)
        self.shapes: dict[str, dict[str, str]] = {
            c: {i.name: i.shape for i in instrs}
            for c, instrs in self.comps.items()}
        self._fusion_flops_cache: dict[str, float] = {}
        self._cost_cache: dict[str, Cost] = {}
        self.unknown_trips: list = []
        self.unknown_ccalls: list = []
        # computations used as fusion bodies (flops counted elementwise,
        # bytes NOT counted — the fusion call site owns the traffic)
        self.entry = self._find_entry(text)

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)\s*\(", text, re.M)
        if m:
            return m.group(1)
        # fall back: computation named main*
        for name in self.comps:
            if name.startswith("main"):
                return name
        raise ValueError("no ENTRY computation found")

    # -- fusion operand narrowing -----------------------------------------
    def _fusion_param_bytes(self, comp: str) -> dict[int, int]:
        """Parameters of a fused computation whose only use is a
        dynamic-slice or gather (scan reading one layer's weights from a
        stacked array; embedding-table lookups): effective read = the
        sliced/gathered bytes, not the full operand. XLA's
        cost model applies the same narrowing; without it a 36-segment
        scan charges 36× the full stacked parameter array."""
        out: dict[int, int] = {}
        instrs = self.comps.get(comp, [])
        params = {}
        for ins in instrs:
            if ins.opcode == "parameter":
                m = re.match(r"(\d+)\)", ins.rest)
                if m:
                    params[ins.name] = int(m.group(1))
        for pname, pidx in params.items():
            # follow single-use convert/bitcast/copy chains from the param
            # (the CPU backend interleaves dtype converts before slicing)
            cur = pname
            slice_bytes = None
            for _ in range(6):
                uses = [i for i in instrs if cur in i.operands]
                if len(uses) != 1:
                    break
                u = uses[0]
                if u.opcode in ("dynamic-slice", "gather") \
                        and u.operands and u.operands[0] == cur:
                    slice_bytes = _shape_bytes(u.shape)
                    break
                if u.opcode in ("convert", "bitcast", "copy", "reshape"):
                    cur = u.name
                    continue
                break
            if slice_bytes is not None:
                out[pidx] = slice_bytes
        return out

    # -- fusion interiors: flops only ------------------------------------
    def _fusion_flops(self, comp: str) -> float:
        if comp in self._fusion_flops_cache:
            return self._fusion_flops_cache[comp]
        total = 0.0
        for ins in self.comps.get(comp, []):
            if ins.opcode == "dot":
                total += _dot_flops(ins, self.shapes[comp])
            elif ins.opcode in _ELEMENTWISE_FLOP:
                total += _ELEMENTWISE_FLOP[ins.opcode] * _shape_elems(ins.shape)
            elif ins.opcode == "reduce":
                total += _shape_elems(self.shapes[comp].get(
                    ins.operands[0], ins.shape) if ins.operands else ins.shape)
            elif ins.opcode == "fusion":
                m = _CALLS_RE.search(ins.rest)
                if m:
                    total += self._fusion_flops(m.group(1))
        self._fusion_flops_cache[comp] = total
        return total

    # -- trip counts ------------------------------------------------------
    def _trip_count(self, cond_comp: str) -> Optional[int]:
        """Largest integer constant in the cond region (scan/fori emit
        compare(counter, constant(N)) with counter from 0)."""
        best = None
        stack = [cond_comp]
        seen = set()
        while stack:
            c = stack.pop()
            if c in seen:
                continue
            seen.add(c)
            for ins in self.comps.get(c, []):
                if ins.opcode == "constant":
                    m = re.match(r"(\d+)\)", ins.rest)
                    if m:
                        v = int(m.group(1))
                        if best is None or v > best:
                            best = v
                m = _CALLS_RE.search(ins.rest)
                if m:
                    stack.append(m.group(1))
        return best

    # -- per-instruction bytes (shared by cost_of and the detail pass) ----
    def _instr_bytes(self, ins: Instr, shapes: dict) -> Optional[float]:
        """HBM bytes for one data-moving instruction, or None if it is
        control flow / free / a collective."""
        op = ins.opcode
        if op in _FREE or op == "while" or op == "conditional" \
                or op in ("call", "async-start"):
            return None
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            return None
        if op in ("gather", "dynamic-slice"):
            idx = sum(_shape_bytes(shapes.get(o, ""))
                      for o in ins.operands[1:])
            return 2 * _shape_bytes(ins.shape) + idx
        if op in ("scatter", "dynamic-update-slice"):
            upd = sum(_shape_bytes(shapes.get(o, ""))
                      for o in ins.operands[1:])
            return upd + _shape_bytes(
                shapes.get(ins.operands[1], "")
                if len(ins.operands) > 1 else ins.shape)
        if op == "fusion":
            m = _CALLS_RE.search(ins.rest)
            called = m.group(1) if m else None
            dus = self._fusion_dus_root(called) if called else None
            if dus is not None:
                # in-place cache update (TPU aliases donated buffers):
                # traffic = the update slab in and out, not the full cache
                return 2 * dus
            narrowed = self._fusion_param_bytes(called) if called else {}
            in_b = sum(narrowed.get(i, _shape_bytes(shapes.get(o, "")))
                       for i, o in enumerate(ins.operands))
            return in_b + _shape_bytes(ins.shape)
        return (sum(_shape_bytes(shapes.get(o, "")) for o in ins.operands)
                + _shape_bytes(ins.shape))

    def _fusion_dus_root(self, comp: str) -> Optional[int]:
        """If a fused computation's root is a dynamic-update-slice whose
        target is a plain parameter (KV-cache write pattern), return the
        update-slab bytes; else None. XLA TPU performs such updates in
        place when the buffer is donated/aliased — charging a full
        cache-sized copy per decode step would be a CPU-backend artifact."""
        instrs = self.comps.get(comp, [])
        if not instrs:
            return None
        used = {o for i in instrs for o in i.operands}
        dus = next((i for i in instrs
                    if i.opcode == "dynamic-update-slice"), None)
        if dus is None or len(dus.operands) < 2:
            return None
        # the DUS must be the root or feed only converts/bitcasts on the
        # way to the root (dus+convert cache-write fusions)
        cur = dus
        while cur.name in used:
            consumers = [i for i in instrs if cur.name in i.operands]
            if len(consumers) != 1 or consumers[0].opcode not in (
                    "convert", "bitcast", "copy"):
                return None
            cur = consumers[0]
        shapes = self.shapes.get(comp, {})
        upd = _shape_bytes(shapes.get(dus.operands[1], ""))
        return upd if upd > 0 else None

    # -- detail pass: per-instruction attribution with trip multipliers ---
    def _comp_multipliers(self) -> dict[str, float]:
        """Effective execution count of each computation (while bodies
        multiply by their trip counts; fusion interiors excluded — the
        call site owns their traffic)."""
        mult: dict[str, float] = {self.entry: 1.0}
        stack = [self.entry]
        while stack:
            comp = stack.pop()
            m0 = mult[comp]
            for ins in self.comps.get(comp, []):
                if ins.opcode == "while":
                    body = _BODY_RE.search(ins.rest)
                    cond = _COND_RE.search(ins.rest)
                    trips = (self._trip_count(cond.group(1))
                             if cond else None) or 1
                    for tgt in filter(None, [body and body.group(1),
                                             cond and cond.group(1)]):
                        mult[tgt] = mult.get(tgt, 0.0) + m0 * trips
                        stack.append(tgt)
                elif ins.opcode == "conditional":
                    m = _BRANCHES_RE.search(ins.rest)
                    if m:
                        for b in m.group(1).split(","):
                            b = b.strip().lstrip("%")
                            if b:
                                mult[b] = mult.get(b, 0.0) + m0
                                stack.append(b)
                elif ins.opcode in ("call", "async-start"):
                    m = re.search(r"to_apply=%?([\w.\-]+)", ins.rest)
                    if m:
                        mult[m.group(1)] = mult.get(m.group(1), 0.0) + m0
                        stack.append(m.group(1))
        return mult

    def top_instructions(self, n: int = 30):
        """The profiling view: heaviest instructions by effective HBM
        bytes (trip-multiplied), with their JAX-source op_name metadata.
        Returns [(bytes, opcode, shape, op_name)]."""
        mult = self._comp_multipliers()
        out = []
        for comp, m0 in mult.items():
            shapes = self.shapes.get(comp, {})
            for ins in self.comps.get(comp, []):
                b = self._instr_bytes(ins, shapes)
                if b is None or b == 0:
                    continue
                meta = _METADATA_RE.search(ins.rest)
                out.append((b * m0, ins.opcode, ins.shape.strip(),
                            meta.group(1) if meta else ins.name))
        out.sort(key=lambda t: -t[0])
        return out[:n]

    def top_collectives(self, n: int = 20):
        """Heaviest collectives by effective payload bytes."""
        mult = self._comp_multipliers()
        out = []
        for comp, m0 in mult.items():
            shapes = self.shapes.get(comp, {})
            for ins in self.comps.get(comp, []):
                op = ins.opcode
                base = op[:-6] if op.endswith("-start") else op
                if base not in _COLLECTIVES or op.endswith("-done"):
                    continue
                payload = sum(_shape_bytes(shapes.get(o, ""))
                              for o in ins.operands) \
                    or _shape_bytes(ins.shape)
                meta = _METADATA_RE.search(ins.rest)
                out.append((payload * m0, base, ins.shape.strip(),
                            meta.group(1) if meta else ins.name))
        out.sort(key=lambda t: -t[0])
        return out[:n]

    # -- main walk ----------------------------------------------------------
    def cost_of(self, comp: str) -> Cost:
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        total = Cost()
        shapes = self.shapes.get(comp, {})
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            if op in _FREE:
                continue
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                if op.endswith("-done") or op.endswith("-update"):
                    continue
                payload = sum(_shape_bytes(shapes.get(o, ""))
                              for o in ins.operands)
                if payload == 0:   # operand shapes unresolved: use output
                    payload = _shape_bytes(ins.shape)
                total.coll[base] = total.coll.get(base, 0) + payload
                total.coll_counts[base] = total.coll_counts.get(base, 0) + 1
                continue
            if op == "while":
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                if body is None:
                    continue
                trips = self._trip_count(cond.group(1)) if cond else None
                if trips is None:
                    trips = 1
                    self.unknown_trips.append(ins.name)
                inner = Cost()
                inner += self.cost_of(body.group(1))
                if cond:
                    inner += self.cost_of(cond.group(1))
                total += inner.scaled(trips)
                continue
            if op == "conditional":
                m = _BRANCHES_RE.search(ins.rest)
                if m:
                    branches = [b.strip().lstrip("%")
                                for b in m.group(1).split(",")]
                    costs = [self.cost_of(b) for b in branches if b]
                    if costs:
                        # one branch executes; take the max-flops branch
                        total += max(costs, key=lambda c: c.flops)
                continue
            if op in ("call", "async-start"):
                m = re.search(r"to_apply=%?([\w.\-]+)", ins.rest)
                if m:
                    total += self.cost_of(m.group(1))
                continue
            # --- data-moving instruction at a fusion boundary ---
            if op in ("gather", "dynamic-slice"):
                # sparse reads: indices + output, not the whole operand
                idx_bytes = sum(_shape_bytes(shapes.get(o, ""))
                                for o in ins.operands[1:])
                total.add_bytes(op, 2 * _shape_bytes(ins.shape) + idx_bytes)
                continue
            if op in ("scatter", "dynamic-update-slice"):
                # sparse writes: indices + updates + written region
                upd_bytes = sum(_shape_bytes(shapes.get(o, ""))
                                for o in ins.operands[1:])
                total.add_bytes(op, upd_bytes + _shape_bytes(
                    shapes.get(ins.operands[1], "")
                    if len(ins.operands) > 1 else ins.shape))
                continue
            if op == "fusion":
                m = _CALLS_RE.search(ins.rest)
                narrowed = (self._fusion_param_bytes(m.group(1))
                            if m else {})
                in_bytes = 0
                for i, o in enumerate(ins.operands):
                    in_bytes += narrowed.get(i, _shape_bytes(
                        shapes.get(o, "")))
                total.add_bytes(op, in_bytes + _shape_bytes(ins.shape))
                if m:
                    total.add_flops(op, self._fusion_flops(m.group(1)))
                continue
            in_bytes = sum(_shape_bytes(shapes.get(o, ""))
                           for o in ins.operands)
            out_bytes = _shape_bytes(ins.shape)
            total.add_bytes(op, in_bytes + out_bytes)
            if op == "dot":
                total.add_flops(op, _dot_flops(ins, shapes))
            elif op in _ELEMENTWISE_FLOP:
                total.add_flops(op, _ELEMENTWISE_FLOP[op]
                                * _shape_elems(ins.shape))
            elif op == "reduce":
                total.add_flops(op, _shape_elems(
                    shapes.get(ins.operands[0], ins.shape)
                    if ins.operands else ins.shape))
            elif op == "custom-call":
                tgt = re.search(r'custom_call_target="([^"]+)"', ins.rest)
                tname = tgt.group(1) if tgt else "?"
                if "matmul" in tname.lower() or "dot" in tname.lower():
                    # library GEMM: flops unavailable from attrs; count as
                    # 2*out_elems*K via first-operand last dim
                    dims = _shape_dims(shapes.get(ins.operands[0], ""))
                    k = dims[-1] if dims else 1
                    total.add_flops(op, 2.0 * _shape_elems(ins.shape) * k)
                elif tname not in ("TopK",):
                    self.unknown_ccalls.append(tname)
        self._cost_cache[comp] = total
        return total

    def analyze(self) -> HloCost:
        c = self.cost_of(self.entry)
        return HloCost(
            flops=c.flops, bytes=c.bytes,
            collective_bytes=sum(c.coll.values()),
            collectives=dict(c.coll),
            collective_counts=dict(c.coll_counts),
            unknown_trip_loops=list(self.unknown_trips),
            unknown_customcalls=sorted(set(self.unknown_ccalls)),
            bytes_by_op=dict(c.bytes_by_op),
            flops_by_op=dict(c.flops_by_op),
        )


def analyze_hlo(text: str) -> HloCost:
    return HloAnalyzer(text).analyze()


def analyze_jit(fn, *args, **kwargs) -> HloCost:
    """Lower + compile ``fn`` for ``args`` and run the while-aware
    analyzer on the scheduled HLO XLA actually emits.

    This is the measured side of the ROADMAP's "measured HLO cost model"
    item: callers (``repro.staticcheck``'s footprint cross-check, the
    dispatch/autotuning layers) hand it a callable + representative
    arguments and get the per-opcode flops/bytes feature vector for the
    compiled program, loop trip counts included. ``fn`` may already be
    jit-wrapped (anything with ``.lower``); plain callables are wrapped
    here. Args may be concrete arrays or ``jax.ShapeDtypeStruct``s.
    """
    import jax

    jfn = fn if hasattr(fn, "lower") else jax.jit(fn)
    return analyze_hlo(jfn.lower(*args, **kwargs).compile().as_text())


# Back-compat helpers -------------------------------------------------------

def parse_hlo_collectives(text: str) -> HloCost:
    return analyze_hlo(text)


def collective_bytes(text: str) -> float:
    return analyze_hlo(text).collective_bytes
