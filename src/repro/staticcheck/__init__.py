"""repro.staticcheck — static verification of the serving hot path.

Traces/lowers the prefill jit, the fused decode tick, the streaming
cross-cache extension and the frontend GEMMs, then verifies structural
invariants from the jaxpr and lowered HLO: donation/aliasing, the
one-host-sync-per-tick budget, q8_0/bf16 dtype-plane integrity,
recompile stability, and the registry's analytic kernel footprints
against the measured HLO cost model.

CLI: ``python -m repro.staticcheck [--json [PATH]] [--only IDS]``.
Intentional exceptions live in ``staticcheck.toml`` at the repo root.
"""

from repro.staticcheck.config import StaticcheckConfig
from repro.staticcheck.report import Finding, Report
from repro.staticcheck.run import ALL_CHECKS, bench_record, run_all

__all__ = ["ALL_CHECKS", "Finding", "Report", "StaticcheckConfig",
           "bench_record", "run_all"]
