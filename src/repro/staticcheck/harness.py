"""Build and trace the serving hot path for static verification.

The harness instantiates the same reduced whisper-tiny-en engine the
dynamic tests use (``tests/test_decode_fused.py``) and *traces* — never
executes — the four hot-path programs:

* ``prefill``            — bucketed prompt prefill jit (pool donated)
* ``decode_block``       — the fused multi-token decode tick (cache +
                           lane state donated)
* ``extend_cross_cache`` — streaming cross-K/V pool extension (pool
                           donated)
* ``frontend_gemm``      — the audio frontend's projection GEMM path

Paged engines (``repro.paging``) trace the paged twins of the first
three — ``paged_decode_block`` (page tables donated alongside the pool
and re-aliased through), ``paged_prefill`` (page-row scatter), and
``paged_extend_cross`` (per-frame page/offset scatter) — under the same
check IDs, so the paged pool obeys the same donation / sync-free /
dtype-plane contract as the slot pool.

Tracing with ``jitted.trace(*args)`` gives the jaxpr (complete with
scan bodies) and, via ``.lower()``, the StableHLO text where donation
appears as ``tf.aliasing_output`` parameter attributes. Nothing runs on
device and no donated buffer is consumed, so one engine serves every
check.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import build
from repro.serving.engine import ServeEngine

# Mirror tests/test_decode_fused.py's harness exactly: same model, same
# pool geometry, so static verdicts and dynamic assertions cover the
# same programs.
N_SLOTS, MAX_LEN, ENC_LEN = 4, 64, 16
DECODE_BLOCK, BUCKET, ENC_S = 2, 32, 8
# Paged pool geometry: usable pages == the slot pool's token capacity
# (+1 for the reserved scratch page 0), mirroring tests/test_paging.py.
PAGE_SIZE = 8
N_PAGES = N_SLOTS * (MAX_LEN // PAGE_SIZE) + 1
N_CROSS_PAGES = N_SLOTS * (ENC_LEN // PAGE_SIZE) + 1


@dataclasses.dataclass
class HotProgram:
    """One traced hot-path program plus the static facts checks need."""

    name: str
    jaxpr: Any                 # ClosedJaxpr, scan/while bodies included
    stablehlo: str             # lowered text with donation attributes
    donated_leaves: int        # buffers jit was told to donate
    cache_dtypes: tuple = ()   # storage dtypes of the donated pool
    plane_dims: tuple = ()     # (n_slots, max_len, enc_len, head_dim);
                               # enc_len 0 for decoder-only engines
    state_shapes: tuple = ()   # shapes of non-KV (recurrent/routing)
                               # cache planes — read-upcast by design


def build_engine(cache_dtype: str = "q8_0",
                 arch: str = "whisper-tiny-en") -> ServeEngine:
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))
    return ServeEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                       enc_len=ENC_LEN, cache_dtype=cache_dtype,
                       decode_block=DECODE_BLOCK)


# The model-zoo engines: one decoder-only arch per served family
# (LaneStateSpec coverage — KV-only dense, KV+routing MoE, hybrid
# KV+ssm, pure-recurrent xlstm). Traced under the same SC-DON/SC-SYNC/
# SC-DTYPE/SC-RECOMP checks as the whisper engines; q8_0 twins only
# where the family's spec supports the tier.
FAMILY_ARCHS = ("qwen3-4b", "qwen3-moe-30b-a3b", "zamba2-7b",
                "xlstm-350m")


def build_family_engines(cache_dtypes: tuple = ("bf16",)
                         ) -> list[ServeEngine]:
    """One engine per (family arch, supported cache dtype)."""
    out = []
    for arch in FAMILY_ARCHS:
        model = build(reduced(get_config(arch)))
        params = model.init_values(jax.random.key(0))
        for cd in cache_dtypes:
            if cd != "bf16" and not model.state_spec().supports_tier(cd):
                continue
            out.append(ServeEngine(model, params, n_slots=N_SLOTS,
                                   max_len=MAX_LEN, enc_len=ENC_LEN,
                                   cache_dtype=cd,
                                   decode_block=DECODE_BLOCK))
    return out


def build_spec_engine(cache_dtype: str = "q4_0",
                      arch: str = "whisper-tiny-en",
                      spec_k: int = DECODE_BLOCK) -> ServeEngine:
    """A self-speculative engine: quantized draft weights + the fused
    draft-verify tick. spec_k defaults to DECODE_BLOCK so the traced
    tick is exactly one draft-verify round."""
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))
    return ServeEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                       enc_len=ENC_LEN, cache_dtype=cache_dtype,
                       decode_block=DECODE_BLOCK, spec_k=spec_k)


def build_paged_engine(cache_dtype: str = "q8_0",
                       arch: str = "whisper-tiny-en") -> ServeEngine:
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init_values(jax.random.key(0))
    return ServeEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                       enc_len=ENC_LEN, cache_dtype=cache_dtype,
                       decode_block=DECODE_BLOCK, paged=True,
                       page_size=PAGE_SIZE, n_pages=N_PAGES,
                       n_cross_pages=N_CROSS_PAGES)


def _donated_leaves(args: tuple, argnums: tuple) -> int:
    return len(jax.tree.leaves(tuple(args[i] for i in argnums)))


def _state_shapes(cache) -> tuple:
    """Shapes of the cache leaves that are *not* KV planes — recurrent
    ``(C, n, m)`` / ``(h, c, ...)`` buffers and routing counters. Same
    classification walk the engine's byte accounting uses."""
    shapes = set()

    def walk(tree):
        if isinstance(tree, dict):
            if set(tree) in ({"k", "v"}, {"kq", "ks", "vq", "vs"},
                             {"kp", "ks", "vp", "vs"}):
                return
            for v in tree.values():
                walk(v)
        elif isinstance(tree, (list, tuple)):
            for v in tree:
                walk(v)
        elif tree is not None:
            shape = tuple(tree.shape)
            shapes.add(shape)
            # layer-stacked planes are sliced per layer inside the
            # block scan — record that view too
            if len(shape) > 1:
                shapes.add(shape[1:])

    walk(cache)
    return tuple(sorted(shapes))


def _trace(name: str, jitted, args: tuple, donate: tuple,
           eng: Optional[ServeEngine] = None) -> HotProgram:
    traced = jitted.trace(*args)
    cache_dtypes = ()
    plane_dims = ()
    state_shapes = ()
    if eng is not None:
        cache_dtypes = tuple(sorted({str(x.dtype) for x in
                                     jax.tree.leaves(eng.cache)}))
        plane_dims = (eng.n_slots, eng.max_len,
                      eng.enc_len if eng.enc_dec else 0,
                      eng.model.cfg.head_dim)
        state_shapes = _state_shapes(eng.cache)
    return HotProgram(name=name, jaxpr=traced.jaxpr,
                      stablehlo=traced.lower().as_text(),
                      donated_leaves=_donated_leaves(args, donate),
                      cache_dtypes=cache_dtypes, plane_dims=plane_dims,
                      state_shapes=state_shapes)


def program_from_fn(name: str, fn, *args, donate: tuple = (),
                    eng: Optional[ServeEngine] = None) -> HotProgram:
    """Trace an arbitrary callable as a HotProgram — the hook the
    seeded-violation test fixtures use."""
    jitted = fn if hasattr(fn, "trace") else jax.jit(fn)
    return _trace(name, jitted, args, donate, eng)


def hot_programs(eng: ServeEngine,
                 frontend: bool = True) -> list[HotProgram]:
    """Trace the serving hot path of one engine. Program names carry
    the cache dtype (``decode_block[q8_0]``) so the two pool layouts
    report separately; model-zoo engines additionally carry the arch
    (``decode_block[xlstm-350m|bf16]``) so every family's programs get
    their own verdicts."""
    cfg = eng.model.cfg
    tag = f"[{eng.cache_dtype}]" if cfg.enc_dec \
        else f"[{cfg.name}|{eng.cache_dtype}]"
    if eng.spec_k:
        # speculative engines trace the draft-verify tick under their
        # own subject names (the draft weights ride inside params)
        tag = f"[spec{eng.spec_k}|{eng.cache_dtype}]"
    programs = []

    # --- fused decode tick (the per-tick program) ---
    dec = eng._decode_fn(DECODE_BLOCK)
    dec_args = (eng.params, eng.cache, eng._tokens, eng._pos,
                eng._lane_active, eng._lane_out, eng._enc_lens,
                eng._lane_eos, eng._lane_max)
    programs.append(_trace(f"decode_block{tag}", dec, dec_args,
                           donate=(1, 2, 3, 4, 5), eng=eng))

    # --- prompt prefill: bucketed, or exact-length for recurrent lanes
    # (spec.prefill_exact); decoder-only engines take no encoder input
    bucket = BUCKET if not eng.spec.prefill_exact else BUCKET - 3
    toks = jax.ShapeDtypeStruct((1, bucket), jnp.int32)
    if eng.enc_dec:
        pre = eng._prefill_fn(bucket, ENC_S)
        frames = jax.ShapeDtypeStruct((1, ENC_S, cfg.d_model),
                                      jnp.float32)
        pre_args = (eng.params, eng.cache, toks, 4, 0, frames)
    else:
        pre = eng._prefill_fn(bucket)
        pre_args = (eng.params, eng.cache, toks, 4, 0)
    programs.append(_trace(f"prefill{tag}", pre, pre_args,
                           donate=(1,), eng=eng))

    # --- streaming cross-K/V pool extension ---
    if eng.enc_dec:
        s_new = 4
        states = jax.ShapeDtypeStruct((1, s_new, cfg.d_model),
                                      jnp.float32)
        k_sds, v_sds = jax.eval_shape(eng._cross_kv, eng.params, states)
        programs.append(_trace(f"extend_cross_cache{tag}", eng._extend,
                               (eng.cache, k_sds, v_sds, 0, 0),
                               donate=(0,), eng=eng))

    # --- audio frontend projection GEMM ---
    if frontend:
        from repro.audio.features import FrontendConfig, mel_to_frames
        fcfg = FrontendConfig()
        d_model = cfg.d_model

        def frontend_fn(logmel):
            return mel_to_frames(logmel, d_model, fcfg)

        mel = jax.ShapeDtypeStruct((4 * fcfg.stride, fcfg.n_mels),
                                   jnp.float32)
        programs.append(program_from_fn("frontend_gemm", frontend_fn,
                                        mel))
    return programs


def paged_hot_programs(eng: ServeEngine) -> list[HotProgram]:
    """Trace the paged engine's hot path: the page-table decode tick,
    the page-row prefill scatter, and the streaming cross extension."""
    assert eng.paged
    tag = f"[{eng.cache_dtype}]"
    cfg = eng.model.cfg
    programs = []

    # --- fused paged decode tick (tables donated + aliased through) ---
    dec = eng._decode_fn(DECODE_BLOCK)
    tables = {"self": eng.pages.self_table.device(),
              "cross": eng.pages.cross_table.device()}
    dec_args = (eng.params, eng.cache, tables, eng._tokens, eng._pos,
                eng._lane_active, eng._lane_out, eng._enc_lens,
                eng._lane_eos, eng._lane_max)
    programs.append(_trace(f"paged_decode_block{tag}", dec, dec_args,
                           donate=(1, 2, 3, 4, 5, 6), eng=eng))

    # --- paged prefill: dense one-lane cache -> page-row scatter ---
    pre = eng._prefill_fn(BUCKET, ENC_S)
    toks = jax.ShapeDtypeStruct((1, BUCKET), jnp.int32)
    frames = jax.ShapeDtypeStruct((1, ENC_S, cfg.d_model), jnp.float32)
    pv_self = jax.ShapeDtypeStruct((MAX_LEN // PAGE_SIZE,), jnp.int32)
    pv_cross = jax.ShapeDtypeStruct((ENC_LEN // PAGE_SIZE,), jnp.int32)
    programs.append(_trace(
        f"paged_prefill{tag}", pre,
        (eng.params, eng.cache, toks, 4, pv_self, pv_cross, frames),
        donate=(1,), eng=eng))

    # --- streaming cross-K/V extension at per-frame page targets ---
    s_new = 4
    states = jax.ShapeDtypeStruct((1, s_new, cfg.d_model), jnp.float32)
    k_sds, v_sds = jax.eval_shape(eng._cross_kv, eng.params, states)
    phys = jax.ShapeDtypeStruct((s_new,), jnp.int32)
    off = jax.ShapeDtypeStruct((s_new,), jnp.int32)
    programs.append(_trace(f"paged_extend_cross{tag}", eng._extend,
                           (eng.cache, k_sds, v_sds, phys, off),
                           donate=(0,), eng=eng))
    return programs
