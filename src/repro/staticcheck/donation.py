"""SC-DON — donation/aliasing: every buffer a hot-path jit donates must
come back as an XLA input/output alias, i.e. an in-place update rather
than a defensive copy.

Evidence: per-parameter ``tf.aliasing_output`` attributes in the
lowered StableHLO (jit resolves ``donate_argnums`` into
``input_output_aliases`` at lowering time, before XLA ever runs, so
this is a fully static fact). A donated pool missing its alias means
the engine would silently allocate + copy the whole KV pool every tick.
"""

from __future__ import annotations

from repro.staticcheck.harness import HotProgram
from repro.staticcheck.jaxpr_utils import alias_count, arg_aliases
from repro.staticcheck.report import Finding

CHECK = "SC-DON"


def check_donation(programs: list[HotProgram]) -> list[Finding]:
    out = []
    for prog in programs:
        if prog.donated_leaves == 0:
            continue
        n = alias_count(prog.stablehlo)
        ok = n >= prog.donated_leaves
        aliases = arg_aliases(prog.stablehlo)
        out.append(Finding(
            check=CHECK, subject=prog.name, ok=ok,
            detail=(f"{n}/{prog.donated_leaves} donated buffers aliased "
                    f"in-place"
                    + ("" if ok else " — donated pool would be copied")),
            data={"aliased": n, "donated": prog.donated_leaves,
                  "arg_to_output": aliases}))
    return out
