"""SC-DTYPE — dtype-plane integrity: the q8_0 / bf16 cache pools must
never be materialized in f32 inside a hot-path program.

The paper's cache-stream ratio (q8_0 at 0.5312x the bf16 bytes) only
holds if reads dequantize into the compute dtype at the point of use —
a ``convert_element_type`` to f32 over a whole pool plane means the
program streams 4-byte planes through HBM regardless of what the pool
stores. The check walks every jaxpr equation (scan bodies included) and
flags converts that are

* from a storage dtype (int8 / bf16 / f16) to float32, and
* plane-sized: the input spans at least ``n_slots * min(seq) *
  head_dim`` elements and carries a pool sequence dim (``max_len`` or
  ``enc_len``) — i.e. it is a cache plane (possibly flattened), not a
  per-token activation.

Per-token activation upcasts (argmax logits, softmax accumulators —
all orders of magnitude below plane size) pass untouched.

A second SC-DTYPE pass guards the *recurrent* planes
(``check_recurrent_state``): SSM ``(C, n, m)`` and xLSTM ``(h, c)``
state is computed in f32 inside a block but must be written back in
its storage dtype — a decode tick whose output cache carries a wider
dtype than its input silently doubles every recurrent lane's resident
bytes from tick one (the pre-fix bug this check pins down). Verified
shape-only via ``jax.eval_shape`` on the fused tick: the carry's leaf
dtypes must be a fixed point.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.staticcheck.harness import HotProgram
from repro.staticcheck.jaxpr_utils import iter_eqns
from repro.staticcheck.report import Finding

CHECK = "SC-DTYPE"

_STORAGE_DTYPES = {jnp.dtype(jnp.int8), jnp.dtype(jnp.uint8),
                   jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)}


def _plane_upcasts(prog: HotProgram) -> list[dict]:
    n_slots, max_len, enc_len, head_dim = prog.plane_dims
    # enc_len is 0 for decoder-only engines: no cross pool, so only
    # max_len counts as a pool sequence dim
    seq_dims = {d for d in (max_len, enc_len) if d}
    min_elems = n_slots * min(seq_dims) * head_dim
    state_shapes = set(prog.state_shapes)
    hits = []
    for eqn, depth in iter_eqns(prog.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        aval = eqn.invars[0].aval
        new_dtype = jnp.dtype(eqn.params["new_dtype"])
        if new_dtype != jnp.dtype(jnp.float32):
            continue
        if jnp.dtype(aval.dtype) not in _STORAGE_DTYPES:
            continue
        shape = tuple(aval.shape)
        if math.prod(shape) < min_elems or not seq_dims & set(shape):
            continue
        if shape in state_shapes:
            # recurrent/routing plane: read-upcast into f32 compute is
            # the designed per-tick path (O(1) state per lane); the
            # storage-width writeback is what check_recurrent_state
            # pins down
            continue
        hits.append({"from": str(aval.dtype), "shape": list(shape),
                     "depth": depth})
    return hits


def check_dtype_planes(programs: list[HotProgram]) -> list[Finding]:
    """One finding per distinct (program, source dtype, shape) upcast —
    narrow enough for a ``staticcheck.toml`` waiver to cover exactly one
    materialization site without masking future leaks — plus one ok
    finding for each clean program."""
    out = []
    for prog in programs:
        if not prog.plane_dims or not prog.cache_dtypes:
            continue
        groups: dict[str, list[dict]] = {}
        for h in _plane_upcasts(prog):
            key = f"{h['from']}{tuple(h['shape'])}"
            groups.setdefault(key, []).append(h)
        if not groups:
            out.append(Finding(
                check=CHECK, subject=prog.name, ok=True,
                detail="no plane-sized f32 upcast",
                data={"cache_dtypes": list(prog.cache_dtypes)}))
            continue
        for key, hits in sorted(groups.items()):
            out.append(Finding(
                check=CHECK, subject=f"{prog.name}:{key}", ok=False,
                detail=(f"{len(hits)} plane-sized f32 upcast(s) of "
                        f"{key} — the pool would stream 4-byte planes"),
                data={"upcasts": hits,
                      "cache_dtypes": list(prog.cache_dtypes)}))
    return out


def check_recurrent_state(engines: list) -> list[Finding]:
    """Recurrent-carry dtype stability: one fused decode tick must hand
    back every cache leaf in the dtype it received it — in particular
    the constant-size recurrent buffers (``ssm``/``mstate``/``sstate``
    lanes), whose blocks compute in f32 and must cast back to storage
    on write. Shape-only (``jax.eval_shape``): nothing runs on device.
    Engines whose spec declares no recurrent state are skipped — their
    planes are covered by the jaxpr upcast walk above."""
    from repro.staticcheck.harness import DECODE_BLOCK
    out = []
    for eng in engines:
        if not eng.spec.recurrent:
            continue
        cfg = eng.model.cfg
        fn = eng._decode_fn(DECODE_BLOCK)
        res = jax.eval_shape(fn, eng.params, eng.cache, eng._tokens,
                             eng._pos, eng._lane_active, eng._lane_out,
                             eng._enc_lens, eng._lane_eos,
                             eng._lane_max)
        new_cache = res[2]   # (tok_blk, emit_blk, cache, ...)
        drift = []
        for (pi, li), (_po, lo) in zip(
                jax.tree_util.tree_leaves_with_path(eng.cache),
                jax.tree_util.tree_leaves_with_path(new_cache)):
            if li.dtype != lo.dtype:
                drift.append(f"{jax.tree_util.keystr(pi)}: "
                             f"{li.dtype} -> {lo.dtype}")
        ok = not drift
        out.append(Finding(
            check=CHECK,
            subject=f"recurrent_state[{cfg.name}|{eng.cache_dtype}]",
            ok=ok,
            detail=("decode carry is a dtype fixed point "
                    f"({'/'.join(eng.spec.recurrent)} state stays "
                    "storage-width)" if ok else
                    "decode tick widens cache leaves: "
                    + "; ".join(drift)),
            data={"recurrent_kinds": list(eng.spec.recurrent),
                  "drift": drift}))
    return out
