"""SC-RECOMP — recompile stability: the serving jit caches must be
keyed so that steady-state traffic never retraces.

Three facts are verified on a live reduced engine:

* the fused decode jit compiles exactly once and is hit by every
  subsequent same-shape tick (``_cache_size() == 1`` after two calls);
* the prefill cache is keyed ``(bucket, enc_s, from_states)``: asking
  for a key twice returns the same function object, a new key adds
  exactly one entry, and two same-shape prefill calls share one
  executable;
* the per-block decode cache (``_decode_fns``) is keyed by block size
  the same way.

A violation here means a tick or admission path retraces per call —
the silent 100x serving regression this check exists to make loud.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.staticcheck.harness import BUCKET, DECODE_BLOCK, ENC_S
from repro.staticcheck.report import Finding

CHECK = "SC-RECOMP"


def _copy(tree):
    return jax.tree.map(jnp.array, tree)


def _decode_args(eng):
    if eng.paged:
        tables = {"self": jnp.array(eng.pages.self_table.device()),
                  "cross": jnp.array(eng.pages.cross_table.device())}
        return (eng.params, _copy(eng.cache), tables,
                jnp.array(eng._tokens), jnp.array(eng._pos),
                jnp.array(eng._lane_active), jnp.array(eng._lane_out),
                eng._enc_lens, eng._lane_eos, eng._lane_max)
    return (eng.params, _copy(eng.cache), jnp.array(eng._tokens),
            jnp.array(eng._pos), jnp.array(eng._lane_active),
            jnp.array(eng._lane_out), eng._enc_lens, eng._lane_eos,
            eng._lane_max)


def check_recompile(eng) -> list[Finding]:
    out = []
    cfg = eng.model.cfg
    ptag = "paged_" if eng.paged else ""
    dtag = f"[{eng.cache_dtype}]" if cfg.enc_dec \
        else f"[{cfg.name}|{eng.cache_dtype}]"
    if eng.spec_k:
        dtag = f"[spec{eng.spec_k}|{eng.cache_dtype}]"
    with warnings.catch_warnings():
        # CPU has no donation support: jit warns per compile; the
        # engine's own paths silence it the same way.
        warnings.simplefilter("ignore")

        # --- fused decode tick ---
        fn = eng._decode_fn(DECODE_BLOCK)
        same = fn is eng._decode_fn(DECODE_BLOCK)
        jax.block_until_ready(fn(*_decode_args(eng)))
        jax.block_until_ready(fn(*_decode_args(eng)))
        n = fn._cache_size()
        ok = same and n == 1
        out.append(Finding(
            check=CHECK,
            subject=f"{ptag}decode_block{dtag}",
            ok=ok,
            detail=(f"2 ticks -> {n} compile(s); keyed lookup "
                    f"{'stable' if same else 'UNSTABLE'}"),
            data={"compiles": n, "keyed_lookup_stable": same}))

        # --- prefill bucket grid ---
        # Recurrent engines prefill at exact prompt length (a zero-pad
        # bucket would fold padding into the end-of-scan state), so
        # their "buckets" are arbitrary lengths; the cache-keying
        # contract is the same.
        bucket = BUCKET if not eng.spec.prefill_exact else BUCKET - 3
        enc = (ENC_S,) if eng.enc_dec else ()
        d_model = cfg.d_model
        n_keys0 = len(eng._prefill_fns)
        pre = eng._prefill_fn(bucket, *enc)
        same = pre is eng._prefill_fn(bucket, *enc)
        grew = len(eng._prefill_fns) - n_keys0
        toks = jnp.zeros((1, bucket), jnp.int32)
        tail = ()
        if eng.enc_dec:
            tail = (jnp.zeros((1, ENC_S, d_model), jnp.float32),)
        if eng.paged:
            # page-vector targets replace the slot index; scratch page 0
            # absorbs both probe writes, so the pool is untouched
            p = eng.page_size
            pv_s = jnp.zeros((eng.max_len // p,), jnp.int32)
            pv_c = jnp.zeros((eng.enc_len // p,), jnp.int32)
            pre_args = [(4, pv_s, pv_c), (5, pv_s, pv_c)]
        else:
            pre_args = [(4, 0), (5, 1)]
        for extra in pre_args:
            jax.block_until_ready(
                pre(eng.params, _copy(eng.cache), toks, *extra, *tail))
        n = pre._cache_size()
        # a second bucket (for exact-length engines: any other prompt
        # length) is a new key — exactly one
        eng._prefill_fn(bucket // 2, *enc)
        grew2 = len(eng._prefill_fns) - n_keys0 - grew
        ok = same and n == 1 and grew <= 1 and grew2 == 1
        out.append(Finding(
            check=CHECK, subject=f"{ptag}prefill{dtag}",
            ok=ok,
            detail=(f"2 same-bucket admits -> {n} compile(s); "
                    f"+{grew2} cache key for a new bucket"),
            data={"compiles": n, "keyed_lookup_stable": same,
                  "new_keys_same_bucket": grew,
                  "new_keys_new_bucket": grew2}))
    return out
