"""Orchestration: build the hot-path harness once, run every check,
apply waivers, return one ``Report``.

Check inventory (IDs are stable — docs, waivers, CI and the JSON
report all key on them):

==========  ==============================================================
SC-DON      every donated hot-path buffer is aliased in-place (no copy)
SC-SYNC     no hidden host transfer inside a compiled hot-path program
SC-AST      source scan: host-sync calls outside the whitelisted inventory
SC-DTYPE    no plane-sized f32 upcast of cache pools; recurrent carry
            dtype-stable across the fused tick
SC-RECOMP   jit caches stable across ticks / admissions / bucket grid
SC-FOOT     registry analytic flops/bytes match the compiled HLO cost
SC-REG      every kernel op is host-servable (backend chain complete)
==========  ==============================================================
"""

from __future__ import annotations

from typing import Optional

from repro.staticcheck.config import StaticcheckConfig, repo_root
from repro.staticcheck.donation import check_donation
from repro.staticcheck.dtypeplanes import check_dtype_planes, \
    check_recurrent_state
from repro.staticcheck.footprint import check_footprint, check_registry
from repro.staticcheck.recompile import check_recompile
from repro.staticcheck.report import Finding, Report
from repro.staticcheck.syncpoints import check_ast_syncs, \
    check_program_sync

ALL_CHECKS = ("SC-DON", "SC-SYNC", "SC-AST", "SC-DTYPE", "SC-RECOMP",
              "SC-FOOT", "SC-REG")
# checks that need traced hot-path programs / a live engine
_PROGRAM_CHECKS = {"SC-DON", "SC-SYNC", "SC-DTYPE"}


def apply_waivers(findings: list[Finding],
                  config: StaticcheckConfig) -> list[Finding]:
    for f in findings:
        if f.ok:
            continue
        w = config.waiver_for(f.check, f.subject)
        if w is not None:
            f.waived = True
            f.waiver_reason = w.reason
    return findings


def run_all(config: Optional[StaticcheckConfig] = None,
            only: Optional[set] = None,
            cache_dtypes: tuple = ("q8_0", "q4_0", "bf16"),
            root: Optional[str] = None) -> Report:
    """Run the selected checks (default: all) and return the Report.
    ``only`` is a set of check IDs; unknown IDs raise."""
    config = config or StaticcheckConfig.load()
    selected = set(only) if only else set(ALL_CHECKS)
    unknown = selected - set(ALL_CHECKS)
    if unknown:
        raise ValueError(f"unknown check IDs: {sorted(unknown)} "
                         f"(known: {list(ALL_CHECKS)})")
    root = root or repo_root()
    findings: list[Finding] = []

    engines, paged_engines, family_engines = [], [], []
    if selected & (_PROGRAM_CHECKS | {"SC-RECOMP"}):
        from repro.staticcheck.harness import (build_engine,
                                               build_family_engines,
                                               build_paged_engine,
                                               build_spec_engine,
                                               hot_programs,
                                               paged_hot_programs)
        engines = [build_engine(cd) for cd in cache_dtypes]
        # the self-speculative draft-verify tick: its donated program
        # carries the q4 draft weights, so SC-DON/SC-SYNC/SC-DTYPE see
        # the draft dequants and the accept-mask rollback logic
        engines.append(build_spec_engine("q4_0"))
        paged_engines = [build_paged_engine(cd) for cd in cache_dtypes]
        # model-zoo coverage: every served family at bf16, plus one
        # q8_0 twin (the MoE arch) so the quantized tier is exercised
        # on a non-whisper family without doubling the engine count
        family_engines = build_family_engines(("bf16",))
        family_engines.append(build_engine("q8_0",
                                           arch="qwen3-moe-30b-a3b"))

    if selected & _PROGRAM_CHECKS:
        programs = []
        for i, eng in enumerate(engines):
            # one frontend trace is enough — it has no cache planes
            programs.extend(hot_programs(eng, frontend=(i == 0)))
        for eng in family_engines:
            programs.extend(hot_programs(eng, frontend=False))
        for eng in paged_engines:
            programs.extend(paged_hot_programs(eng))
        if "SC-DON" in selected:
            findings.extend(check_donation(programs))
        if "SC-SYNC" in selected:
            findings.extend(check_program_sync(programs))
        if "SC-DTYPE" in selected:
            findings.extend(check_dtype_planes(programs))
            findings.extend(check_recurrent_state(family_engines))
    if "SC-AST" in selected:
        findings.extend(check_ast_syncs(root))
    if "SC-RECOMP" in selected:
        for eng in engines + family_engines + paged_engines:
            findings.extend(check_recompile(eng))
    if "SC-FOOT" in selected:
        findings.extend(check_footprint(config))
    if "SC-REG" in selected:
        findings.extend(check_registry())

    apply_waivers(findings, config)
    return Report(findings)


def bench_record() -> dict:
    """The invariant slice ``BENCH_platforms.json`` carries: the cheap
    static checks (no engine execution, no footprint compiles) plus the
    per-function verdict map."""
    rep = run_all(only={"SC-DON", "SC-SYNC", "SC-AST", "SC-DTYPE",
                        "SC-REG"})
    d = rep.to_dict()
    return {"ok": d["ok"], "checks": d["checks"],
            "failed_checks": d["failed_checks"],
            "functions": d["functions"]}
