"""``python -m repro.staticcheck`` — run the hot-path invariant checks.

Exit status is 0 iff every finding passes (or carries a reviewed
waiver); on failure the offending check IDs are named on the last line
and in the process exit. ``--json`` writes the machine-readable report
(CI uploads it as an artifact next to ``BENCH_platforms.json``).
"""

from __future__ import annotations

import argparse
import sys

from repro.staticcheck.config import StaticcheckConfig
from repro.staticcheck.run import ALL_CHECKS, run_all


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.staticcheck",
        description="Static hot-path invariant checker.")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="emit the JSON report to PATH ('-' = stdout)")
    ap.add_argument("--only", default=None, metavar="IDS",
                    help="comma-separated check IDs "
                         f"(default: all of {','.join(ALL_CHECKS)})")
    ap.add_argument("--config", default=None, metavar="PATH",
                    help="staticcheck.toml path (default: repo root)")
    ap.add_argument("--verbose", action="store_true",
                    help="list passing findings too")
    ap.add_argument("--list", action="store_true",
                    help="list check IDs and exit")
    args = ap.parse_args(argv)

    if args.list:
        for c in ALL_CHECKS:
            print(c)
        return 0

    only = set(args.only.split(",")) if args.only else None
    config = StaticcheckConfig.load(args.config)
    report = run_all(config=config, only=only)

    if args.json == "-":
        print(report.to_json())
    else:
        print(report.human(verbose=args.verbose))
        if args.json:
            with open(args.json, "w") as fh:
                fh.write(report.to_json())
            print(f"wrote {args.json}")
    if not report.ok:
        print(f"FAILED CHECKS: {', '.join(report.failed_checks())}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
