"""SC-SYNC / SC-AST — the sync-point budget.

The serving loop's contract (PR 5/6) is *exactly one* host sync per
fused decode tick: the single ``jax.device_get`` in
``ServeEngine.step_fetch``. Two static passes keep that true:

* **SC-SYNC** — the compiled per-tick programs must contain no hidden
  host transfer: no callback primitives in any jaxpr (scan/while bodies
  included) and no host callback custom-calls / infeed / outfeed in the
  lowered text. Anything that round-trips to Python mid-program would
  serialize the device pipeline.

* **SC-AST** — a source-level scan of ``serving/``, ``gateway/`` and
  ``models/`` for host-sync-inducing calls: ``float(x)``,
  ``np.asarray``/``np.array``, ``.block_until_ready()``,
  ``jax.device_get``. Every hit must either be in the built-in sync
  inventory (the one per-tick fetch) or carry a reviewed waiver in
  ``staticcheck.toml``.
"""

from __future__ import annotations

import ast
import os

from repro.staticcheck.harness import HotProgram
from repro.staticcheck.jaxpr_utils import iter_eqns
from repro.staticcheck.report import Finding

CHECK_PROGRAM = "SC-SYNC"
CHECK_AST = "SC-AST"

# Primitives that re-enter Python / the host from inside a traced
# program (jax names across versions; matched by exact name or a
# "callback" substring).
_SYNC_PRIMITIVES = {"infeed", "outfeed", "io_callback", "pure_callback",
                    "callback", "debug_callback", "python_callback"}
# Lowered-text markers of the same (host callbacks lower to
# custom_call @xla_python_*_callback; infeed/outfeed lower to their ops)
_SYNC_TEXT = ("callback", "stablehlo.infeed", "stablehlo.outfeed")

# The whitelisted sync inventory: sites that ARE the sync budget (the
# one per-tick fetch) or reviewed off-tick diagnostics. Each entry is
# (path suffix, qualname, call).
SYNC_INVENTORY = [
    ("serving/engine.py", "ServeEngine.step_fetch", "jax.device_get"),
    # MoE expert-load diagnostic: explicit operator call, never on the
    # per-tick decode path
    ("serving/engine.py", "ServeEngine.routing_report", "jax.device_get"),
]

SCAN_DIRS = ("src/repro/serving", "src/repro/gateway",
             "src/repro/models", "src/repro/paging")


def check_program_sync(programs: list[HotProgram]) -> list[Finding]:
    out = []
    for prog in programs:
        hits = []
        for eqn, depth in iter_eqns(prog.jaxpr):
            name = eqn.primitive.name
            if name in _SYNC_PRIMITIVES or "callback" in name:
                hits.append(f"{name} (depth {depth})")
        for marker in _SYNC_TEXT:
            if marker == "callback":
                if "custom_call" in prog.stablehlo and \
                        "callback" in prog.stablehlo:
                    hits.append("custom_call callback in lowered text")
            elif marker in prog.stablehlo:
                hits.append(marker)
        ok = not hits
        out.append(Finding(
            check=CHECK_PROGRAM, subject=prog.name, ok=ok,
            detail=("no host transfer inside the compiled program"
                    if ok else "hidden host transfer: "
                    + "; ".join(sorted(set(hits)))),
            data={"hits": sorted(set(hits))}))
    return out


# ---------------------------------------------------------------- AST pass

class _SyncCallScanner(ast.NodeVisitor):
    def __init__(self):
        self.stack: list[str] = []
        self.hits: list[tuple[int, str, str]] = []  # (line, qualname, call)

    def _qual(self) -> str:
        return ".".join(self.stack) or "<module>"

    def visit_ClassDef(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def visit_FunctionDef(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node):
        call = _classify_call(node.func)
        if call is not None:
            self.hits.append((node.lineno, self._qual(), call))
        self.generic_visit(node)


def _classify_call(func: ast.expr):
    if isinstance(func, ast.Name) and func.id == "float":
        return "float"
    if isinstance(func, ast.Attribute):
        if func.attr == "block_until_ready":
            return ".block_until_ready"
        if isinstance(func.value, ast.Name):
            base = func.value.id
            if base in ("np", "numpy") and func.attr in ("asarray",
                                                         "array"):
                return f"np.{func.attr}"
            if base == "jax" and func.attr == "device_get":
                return "jax.device_get"
    return None


def scan_source(path: str, src: str, relpath: str = "") -> list[Finding]:
    """Scan one module's source for host-sync-inducing calls. Inventory
    sites report ok; everything else is a violation until waived."""
    rel = relpath or path
    tree = ast.parse(src, filename=path)
    scanner = _SyncCallScanner()
    scanner.visit(tree)
    out = []
    for line, qual, call in scanner.hits:
        inventoried = any(
            rel.endswith(suffix) and qual == q and call == c
            for suffix, q, c in SYNC_INVENTORY)
        subject = f"{rel}:{qual}:{call}"
        out.append(Finding(
            check=CHECK_AST, subject=subject, ok=inventoried,
            detail=(f"line {line}: {call}() "
                    + ("— whitelisted sync inventory" if inventoried
                       else "outside the sync inventory")),
            data={"line": line, "call": call}))
    return out


def check_ast_syncs(root: str) -> list[Finding]:
    out = []
    for d in SCAN_DIRS:
        full = os.path.join(root, d)
        if not os.path.isdir(full):
            continue
        for dirpath, _dirs, files in os.walk(full):
            for fname in sorted(files):
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, root)
                with open(path, "r") as fh:
                    out.extend(scan_source(path, fh.read(), rel))
    return out
