"""``staticcheck.toml``: waivers and footprint tolerances.

The file lives at the repo root and records every *intentional*
exception to the invariants, each with a reason — so a new violation
can only land by editing a reviewed file, never silently.

Format::

    schema = 1

    [[waivers]]
    check = "SC-AST"                           # check ID the waiver applies to
    subject = "src/repro/gateway/metrics.py:*" # pattern on the subject; * is the wildcard
    reason = "host-side wall-clock metrics; no device arrays here"

    [footprint]                 # SC-FOOT default tolerance bands
    flops_ratio = [0.5, 3.0]    # measured/analytic must fall inside
    bytes_ratio = [0.2, 12.0]

    [footprint.ops.flash_attention]   # per-op override
    bytes_ratio = [0.2, 24.0]
"""

from __future__ import annotations

import dataclasses
import os
import re
from typing import Optional

try:  # Python 3.11+
    import tomllib
except ModuleNotFoundError:  # pragma: no cover - 3.10 container
    import tomli as tomllib  # type: ignore[no-redef]

DEFAULT_FLOPS_RATIO = (0.5, 3.0)
DEFAULT_BYTES_RATIO = (0.2, 12.0)


def repo_root() -> str:
    """The repo root: nearest ancestor of this file with pyproject.toml,
    falling back to the current directory."""
    d = os.path.dirname(os.path.abspath(__file__))
    while True:
        if os.path.exists(os.path.join(d, "pyproject.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.getcwd()
        d = parent


def _pattern_match(pattern: str, subject: str) -> bool:
    """Literal match with ``*`` as the only wildcard. Deliberately not
    fnmatch: subjects contain ``[q8_0]``-style brackets that fnmatch
    would read as character classes."""
    rx = ".*".join(re.escape(part) for part in pattern.split("*"))
    return re.fullmatch(rx, subject) is not None


@dataclasses.dataclass(frozen=True)
class Waiver:
    check: str
    subject: str          # literal pattern, '*' matches any run of chars
    reason: str

    def matches(self, check: str, subject: str) -> bool:
        return self.check == check and _pattern_match(self.subject,
                                                      subject)


@dataclasses.dataclass
class StaticcheckConfig:
    waivers: list[Waiver] = dataclasses.field(default_factory=list)
    flops_ratio: tuple[float, float] = DEFAULT_FLOPS_RATIO
    bytes_ratio: tuple[float, float] = DEFAULT_BYTES_RATIO
    op_ratios: dict[str, dict[str, tuple[float, float]]] = \
        dataclasses.field(default_factory=dict)
    path: str = ""

    @classmethod
    def load(cls, path: Optional[str] = None) -> "StaticcheckConfig":
        """Parse ``staticcheck.toml`` (default: repo root). A missing
        file yields the built-in defaults with no waivers."""
        if path is None:
            path = os.path.join(repo_root(), "staticcheck.toml")
        cfg = cls(path=path)
        if not os.path.exists(path):
            return cfg
        with open(path, "rb") as fh:
            doc = tomllib.load(fh)
        for w in doc.get("waivers", []):
            cfg.waivers.append(Waiver(check=str(w["check"]),
                                      subject=str(w["subject"]),
                                      reason=str(w.get("reason", ""))))
        foot = doc.get("footprint", {})
        if "flops_ratio" in foot:
            cfg.flops_ratio = tuple(foot["flops_ratio"])  # type: ignore
        if "bytes_ratio" in foot:
            cfg.bytes_ratio = tuple(foot["bytes_ratio"])  # type: ignore
        for op, band in foot.get("ops", {}).items():
            cfg.op_ratios[op] = {k: tuple(v) for k, v in band.items()}
        return cfg

    def waiver_for(self, check: str, subject: str) -> Optional[Waiver]:
        for w in self.waivers:
            if w.matches(check, subject):
                return w
        return None

    def ratio_band(self, op: str, kind: str) -> tuple[float, float]:
        """Tolerance band for ``kind`` in {"flops_ratio", "bytes_ratio"}
        for op ``op`` (per-op override, else the default)."""
        band = self.op_ratios.get(op, {}).get(kind)
        if band is not None:
            return band
        return self.flops_ratio if kind == "flops_ratio" else \
            self.bytes_ratio
