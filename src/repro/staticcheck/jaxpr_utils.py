"""Shared jaxpr / StableHLO inspection helpers.

The checks reason over two static artifacts per hot-path program:

* the **jaxpr** (``jitted.trace(*args).jaxpr``) — a complete primitive
  graph including every scan/while/cond body, which is where dtype
  converts and callback primitives are visible; and
* the **StableHLO text** (``traced.lower().as_text()``) — where jit
  donation shows up as per-parameter ``tf.aliasing_output`` attributes
  (XLA's ``input_output_aliases``), the same marker the dynamic tests
  in ``tests/test_decode_fused.py`` assert on.
"""

from __future__ import annotations

import re
from typing import Any, Iterator

_ALIAS_ATTR = "tf.aliasing_output"
# %argN ... tf.aliasing_output = M : i32 — nothing between an argument
# and its attribute dict contains a '%', so [^%]* cannot cross into the
# next parameter.
_ALIAS_RE = re.compile(r"%arg(\d+):[^%]*?tf\.aliasing_output\s*=\s*(\d+)")


def iter_eqns(jaxpr: Any, depth: int = 0) -> Iterator[tuple[Any, int]]:
    """Yield ``(eqn, depth)`` for every equation in ``jaxpr`` and every
    nested sub-jaxpr (scan/while/cond bodies, inner pjit calls)."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr -> Jaxpr
    for eqn in inner.eqns:
        yield eqn, depth
        for sub in sub_jaxprs(eqn):
            yield from iter_eqns(sub, depth + 1)


def sub_jaxprs(eqn: Any) -> list[Any]:
    """Jaxprs nested in one equation's params (any primitive)."""
    out = []
    for v in eqn.params.values():
        for x in (v if isinstance(v, (list, tuple)) else [v]):
            if hasattr(x, "eqns") or hasattr(x, "jaxpr"):
                out.append(x)
    return out


def alias_count(stablehlo_text: str) -> int:
    """Number of entry parameters carrying a ``tf.aliasing_output``
    attribute — i.e. donated buffers XLA will update in place."""
    return stablehlo_text.count(_ALIAS_ATTR)


def arg_aliases(stablehlo_text: str) -> dict[int, int]:
    """{entry arg index -> aliased output index} from the StableHLO
    main signature."""
    return {int(m.group(1)): int(m.group(2))
            for m in _ALIAS_RE.finditer(stablehlo_text)}


def eqn_dtypes(eqn: Any) -> tuple[Any, Any, tuple]:
    """(input dtype, output dtype, input shape) of a unary equation —
    the slice ``convert_element_type`` checks need."""
    aval = eqn.invars[0].aval
    return aval.dtype, eqn.outvars[0].aval.dtype, tuple(aval.shape)
