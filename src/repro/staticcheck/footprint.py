"""SC-FOOT / SC-REG — the kernel registry's analytic footprints,
cross-checked against what XLA actually emits.

For every registered op, a representative call is built, its
``KernelSpec`` taken from the registry's own spec builder, and the op's
host backend compiled; the while-aware HLO cost model
(``analysis.hlo.analyze_jit``) then measures the program's flops and
HBM bytes. The measured/analytic ratios must sit inside the tolerance
bands in ``staticcheck.toml`` — a spec that drifts from the code it
describes (stale ``count``, wrong contraction dims) corrupts every
downstream energy/PDP figure, which is exactly the ROADMAP's "measured
HLO cost model" concern.

SC-REG additionally requires each op to be host-servable: at least one
backend in its ``host_order`` chain must be registered, so a
pallas-less platform can always execute the op.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze_jit
from repro.staticcheck.config import StaticcheckConfig
from repro.staticcheck.report import Finding

CHECK_FOOT = "SC-FOOT"
CHECK_REG = "SC-REG"

# Analytic stream model per spec: stationary operand (weights / cached
# plane) in the storage dtype, activations in the 2-byte compute dtype,
# f32 accumulator out — the LMM traffic convention the energy
# accounting uses.
_ELEM_BYTES = {"f16": 2.0, "bf16": 2.0, "f32": 4.0,
               "q8_0": 1.0 + 2.0 / 32.0}


def spec_stream_bytes(spec) -> float:
    eb = _ELEM_BYTES.get(spec.dtype, 4.0)
    stationary = spec.n * spec.k * eb
    moving = spec.m * spec.k * 2.0
    out = spec.m * spec.n * 4.0
    return spec.count * (stationary + moving + out)


def representative_calls() -> dict[str, tuple[tuple, dict]]:
    """(args, kwargs) per builtin op: small shapes in each op's real
    serving layout (GQA planes, q8 pools, scanned recurrences)."""
    from repro.core.quantize import quantize_q8_0

    key = jax.random.key(0)
    x8 = jax.random.normal(key, (8, 256), jnp.float32)
    w8 = quantize_q8_0(jax.random.normal(key, (256, 128)), axis=0)
    xf = jax.random.normal(key, (8, 128), jnp.bfloat16)
    wf = jax.random.normal(key, (128, 128), jnp.bfloat16)
    q = jax.random.normal(key, (2, 64, 4, 32), jnp.bfloat16)
    kv = jax.random.normal(key, (2, 64, 2, 32), jnp.bfloat16)
    dq = jax.random.normal(key, (8, 1, 32), jnp.float32)
    kq = jax.random.randint(key, (8, 64, 32), -127, 127, jnp.int8)
    ks = jnp.full((8, 64, 1), 0.02, jnp.float16)
    length = jnp.full((8,), 48, jnp.int32)
    wx = jax.random.normal(key, (16, 4, 2, 2, 16), jnp.float32)
    r = jax.random.normal(key, (4, 2, 16, 16), jnp.float32) * 0.1
    s0 = jnp.zeros((4, 2, 2, 16), jnp.float32)
    # paged pool planes: (n_pages, P, Hkv, D) + per-lane page tables
    # (B, n_lp) reassembling 8 logical pages of 8 — the reduced serving
    # geometry (4 lanes x max_len 64 + scratch page 0)
    pq = jax.random.normal(key, (4, 1, 4, 32), jnp.bfloat16)
    plane = jax.random.normal(key, (33, 8, 2, 32), jnp.bfloat16)
    table = jax.random.randint(jax.random.key(1), (4, 8), 1, 33,
                               jnp.int32)
    plens = jnp.full((4,), 48, jnp.int32)
    return {
        "q8_matmul": ((x8, w8), {}),
        "fp16_matmul": ((xf, wf), {}),
        "flash_attention": ((q, kv, kv), {"causal": True}),
        "q8_decode_attention": ((dq, kq, ks, kq, ks, length), {}),
        "paged_decode_attention": ((pq, plane, plane, table, plens), {}),
        "slstm_scan": ((wx, r, s0), {}),
    }


def _host_backend(op) -> Optional[str]:
    for b in op.host_order:
        if b in op.backends:
            return b
    return None


def check_registry(op_names: Optional[list[str]] = None) -> list[Finding]:
    from repro.kernels import registry

    out = []
    for name in (op_names or registry.list_ops()):
        op = registry.get_op(name)
        host = _host_backend(op)
        ok = host is not None
        out.append(Finding(
            check=CHECK_REG, subject=name, ok=ok,
            detail=(f"host-servable via '{host}' backend" if ok else
                    f"no host backend: host_order={op.host_order}, "
                    f"registered={sorted(op.backends)}"),
            data={"backends": sorted(op.backends),
                  "host_backend": host}))
    return out


def check_footprint(config: StaticcheckConfig,
                    op_names: Optional[list[str]] = None,
                    reps: Optional[dict] = None) -> list[Finding]:
    from repro.kernels import registry
    from repro.kernels.api import current_context

    reps = reps if reps is not None else representative_calls()
    ctx = current_context()
    out = []
    for name in (op_names or registry.list_ops()):
        if name not in reps:
            continue
        op = registry.get_op(name)
        backend = _host_backend(op)
        if backend is None:
            continue  # SC-REG reports this
        args, kwargs = reps[name]
        spec = op.spec(*args, **kwargs)
        fn = op.backends[backend]
        measured = analyze_jit(lambda *a: fn(ctx, *a, **kwargs), *args)
        a_flops = float(spec.flops)
        a_bytes = spec_stream_bytes(spec)
        rf = measured.flops / a_flops if a_flops else math.inf
        rb = measured.bytes / a_bytes if a_bytes else math.inf
        f_lo, f_hi = config.ratio_band(name, "flops_ratio")
        b_lo, b_hi = config.ratio_band(name, "bytes_ratio")
        ok = f_lo <= rf <= f_hi and b_lo <= rb <= b_hi
        out.append(Finding(
            check=CHECK_FOOT, subject=name, ok=ok,
            detail=(f"[{backend}] measured/analytic flops {rf:.2f}x "
                    f"(band [{f_lo}, {f_hi}]), bytes {rb:.2f}x "
                    f"(band [{b_lo}, {b_hi}])"),
            data={"backend": backend, "flops_ratio": rf,
                  "bytes_ratio": rb,
                  "analytic": {"flops": a_flops, "bytes": a_bytes,
                               "spec": {"m": spec.m, "n": spec.n,
                                        "k": spec.k,
                                        "count": spec.count,
                                        "dtype": spec.dtype}},
                  "measured": {"flops": measured.flops,
                               "bytes": measured.bytes}}))
    return out
