"""Findings and reports for the hot-path invariant checker.

A ``Finding`` is one (check, subject) verdict; a ``Report`` is the
ordered collection for one run. Reports render two ways: a human
console summary and a machine-readable JSON document (the artifact CI
uploads next to ``BENCH_platforms.json``).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional


@dataclasses.dataclass
class Finding:
    """One verdict: ``check`` is the check ID (``SC-DON`` ...),
    ``subject`` names what was checked (a hot-path program, an op, or a
    ``path:qualname:call`` source site)."""

    check: str
    subject: str
    ok: bool
    detail: str = ""
    waived: bool = False
    waiver_reason: str = ""
    data: dict = dataclasses.field(default_factory=dict)

    @property
    def passed(self) -> bool:
        return self.ok or self.waived

    def to_dict(self) -> dict:
        d = {"check": self.check, "subject": self.subject, "ok": self.ok,
             "detail": self.detail}
        if self.waived:
            d["waived"] = True
            d["waiver_reason"] = self.waiver_reason
        if self.data:
            d["data"] = _jsonable(self.data)
        return d


def _jsonable(x: Any) -> Any:
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (bool, int, str)) or x is None:
        return x
    if isinstance(x, float):
        return round(x, 6)
    return str(x)


@dataclasses.dataclass
class Report:
    findings: list[Finding] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(f.passed for f in self.findings)

    def failed_checks(self) -> list[str]:
        """Sorted unique check IDs with at least one unwaived failure."""
        return sorted({f.check for f in self.findings if not f.passed})

    def by_check(self) -> dict[str, list[Finding]]:
        out: dict[str, list[Finding]] = {}
        for f in self.findings:
            out.setdefault(f.check, []).append(f)
        return out

    def check_ok(self, check: str) -> Optional[bool]:
        fs = [f for f in self.findings if f.check == check]
        if not fs:
            return None
        return all(f.passed for f in fs)

    def function_verdicts(self) -> dict[str, dict[str, bool]]:
        """Per hot-path program, the invariant verdicts that have a
        per-function meaning (donation / sync-free / dtype planes) —
        the slice ``BENCH_platforms.json`` records."""
        invariant = {"SC-DON": "donation", "SC-SYNC": "sync_free",
                     "SC-DTYPE": "dtype_planes"}
        out: dict[str, dict[str, bool]] = {}
        for f in self.findings:
            key = invariant.get(f.check)
            if key is None:
                continue
            # SC-DTYPE subjects may carry a per-shape suffix
            # ("prog:int8(...)"); verdicts aggregate per program.
            func = f.subject.split(":", 1)[0]
            d = out.setdefault(func, {})
            d[key] = bool(f.passed) and d.get(key, True)
        return out

    def to_dict(self) -> dict:
        checks = {c: all(f.passed for f in fs)
                  for c, fs in self.by_check().items()}
        return {
            "schema": 1,
            "ok": self.ok,
            "checks": checks,
            "failed_checks": self.failed_checks(),
            "functions": self.function_verdicts(),
            "findings": [f.to_dict() for f in self.findings],
        }

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def human(self, verbose: bool = False) -> str:
        lines = []
        for check, fs in sorted(self.by_check().items()):
            n_fail = sum(not f.passed for f in fs)
            n_waiv = sum(f.waived for f in fs)
            mark = "PASS" if n_fail == 0 else "FAIL"
            extra = f", {n_waiv} waived" if n_waiv else ""
            lines.append(f"[{mark}] {check}: {len(fs)} finding(s){extra}")
            for f in fs:
                if f.passed and not verbose:
                    continue
                status = ("waived" if f.waived
                          else "ok" if f.ok else "VIOLATION")
                lines.append(f"    {status:9s} {f.subject}  {f.detail}")
                if f.waived and f.waiver_reason:
                    lines.append(f"              reason: {f.waiver_reason}")
        verdict = "OK" if self.ok else (
            "FAILED: " + ", ".join(self.failed_checks()))
        lines.append(f"staticcheck: {verdict}")
        return "\n".join(lines)
