"""repro — JAX/Pallas reproduction of "Energy-Efficient Hardware
Acceleration of Whisper ASR on a CGLA".

End-user entry points re-exported lazily (importing ``repro`` stays
cheap; jax loads on first use)::

    from repro import transcribe
    result = transcribe(samples, 16_000, platform="imax3-28nm")
"""

__all__ = ["TranscribeResult", "transcribe"]


def __getattr__(name):
    if name in __all__:
        import importlib
        mod = importlib.import_module("repro.audio.transcribe")
        return getattr(mod, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
