"""Attention block: GQA/SWA/local-global/softcap/qk-norm, three modes.

Train/prefill route through ``kernels.api.dispatch("flash_attention")``:
the ACCEL/HOST control law picks the Pallas flash kernel or the chunked
online-softmax scan below (its XLA binding — DESIGN.md §7), so
32k-prefill cells never materialize S×S scores either way. Decode
updates a KV cache in place and runs the matvec path. Sharding is
expressed through logical-axis constraints; the head-vs-context-parallel
fallback is decided by the rules (sharding.py).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import flags
from repro.configs import ArchConfig
from repro.core.quantize import QBLOCK, quantize_q4_0, quantize_q8_0
from repro.kernels.api import dispatch
from repro.models.layers import (KeyGen, Param, mm, mm_out, ninit, rmsnorm,
                                 rope)
from repro.parallel.sharding import constrain

NEG_INF = -1e30
DEFAULT_CHUNK = 512


def init_attention(keys: KeyGen, cfg: ArchConfig) -> dict:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": Param(ninit(keys(), (d, h, dh), d), ("param_embed", "heads", "head_dim")),
        "wk": Param(ninit(keys(), (d, hk, dh), d), ("param_embed", "kv_heads", "head_dim")),
        "wv": Param(ninit(keys(), (d, hk, dh), d), ("param_embed", "kv_heads", "head_dim")),
        "wo": Param(ninit(keys(), (h, dh, d), h * dh), ("heads", "head_dim", "param_embed")),
    }
    if cfg.attn_bias:
        p["bq"] = Param(jnp.zeros((h, dh), jnp.float32), ("heads", "head_dim"))
        p["bk"] = Param(jnp.zeros((hk, dh), jnp.float32), ("kv_heads", "head_dim"))
        p["bv"] = Param(jnp.zeros((hk, dh), jnp.float32), ("kv_heads", "head_dim"))
    if cfg.qk_norm:
        p["q_norm"] = Param(jnp.ones((dh,), jnp.float32), ("head_dim",))
        p["k_norm"] = Param(jnp.ones((dh,), jnp.float32), ("head_dim",))
    return p


def init_cross_attention(keys: KeyGen, cfg: ArchConfig) -> dict:
    return init_attention(keys, cfg)


def _project_qkv(p: dict, x: jax.Array, cfg: ArchConfig,
                 x_kv: Optional[jax.Array] = None):
    wq, wk, wv = p["wq"], p["wk"], p["wv"]
    if x_kv is None and isinstance(wq, jax.Array):
        # self-attention with plain (non-Q8) weights: one fused QKV dot
        # over the head-concatenated weight instead of three — fewer
        # kernel launches on the decode hot path (the per-element
        # contraction is unchanged, so the split results are
        # bit-identical to three separate projections).
        h, hk = cfg.n_heads, cfg.n_kv_heads
        y = mm(x, jnp.concatenate([wq, wk, wv], axis=1))
        q, k, v = y[..., :h, :], y[..., h:h + hk, :], y[..., h + hk:, :]
    else:
        x_kv = x if x_kv is None else x_kv
        q = mm(x, wq)
        k = mm(x_kv, wk)
        v = mm(x_kv, wv)
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    hk = k.shape[2]
    if hk == n_heads:
        return k
    return jnp.repeat(k, n_heads // hk, axis=2)


def _window_for(cfg: ArchConfig, kind: str) -> Optional[int]:
    if kind == "local":
        return cfg.local_window
    if cfg.sliding_window is not None and kind != "bidir":
        return cfg.sliding_window
    return None


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, window: Optional[int],
                      softcap: Optional[float],
                      q_offset=0,
                      chunk: int = DEFAULT_CHUNK) -> jax.Array:
    """Online-softmax over KV chunks. q: (B,Sq,H,D); k,v: (B,Sk,H,D).
    ``q_offset``: absolute position of q[0] (decode/chunked prefill)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = d ** -0.5
    # C1-inline: operands stay bf16; the MXU upconverts in-core and
    # accumulates f32 (no HBM-materialized f32 copies of Q/K/V/P).
    # (REPRO_BASELINE=1: pre-hillclimb f32-in-HBM upcasts.)
    cdt = jnp.float32 if flags.BASELINE else jnp.bfloat16
    qf = q.astype(cdt)
    chunk = min(chunk, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, h, d)
    vc = v.reshape(b, n_chunks, chunk, h, d)

    qpos = q_offset + jnp.arange(sq)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(cdt),
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < sk  # chunk padding
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(cdt), vb.astype(cdt),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         jnp.arange(n_chunks)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).transpose(0, 2, 1, 3)  # (B,Sq,H,D)
    return out.astype(q.dtype)


def attention(p: dict, x: jax.Array, cfg: ArchConfig, *,
              kind: str = "global", mode: str = "train",
              cache: Optional[dict] = None, pos=None,
              x_kv: Optional[jax.Array] = None,
              positions: Optional[jax.Array] = None,
              use_rope: bool = True,
              layer_idx=None,
              kv_lens=None,
              page_table=None):
    """Returns (y, new_cache). Modes:
      train   — full-sequence, no cache
      prefill — full-sequence, fills and returns cache
      decode  — x is (B, 1, d); cache holds (k, v) of length max_len;
                ``pos`` is the current absolute position (scalar int32)
    ``kind``: global | local | bidir. Cross-attention passes x_kv (encoder
    states) in prefill and reuses cached cross K/V in decode.

    ``layer_idx`` (decode only): the cache is the whole STACKED
    (L, B, S, Hkv, D) tree carried through the layer scan; this layer
    writes its one new token in place at (layer_idx, :, pos) — a
    token-sized dynamic-update-slice instead of re-materializing the full
    per-layer cache through the scan's output stacking (§Perf cell C:
    the baseline rewrote the entire KV cache every decode step).

    ``kv_lens`` (decode, cross-attention): per-lane valid KV lengths —
    serving pads encoder states to the pool's ``enc_len``, so lane b
    attends cached cross K/V positions ``[0, kv_lens[b])`` only.

    A cache produced with ``dtype="q8_0"`` (``init_kv_cache`` /
    ``quantize_kv_cache``) stores ``{kq, ks, vq, vs}`` planes; decode
    quantizes the new token in place and reads the cache through
    ``dispatch("q8_decode_attention", ...)`` — the paper's Q8_0 LOAD
    saving applied to the decode-cache stream (~0.53x bf16 bytes).

    ``page_table`` (decode, stacked only): the cache planes are a shared
    page *pool* ``(L, n_pages, P, Hkv, ·)`` instead of per-lane rows;
    ``page_table`` (B, n_lp) int32 maps lane b's logical page i to a
    physical pool page (``repro.paging``). The new token is scattered at
    ``(layer_idx, table[b, pos//P], pos % P)`` and the matvec runs
    through ``dispatch("paged_decode_attention", ...)`` — a gather over
    the table followed by the exact dense decode chain, so paged output
    is bit-identical to the slot pool's whenever the page content
    matches.
    """
    b, s, _ = x.shape
    h = cfg.n_heads
    causal = kind != "bidir" and x_kv is None
    window = _window_for(cfg, kind)
    softcap = cfg.attn_softcap

    if mode in ("train", "prefill"):
        q, k, v = _project_qkv(p, x, cfg, x_kv)
        if use_rope and x_kv is None:
            if positions is None:
                positions = jnp.broadcast_to(jnp.arange(s), (b, s))
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        q = constrain(q, "batch", "q_seq", "heads", "head_dim")
        k = constrain(k, "batch", "kv_seq", "kv_heads", "head_dim")
        v = constrain(v, "batch", "kv_seq", "kv_heads", "head_dim")
        # dispatched: the control law binds this to the Pallas flash
        # kernel (ACCEL) or the chunked online-softmax below (HOST/XLA).
        # k/v pass through un-repeated; every backend is GQA-aware.
        out = dispatch("flash_attention", q, k, v, causal=causal,
                       window=window, softcap=softcap)
        new_cache = None
        if mode == "prefill":
            new_cache = _write_prefill_cache(cache, k, v)
        y = mm_out(out, p["wo"])
        return constrain(y, "batch", "q_seq", "embed"), new_cache

    assert mode == "decode" and cache is not None
    # ``pos`` may be a scalar (lockstep decode; all the dry-run decode
    # cells) or a (B,) vector (continuous batching: each serving slot at
    # its own position — serving/engine.py). ``x`` may carry Q > 1 tokens
    # per lane (the speculative verify forward): token j sits at absolute
    # position pos + j and attends cache positions [0, pos + j].
    pos_v = jnp.asarray(pos, jnp.int32)
    per_lane = pos_v.ndim == 1
    pos_b = pos_v if per_lane else jnp.broadcast_to(pos_v, (b,))
    nq = s
    posq = pos_b[:, None] + jnp.arange(nq)[None, :]      # (B, Q)
    stacked = layer_idx is not None
    q8 = is_q8_cache(cache)
    q4 = is_q4_cache(cache)
    quant = q8 or q4
    tier = "q4_0" if q4 else "q8_0"
    if quant and (softcap is not None or window is not None):
        raise NotImplementedError(
            f"{tier} KV-cache decode supports plain softmax attention "
            "only (no attn_softcap / sliding window)")
    if quant and not stacked:
        raise NotImplementedError(
            f"{tier} KV-cache decode requires the stacked cache path "
            "(REPRO_BASELINE=1 serves bf16 caches only)")
    if page_table is not None and (not stacked or softcap is not None
                                   or window is not None):
        raise NotImplementedError(
            "paged KV-cache decode requires the stacked cache path and "
            "plain softmax attention (no softcap / sliding window)")
    if x_kv is None:
        q, k_new, v_new = _project_qkv(p, x, cfg)
        if use_rope:
            q = rope(q, posq, cfg.rope_theta)
            k_new = rope(k_new, posq, cfg.rope_theta)
        # read depths: token j attends [0, pos + j]. Q == 1 keeps the
        # (B,) form so the single-query Pallas decode kernels stay
        # eligible; Q > 1 passes per-query (B, Q) depths through to the
        # multi-query XLA backends.
        read_lens = pos_b + 1 if nq == 1 else posq + 1
        if page_table is not None:
            # paged pool: scatter token j per lane at
            # (layer_idx, table[b, (pos+j) // P], (pos+j) % P). Parked
            # lanes' table rows all point at the scratch page (0), so
            # their writes can never corrupt an allocated page; the
            # logical page index is clipped for frozen lanes sitting at
            # the end of their extent (their writes land inside their own
            # extent and are never read back).
            psz = (cache["kq"] if q8 else
                   cache["kp"] if q4 else cache["k"]).shape[2]
            n_lp = page_table.shape[1]

            def updp(c, new):
                for j in range(nq):
                    pj = pos_b + j
                    lp = jnp.minimum(pj // psz, n_lp - 1)
                    phys = jnp.take_along_axis(
                        page_table, lp[:, None], axis=1)[:, 0]
                    c = c.at[layer_idx, phys, pj % psz].set(
                        new[:, j].astype(c.dtype))
                return c
            if quant:
                qz = quantize_q4_0 if q4 else quantize_q8_0
                kt = qz(k_new, axis=-1)
                vt = qz(v_new, axis=-1)
                kk, vk = ("kp", "vp") if q4 else ("kq", "vq")
                new_cache = {kk: updp(cache[kk], kt.q),
                             "ks": updp(cache["ks"], kt.scale),
                             vk: updp(cache[vk], vt.q),
                             "vs": updp(cache["vs"], vt.scale)}
            else:
                new_cache = {"k": updp(cache["k"], k_new),
                             "v": updp(cache["v"], v_new)}
            out = _paged_cache_attention(q, new_cache, layer_idx,
                                         page_table, read_lens)
            y = mm_out(out.astype(x.dtype), p["wo"])
            return constrain(y, "batch", None, "embed"), new_cache
        if stacked:
            # slab-sized in-place write into the (L,B,S,Hkv,D) stack
            def upd5(c, new):
                if not per_lane:
                    # one DUS, update (1, B, Q, Hkv, D) — lowers to an
                    # in-place slab write (no scatter, no transpose)
                    return jax.lax.dynamic_update_slice(
                        c, new[None, :].astype(c.dtype),
                        (layer_idx, 0, pos_v, 0, 0))
                return _per_lane_write(c, new, layer_idx, pos_b)
            if quant:
                # quantize the new token slab and write its code+scale
                # planes in place; the cache matvec then runs through
                # the dispatched q8/q4_decode_attention kernel.
                qz = quantize_q4_0 if q4 else quantize_q8_0
                kt = qz(k_new, axis=-1)
                vt = qz(v_new, axis=-1)
                kk, vk = ("kp", "vp") if q4 else ("kq", "vq")
                new_cache = {kk: upd5(cache[kk], kt.q),
                             "ks": upd5(cache["ks"], kt.scale),
                             vk: upd5(cache[vk], vt.q),
                             "vs": upd5(cache["vs"], vt.scale)}
                out = _quant_cache_attention(q, new_cache, layer_idx,
                                             read_lens)
                y = mm_out(out.astype(x.dtype), p["wo"])
                return constrain(y, "batch", None, "embed"), new_cache
            k_cache = upd5(cache["k"], k_new)
            v_cache = upd5(cache["v"], v_new)
            new_cache = {"k": k_cache, "v": v_cache}
            k_layer = jax.lax.dynamic_index_in_dim(k_cache, layer_idx, 0,
                                                   keepdims=False)
            v_layer = jax.lax.dynamic_index_in_dim(v_cache, layer_idx, 0,
                                                   keepdims=False)
            kv_len = cache["k"].shape[2]
        else:
            if per_lane:
                upd = jax.vmap(
                    lambda c, kn, pp: jax.lax.dynamic_update_slice(
                        c, kn, (pp, 0, 0)))
                k_cache = upd(cache["k"], k_new.astype(cache["k"].dtype),
                              pos_b)
                v_cache = upd(cache["v"], v_new.astype(cache["v"].dtype),
                              pos_b)
            else:
                k_cache = jax.lax.dynamic_update_slice(
                    cache["k"], k_new.astype(cache["k"].dtype),
                    (0, pos_v, 0, 0))
                v_cache = jax.lax.dynamic_update_slice(
                    cache["v"], v_new.astype(cache["v"].dtype),
                    (0, pos_v, 0, 0))
            new_cache = {"k": constrain(k_cache, "batch", "cache_seq", "kv_heads", "head_dim"),
                         "v": constrain(v_cache, "batch", "cache_seq", "kv_heads", "head_dim")}
            k_layer, v_layer = k_cache, v_cache
            kv_len = cache["k"].shape[1]
        kpos = jnp.arange(kv_len)
        mask = kpos[None, None, :] <= posq[:, :, None]   # (B, Q, K)
        if window is not None:
            mask &= (posq[:, :, None] - kpos[None, None, :]) < window
    else:  # cross-attention decode: cached encoder K/V
        q = mm(x, p["wq"])
        if "bq" in p:
            q = q + p["bq"].astype(q.dtype)
        if "q_norm" in p:
            q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        new_cache = cache
        if page_table is not None:
            # read-only paged cross block; lane b attends its gathered
            # logical positions [0, kv_lens[b])
            psz = (cache["kq"] if q8 else
                   cache["kp"] if q4 else cache["k"]).shape[2]
            kv_len = page_table.shape[1] * psz
            lens = (jnp.asarray(kv_lens, jnp.int32) if kv_lens is not None
                    else jnp.full((b,), kv_len, jnp.int32))
            out = _paged_cache_attention(q, cache, layer_idx, page_table,
                                         lens)
            y = mm_out(out.astype(x.dtype), p["wo"])
            return constrain(y, "batch", None, "embed"), new_cache
        if quant:  # read-only quantized planes; per-lane encoder lengths
            kv_len = cache["kq" if q8 else "kp"].shape[2]
            lens = (jnp.asarray(kv_lens, jnp.int32) if kv_lens is not None
                    else jnp.full((b,), kv_len, jnp.int32))
            out = _quant_cache_attention(q, cache, layer_idx, lens)
            y = mm_out(out.astype(x.dtype), p["wo"])
            return constrain(y, "batch", None, "embed"), new_cache
        if stacked:   # read-only slice of the stacked cross cache
            k_layer = jax.lax.dynamic_index_in_dim(cache["k"], layer_idx,
                                                   0, keepdims=False)
            v_layer = jax.lax.dynamic_index_in_dim(cache["v"], layer_idx,
                                                   0, keepdims=False)
            kv_len = cache["k"].shape[2]
        else:
            k_layer, v_layer = cache["k"], cache["v"]
            kv_len = cache["k"].shape[1]
        if kv_lens is None:
            mask = jnp.ones((b, 1, kv_len), bool)
        else:   # serving: encoder states padded to the pool's enc_len
            mask = (jnp.arange(kv_len)[None, :]
                    < jnp.asarray(kv_lens, jnp.int32)[:, None])[:, None, :]

    q = constrain(q, "batch", None, "heads", "head_dim")
    k = _repeat_kv(k_layer, h)
    v = _repeat_kv(v_layer, h)
    scale = cfg.head_dim ** -0.5
    # C1-inline: the KV cache streams bf16 straight into the MXU with f32
    # accumulation — the baseline upconverted the whole cache to f32 in
    # HBM first (the dominant memory bytes of every decode cell).
    ddt = jnp.float32 if flags.BASELINE else jnp.bfloat16
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q.astype(ddt), k.astype(ddt),
                    preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s_ = softcap * jnp.tanh(s_ / softcap)
    s_ = jnp.where(mask[:, None], s_, NEG_INF)   # mask: (B, Q|1, K)
    w = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(ddt), v.astype(ddt),
                     preferred_element_type=jnp.float32)
    y = mm_out(out.astype(x.dtype), p["wo"])
    return constrain(y, "batch", None, "embed"), new_cache


def _per_lane_write(c: jax.Array, new: jax.Array, layer_idx,
                    pos_b: jax.Array) -> jax.Array:
    """Write a Q-token slab per lane into the stacked cache:
    ``c[layer_idx, b, pos_b[b] + j] = new[b, j]`` for every lane ``b``
    and slab token ``j`` (Q == 1 on the plain decode path; Q == spec_k
    in the speculative verify).

    Continuous batching puts each lane at its own position, so this is
    inherently a scatter — but XLA-CPU lowers small scatters through a
    slow generic path that dominates a fused decode step. On CPU the
    one-hot ``where`` formulation (a vectorized full-plane select) is
    ~4x cheaper and the plane is already streamed by the decode matvec
    anyway; on TPU/GPU the per-lane DUS scatter writes a slab-sized
    update in place and never touches the rest of the pool. Both are
    elementwise-identical; the choice is made at trace time."""
    nq = new.shape[1]
    if jax.default_backend() == "cpu":
        n_layers, _, s = c.shape[:3]
        j_rel = jnp.arange(s)[None, :] - pos_b[:, None]          # (B, S)
        sel = (jnp.arange(n_layers)[:, None, None] == layer_idx) \
            & (j_rel >= 0)[None] & (j_rel < nq)[None]
        slab = jnp.take_along_axis(
            new, jnp.clip(j_rel, 0, nq - 1)[..., None, None],
            axis=1)                                               # (B,S,·,·)
        return jnp.where(sel[..., None, None],
                         slab[None].astype(c.dtype), c)
    return jax.vmap(
        lambda cb, kn, pp: jax.lax.dynamic_update_slice(
            cb, kn[None].astype(cb.dtype), (layer_idx, pp, 0, 0)),
        in_axes=(1, 0, 0), out_axes=1)(c, new, pos_b)


def _quant_cache_attention(q: jax.Array, planes: dict, layer_idx,
                           lens: jax.Array) -> jax.Array:
    """Decode matvec over one layer of a stacked quantized cache.

    q: (B, Q, H, D); ``planes``: {kq, ks, vq, vs} (q8_0) or
    {kp, ks, vp, vs} (q4_0 nibble-packed), each (L, B, S, Hkv, ·); lane b
    attends cache positions [0, lens[b]) (``lens`` (B,) — or (B, Q)
    per-query depths in the speculative verify). The cache stays in code
    planes all the way to the kernel — dequantization happens next to
    the dot (paper C1), via the ACCEL/HOST-routed decode-attention op.
    Returns (B, Q, H, D)."""
    b, nq, h, d = q.shape
    q4 = is_q4_cache(planes)

    def flat(c):
        lay = jax.lax.dynamic_index_in_dim(c, layer_idx, 0, keepdims=False)
        lay = _repeat_kv(lay, h)                      # (B, S, H, ·)
        return lay.transpose(0, 2, 1, 3).reshape(b * h, lay.shape[1], -1)

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, nq, d)
    lens_f = jnp.repeat(jnp.asarray(lens, jnp.int32), h, axis=0)
    if q4:
        out = dispatch("q4_decode_attention", qf, flat(planes["kp"]),
                       flat(planes["ks"]), flat(planes["vp"]),
                       flat(planes["vs"]), lens_f)
    else:
        out = dispatch("q8_decode_attention", qf, flat(planes["kq"]),
                       flat(planes["ks"]), flat(planes["vq"]),
                       flat(planes["vs"]), lens_f)
    return out.reshape(b, h, nq, d).transpose(0, 2, 1, 3)


_q8_cache_attention = _quant_cache_attention  # back-compat alias


def _paged_cache_attention(q: jax.Array, planes: dict, layer_idx,
                           table: jax.Array, lens) -> jax.Array:
    """Decode matvec over one layer of a stacked paged pool.

    q: (B, 1, H, D); ``planes``: ``{k, v}`` or ``{kq, ks, vq, vs}``, each
    ``(L, n_pages, P, Hkv, ·)``; ``table``: (B, n_lp) int32 page table;
    lane b attends gathered logical positions [0, lens[b]). Returns
    (B, 1, H, D)."""
    def lay(c):
        return jax.lax.dynamic_index_in_dim(c, layer_idx, 0,
                                            keepdims=False)
    if is_q4_cache(planes):
        kc = {"p": lay(planes["kp"]), "s": lay(planes["ks"])}
        vc = {"p": lay(planes["vp"]), "s": lay(planes["vs"])}
    elif is_q8_cache(planes):
        kc = {"q": lay(planes["kq"]), "s": lay(planes["ks"])}
        vc = {"q": lay(planes["vq"]), "s": lay(planes["vs"])}
    else:
        kc, vc = lay(planes["k"]), lay(planes["v"])
    return dispatch("paged_decode_attention", q, kc, vc, table,
                    jnp.asarray(lens, jnp.int32))


def init_paged_kv_cache(cfg: ArchConfig, n_pages: int, page_size: int,
                        dtype=jnp.bfloat16) -> dict:
    """Page-pool KV planes ``(n_pages, P, Hkv, Dh)`` — same plane dict
    layout as ``init_kv_cache`` with (batch, max_len) replaced by the
    pool's (n_pages, page_size). Page 0 is the reserved scratch page."""
    return init_kv_cache(cfg, n_pages, page_size, dtype)


def _write_prefill_cache(cache: Optional[dict], k: jax.Array, v: jax.Array):
    """Store prefill K/V (padding up to cache length if one was allocated)."""
    if cache is None:
        return {"k": k, "v": v}
    kv_len = cache["k"].shape[1]
    s = k.shape[1]
    if s < kv_len:
        k = jnp.pad(k, ((0, 0), (0, kv_len - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_len - s), (0, 0), (0, 0)))
    return {"k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype)}


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> dict:
    """KV cache planes. ``dtype`` is an array dtype (bf16/f32 cache) or
    a tier string: ``"q8_0"`` (int8 planes + f16 scales blocked along
    head_dim) or ``"q4_0"`` (nibble-packed uint8 planes, head_dim halved,
    + f16 scales) — the serving engine's quantized-cache policies."""
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if isinstance(dtype, str):
        if cfg.head_dim % QBLOCK:
            raise ValueError(
                f"{dtype} KV cache needs head_dim % {QBLOCK} == 0, got "
                f"{cfg.head_dim}")
        sshape = shape[:-1] + (cfg.head_dim // QBLOCK,)
        if dtype == "q8_0":
            return {"kq": jnp.zeros(shape, jnp.int8),
                    "ks": jnp.zeros(sshape, jnp.float16),
                    "vq": jnp.zeros(shape, jnp.int8),
                    "vs": jnp.zeros(sshape, jnp.float16)}
        if dtype == "q4_0":
            pshape = shape[:-1] + (cfg.head_dim // 2,)
            return {"kp": jnp.zeros(pshape, jnp.uint8),
                    "ks": jnp.zeros(sshape, jnp.float16),
                    "vp": jnp.zeros(pshape, jnp.uint8),
                    "vs": jnp.zeros(sshape, jnp.float16)}
        raise ValueError(f"unknown KV-cache tier {dtype!r}")
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def is_q8_cache(cache) -> bool:
    return isinstance(cache, dict) and "kq" in cache


def is_q4_cache(cache) -> bool:
    return isinstance(cache, dict) and "kp" in cache


def quantize_kv_cache(tree, tier: str = "q8_0"):
    """bf16 KV-cache pytree -> quantized plane pytree.

    Every ``{"k", "v"}`` dict becomes ``{"kq", "ks", "vq", "vs"}``
    (``tier="q8_0"``: int8 planes + f16 scales, 32-blocked along
    head_dim) or ``{"kp", "ks", "vp", "vs"}`` (``tier="q4_0"``:
    nibble-packed uint8 planes); state caches (ssm/xlstm — different key
    sets) pass through untouched. The serving engine applies this to each
    one-shot prefill cache before scattering it into a quantized pool."""
    if isinstance(tree, dict):
        if set(tree) == {"k", "v"}:
            if tier == "q4_0":
                kt = quantize_q4_0(tree["k"], axis=-1)
                vt = quantize_q4_0(tree["v"], axis=-1)
                return {"kp": kt.q, "ks": kt.scale,
                        "vp": vt.q, "vs": vt.scale}
            kt = quantize_q8_0(tree["k"], axis=-1)
            vt = quantize_q8_0(tree["v"], axis=-1)
            return {"kq": kt.q, "ks": kt.scale,
                    "vq": vt.q, "vs": vt.scale}
        return {key: quantize_kv_cache(sub, tier) for key, sub in
                tree.items()}
    return tree
