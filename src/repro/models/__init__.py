from repro.models.model import Model, build, input_specs, SHAPES, shape_applicable
from repro.models.layers import Param, split_params
