"""Decoder-only stack: composable blocks, scanned segments, KV/state cache.

The layer stack is organized as ``n_segments`` repetitions of a per-arch
*segment pattern* (1 block for plain dense/MoE; (local, global) pairs for
gemma2; (mLSTM, sLSTM) pairs for xlstm; 5×mamba + shared-attn for zamba2),
scanned with ``jax.lax.scan`` so the HLO stays compact at 30–80 layers.
zamba2's attention block params are *shared* across segments (closure),
matching the architecture; its KV caches remain per-occurrence.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import flags as _flags
from repro.configs import ArchConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (KeyGen, Param, init_embedding, init_mlp,
                                 init_rmsnorm, embed, logits_head, mlp,
                                 rmsnorm, stack_axes)
from repro.parallel.sharding import constrain


def segment_pattern(cfg: ArchConfig) -> list[tuple[str, str]]:
    """[(block_type, attn_kind)] per scanned segment."""
    if cfg.xlstm:
        return [("mlstm", "-"), ("slstm", "-")]
    if cfg.family == "hybrid" and cfg.attn_every:
        return [("mamba", "-")] * (cfg.attn_every - 1) + [("shared_attn", "global")]
    if cfg.local_global:
        return [("attn", "local"), ("attn", "global")]
    return [("attn", "global")]


def tail_pattern(cfg: ArchConfig) -> list[tuple[str, str]]:
    """Trailing blocks that don't fill a whole segment (zamba2: 81 % 6 = 3)."""
    if cfg.family == "hybrid" and cfg.attn_every and cfg.n_layers % cfg.attn_every:
        return [("mamba", "-")] * (cfg.n_layers % cfg.attn_every)
    return []


def n_segments(cfg: ArchConfig) -> int:
    unit = len(segment_pattern(cfg))
    if cfg.family == "hybrid" and cfg.attn_every:
        return cfg.n_layers // cfg.attn_every
    assert cfg.n_layers % unit == 0, (cfg.name, cfg.n_layers, unit)
    return cfg.n_layers // unit


# ----------------------------------------------------------------------------
# Block init / apply
# ----------------------------------------------------------------------------

def _init_block(keys: KeyGen, cfg: ArchConfig, btype: str) -> dict:
    if btype == "attn":
        p = {"ln1": init_rmsnorm(cfg.d_model),
             "attn": attn_mod.init_attention(keys, cfg)}
        if cfg.d_ff:
            p["ln2"] = init_rmsnorm(cfg.d_model)
            if cfg.is_moe:
                p["moe"] = moe_mod.init_moe(keys, cfg)
            else:
                p["mlp"] = init_mlp(keys, cfg.d_model, cfg.d_ff, gated=True)
        return p
    if btype == "shared_attn":
        return {}  # params live in the shared tree
    if btype == "mamba":
        return {"ln1": init_rmsnorm(cfg.d_model),
                "mamba": ssm_mod.init_mamba(keys, cfg)}
    if btype == "mlstm":
        return {"ln1": init_rmsnorm(cfg.d_model),
                "mlstm": xlstm_mod.init_mlstm(keys, cfg)}
    if btype == "slstm":
        return {"ln1": init_rmsnorm(cfg.d_model),
                "slstm": xlstm_mod.init_slstm(keys, cfg)}
    raise ValueError(btype)


def _apply_block(bp: dict, x, cfg: ArchConfig, btype: str, kind: str, *,
                 mode: str, cache, pos, shared: Optional[dict],
                 layer_idx=None, n_valid=None):
    """``layer_idx`` (decode): ``cache`` holds the STACKED (L, …) subtree
    for this block; attention writes its token in place at layer_idx;
    state blocks (ssm/xlstm) slice their layer's state and write the
    full state back (a real full-state update — SSM/LSTM states change
    entirely every step, unlike sparse KV appends)."""
    def _slice(sub):
        if layer_idx is None or sub is None:
            return sub
        return jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, layer_idx, 0,
                                                   keepdims=False), sub)

    def _unslice(old, new):
        if layer_idx is None or new is None:
            return new
        return jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), layer_idx, 0), old, new)

    if btype in ("attn", "shared_attn"):
        p = shared if btype == "shared_attn" else bp
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, new_cache = attn_mod.attention(
            p["attn"], h, cfg, kind=kind, mode=mode,
            cache=None if cache is None else cache.get("kv"), pos=pos,
            layer_idx=layer_idx)
        x = x + a
        new_routing = None
        if cfg.d_ff and "ln2" in p:
            h = rmsnorm(p["ln2"], x, cfg.norm_eps)
            if cfg.is_moe:
                # per-lane expert-routing counters (LaneStateSpec
                # "routing"): caches that carry a "routing" plane get it
                # updated with this layer's executed top-k assignments
                rsub = None if cache is None else cache.get("routing")
                if rsub is not None:
                    y, rc = moe_mod.moe_ffn(p["moe"], h, cfg,
                                            route_counts=_slice(rsub),
                                            valid_len=n_valid)
                    x = x + y
                    new_routing = _unslice(rsub, rc)
                else:
                    x = x + moe_mod.moe_ffn(p["moe"], h, cfg,
                                            valid_len=n_valid)
            else:
                x = x + mlp(p["mlp"], h, cfg.act)
        if new_cache is None and new_routing is None:
            return x, None
        out_cache = {}
        if new_cache is not None:
            out_cache["kv"] = new_cache
        if new_routing is not None:
            out_cache["routing"] = new_routing
        return x, out_cache
    if btype == "mamba":
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        sub = None if cache is None else cache.get("ssm")
        y, new_cache = ssm_mod.mamba_block(
            bp["mamba"], h, cfg, mode=mode, cache=_slice(sub), pos=pos)
        new_cache = _unslice(sub, new_cache)
        return x + y, (None if new_cache is None else {"ssm": new_cache})
    if btype == "mlstm":
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        sub = None if cache is None else cache.get("mstate")
        y, new_cache = xlstm_mod.mlstm_block(
            bp["mlstm"], h, cfg, mode=mode, cache=_slice(sub), pos=pos)
        new_cache = _unslice(sub, new_cache)
        return x + y, (None if new_cache is None else {"mstate": new_cache})
    if btype == "slstm":
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        sub = None if cache is None else cache.get("sstate")
        y, new_cache = xlstm_mod.slstm_block(
            bp["slstm"], h, cfg, mode=mode, cache=_slice(sub), pos=pos)
        new_cache = _unslice(sub, new_cache)
        return x + y, (None if new_cache is None else {"sstate": new_cache})
    raise ValueError(btype)


def _block_cache(cfg: ArchConfig, btype: str, kind: str, batch: int,
                 max_len: int, dtype):
    if btype in ("attn", "shared_attn"):
        c = {"kv": attn_mod.init_kv_cache(cfg, batch, max_len, dtype)}
        if cfg.is_moe and cfg.d_ff:
            # LaneStateSpec "routing": per-lane executed top-k counters
            c["routing"] = jnp.zeros((batch, cfg.n_experts), jnp.int32)
        return c
    # "q8_0"/"q4_0" apply to KV planes only; recurrent states stay bf16
    # (they are O(1)-sized and fully rewritten every step — no LOAD win)
    if isinstance(dtype, str) and dtype in ("q8_0", "q4_0"):
        dtype = jnp.bfloat16
    if btype == "mamba":
        return {"ssm": ssm_mod.init_mamba_cache(cfg, batch, dtype)}
    if btype == "mlstm":
        return {"mstate": xlstm_mod.init_mlstm_cache(cfg, batch, dtype)}
    if btype == "slstm":
        return {"sstate": xlstm_mod.init_slstm_cache(cfg, batch, dtype)}
    raise ValueError(btype)


# ----------------------------------------------------------------------------
# Whole-model init / apply
# ----------------------------------------------------------------------------

def init_decoder(key, cfg: ArchConfig) -> dict:
    keys = KeyGen(key)
    pattern = segment_pattern(cfg)
    nseg = n_segments(cfg)

    def seg_init(k):
        kg = KeyGen(k)
        return {f"block{j}": _init_block(kg, cfg, bt)
                for j, (bt, _) in enumerate(pattern)}

    seg_keys = jax.random.split(keys(), nseg)
    segments = jax.vmap(seg_init)(seg_keys)
    segments = stack_axes(segments, "layers")

    params = {
        "embed": init_embedding(keys, cfg.vocab, cfg.d_model),
        "segments": segments,
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    tail = tail_pattern(cfg)
    if tail:
        tail_keys = jax.random.split(keys(), len(tail))

        def tail_init(k):
            return {"block0": _init_block(KeyGen(k), cfg, "mamba")}

        params["tail"] = stack_axes(jax.vmap(tail_init)(tail_keys), "layers")
    if any(bt == "shared_attn" for bt, _ in pattern):
        kg = KeyGen(keys())
        params["shared"] = _init_block(kg, cfg, "attn")
    if not cfg.tie_embeddings:
        from repro.models.layers import ninit, pad_vocab
        params["lm_head"] = Param(
            ninit(keys(), (cfg.d_model, pad_vocab(cfg.vocab)), cfg.d_model),
            ("param_embed", "vocab"))
    return params


def _scan_stack(params_stack, cache_stack, x, cfg, pattern, *, mode, pos,
                shared, n_valid=None):
    """Scan segments; returns (x, new_cache_stack).

    Decode carries the stacked cache through the scan and each segment
    updates it in place (token-sized writes for KV; full-state writes for
    SSM/LSTM states) — the ys-stacking path would re-materialize the
    entire cache every step (§Perf cell C). Train/prefill keep the
    ys-stacking formulation (prefill legitimately writes the full cache).
    """
    nseg = jax.tree.leaves(params_stack)[0].shape[0]

    def seg_fn(x, seg_params, seg_cache, layer_idx=None):
        new_caches = {}
        for j, (bt, kind) in enumerate(pattern):
            bc = None if seg_cache is None else seg_cache[f"block{j}"]
            x, nc = _apply_block(seg_params[f"block{j}"], x, cfg, bt, kind,
                                 mode=mode, cache=bc, pos=pos,
                                 shared=shared, layer_idx=layer_idx,
                                 n_valid=n_valid)
            new_caches[f"block{j}"] = nc
        x = constrain(x, "batch", "q_seq", "embed")
        return x, (None if mode == "train" else new_caches)

    if cfg.remat and mode == "train":
        seg_fn = jax.checkpoint(
            seg_fn, policy=jax.checkpoint_policies.nothing_saveable)

    if mode == "decode" and not _flags.BASELINE:
        assert cache_stack is not None
        # KV subtrees ride the carry (token-sized in-place writes);
        # SSM/LSTM state subtrees ride xs/ys (they are fully rewritten
        # every step anyway — carrying them would double the traffic
        # with a slice-out/write-back round trip).
        kv_names = {f"block{j}" for j, (bt, _) in enumerate(pattern)
                    if bt in ("attn", "shared_attn")}
        kv_cache = {k: v for k, v in cache_stack.items() if k in kv_names}
        st_cache = {k: v for k, v in cache_stack.items()
                    if k not in kv_names}

        def seg_dec(carry, xs):
            x, kvc = carry
            seg_params, stc, idx = xs
            new_kv, new_st = {}, {}
            for j, (bt, kind) in enumerate(pattern):
                name = f"block{j}"
                if name in kv_names:
                    x, nc = _apply_block(seg_params[name], x, cfg, bt,
                                         kind, mode=mode, cache=kvc[name],
                                         pos=pos, shared=shared,
                                         layer_idx=idx)
                    new_kv[name] = nc
                else:
                    x, nc = _apply_block(seg_params[name], x, cfg, bt,
                                         kind, mode=mode, cache=stc[name],
                                         pos=pos, shared=shared)
                    new_st[name] = nc
            x = constrain(x, "batch", "q_seq", "embed")
            return (x, new_kv), new_st

        (x, kv_new), st_new = jax.lax.scan(
            seg_dec, (x, kv_cache),
            (params_stack, st_cache, jnp.arange(nseg)))
        return x, {**kv_new, **st_new}

    if cache_stack is None:
        x, ys = jax.lax.scan(lambda c, sp: seg_fn(c, sp, None),
                             x, params_stack)
    else:
        x, ys = jax.lax.scan(lambda c, xs: seg_fn(c, xs[0], xs[1]),
                             x, (params_stack, cache_stack))
    return x, (None if mode == "train" else ys)


def decoder_forward(params: dict, cfg: ArchConfig, tokens, *,
                    mode: str = "train", cache=None, pos=None,
                    prefix_embed=None, n_valid=None):
    """tokens: (B, S) int32 (S=1 for decode). ``prefix_embed``: (B, P, d)
    continuous embeddings prepended at position 0 (VLM patch stub).
    ``n_valid`` (scalar int, bucketed serving prefill): live prompt
    length — positions past it are padding, masked out of MoE
    expert-capacity routing (attention already hides them causally).
    Returns (logits, new_cache)."""
    values = params
    x = embed(values["embed"], tokens)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
        if n_valid is not None:
            n_valid = n_valid + prefix_embed.shape[1]
    x = constrain(x, "batch", "q_seq", "embed")

    pattern = segment_pattern(cfg)
    shared = values.get("shared")
    seg_cache = None if cache is None else cache["segments"]
    x, new_seg_cache = _scan_stack(values["segments"], seg_cache, x, cfg,
                                   pattern, mode=mode, pos=pos,
                                   shared=shared, n_valid=n_valid)
    new_cache = None
    tail_cache = None
    if "tail" in values:
        tc = None if cache is None else cache["tail"]
        x, tail_cache = _scan_stack(values["tail"], tc, x, cfg,
                                    [("mamba", "-")], mode=mode, pos=pos,
                                    shared=None)
    if mode != "train":
        new_cache = {"segments": new_seg_cache}
        if "tail" in values:
            new_cache["tail"] = tail_cache

    x = rmsnorm(values["final_norm"], x, cfg.norm_eps)
    head = values.get("lm_head")
    logits = logits_head(values["embed"], x, cfg.vocab,
                         softcap=cfg.final_softcap, head=head)
    return logits, new_cache


def init_decoder_cache(cfg: ArchConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16) -> dict:
    pattern = segment_pattern(cfg)
    nseg = n_segments(cfg)

    def one_seg(_):
        return {f"block{j}": _block_cache(cfg, bt, kind, batch, max_len, dtype)
                for j, (bt, kind) in enumerate(pattern)}

    seg = jax.tree.map(lambda x: jnp.broadcast_to(x, (nseg,) + x.shape),
                       one_seg(0))
    cache = {"segments": seg}
    tail = tail_pattern(cfg)
    if tail:
        t = {"block0": _block_cache(cfg, "mamba", "-", batch, max_len, dtype)}
        cache["tail"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (len(tail),) + x.shape), t)
    return cache
