"""Mixture-of-Experts FFN: top-k routing with per-expert capacity.

GShard-style capacity semantics implemented as a *gather* formulation that
is GSPMD-friendly at 128-expert scale (the one-hot dispatch einsum would
materialize tokens×E×C): each expert top-k's its own highest-gate tokens up
to capacity C, gathers them, runs the gated FFN, and scatter-adds weighted
outputs back. Compute is top_k×capacity_factor of the dense equivalent —
the correct active-FLOPs profile for the roofline (DESIGN.md §4).

Sharding: experts over 'model' when E % tp == 0 (qwen3-moe: EP), otherwise
per-expert d_ff over 'model' (mixtral: TP-in-expert).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.layers import KeyGen, Param, _act, ninit
from repro.parallel.sharding import constrain


def init_moe(keys: KeyGen, cfg: ArchConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": Param(ninit(keys(), (d, e), d), ("param_embed", None)),
        "gate": Param(ninit(keys(), (e, d, ff), d), ("experts", "param_embed", "expert_ff")),
        "up": Param(ninit(keys(), (e, d, ff), d), ("experts", "param_embed", "expert_ff")),
        "down": Param(ninit(keys(), (e, ff, d), ff), ("experts", "expert_ff", "param_embed")),
    }


def _count_routes(top_i: jax.Array, b: int, e: int,
                  counts: jax.Array) -> jax.Array:
    """Accumulate executed top-k assignments into per-lane counters.
    ``top_i``: (b, s, k) or (b*s, k) expert indices; ``counts``: (b, e)
    int32. Counts *executed* routing decisions — the serving engine
    decodes every slot each tick, so parked lanes keep counting; this
    is a device-work diagnostic (who loaded which expert), not a
    billing meter."""
    hits = jax.nn.one_hot(top_i.reshape(b, -1), e, dtype=jnp.int32)
    return counts + hits.sum(axis=1)


def moe_ffn(p: dict, x: jax.Array, cfg: ArchConfig,
            grouped: bool = None, route_counts: jax.Array = None,
            valid_len: jax.Array = None):
    """x: (B, S, d) -> (B, S, d).

    ``grouped=True`` (default; §Perf hillclimb B): GShard-style *groups* —
    capacity and expert top-C selection are per batch row, so dispatch
    tensors carry a leading B dim that shards over ('pod','data') and the
    expert dim shards over 'model' (EP) when divisible: the dispatch is
    fully 2-D-sharded and no collective crosses the data axis inside the
    layer. The ``grouped=False`` baseline top-k'd over the globally
    flattened token dim — replicated (E, global_cap, d) dispatch tensors
    and (n_global, d) all-reduces every layer made mixtral-8x7b the only
    collective-bound cell of the baseline table (EXPERIMENTS.md §Perf).

    ``route_counts`` ((B, E) int32, the serving cache's per-lane
    "routing" plane): when given, returns ``(out, new_counts)`` with
    this layer's executed top-k assignments accumulated in.

    ``valid_len`` (scalar int, serving prefill): positions >= valid_len
    are bucket padding — their gates are zeroed before the per-expert
    capacity top-C, so padding can never evict a live token from an
    expert. Capacity routing is non-causal (unlike attention, where the
    causal mask already hides the padded tail), so an unmasked padded
    bucket would change live tokens' expert assignments.
    """
    if grouped is None:
        from repro import flags
        grouped = not flags.BASELINE
    if not grouped:
        return _moe_ffn_global(p, x, cfg, route_counts=route_counts,
                               valid_len=valid_len)
    b, s, d = x.shape
    e, top_k = cfg.n_experts, cfg.top_k
    cap = min(s, max(top_k, int(cfg.capacity_factor * s * top_k / e)))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)             # (b, s, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # per-row dense gate (b, s, e), then per-(row, expert) top-C tokens
    gate = jnp.zeros((b, s, e), jnp.float32)
    gate = gate.at[jnp.arange(b)[:, None, None],
                   jnp.arange(s)[None, :, None], top_i].set(top_p)
    if valid_len is not None:
        gate = gate * (jnp.arange(s) < valid_len)[None, :, None]
    gate_t = constrain(gate.swapaxes(1, 2), "batch", "experts", None)
    sel_gate, sel_tok = jax.lax.top_k(gate_t, cap)         # (b, e, cap)

    x_e = jnp.take_along_axis(
        x[:, None].astype(jnp.bfloat16),                   # (b, 1, s, d)
        sel_tok[..., None], axis=2)                        # (b, e, cap, d)
    x_e = constrain(x_e, "batch", "experts", None, "embed")
    g = _act(cfg.act)(jnp.einsum("becd,edf->becf", x_e,
                                 p["gate"].astype(jnp.bfloat16)))
    u = jnp.einsum("becd,edf->becf", x_e, p["up"].astype(jnp.bfloat16))
    h = constrain(g * u, "batch", "experts", None, "expert_ff")
    y_e = jnp.einsum("becf,efd->becd", h, p["down"].astype(jnp.bfloat16))
    y_e = y_e * sel_gate[..., None].astype(jnp.bfloat16)   # combine weights
    y_e = constrain(y_e, "batch", "experts", None, "embed")

    def combine_row(sel, ye):                              # (e,cap),(e,cap,d)
        out = jnp.zeros((s, d), jnp.float32)
        return out.at[sel.reshape(-1)].add(
            ye.reshape(e * cap, d).astype(jnp.float32))

    out = jax.vmap(combine_row)(sel_tok, y_e).astype(x.dtype)
    out = constrain(out, "batch", "q_seq", "embed")
    if route_counts is not None:
        return out, _count_routes(top_i, b, e, route_counts)
    return out


def _moe_ffn_global(p: dict, x: jax.Array, cfg: ArchConfig,
                    route_counts: jax.Array = None,
                    valid_len: jax.Array = None):
    """Baseline (pre-hillclimb) dispatch: global-token top-C. Kept for
    the §Perf A/B and the equivalence tests."""
    b, s, d = x.shape
    e, top_k = cfg.n_experts, cfg.top_k
    n = b * s
    cap = max(top_k, int(cfg.capacity_factor * n * top_k / e))
    cap = min(cap, n)

    xf = x.reshape(n, d)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)             # (n, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    gate = jnp.zeros((n, e), jnp.float32)
    gate = gate.at[jnp.arange(n)[:, None], top_i].set(top_p)
    if valid_len is not None:                 # see moe_ffn: padding mask
        live = jnp.broadcast_to(jnp.arange(s) < valid_len, (b, s))
        gate = gate * live.reshape(n)[:, None]
    gate_t = constrain(gate.T, "experts", None)            # (e, n)
    sel_gate, sel_tok = jax.lax.top_k(gate_t, cap)         # (e, cap)

    x_e = jnp.take(xf, sel_tok.reshape(-1), axis=0).reshape(e, cap, d)
    x_e = constrain(x_e, "experts", None, "embed")
    g = _act(cfg.act)(jnp.einsum("ecd,edf->ecf", x_e.astype(jnp.bfloat16),
                                 p["gate"].astype(jnp.bfloat16)))
    u = jnp.einsum("ecd,edf->ecf", x_e.astype(jnp.bfloat16),
                   p["up"].astype(jnp.bfloat16))
    h = constrain(g * u, "experts", None, "expert_ff")
    y_e = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(jnp.bfloat16))
    y_e = y_e.astype(jnp.float32) * sel_gate[..., None]    # combine weights
    y_e = constrain(y_e, "experts", None, "embed")

    out = jnp.zeros((n, d), jnp.float32)
    out = out.at[sel_tok.reshape(-1)].add(y_e.reshape(e * cap, d))
    out = out.astype(x.dtype).reshape(b, s, d)
    out = constrain(out, "batch", "q_seq", "embed")
    if route_counts is not None:
        return out, _count_routes(top_i, b, e, route_counts)
    return out


def load_balance_loss(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Auxiliary load-balancing loss (Switch/GShard)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
