"""Shared model primitives + the Param/logical-axes machinery."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import QBLOCK, Q4Tensor, Q8Tensor, unpack_q4
from repro.kernels.api import dispatch
from repro.parallel.sharding import constrain


# ----------------------------------------------------------------------------
# Param: a pytree wrapper carrying logical axis names as static aux data.
# ----------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    value: Any
    axes: tuple

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    """Param tree -> (values tree, axes tree)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def stack_axes(tree, axis_name: str = "layers"):
    """Prepend a logical axis to every Param's axes (after vmap-stacking)."""
    return jax.tree.map(lambda p: Param(p.value, (axis_name,) + p.axes),
                        tree, is_leaf=is_param)


class KeyGen:
    """Deterministic sequential key splitter for init functions."""

    def __init__(self, key):
        self._key = key
        self._n = 0

    def __call__(self):
        self._n += 1
        return jax.random.fold_in(self._key, self._n)


def ninit(key, shape, fan_in: int, dtype=jnp.float32) -> jax.Array:
    """Scaled-normal init (1/sqrt(fan_in))."""
    return (jax.random.normal(key, shape, jnp.float32)
            * (fan_in ** -0.5)).astype(dtype)


# ----------------------------------------------------------------------------
# Linear / matmul with Q8Tensor support (C1: serving path uses quantized
# weights). Both entry points route through the kernel-dispatch API: the
# ACCEL/HOST control law (core.offload) picks per call between the Pallas
# wrappers and the XLA/ref host paths — see repro.kernels.api.
# ----------------------------------------------------------------------------

def mm(x: jax.Array, w, compute_dtype=jnp.bfloat16) -> jax.Array:
    """x @ w where w may be a Q8Tensor/Q4Tensor (dispatched q8/q4_matmul)
    or an array. Contraction over x's last dim and w's first (or
    first-two for fused head layouts)."""
    if isinstance(w, Q8Tensor):
        lead = x.shape[:-1]
        k = x.shape[-1]
        w2 = Q8Tensor(w.q.reshape(k, -1),
                      w.scale.reshape(w.scale.shape[0], -1))
        y = dispatch("q8_matmul", x.reshape(-1, k), w2,
                     out_dtype=compute_dtype)
        return y.reshape(*lead, *w.q.shape[1:])
    if isinstance(w, Q4Tensor):
        # w.q is nibble-packed along K: (K//2, N) for a logical (K, N)
        # weight, so the output dims are w.q.shape[1:] unchanged.
        lead = x.shape[:-1]
        k = x.shape[-1]
        w2 = Q4Tensor(w.q.reshape(k // 2, -1),
                      w.scale.reshape(w.scale.shape[0], -1))
        y = dispatch("q4_matmul", x.reshape(-1, k), w2,
                     out_dtype=compute_dtype)
        return y.reshape(*lead, *w.q.shape[1:])
    w = w.astype(compute_dtype)
    x = x.astype(compute_dtype)
    if w.ndim == 2:
        return dispatch("fp16_matmul", x, w, out_dtype=compute_dtype)
    if w.ndim == 3:   # (k, heads, head_dim)
        return jnp.einsum("...k,khd->...hd", x, w)
    raise ValueError(f"unsupported weight rank {w.ndim}")


def mm_out(x: jax.Array, w, compute_dtype=jnp.bfloat16) -> jax.Array:
    """(…, h, d) @ (h, d, n) -> (…, n) output projection."""
    if isinstance(w, Q8Tensor):
        h, d, n = w.q.shape
        w2 = Q8Tensor(w.q.reshape(h * d, n), w.scale.reshape(-1, n))
        y = dispatch("q8_matmul", x.reshape(-1, h * d), w2,
                     out_dtype=compute_dtype)
        return y.reshape(*x.shape[:-2], n)
    if isinstance(w, Q4Tensor):
        # packed along head_dim (axis -2): w.q is (h, dh//2, n) for a
        # logical (h, dh, n) weight; dh % QBLOCK == 0 keeps the flattened
        # (h·dh) contraction's 32-blocks inside one head.
        h, dp, n = w.q.shape
        w2 = Q4Tensor(w.q.reshape(h * dp, n), w.scale.reshape(-1, n))
        y = dispatch("q4_matmul", x.reshape(-1, h * 2 * dp), w2,
                     out_dtype=compute_dtype)
        return y.reshape(*x.shape[:-2], n)
    h, d, n = w.shape
    xc = x.astype(compute_dtype).reshape(*x.shape[:-2], h * d)
    y = dispatch("fp16_matmul", xc,
                 w.astype(compute_dtype).reshape(h * d, n),
                 out_dtype=compute_dtype)
    return y


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------

def init_rmsnorm(d: int) -> Param:
    return Param(jnp.ones((d,), jnp.float32), ("embed",))


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dt)


def init_layernorm(keys: KeyGen, d: int) -> dict:
    return {"scale": Param(jnp.ones((d,), jnp.float32), ("embed",)),
            "bias": Param(jnp.zeros((d,), jnp.float32), ("embed",))}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------------
# Rotary position embedding
# ----------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(s: int, d: int) -> jax.Array:
    """Whisper-encoder style sinusoids (S, D)."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(s)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


# ----------------------------------------------------------------------------
# Embedding + logits head (vocab padded to mesh*lane multiple, DESIGN.md §4)
# ----------------------------------------------------------------------------

VOCAB_MULT = 2048


def pad_vocab(v: int, mult: int = VOCAB_MULT) -> int:
    return -(-v // mult) * mult


def init_embedding(keys: KeyGen, vocab: int, d: int) -> dict:
    vp = pad_vocab(vocab)
    return {"table": Param(ninit(keys(), (vp, d), d), ("vocab", "param_embed"))}


def _dequant_q4_bf16(t: Q4Tensor) -> jax.Array:
    """Dequantize a vocab-axis-packed Q4 table to bf16 (no f32 plane)."""
    codes = unpack_q4(t.q, axis=-2).astype(jnp.bfloat16)
    return codes * jnp.repeat(t.scale.astype(jnp.bfloat16), QBLOCK, axis=-2)


def embed(p: dict, tokens: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    tbl = p["table"]
    if isinstance(tbl, Q8Tensor):
        from repro.core.quantize import dequantize_q8_0
        tbl = dequantize_q8_0(tbl, axis=-2)
    elif isinstance(tbl, Q4Tensor):
        # q4 tables live inside the traced draft-verify decode program:
        # widen to bf16, never a full f32 plane (SC-DTYPE). The f16->bf16
        # scale rounding only perturbs draft logits, which the verify
        # forward makes irrelevant.
        tbl = _dequant_q4_bf16(tbl)
    # gather rows first, cast the (B, S, d) result after: decode looks
    # up S=1 tokens per lane per step, and casting the padded-vocab
    # table before the take would re-stream it every fused-scan step
    # (gather commutes with the cast bit-exactly).
    x = jnp.take(tbl, tokens, axis=0).astype(compute_dtype)
    return constrain(x, "batch", "q_seq", "embed")


def logits_head(p: dict, x: jax.Array, vocab: int,
                softcap: Optional[float] = None,
                head=None) -> jax.Array:
    """Project to (padded) vocab; mask padding with a large negative."""
    if head is not None:
        y = mm(x, head, jnp.float32)
    else:
        tbl = p["table"]
        if isinstance(tbl, Q4Tensor):
            # bf16-widened (SC-DTYPE: no f32 vocab plane in the traced
            # draft program); f32 accumulation keeps the argmax stable.
            y = jnp.einsum("...d,vd->...v", x.astype(jnp.bfloat16),
                           _dequant_q4_bf16(tbl),
                           preferred_element_type=jnp.float32)
        else:
            if isinstance(tbl, Q8Tensor):
                from repro.core.quantize import dequantize_q8_0
                tbl = dequantize_q8_0(tbl, axis=-2)
            y = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                           tbl.astype(jnp.float32))
    if softcap is not None:
        y = softcap * jnp.tanh(y / softcap)
    vp = y.shape[-1]
    pad_mask = jnp.arange(vp) >= vocab
    y = y - 1e9 * pad_mask.astype(y.dtype)
    return constrain(y, "batch", "q_seq", "vocab")


# ----------------------------------------------------------------------------
# MLP (gated SwiGLU/GeGLU, or plain 2-layer for whisper)
# ----------------------------------------------------------------------------

def init_mlp(keys: KeyGen, d: int, ff: int, gated: bool = True) -> dict:
    p = {"up": Param(ninit(keys(), (d, ff), d), ("param_embed", "ff")),
         "down": Param(ninit(keys(), (ff, d), ff), ("ff", "param_embed"))}
    if gated:
        p["gate"] = Param(ninit(keys(), (d, ff), d), ("param_embed", "ff"))
    return p


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    up = mm(x, p["up"])
    up = constrain(up, "batch", "q_seq", "ff")
    if "gate" in p:
        g = _act(act)(mm(x, p["gate"]))
        h = constrain(g, "batch", "q_seq", "ff") * up
    else:
        h = _act(act)(up)
    y = mm(h, p["down"])
    return constrain(y, "batch", "q_seq", "embed")
