"""Whisper-style encoder-decoder (the paper's model family).

The model consumes frame *embeddings* (B, S_enc, d_model): either
precomputed (``input_specs()``/synthetic) or produced from raw audio by
the ``repro.audio`` log-mel frontend; a tiny learnable projection stands
in for conv2 so the frontend remains trainable end to end. Encoder:
sinusoidal positions + bidirectional attention (``encode_chunked`` for
the streaming block-diagonal variant). Decoder: learned positions,
causal self-attn + cross-attn + GELU MLP (whisper uses LayerNorm and
untied... tied token embeddings — we tie, per whisper).
``cross_attn_kv`` projects new encoder states into the per-layer cross
K/V planes serving uses to extend a streaming slot's cache in place.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.core.quantize import as_array
from repro.models import attention as attn_mod
from repro.models.layers import (KeyGen, Param, embed, init_embedding,
                                 init_layernorm, init_mlp, layernorm,
                                 logits_head, mlp, mm, ninit, rmsnorm,
                                 sinusoidal_positions,
                                 stack_axes)
from repro.parallel.sharding import constrain

MAX_DEC_POS = 32768  # learned decoder positions (whisper: 448; the
                     # assigned decode_32k shape needs 32k (DESIGN.md §5)


def _init_enc_layer(k, cfg: ArchConfig) -> dict:
    kg = KeyGen(k)
    return {
        "ln1": init_layernorm(kg, cfg.d_model),
        "attn": attn_mod.init_attention(kg, cfg),
        "ln2": init_layernorm(kg, cfg.d_model),
        "mlp": init_mlp(kg, cfg.d_model, cfg.d_ff, gated=False),
    }


def _init_dec_layer(k, cfg: ArchConfig) -> dict:
    kg = KeyGen(k)
    return {
        "ln1": init_layernorm(kg, cfg.d_model),
        "self_attn": attn_mod.init_attention(kg, cfg),
        "ln_x": init_layernorm(kg, cfg.d_model),
        "cross_attn": attn_mod.init_cross_attention(kg, cfg),
        "ln2": init_layernorm(kg, cfg.d_model),
        "mlp": init_mlp(kg, cfg.d_model, cfg.d_ff, gated=False),
    }


def init_encdec(key, cfg: ArchConfig) -> dict:
    keys = KeyGen(key)
    enc_keys = jax.random.split(keys(), cfg.enc_layers)
    dec_keys = jax.random.split(keys(), cfg.n_layers)
    kg = KeyGen(keys())
    return {
        "frontend": Param(ninit(keys(), (cfg.d_model, cfg.d_model),
                                cfg.d_model), ("param_embed", "embed")),
        "embed": init_embedding(kg, cfg.vocab, cfg.d_model),
        "dec_pos": Param(0.02 * jax.random.normal(
            keys(), (MAX_DEC_POS, cfg.d_model)), (None, "param_embed")),
        "enc_layers": stack_axes(jax.vmap(
            lambda k: _init_enc_layer(k, cfg))(enc_keys), "layers"),
        "enc_ln": init_layernorm(kg, cfg.d_model),
        "dec_layers": stack_axes(jax.vmap(
            lambda k: _init_dec_layer(k, cfg))(dec_keys), "layers"),
        "dec_ln": init_layernorm(kg, cfg.d_model),
    }


def encode(params: dict, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d_model) stub embeddings -> encoder states."""
    b, s, d = frames.shape
    x = jnp.einsum("bsd,de->bse", frames.astype(jnp.bfloat16),
                   as_array(params["frontend"]))
    x = x + sinusoidal_positions(s, d).astype(x.dtype)[None]
    x = constrain(x, "batch", "q_seq", "embed")

    def layer(x, lp):
        h = layernorm(lp["ln1"], x)
        a, _ = attn_mod.attention(lp["attn"], h, cfg, kind="bidir",
                                  mode="train", use_rope=False)
        x = x + a
        h = layernorm(lp["ln2"], x)
        x = x + mlp(lp["mlp"], h, cfg.act)
        return constrain(x, "batch", "q_seq", "embed"), None

    if cfg.remat:
        layer = jax.checkpoint(layer)
    x, _ = jax.lax.scan(layer, x, params["enc_layers"])
    return layernorm(params["enc_ln"], x)


def encode_chunked(params: dict, cfg: ArchConfig, frames: jax.Array,
                   chunk: int) -> jax.Array:
    """Block-diagonal encode: frames (B, S, d_model) split into
    fixed-size chunks, each encoded independently (bidirectional
    attention *within* the chunk only), states concatenated.

    This is the streaming-ASR encoder semantics: a chunk's states never
    depend on later audio, so incremental chunk-at-a-time encoding
    (serving's ``stream_feed``) reproduces the one-shot result exactly.
    One compile per distinct chunk length (the fixed size + one tail)."""
    s = frames.shape[1]
    outs = [encode(params, cfg, frames[:, i:i + chunk])
            for i in range(0, s, chunk)]
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)


def cross_attn_kv(params: dict, cfg: ArchConfig, states: jax.Array):
    """Per-decoder-layer cross-attention K/V for new encoder states.

    states: (B, S_new, d_model) -> (k, v), each (L, B, S_new, Hkv, Dh) —
    exactly the planes ``decode_tokens``'s prefill writes into the cross
    cache (same ``mm`` compute dtype, biases, and k-norm as
    ``attention._project_qkv``), so serving can *extend* a slot's cached
    encoder K/V as audio chunks arrive instead of re-encoding."""
    def one(lp):
        k = mm(states, lp["wk"])
        v = mm(states, lp["wv"])
        if "bk" in lp:
            k = k + lp["bk"].astype(k.dtype)
            v = v + lp["bv"].astype(v.dtype)
        if "k_norm" in lp:
            k = rmsnorm(lp["k_norm"], k, cfg.norm_eps)
        return k, v
    return jax.vmap(one)(params["dec_layers"]["cross_attn"])


def decode_tokens(params: dict, cfg: ArchConfig, tokens: jax.Array,
                  enc_out: Optional[jax.Array] = None, *,
                  mode: str = "train", cache=None, pos=None,
                  enc_lens=None, pages=None):
    """Decoder pass. train/prefill: tokens (B, S) with enc_out given.
    decode: tokens (B, 1), cache holds self KV + cross KV. ``enc_lens``
    (decode, optional): (B,) valid encoder lengths — serving pads cached
    encoder K/V to the pool's enc_len, so cross-attention must mask the
    padded tail per lane. ``pages`` (decode, optional):
    ``{"self": (B, n_lp), "cross": (B, n_lp_c)}`` int32 page tables —
    the cache planes are then shared page pools (``repro.paging``) and
    each attention reads/writes through its lane's table row."""
    b, s = tokens.shape
    x = embed(params["embed"], tokens)
    if mode == "decode":
        posv = jnp.asarray(pos, jnp.int32)
        # gather the one needed row per lane BEFORE any dtype cast: the
        # fused decode scan runs this every step, and casting the whole
        # (MAX_DEC_POS, d_model) table first would stream ~16 MB through
        # a loop-invariant cast per token (the dominant cost of a decode
        # step at reduced sizes). Gather is exact, so the order change
        # is bit-identical.
        dec_pos = params["dec_pos"]
        if not isinstance(dec_pos, jax.Array):
            dec_pos = as_array(dec_pos, jnp.float32)   # Q8Tensor params
        if posv.ndim == 1:    # per-lane positions (continuous batching)
            # token j of a Q-token slab (speculative verify) sits at
            # absolute position pos + j
            pe = jnp.take(dec_pos,
                          posv[:, None] + jnp.arange(s)[None, :], axis=0)
        else:
            pe = jax.lax.dynamic_slice_in_dim(dec_pos, posv, s,
                                              axis=0)[None]
        x = x + pe.astype(x.dtype)
    else:
        x = x + as_array(params["dec_pos"], x.dtype)[:s][None]
    x = constrain(x, "batch", "q_seq", "embed")

    def layer(x, lp, lc, layer_idx=None):
        h = layernorm(lp["ln1"], x)
        a, self_c = attn_mod.attention(
            lp["self_attn"], h, cfg, kind="global", mode=mode,
            cache=None if lc is None else lc["self"], pos=pos,
            use_rope=False, layer_idx=layer_idx,
            page_table=None if pages is None else pages["self"])
        x = x + a
        h = layernorm(lp["ln_x"], x)
        if mode == "decode":
            c, cross_c = attn_mod.attention(
                lp["cross_attn"], h, cfg, kind="bidir", mode=mode,
                cache=lc["cross"], pos=pos, use_rope=False,
                x_kv=h,  # x_kv flags the cross path; cached K/V are used
                layer_idx=layer_idx, kv_lens=enc_lens,
                page_table=None if pages is None else pages["cross"])
        else:
            c, cross_c = attn_mod.attention(
                lp["cross_attn"], h, cfg, kind="bidir", mode=mode,
                cache=None if lc is None else lc["cross"],
                x_kv=enc_out, use_rope=False)
        x = x + c
        h = layernorm(lp["ln2"], x)
        x = x + mlp(lp["mlp"], h, cfg.act)
        x = constrain(x, "batch", "q_seq", "embed")
        nc = None
        if mode != "train":
            nc = {"self": self_c, "cross": cross_c}
        return x, nc

    if cfg.remat and mode == "train":
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)

    from repro import flags as _flags
    if mode == "decode" and not _flags.BASELINE:
        # stacked cache as scan carry: each layer writes its token in
        # place (token-sized DUS) instead of re-stacking the full cache
        # per step (§Perf cell C)
        n_layers = cfg.n_layers

        def layer_dec(carry, xs):
            x, cache_all = carry
            lp, idx = xs
            x, nc = layer(x, lp, cache_all, layer_idx=idx)
            return (x, nc), None

        (x, new_layers), _ = jax.lax.scan(
            layer_dec, (x, cache["layers"]),
            (params["dec_layers"], jnp.arange(n_layers)))
        x = layernorm(params["dec_ln"], x)
        logits = logits_head(params["embed"], x, cfg.vocab,
                             softcap=cfg.final_softcap)
        return logits, {"layers": new_layers}

    if cache is None:
        x, ys = jax.lax.scan(lambda c, lp: layer(c, lp, None),
                             x, params["dec_layers"])
    else:
        x, ys = jax.lax.scan(lambda c, xs: layer(c, xs[0], xs[1]),
                             x, (params["dec_layers"], cache["layers"]))
    x = layernorm(params["dec_ln"], x)
    logits = logits_head(params["embed"], x, cfg.vocab,
                         softcap=cfg.final_softcap)
    new_cache = None if mode == "train" else {"layers": ys}
    return logits, new_cache


def init_encdec_cache(cfg: ArchConfig, batch: int, max_len: int,
                      enc_len: int, dtype=jnp.bfloat16) -> dict:
    self_kv = attn_mod.init_kv_cache(cfg, batch, max_len, dtype)
    cross_kv = attn_mod.init_kv_cache(cfg, batch, enc_len, dtype)
    layer = {"self": self_kv, "cross": cross_kv}
    return {"layers": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), layer)}


def init_paged_encdec_cache(cfg: ArchConfig, n_pages: int,
                            n_cross_pages: int, page_size: int,
                            dtype=jnp.bfloat16) -> dict:
    """Paged pool variant of ``init_encdec_cache``: the per-lane
    (batch, seq) leading dims become shared (n_pages, P) pools indexed
    through per-lane page tables (``repro.paging``). The pytree layout
    is unchanged, so the stacked decode scan carries it as-is."""
    self_kv = attn_mod.init_paged_kv_cache(cfg, n_pages, page_size, dtype)
    cross_kv = attn_mod.init_paged_kv_cache(cfg, n_cross_pages, page_size,
                                            dtype)
    layer = {"self": self_kv, "cross": cross_kv}
    return {"layers": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), layer)}
