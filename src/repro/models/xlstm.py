"""xLSTM blocks: mLSTM (chunked-parallel / recurrent) and sLSTM (scan).

mLSTM is a gated matrix-memory linear recurrence; training uses a chunked
form (intra-chunk quadratic + carried (C, n, m) state with running-max
stabilization, per the xLSTM paper's stabilized formulas). sLSTM has a
true sequential dependency (block-diagonal recurrent matrices per head)
and runs as a lax.scan over time — the paper's technique does not apply to
its recurrence (DESIGN.md §5), only to its projections.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.layers import KeyGen, Param, ninit, rmsnorm
from repro.parallel.sharding import constrain

MCHUNK = 128


# ----------------------------------------------------------------------------
# mLSTM
# ----------------------------------------------------------------------------

def _mdims(cfg: ArchConfig):
    d_in = int(cfg.proj_factor * cfg.d_model)
    h = cfg.n_heads
    return d_in, h, d_in // h


def init_mlstm(keys: KeyGen, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_in, h, hd = _mdims(cfg)
    return {
        "w_up": Param(ninit(keys(), (d, d_in), d), ("param_embed", "inner")),
        "w_gate": Param(ninit(keys(), (d, d_in), d), ("param_embed", "inner")),
        "wq": Param(ninit(keys(), (d_in, d_in), d_in), ("inner", None)),
        "wk": Param(ninit(keys(), (d_in, d_in), d_in), ("inner", None)),
        "wv": Param(ninit(keys(), (d_in, d_in), d_in), ("inner", None)),
        "wi": Param(ninit(keys(), (d_in, h), d_in), ("inner", None)),
        "wf": Param(ninit(keys(), (d_in, h), d_in), ("inner", None)),
        "f_bias": Param(3.0 * jnp.ones((h,), jnp.float32), (None,)),
        "out_norm": Param(jnp.ones((d_in,), jnp.float32), ("inner",)),
        "w_down": Param(ninit(keys(), (d_in, d), d_in), ("inner", "param_embed")),
    }


def _mlstm_core_chunked(q, k, v, i_raw, logf, state, chunk=MCHUNK):
    """q/k/v: (B,S,H,hd); i_raw/logf: (B,S,H); state: (C, n, m) with
    C (B,H,hd,hd), n (B,H,hd), m (B,H). Returns (y, state)."""
    b, s, h, hd = q.shape
    scale = hd ** -0.5
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, z) for t in (q, k, v))
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk

    def r(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, ic, fc = r(q), r(k), r(v), r(i_raw), r(logf)

    def step(carry, xs):
        C, n, m = carry
        qq, kk, vv, ii, ff = xs
        F = jnp.cumsum(ff, axis=1)                       # (b,q,h)
        # log weights: intra D[t,s] = F_t - F_s + i_s (s<=t)
        Dlog = F[:, :, None, :] - F[:, None, :, :] + ii[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        Dlog = jnp.where(tri[None, :, :, None], Dlog, -jnp.inf)
        # inter weight for carried state: F_t + m_prev
        inter_log = F + m[:, None, :]                    # (b,q,h)
        m_t = jnp.maximum(jnp.max(Dlog, axis=2), inter_log)
        m_t = jnp.maximum(m_t, -1e30)
        w_intra = jnp.exp(Dlog - m_t[:, :, None, :])     # (b,t,s,h)
        w_inter = jnp.exp(inter_log - m_t)               # (b,t,h)

        qk = jnp.einsum("bthd,bshd->bths", qq.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale   # (b,t,h,s)
        sc = qk * w_intra.swapaxes(2, 3)                  # (b,t,h,s)
        num_intra = jnp.einsum("bths,bshd->bthd", sc, vv.astype(jnp.float32))
        den_intra = jnp.sum(sc, axis=-1)                  # (b,t,h)
        qC = jnp.einsum("bthd,bhde->bthe", qq.astype(jnp.float32), C) * scale
        qn = jnp.einsum("bthd,bhd->bth", qq.astype(jnp.float32), n) * scale
        num = num_intra + qC * w_inter[..., None]
        den = den_intra + qn * w_inter
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        y = num / den[..., None]

        # carry update to chunk end
        F_end = F[:, -1, :]                               # (b,h)
        m_new = jnp.maximum(F_end + m, jnp.max(F_end[:, None] - F + ii, axis=1))
        w_state = jnp.exp(F_end[:, None] - F + ii - m_new[:, None])  # (b,s,h)
        C_new = (C * jnp.exp(F_end + m - m_new)[..., None, None]
                 + jnp.einsum("bshd,bshe,bsh->bhde", kk.astype(jnp.float32),
                              vv.astype(jnp.float32), w_state))
        n_new = (n * jnp.exp(F_end + m - m_new)[..., None]
                 + jnp.einsum("bshd,bsh->bhd", kk.astype(jnp.float32), w_state))
        return (C_new, n_new, m_new), y

    (C, n, m), yc = jax.lax.scan(step, state, (qc, kc, vc, ic, fc))
    y = yc.swapaxes(0, 1).reshape(b, nc * chunk, h, hd)[:, :s]
    return y, (C, n, m)


def _mlstm_core_step(q, k, v, i_raw, logf, state):
    """Single decode step. q/k/v: (B,H,hd); i_raw/logf: (B,H)."""
    C, n, m = state
    scale = q.shape[-1] ** -0.5
    m_new = jnp.maximum(logf + m, i_raw)
    C = (C * jnp.exp(logf + m - m_new)[..., None, None]
         + jnp.exp(i_raw - m_new)[..., None, None]
         * jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32),
                      v.astype(jnp.float32)))
    n = (n * jnp.exp(logf + m - m_new)[..., None]
         + jnp.exp(i_raw - m_new)[..., None] * k.astype(jnp.float32))
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C) * scale
    den = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n) * scale
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    return num / den[..., None], (C, n, m_new)


def mlstm_block(p: dict, x: jax.Array, cfg: ArchConfig, *,
                mode: str = "train", cache: Optional[dict] = None, pos=None):
    b, s, d = x.shape
    d_in, h, hd = _mdims(cfg)
    u = jnp.einsum("bsd,di->bsi", x.astype(jnp.bfloat16),
                   p["w_up"].astype(jnp.bfloat16))
    u = constrain(u, "batch", "q_seq", "inner")
    g = jax.nn.silu(jnp.einsum("bsd,di->bsi", x.astype(jnp.bfloat16),
                               p["w_gate"].astype(jnp.bfloat16)))
    q = jnp.einsum("bsi,ij->bsj", u, p["wq"].astype(jnp.bfloat16)).reshape(b, s, h, hd)
    k = jnp.einsum("bsi,ij->bsj", u, p["wk"].astype(jnp.bfloat16)).reshape(b, s, h, hd)
    v = jnp.einsum("bsi,ij->bsj", u, p["wv"].astype(jnp.bfloat16)).reshape(b, s, h, hd)
    i_raw = jnp.einsum("bsi,ih->bsh", u.astype(jnp.float32), p["wi"].astype(jnp.float32))
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bsi,ih->bsh", u.astype(jnp.float32),
                   p["wf"].astype(jnp.float32)) + p["f_bias"])

    # the state cache declares its storage dtype (LaneStateSpec); steps
    # compute in f32 and cast back on write so a serving pool's donated
    # scan carry never silently widens to f32
    cdt = cache["C"].dtype if cache is not None else jnp.float32
    if mode == "decode":
        assert cache is not None
        state = (cache["C"].astype(jnp.float32), cache["n"].astype(jnp.float32),
                 cache["m"].astype(jnp.float32))
        y, (C, n, m) = _mlstm_core_step(q[:, 0], k[:, 0], v[:, 0],
                                        i_raw[:, 0], logf[:, 0], state)
        y = y[:, None]
        new_cache = {"C": C.astype(cdt), "n": n.astype(cdt),
                     "m": m.astype(cdt)}
    else:
        state = _init_mstate(b, h, hd)
        y, (C, n, m) = _mlstm_core_chunked(q, k, v, i_raw, logf, state)
        new_cache = {"C": C.astype(cdt), "n": n.astype(cdt),
                     "m": m.astype(cdt)} if mode == "prefill" else None

    y = y.reshape(b, -1, d_in).astype(x.dtype)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps) * g[:, :y.shape[1]]
    out = jnp.einsum("bsi,id->bsd", y.astype(jnp.bfloat16),
                     p["w_down"].astype(jnp.bfloat16)).astype(x.dtype)
    return constrain(out, "batch", "q_seq", "embed"), new_cache


def _init_mstate(b, h, hd):
    return (jnp.zeros((b, h, hd, hd), jnp.float32),
            jnp.zeros((b, h, hd), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))


def init_mlstm_cache(cfg: ArchConfig, batch: int,
                     dtype=jnp.bfloat16) -> dict:
    """Per-lane mLSTM state ``{C: (b,h,hd,hd), n: (b,h,hd), m: (b,h)}``.
    ``dtype`` is the storage dtype (every leaf, ``m`` included — it
    used to stay f32, which silently widened serving pools); defaults
    bf16, unified with ``init_mamba_cache``."""
    d_in, h, hd = _mdims(cfg)
    C, n, m = _init_mstate(batch, h, hd)
    return {"C": C.astype(dtype), "n": n.astype(dtype),
            "m": m.astype(dtype)}


# ----------------------------------------------------------------------------
# sLSTM
# ----------------------------------------------------------------------------

def init_slstm(keys: KeyGen, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    def gate():
        return {
            "w": Param(ninit(keys(), (d, h, hd), d), ("param_embed", None, None)),
            "r": Param(ninit(keys(), (h, hd, hd), hd), (None, None, None)),
            "b": Param(jnp.zeros((h, hd), jnp.float32), (None, None)),
        }
    return {
        "i": gate(), "f": gate(), "z": gate(), "o": gate(),
        "out_norm": Param(jnp.ones((d,), jnp.float32), ("embed",)),
        "w_up": Param(ninit(keys(), (d, int(cfg.proj_factor * d)), d),
                      ("param_embed", "inner")),
        "w_down": Param(ninit(keys(), (int(cfg.proj_factor * d), d),
                              int(cfg.proj_factor * d)), ("inner", "param_embed")),
    }


GATES = ("i", "f", "z", "o")


def _slstm_wx(p: dict, x: jax.Array) -> jax.Array:
    """Input projections for ALL timesteps at once: (4, B, S, H, hd).

    §Perf optimization (xlstm train_4k): the baseline computed these four
    d×d GEMVs *inside* the 4096-step scan, re-reading (and re-gathering,
    under FSDP) every gate weight each timestep — the dominant memory
    term of the whole 40-cell table. Hoisted, they are four large
    MXU-friendly GEMMs; only the small per-head recurrent matvec R·h
    remains sequential. Exact rewrite (same ops, reassociated).
    """
    return jnp.stack([
        jnp.einsum("bsd,dhe->bshe", x.astype(jnp.bfloat16),
                   p[g]["w"].astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32) + p[g]["b"]
        for g in GATES])


def _stacked_r(p: dict) -> jax.Array:
    """(4, H, hd, hd) stacked recurrent weights — hoisted out of the scan
    (loop-invariant) so each timestep issues ONE gate matvec instead of
    four (§Perf xlstm iteration 2: fewer, larger per-step ops)."""
    return jnp.stack([p[g]["r"].astype(jnp.float32) for g in GATES])


def _slstm_step(r_all, wx_t, state):
    """r_all: (4, H, hd, hd); wx_t: (4, B, H, hd) input pre-activations."""
    c, n, h, m = state
    pre = wx_t + jnp.einsum("bhe,ghef->gbhf", h, r_all)
    i_r, f_r, z_r, o_r = pre[0], pre[1], pre[2], pre[3]
    logf = jax.nn.log_sigmoid(f_r)
    m_new = jnp.maximum(logf + m, i_r)
    i_g = jnp.exp(i_r - m_new)
    f_g = jnp.exp(logf + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z_r)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_r) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_block(p: dict, x: jax.Array, cfg: ArchConfig, *,
                mode: str = "train", cache: Optional[dict] = None, pos=None):
    b, s, d = x.shape
    h_, hd = cfg.n_heads, d // cfg.n_heads

    from repro import flags as _flags
    if _flags.BASELINE and mode != "decode":
        # pre-hillclimb formulation: gate GEMVs inside the timestep scan
        state0 = _init_sstate(b, h_, hd)

        def step_legacy(st, x_t):
            wx = jnp.stack([
                jnp.einsum("bd,dhe->bhe", x_t.astype(jnp.float32),
                           p[g]["w"].astype(jnp.float32)) + p[g]["b"]
                for g in GATES])
            st = _slstm_step(_stacked_r(p), wx, st)
            return st, st[2]

        state, hs = jax.lax.scan(step_legacy, state0, x.swapaxes(0, 1))
        y = hs.swapaxes(0, 1).reshape(b, s, d)
        cdt = cache["c"].dtype if cache is not None else jnp.float32
        new_cache = dict(zip(("c", "n", "h", "m"),
                             (s_.astype(cdt) for s_ in state))) \
            if mode == "prefill" else None
        y = rmsnorm(p["out_norm"], y.astype(x.dtype), cfg.norm_eps)
        u = jax.nn.gelu(jnp.einsum("bsd,di->bsi", y.astype(jnp.bfloat16),
                                   p["w_up"].astype(jnp.bfloat16)))
        out = jnp.einsum("bsi,id->bsd", u, p["w_down"].astype(jnp.bfloat16))
        return out.astype(x.dtype), new_cache

    r_all = _stacked_r(p)
    # storage-dtype contract as in mlstm_block: f32 step math, cast back
    # to the cache's declared dtype on write
    cdt = cache["c"].dtype if cache is not None else jnp.float32
    if mode == "decode":
        assert cache is not None
        state = tuple(cache[k].astype(jnp.float32) for k in ("c", "n", "h", "m"))
        wx = _slstm_wx(p, x)[:, :, 0]          # (4, B, H, hd)
        state = _slstm_step(r_all, wx, state)
        y = state[2].reshape(b, 1, d)
        new_cache = dict(zip(("c", "n", "h", "m"),
                             (s_.astype(cdt) for s_ in state)))
    else:
        state0 = _init_sstate(b, h_, hd)
        wx_all = _slstm_wx(p, x)               # (4, B, S, H, hd)

        def step(st, wx_t):
            st = _slstm_step(r_all, wx_t, st)
            return st, st[2]

        state, hs = jax.lax.scan(step, state0,
                                 wx_all.transpose(2, 0, 1, 3, 4))
        y = hs.swapaxes(0, 1).reshape(b, s, d)
        new_cache = dict(zip(("c", "n", "h", "m"),
                             (s_.astype(cdt) for s_ in state))) \
            if mode == "prefill" else None

    y = rmsnorm(p["out_norm"], y.astype(x.dtype), cfg.norm_eps)
    u = jax.nn.gelu(jnp.einsum("bsd,di->bsi", y.astype(jnp.bfloat16),
                               p["w_up"].astype(jnp.bfloat16)))
    out = jnp.einsum("bsi,id->bsd", u, p["w_down"].astype(jnp.bfloat16))
    return out.astype(x.dtype), new_cache


def _init_sstate(b, h, hd):
    z = jnp.zeros((b, h, hd), jnp.float32)
    return (z, z, z, jnp.full((b, h, hd), -1e30, jnp.float32))


def init_slstm_cache(cfg: ArchConfig, batch: int,
                     dtype=jnp.bfloat16) -> dict:
    """Per-lane sLSTM state, four ``(b, h, hd)`` leaves. ``dtype`` is
    the storage dtype (previously ignored — the cache was always f32);
    defaults bf16, unified with ``init_mamba_cache``."""
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    c, n, hh, m = _init_sstate(batch, h, hd)
    return {"c": c.astype(dtype), "n": n.astype(dtype),
            "h": hh.astype(dtype), "m": m.astype(dtype)}
