"""Mamba2 (SSD) block — zamba2's backbone.

Train/prefill run the chunked SSD algorithm (matmul-dominated, MXU-
friendly: intra-chunk quadratic term + inter-chunk state recurrence via
lax.scan). Decode is the O(1) recurrent state update. All decay
exponentials are of non-positive arguments (log a <= 0), so the chunked
form is numerically stable without extra rescaling.

Cache = {"conv": (B, w-1, C_conv), "h": (B, H, hd, N)} — constant-size
state, which is why zamba2/xlstm are the long_500k-eligible archs.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.layers import KeyGen, Param, ninit, rmsnorm
from repro.parallel.sharding import constrain

CHUNK = 256


def _dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state, cfg.ssm_head_dim


def init_mamba(keys: KeyGen, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_in, h, n, hd = _dims(cfg)
    w = cfg.ssm_conv
    return {
        "wz": Param(ninit(keys(), (d, d_in), d), ("param_embed", "inner")),
        "wx": Param(ninit(keys(), (d, d_in), d), ("param_embed", "inner")),
        "wB": Param(ninit(keys(), (d, n), d), ("param_embed", None)),
        "wC": Param(ninit(keys(), (d, n), d), ("param_embed", None)),
        "wdt": Param(ninit(keys(), (d, h), d), ("param_embed", "ssm_heads")),
        "dt_bias": Param(jnp.zeros((h,), jnp.float32), ("ssm_heads",)),
        "A_log": Param(jnp.zeros((h,), jnp.float32), ("ssm_heads",)),
        "D": Param(jnp.ones((h,), jnp.float32), ("ssm_heads",)),
        "conv_x": Param(ninit(keys(), (w, d_in), w), ("conv", "inner")),
        "conv_B": Param(ninit(keys(), (w, n), w), ("conv", None)),
        "conv_C": Param(ninit(keys(), (w, n), w), ("conv", None)),
        "out_norm": Param(jnp.ones((d_in,), jnp.float32), ("inner",)),
        "wo": Param(ninit(keys(), (d_in, d), d_in), ("inner", "param_embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (B, S, C); w: (W, C). Returns (y, tail)."""
    width = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    ys = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
             for i in range(width))
    tail = xp[:, xp.shape[1] - (width - 1):, :]
    return jax.nn.silu(ys), tail


def _ssd_chunked(xh, dt, a_log_dt, B, C, h0, chunk: int = CHUNK):
    """Chunked SSD.
      xh: (B, S, H, hd)   inputs per head
      dt: (B, S, H)       softplus'd step sizes
      a_log_dt: (B, S, H) log decay per step (= -exp(A_log)*dt, <= 0)
      B, C: (B, S, N)
      h0: (B, H, hd, N) initial state
    Returns (y: (B,S,H,hd), h_final)."""
    b, s, h, hd = xh.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a_log_dt = jnp.pad(a_log_dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    q = chunk

    def r(t):  # reshape to chunks
        return t.reshape(b, nc, q, *t.shape[2:]).swapaxes(0, 1)

    xc, dtc, ac, Bc, Cc = r(xh), r(dt), r(a_log_dt), r(B), r(C)

    def step(h_prev, xs):
        xq, dtq, aq, Bq, Cq = xs          # (b,q,h,hd) (b,q,h) (b,q,h) (b,q,n) (b,q,n)
        acs = jnp.cumsum(aq, axis=1)      # (b,q,h) cumulative log decay
        # intra-chunk: scores[t,s_] = C_t.B_s * exp(acs_t - acs_s) * dt_s
        cb = jnp.einsum("btn,bsn->bts", Cq.astype(jnp.float32),
                        Bq.astype(jnp.float32))
        seg = acs[:, :, None, :] - acs[:, None, :, :]      # (b,t,s,h)
        tri = jnp.tril(jnp.ones((q, q), bool))
        w_ts = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        scores = cb[..., None] * w_ts                       # (b,t,s,h)
        xdt = xq.astype(jnp.float32) * dtq[..., None]       # (b,s,h,hd)
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, xdt)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("btn,bhdn->bthd", Cq.astype(jnp.float32), h_prev
                             ) * jnp.exp(acs)[..., None]
        # state update
        decay_to_end = jnp.exp(acs[:, -1:, :] - acs)        # (b,s,h)
        dh = jnp.einsum("bshd,bsn,bsh->bhdn", xdt, Bq.astype(jnp.float32),
                        decay_to_end)
        h_new = h_prev * jnp.exp(acs[:, -1])[:, :, None, None] + dh
        return h_new, (y_intra + y_inter)

    h_final, yc = jax.lax.scan(step, h0.astype(jnp.float32),
                               (xc, dtc, ac, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(b, nc * q, h, hd)[:, :s]
    return y, h_final


def mamba_block(p: dict, x: jax.Array, cfg: ArchConfig, *,
                mode: str = "train", cache: Optional[dict] = None,
                pos=None):
    """Returns (y, new_cache)."""
    b, s, d = x.shape
    d_in, h, n, hd = _dims(cfg)
    z = jax.nn.silu(jnp.einsum("bsd,di->bsi", x.astype(jnp.bfloat16),
                               p["wz"].astype(jnp.bfloat16)))
    xi = jnp.einsum("bsd,di->bsi", x.astype(jnp.bfloat16),
                    p["wx"].astype(jnp.bfloat16))
    Bi = jnp.einsum("bsd,dn->bsn", x.astype(jnp.bfloat16),
                    p["wB"].astype(jnp.bfloat16))
    Ci = jnp.einsum("bsd,dn->bsn", x.astype(jnp.bfloat16),
                    p["wC"].astype(jnp.bfloat16))
    dt_raw = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32),
                        p["wdt"].astype(jnp.float32)) + p["dt_bias"]
    dt = jax.nn.softplus(dt_raw)                           # (b,s,h)
    a_log_dt = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt

    xi = constrain(xi, "batch", "q_seq", "inner")
    conv_cache = cache["conv"] if cache is not None else None
    if mode == "decode":
        cx, cB, cC = (None if conv_cache is None else
                      (conv_cache[..., :d_in], conv_cache[..., d_in:d_in + n],
                       conv_cache[..., d_in + n:]))
        xi, tx = _causal_conv(xi, p["conv_x"], cx)
        Bi, tB = _causal_conv(Bi, p["conv_B"], cB)
        Ci, tC = _causal_conv(Ci, p["conv_C"], cC)
        new_conv = jnp.concatenate([tx, tB, tC], axis=-1)
        xh = xi.reshape(b, s, h, hd).astype(jnp.float32)
        h_prev = cache["h"].astype(jnp.float32)
        decay = jnp.exp(a_log_dt[:, 0])                    # (b,h)
        xdt = xh[:, 0] * dt[:, 0, :, None]                 # (b,h,hd)
        dh = jnp.einsum("bhd,bn->bhdn", xdt, Bi[:, 0].astype(jnp.float32))
        h_new = h_prev * decay[:, :, None, None] + dh
        y = jnp.einsum("bhdn,bn->bhd", h_new, Ci[:, 0].astype(jnp.float32))
        y = y + xh[:, 0] * p["D"][None, :, None]
        y = y.reshape(b, 1, d_in)
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype)
                     if cache is not None else new_conv,
                     "h": h_new.astype(cache["h"].dtype)}
    else:
        xi, tx = _causal_conv(xi, p["conv_x"])
        Bi, tB = _causal_conv(Bi, p["conv_B"])
        Ci, tC = _causal_conv(Ci, p["conv_C"])
        xh = xi.reshape(b, s, h, hd)
        h0 = jnp.zeros((b, h, hd, n), jnp.float32)
        y, h_fin = _ssd_chunked(xh, dt, a_log_dt, Bi, Ci, h0)
        y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
        y = y.reshape(b, s, d_in)
        new_cache = None
        if mode == "prefill":
            # state dtype follows the allocated cache (f32 for exactness
            # in tests; bf16 in production serving)
            cdt = cache["h"].dtype if cache is not None else jnp.bfloat16
            new_conv = jnp.concatenate([tx, tB, tC], axis=-1)
            new_cache = {"conv": new_conv.astype(cdt),
                         "h": h_fin.astype(cdt)}

    y = rmsnorm(p["out_norm"], y.astype(x.dtype), cfg.norm_eps) * z
    out = jnp.einsum("bsi,id->bsd", y.astype(jnp.bfloat16),
                     p["wo"].astype(jnp.bfloat16)).astype(x.dtype)
    return constrain(out, "batch", "q_seq", "embed"), new_cache


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    d_in, h, n, hd = _dims(cfg)
    w = cfg.ssm_conv
    return {"conv": jnp.zeros((batch, w - 1, d_in + 2 * n), dtype),
            "h": jnp.zeros((batch, h, hd, n), dtype)}


def mamba_recurrent_ref(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Step-by-step oracle for the chunked SSD path (tests)."""
    b, s, d = x.shape
    cache = init_mamba_cache(cfg, b, jnp.float32)
    ys = []
    for t in range(s):
        y, cache = mamba_block(p, x[:, t:t + 1], cfg, mode="decode",
                               cache=cache, pos=t)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)
