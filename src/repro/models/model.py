"""Unified model API over all architecture families + dry-run input specs."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod
from repro.models.layers import split_params

# assigned input shapes: name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic (ssm/hybrid) archs, per the brief."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention arch; 500k dense-KV "
                       "decode reserved for SSM/hybrid (DESIGN.md §5)")
    return True, ""


@dataclasses.dataclass(frozen=True)
class LaneStateSpec:
    """What one serving lane of this model carries between decode steps.

    The serving engine (``repro.serving``) is family-agnostic: it asks
    the model for this spec and drives admission, prefill, the fused
    decode tick, q8_0 storage, abort/free and the energy accounting off
    it instead of assuming a KV cache. Declared state kinds:

    * ``self_kv`` — causal attention K/V planes, ``O(max_len)`` per
      lane (dense, enc-dec, MoE, and the hybrid families).
    * ``cross_kv`` — encoder-side K/V planes, ``O(enc_len)`` per lane
      (enc-dec only).
    * ``recurrent`` — constant-size per-lane state, rewritten in full
      every decode step: ``"ssm"`` (mamba ``conv``/``h``), ``"mstate"``
      (mLSTM ``(C, n, m)``), ``"sstate"`` (sLSTM ``(c, n, h, m)``).
    * ``moe_experts > 0`` — per-lane expert-routing counters
      ``(n_experts,) int32``, updated by every routed MoE layer.

    ``prefill_exact``: recurrent scans fold *every* input position into
    the end-of-prompt state, so bucket zero-padding would corrupt it
    (attention is immune — decode masks positions beyond ``pos``).
    Engines prefill such lanes at the exact prompt length, one compile
    per distinct length.

    ``recurrent_dtype``: the storage dtype of recurrent leaves in a
    serving pool. Steps compute in f32 and cast back on write, so the
    donated decode scan carry keeps a stable dtype (no silent f32
    widening — checked by staticcheck SC-DTYPE).

    ``quant_tiers``: the quantized cache tiers this family can serve
    under (``"q8_0"``: int8+scale planes; ``"q4_0"``: nibble-packed
    planes). Both tiers quantize K/V planes blocked along head_dim, so
    they need plain-softmax decode attention with
    ``head_dim % 32 == 0`` and at least one KV plane to quantize
    (pure-recurrent lanes have none — their O(1) state stays
    ``recurrent_dtype``). ``q8_supported`` is kept as a derived
    property for older call sites."""
    family: str
    self_kv: bool
    cross_kv: bool
    recurrent: tuple = ()
    recurrent_dtype: str = "bfloat16"
    moe_experts: int = 0
    moe_top_k: int = 0
    prefill_exact: bool = False
    quant_tiers: tuple = ()

    @property
    def q8_supported(self) -> bool:
        return "q8_0" in self.quant_tiers

    def supports_tier(self, cache_dtype: str) -> bool:
        """True if ``cache_dtype`` (a tier string or array-dtype name)
        can hold this family's lane state."""
        if cache_dtype in ("q8_0", "q4_0"):
            return cache_dtype in self.quant_tiers
        return True

    @property
    def state_kinds(self) -> tuple:
        """Every state kind a lane of this family holds, in engine
        order — the allocator's reservation key."""
        out = []
        if self.self_kv:
            out.append("self_kv")
        if self.cross_kv:
            out.append("cross_kv")
        out.extend(self.recurrent)
        if self.moe_experts:
            out.append("routing")
        return tuple(out)


_RECURRENT_KIND = {"mamba": "ssm", "mlstm": "mstate", "slstm": "sstate"}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---- init -------------------------------------------------------------
    def init(self, key) -> Any:
        """Returns a Param tree (use split_params to get values + axes)."""
        if self.cfg.enc_dec:
            return encdec_mod.init_encdec(key, self.cfg)
        return tf_mod.init_decoder(key, self.cfg)

    def init_values(self, key):
        values, _ = split_params(self.init(key))
        return values

    def param_axes(self):
        boxed = jax.eval_shape(self.init, jax.random.key(0))
        _, axes = split_params(boxed)
        return axes

    def param_shapes(self, dtype=None):
        """``dtype`` casts float leaves (serving lowers bf16 weights)."""
        boxed = jax.eval_shape(self.init, jax.random.key(0))
        shapes, _ = split_params(boxed)
        if dtype is not None:
            shapes = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, dtype)
                if jnp.issubdtype(l.dtype, jnp.floating) else l, shapes)
        return shapes

    # ---- forward ----------------------------------------------------------
    def forward(self, values, batch: dict, *, mode: str = "train",
                cache=None, pos=None, pages=None):
        """Returns (logits, new_cache). ``batch`` keys by family:
        tokens (all); enc_frames (audio) or enc_states (audio:
        precomputed encoder output, e.g. streaming chunked encode —
        skips the encoder); img_embed (vlm, train/prefill); enc_lens
        (audio decode, optional: per-lane valid encoder lengths for
        cross-attention over padded cached encoder states); n_valid
        (decoder-only prefill, optional: live prompt length in a padded
        bucket — masks padding out of MoE expert-capacity routing).
        ``pages``
        (enc-dec decode, optional): per-lane page tables when ``cache``
        is a paged pool (``repro.paging``)."""
        cfg = self.cfg
        if cfg.enc_dec:
            if mode == "decode":
                return encdec_mod.decode_tokens(values, cfg, batch["tokens"],
                                                mode="decode", cache=cache,
                                                pos=pos,
                                                enc_lens=batch.get("enc_lens"),
                                                pages=pages)
            enc_out = batch.get("enc_states")
            if enc_out is None:
                enc_out = encdec_mod.encode(values, cfg, batch["enc_frames"])
            return encdec_mod.decode_tokens(values, cfg, batch["tokens"],
                                            enc_out, mode=mode, cache=cache)
        prefix = batch.get("img_embed") if mode != "decode" else None
        return tf_mod.decoder_forward(values, cfg, batch["tokens"],
                                      mode=mode, cache=cache, pos=pos,
                                      prefix_embed=prefix,
                                      n_valid=batch.get("n_valid"))

    def encode(self, values, frames):
        """Encoder-only pass (enc-dec models): frame embeddings
        (B, S, d_model) -> encoder states (B, S, d_model)."""
        if not self.cfg.enc_dec:
            raise ValueError(f"{self.cfg.name} is not encoder-decoder")
        return encdec_mod.encode(values, self.cfg, frames)

    # ---- cache ------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, enc_len: int = 1500,
                   dtype=jnp.bfloat16):
        """``dtype``: an array dtype, or a tier string (``"q8_0"`` /
        ``"q4_0"``) for the serving engine's quantized KV-cache policies
        (code+scale planes; recurrent states stay bf16)."""
        if self.cfg.enc_dec:
            return encdec_mod.init_encdec_cache(self.cfg, batch, max_len,
                                                enc_len, dtype)
        return tf_mod.init_decoder_cache(self.cfg, batch, max_len, dtype)

    def init_paged_cache(self, n_pages: int, n_cross_pages: int,
                         page_size: int, dtype=jnp.bfloat16):
        """Paged-pool cache (enc-dec only): shared ``(n_pages, P)`` self
        and cross planes indexed through per-lane page tables
        (``repro.paging``). Same ``dtype`` contract as ``init_cache``."""
        if not self.cfg.enc_dec:
            raise ValueError(
                f"{self.cfg.name}: paged KV cache requires an enc-dec "
                f"model (the serving engine's paged mode)")
        return encdec_mod.init_paged_encdec_cache(
            self.cfg, n_pages, n_cross_pages, page_size, dtype)

    def cache_specs(self, batch: int, max_len: int, enc_len: int = 1500):
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len, enc_len))

    # ---- lane state spec ---------------------------------------------------
    def state_spec(self) -> LaneStateSpec:
        """The model-declared per-lane serving state (``LaneStateSpec``).
        Derived from the block pattern, so it is exact for every config
        in the registry — including reduced() shrinks."""
        cfg = self.cfg
        if cfg.enc_dec:
            return LaneStateSpec(
                family=cfg.family, self_kv=True, cross_kv=True,
                quant_tiers=(("q8_0", "q4_0")
                             if cfg.head_dim % 32 == 0 else ()))
        blocks = [bt for bt, _ in tf_mod.segment_pattern(cfg)
                  + tf_mod.tail_pattern(cfg)]
        recurrent = []
        for bt in blocks:
            kind = _RECURRENT_KIND.get(bt)
            if kind is not None and kind not in recurrent:
                recurrent.append(kind)
        self_kv = any(bt in ("attn", "shared_attn") for bt in blocks)
        q8 = (self_kv and cfg.head_dim % 32 == 0
              and cfg.attn_softcap is None and cfg.sliding_window is None
              and not cfg.local_global)
        return LaneStateSpec(
            family=cfg.family, self_kv=self_kv, cross_kv=False,
            recurrent=tuple(recurrent),
            moe_experts=cfg.n_experts if cfg.is_moe else 0,
            moe_top_k=cfg.top_k if cfg.is_moe else 0,
            prefill_exact=bool(recurrent),
            quant_tiers=("q8_0", "q4_0") if q8 else ())

    def lane_state_bytes(self, max_len: int, enc_len: int = 1500,
                         dtype=jnp.bfloat16) -> dict:
        """Per-lane state footprint by kind, in bytes (eval_shape — no
        allocation): ``{"kv": ..., "state": ..., "total": ...}``. ``kv``
        grows O(max_len) (+O(enc_len) cross); ``state`` is the
        constant-size recurrent/routing footprint — the number the
        edge-memory story in the paper's follow-up turns on."""
        specs = jax.eval_shape(
            lambda: self.init_cache(1, max_len, enc_len, dtype=dtype))

        def walk(tree):
            if isinstance(tree, dict):
                if set(tree) in ({"k", "v"}, {"kq", "ks", "vq", "vs"},
                                 {"kp", "ks", "vp", "vs"}):
                    return (sum(int(l.size * l.dtype.itemsize)
                                for l in jax.tree.leaves(tree)), 0)
                kv = st = 0
                for sub in tree.values():
                    a, b = walk(sub)
                    kv, st = kv + a, st + b
                return kv, st
            return 0, sum(int(l.size * l.dtype.itemsize)
                          for l in jax.tree.leaves(tree))

        kv, st = walk(specs)
        return {"kv": kv, "state": st, "total": kv + st}

    # ---- count ------------------------------------------------------------
    def n_params(self) -> int:
        import math
        shapes = self.param_shapes()
        # python ints: stacked-layer shapes overflow int32 jnp.prod
        return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))

    def n_active_params(self) -> int:
        """MoE: experts count at top_k/E of their size (for 6·N·D)."""
        cfg = self.cfg
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self.param_shapes())[0]:
            size = 1
            for s in leaf.shape:
                size *= int(s)
            keys = "/".join(str(getattr(k, "key", k)) for k in path)
            if cfg.is_moe and any(s in keys for s in ("gate", "up", "down")) \
                    and "moe" in keys:
                size = size * cfg.top_k // max(cfg.n_experts, 1)
            total += size
        return total


def build(cfg: ArchConfig) -> Model:
    return Model(cfg)


# ----------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins; no allocation)
# ----------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: str) -> dict:
    """Inputs for the step function of a (arch, shape) cell.

    train:   {tokens, targets[, enc_frames | img_embed]}
    prefill: {tokens[, enc_frames | img_embed]}
    decode:  {tokens (B,1), pos ()}  (cache specs come from Model.cache_specs)
    """
    seq, gbatch, kind = SHAPES[shape]
    f = jax.ShapeDtypeStruct
    i32, bf16 = jnp.int32, jnp.bfloat16
    d = cfg.d_model
    if kind == "train":
        if cfg.enc_dec:
            s2 = seq // 2
            return {"enc_frames": f((gbatch, s2, d), bf16),
                    "tokens": f((gbatch, s2), i32),
                    "targets": f((gbatch, s2), i32)}
        if cfg.vlm:
            s_text = seq - cfg.n_img_tokens
            return {"img_embed": f((gbatch, cfg.n_img_tokens, d), bf16),
                    "tokens": f((gbatch, s_text), i32),
                    "targets": f((gbatch, seq), i32)}
        return {"tokens": f((gbatch, seq), i32),
                "targets": f((gbatch, seq), i32)}
    if kind == "prefill":
        out = {"tokens": f((gbatch, seq), i32)}
        if cfg.enc_dec:
            out["enc_frames"] = f((gbatch, 1500, d), bf16)
        if cfg.vlm:
            out["tokens"] = f((gbatch, seq - cfg.n_img_tokens), i32)
            out["img_embed"] = f((gbatch, cfg.n_img_tokens, d), bf16)
        return out
    # decode
    return {"tokens": f((gbatch, 1), i32),
            "pos": f((), i32)}
