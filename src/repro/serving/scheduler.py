"""Continuous-batching admission scheduler.

Policy layer over ServeEngine: FCFS queue with slot-aware admission and
optional prefill/decode interleave ratio. One ``tick()`` =

  0. feed one pending audio chunk to every open stream (finalizing
     streams whose audio has fully arrived);
  1. admit waiting requests while slots are free (each admit = one
     bucketed prefill; streaming requests open a stream and feed their
     first chunk);
  2. one fused decode tick over all active slots — the engine runs
     ``engine.decode_block`` decode steps on device and returns the
     whole per-tick token block after a single host sync, so every
     active lane advances up to ``decode_block`` tokens per tick;
  3. collect finished requests.

Streaming audio (``StreamingAudioRequest``): one chunk is delivered per
tick — the serving-time model of real-time arrival — so a lane decodes
*while* its audio is still arriving (partial hypotheses land in
``RequestState.partials``, one per fed chunk, each up to ``decode_block``
tokens ahead of the last) and is re-anchored at end of audio for the
final transcript.

Metrics track queue latency, time-to-first-token (in ticks), emitted
tokens, and slot occupancy — the quantities a production scheduler
optimizes. With ``decode_block > 1`` a tick is a coarser unit: TTFT and
queue-wait resolve to one block, and ``tokens`` is the per-tick token
blocks summed.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

from repro.serving.engine import (Request, RequestState, ServeEngine,
                                  StreamingAudioRequest)


@dataclasses.dataclass
class SchedMetrics:
    ticks: int = 0
    admitted: int = 0
    completed: int = 0
    rejected: int = 0           # failed validation; completed as errors
    tokens: int = 0             # tokens the engine emitted under this
                                # scheduler (prefill firsts + decode
                                # blocks) — tokens/tick > n_active when
                                # decode_block > 1
    occupancy_sum: float = 0.0
    queue_wait_sum: int = 0     # ticks spent waiting, summed over requests
    ttft_sum: int = 0           # ticks from submit to first token

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / max(self.ticks, 1)

    @property
    def mean_ttft(self) -> float:
        return self.ttft_sum / max(self.admitted, 1)

    @property
    def tokens_per_tick(self) -> float:
        return self.tokens / max(self.ticks, 1)


class BatchScheduler:
    def __init__(self, engine: ServeEngine, max_admit_per_tick: int = 2):
        self.engine = engine
        self.max_admit_per_tick = max_admit_per_tick
        self.queue: deque[tuple[Request, int]] = deque()   # (req, t_submit)
        self.metrics = SchedMetrics()
        self.results: dict[int, RequestState] = {}
        # open streams: slot -> (state, pending frame chunks)
        self._streams: dict[int, tuple[RequestState, deque]] = {}

    def submit(self, req: Request) -> Optional[RequestState]:
        """Queue a request. Requests this engine can never serve
        (too long, missing/oversized enc_frames, ...) are rejected here
        — completed immediately as a failed RequestState in ``results``
        — so one bad request cannot kill the serving loop. Returns the
        failed state for rejected requests, None when queued."""
        err = self.engine.validate(req)
        if err is not None:
            st = RequestState(req=req, slot=-1, pos=0, out=[], done=True,
                              error=err)
            self.results[req.uid] = st
            self.metrics.rejected += 1
            return st
        self.queue.append((req, self.metrics.ticks))
        return None

    def tick(self) -> list[RequestState]:
        m = self.metrics
        gen0 = self.engine._generated
        # 0. deliver one audio chunk per open stream (real-time model);
        # streams whose audio has fully arrived are finalized.
        for slot in list(self._streams):
            st, pending = self._streams[slot]
            self.engine.stream_feed(st, pending.popleft())
            if not pending:
                del self._streams[slot]
                st = self.engine.stream_finalize(st)
                if st.done:
                    m.completed += 1
                    self.results[st.req.uid] = st
        # 1. admission
        admitted = 0
        while (self.queue and self.engine.free
               and admitted < self.max_admit_per_tick):
            req, t_submit = self.queue.popleft()
            try:
                if isinstance(req, StreamingAudioRequest):
                    st = self.engine.open_stream(req)
                else:
                    st = self.engine.admit(req)
            except ValueError as e:
                # a request submit()'s precheck missed: fail it, keep
                # the serving loop alive
                st = RequestState(req=req, slot=-1, pos=0, out=[],
                                  done=True, error=str(e))
                self.results[req.uid] = st
                m.rejected += 1
                continue
            if st is None:      # pool filled since the loop condition
                self.queue.appendleft((req, t_submit))
                break
            if isinstance(req, StreamingAudioRequest):
                pending = deque(req.chunks)
                self.engine.stream_feed(st, pending.popleft())
                if pending:
                    self._streams[st.slot] = (st, pending)
                else:
                    st = self.engine.stream_finalize(st)
                    if st.done:
                        m.completed += 1
                        self.results[req.uid] = st
            m.admitted += 1
            m.queue_wait_sum += m.ticks - t_submit
            m.ttft_sum += m.ticks - t_submit   # first token at admit
            admitted += 1
            if st.done and st.req.uid not in self.results:
                m.completed += 1
                self.results[req.uid] = st
        # 2. fused decode tick (decode_block tokens per active lane,
        # one host sync)
        finished = self.engine.step()
        for st in finished:
            m.completed += 1
            self.results[st.req.uid] = st
        m.ticks += 1
        m.tokens += self.engine._generated - gen0
        m.occupancy_sum += self.engine.n_active / self.engine.n_slots
        return finished

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        while (self.queue or self._streams or self.engine.n_active) and \
                self.metrics.ticks < max_ticks:
            self.tick()

    @property
    def drained(self) -> bool:
        return (not self.queue and not self._streams
                and self.engine.n_active == 0)
