"""Continuous-batching admission scheduler.

Policy layer over ServeEngine: FCFS queue with slot-aware admission and
optional prefill/decode interleave ratio. One ``tick()`` =

  0. feed one pending audio chunk to every open stream (finalizing
     streams whose audio has fully arrived);
  1. admit waiting requests while slots are free (each admit = one
     bucketed prefill; streaming requests open a stream and feed their
     first chunk);
  2. one fused decode tick over all active slots — the engine runs
     ``engine.decode_block`` decode steps on device and returns the
     whole per-tick token block after a single host sync, so every
     active lane advances up to ``decode_block`` tokens per tick;
  3. collect finished requests.

Streaming audio (``StreamingAudioRequest``): one chunk is delivered per
tick — the serving-time model of real-time arrival — so a lane decodes
*while* its audio is still arriving (partial hypotheses land in
``RequestState.partials``, one per fed chunk, each up to ``decode_block``
tokens ahead of the last) and is re-anchored at end of audio for the
final transcript.

Metrics track queue latency and time-to-first-token both in ticks and
in wall-clock seconds (``time.monotonic()`` stamped at submit, admit,
and first token — the quantities the gateway's SLO logic prices),
emitted tokens, and slot occupancy. With ``decode_block > 1`` a tick is
a coarser unit: tick-resolution TTFT and queue-wait resolve to one
block (the wall-clock figures do not), and ``tokens`` is the per-tick
token blocks summed.

This scheduler is synchronous and FCFS — the hand-cranked baseline.
The asyncio front door with SLO classes, earliest-deadline-first
admission, and load shedding is ``repro.gateway`` (token-identical to
this loop for the same request set).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

from repro.serving.engine import (Request, RequestState, RejectionError,
                                  ServeEngine, StreamingAudioRequest)


@dataclasses.dataclass
class SchedMetrics:
    ticks: int = 0
    admitted: int = 0
    completed: int = 0
    rejected: int = 0           # failed validation; completed as errors
    tokens: int = 0             # tokens the engine emitted under this
                                # scheduler (prefill firsts + decode
                                # blocks) — tokens/tick > n_active when
                                # decode_block > 1
    occupancy_sum: float = 0.0
    queue_wait_sum: int = 0     # ticks spent waiting, summed over requests
    ttft_sum: int = 0           # ticks from submit to first token
    # wall-clock (seconds) counterparts — time.monotonic() stamped at
    # submit, admit (queue popped, pre-prefill), and first token (the
    # prefill/anchor argmax fetched); tick counts quantize to the block
    # size, these do not
    queue_wait_s_sum: float = 0.0
    ttft_s_sum: float = 0.0

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / max(self.ticks, 1)

    @property
    def mean_ttft(self) -> float:
        return self.ttft_sum / max(self.admitted, 1)

    @property
    def mean_ttft_s(self) -> float:
        return self.ttft_s_sum / max(self.admitted, 1)

    @property
    def mean_queue_wait_s(self) -> float:
        return self.queue_wait_s_sum / max(self.admitted, 1)

    @property
    def tokens_per_tick(self) -> float:
        return self.tokens / max(self.ticks, 1)


class SchedulerStuckError(RuntimeError):
    """``run_until_drained`` exhausted its tick budget with work still
    queued/active — a stuck load must fail loudly, not return quietly
    with partial results."""


class BatchScheduler:
    def __init__(self, engine: ServeEngine, max_admit_per_tick: int = 2):
        self.engine = engine
        self.max_admit_per_tick = max_admit_per_tick
        # (req, t_submit_tick, t_submit_wall)
        self.queue: deque[tuple[Request, int, float]] = deque()
        self.metrics = SchedMetrics()
        self.results: dict[int, RequestState] = {}
        # open streams: slot -> (state, pending frame chunks)
        self._streams: dict[int, tuple[RequestState, deque]] = {}

    def submit(self, req: Request) -> Optional[RequestState]:
        """Queue a request. Requests this engine can never serve
        (too long, missing/oversized enc_frames, ...) are rejected here
        — completed immediately as a failed RequestState in ``results``
        (``error`` message + machine-readable ``error_code``) — so one
        bad request cannot kill the serving loop. Returns the failed
        state for rejected requests, None when queued."""
        err = self.engine.validate(req)
        if err is not None:
            st = RequestState(req=req, slot=-1, pos=0, out=[], done=True,
                              error=str(err), error_code=err.code)
            self.results[req.uid] = st
            self.metrics.rejected += 1
            return st
        self.queue.append((req, self.metrics.ticks, time.monotonic()))
        return None

    def tick(self) -> list[RequestState]:
        m = self.metrics
        gen0 = self.engine._generated
        # 0. deliver one audio chunk per open stream (real-time model);
        # streams whose audio has fully arrived are finalized.
        for slot in list(self._streams):
            st, pending = self._streams[slot]
            self.engine.stream_feed(st, pending.popleft())
            if not pending:
                del self._streams[slot]
                st = self.engine.stream_finalize(st)
                if st.done:
                    m.completed += 1
                    self.results[st.req.uid] = st
        # 1. admission
        admitted = 0
        while (self.queue and self.engine.free
               and admitted < self.max_admit_per_tick):
            req, t_submit, t_wall = self.queue.popleft()
            t_admit = time.monotonic()
            try:
                if isinstance(req, StreamingAudioRequest):
                    st = self.engine.open_stream(req)
                else:
                    st = self.engine.admit(req)
            except ValueError as e:
                # a request submit()'s precheck missed: fail it, keep
                # the serving loop alive
                code = e.rejection.code \
                    if isinstance(e, RejectionError) else None
                st = RequestState(req=req, slot=-1, pos=0, out=[],
                                  done=True, error=str(e),
                                  error_code=code)
                self.results[req.uid] = st
                m.rejected += 1
                continue
            if st is None:      # pool filled since the loop condition
                self.queue.appendleft((req, t_submit, t_wall))
                break
            if isinstance(req, StreamingAudioRequest):
                pending = deque(req.chunks)
                self.engine.stream_feed(st, pending.popleft())
                if pending:
                    self._streams[st.slot] = (st, pending)
                else:
                    st = self.engine.stream_finalize(st)
                    if st.done:
                        m.completed += 1
                        self.results[req.uid] = st
            m.admitted += 1
            m.queue_wait_sum += m.ticks - t_submit
            m.ttft_sum += m.ticks - t_submit   # first token at admit
            m.queue_wait_s_sum += t_admit - t_wall
            # the first token exists once the prefill/anchor returned —
            # for one-shot requests that was admit(), for streams the
            # first stream_feed
            m.ttft_s_sum += time.monotonic() - t_wall
            admitted += 1
            if st.done and st.req.uid not in self.results:
                m.completed += 1
                self.results[req.uid] = st
        # 2. fused decode tick (decode_block tokens per active lane,
        # one host sync)
        finished = self.engine.step()
        for st in finished:
            m.completed += 1
            self.results[st.req.uid] = st
        m.ticks += 1
        m.tokens += self.engine._generated - gen0
        m.occupancy_sum += self.engine.n_active / self.engine.n_slots
        return finished

    def abort(self, uid) -> Optional[RequestState]:
        """Cancel a request by uid wherever it currently lives: still
        queued (completed as CANCELLED without ever touching the
        engine), in flight (``engine.abort`` — the lane's state
        reservations are released and the slot zeroed for reuse), or an
        open stream (pending chunks dropped, stream closed). Returns
        the cancelled state, or None if the uid is unknown/already
        completed. Works for every model family — lane teardown is
        spec-driven in the engine."""
        from repro.serving.engine import RejectCode
        for i, (req, t_submit, t_wall) in enumerate(self.queue):
            if req.uid == uid:
                del self.queue[i]
                st = RequestState(
                    req=req, slot=-1, pos=0, out=[], done=True,
                    error=f"request {uid} cancelled while queued",
                    error_code=RejectCode.CANCELLED)
                self.results[uid] = st
                return st
        for slot, (st, _pending) in list(self._streams.items()):
            if st.req.uid == uid:
                del self._streams[slot]
                self.engine.abort(st)
                self.results[uid] = st
                return st
        for st in list(self.engine.active.values()):
            if st.req.uid == uid:
                self.engine.abort(st)
                self.results[uid] = st
                return st
        return None

    def run_until_drained(self, max_ticks: int = 10_000, *,
                          strict: bool = True) -> bool:
        """Tick until every queued/streaming/active request completes,
        running at most ``max_ticks`` ticks *from this call*. A load
        that fails to drain raises ``SchedulerStuckError`` (default) or,
        with ``strict=False``, returns False — either way a stuck load
        is loud, never a silent partial result. Returns True when
        drained."""
        budget = max_ticks
        while (self.queue or self._streams or self.engine.n_active) \
                and budget > 0:
            self.tick()
            budget -= 1
        if not self.drained:
            if strict:
                raise SchedulerStuckError(
                    f"scheduler not drained after {max_ticks} ticks: "
                    f"{len(self.queue)} queued, {len(self._streams)} "
                    f"open streams, {self.engine.n_active} active lanes")
            return False
        return True

    @property
    def drained(self) -> bool:
        return (not self.queue and not self._streams
                and self.engine.n_active == 0)
