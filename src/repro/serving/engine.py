"""Serving engine: slot-based KV cache + jitted prefill/decode.

Continuous-batching design (vLLM-style, adapted to JAX's static shapes):

* the engine owns a fixed pool of ``n_slots`` cache slots — one batched
  KV/state cache pytree; every decode tick runs **one** jitted step over
  the whole pool with *per-lane positions* (the model's decode path
  accepts ``pos`` as a (B,) vector), so requests at different depths
  batch together;
* prefill runs per-request at a bucketed sequence length (powers of two:
  compile once per bucket) and the resulting cache is scattered into a
  free lane. Bucket-padding junk beyond the prompt is never attendable:
  decode writes position ``pos`` before attending ``[0, pos]``;
* Q8_0 weights (``core.quantize.quantize_tree``) serve through the same
  forward — the paper's quantized serving variant is a flag, not a fork.

Cache-dtype policy (``cache_dtype="bf16" | "q8_0"``): a q8_0 pool stores
int8+f16-scale planes (``models.attention.init_kv_cache``); prefill
caches are quantized before the slot scatter, decode writes quantize the
new token in place, and the decode cache matvec routes through
``dispatch("q8_decode_attention", ...)`` — the paper's Q8_0 LOAD saving
(~0.53x cache bytes/step, ``kernels.q8_attention.ops.cache_traffic_ratio``)
applied to the decode bottleneck.

Encoder-decoder serving (whisper): requests carry ``enc_frames``; admit
encodes them at their exact length (bidirectional attention — padding
would corrupt the states), caches the per-slot encoder K/V in the pool's
cross-cache (padded to ``enc_len``), and decode masks each lane's cross
attention to its true encoder length.

The batch scheduler (scheduler.py) decides admission; this module is the
mechanism: slot allocation, cache scatter, masked decode.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import flags
from repro.core.quantize import quantize_q8_0, stored_bytes
from repro.kernels.api import (DispatchContext, dispatch_counters,
                               dispatch_trace, use_context)
from repro.kernels.q8_attention.ops import cache_traffic_ratio
from repro.models import encdec as encdec_mod
from repro.models.attention import quantize_kv_cache
from repro.models.model import Model
from repro.platforms import Platform, get_platform

EOS_DEFAULT = 2

CACHE_DTYPES = ("bf16", "q8_0")

_ENGINE_SEQ = itertools.count()   # unique dispatch-trace tags per engine


@dataclasses.dataclass
class Request:
    uid: int
    tokens: list             # prompt token ids
    max_new: int = 16
    eos_id: int = EOS_DEFAULT
    # enc-dec (audio) requests: precomputed frame embeddings
    # (S_enc, d_model); required when the served model is enc_dec.
    enc_frames: Optional[Any] = None
    # alternatively, precomputed *encoder states* (S_enc, d_model) —
    # e.g. from the chunked streaming encoder — which skip the
    # engine-side encode entirely (exactly one of the two for enc-dec).
    enc_states: Optional[Any] = None


@dataclasses.dataclass
class AudioRequest(Request):
    """A Request that must carry encoder input — the whisper serving
    path: either ``enc_frames`` (encoded once at admit) or precomputed
    ``enc_states`` (chunked/streaming encode output). Same scheduler/
    engine treatment as text requests; the encoder result is cached per
    slot."""

    def __post_init__(self):
        if self.enc_frames is None and self.enc_states is None:
            raise ValueError(
                f"AudioRequest {self.uid} requires enc_frames or "
                f"enc_states")


@dataclasses.dataclass
class StreamingAudioRequest(Request):
    """An audio request whose encoder frames arrive incrementally.

    ``chunks`` is the list of frame-embedding chunks ((s_i, d_model),
    fixed size except the tail — ``repro.audio.stream`` produces them
    from raw samples). The scheduler feeds one chunk per tick through
    ``ServeEngine.open_stream``/``stream_feed``: each chunk is encoded
    once (block-diagonal chunked encode), the slot's cached encoder K/V
    is *extended* in place, and the lane's ``enc_lens`` grows — decode
    ticks in between emit partial hypotheses (``RequestState.partials``).
    ``stream_finalize`` re-anchors the prompt against the full audio, so
    the final transcript is token-identical to one-shot serving."""

    chunks: Optional[list] = None

    def __post_init__(self):
        if not self.chunks:
            raise ValueError(
                f"StreamingAudioRequest {self.uid} requires a non-empty "
                f"list of frame chunks")
        if self.enc_frames is not None or self.enc_states is not None:
            raise ValueError(
                f"StreamingAudioRequest {self.uid}: frames arrive via "
                f"chunks, not enc_frames/enc_states")


@dataclasses.dataclass
class RequestState:
    req: Request
    slot: int
    pos: int                 # next position to write
    out: list                # generated ids
    done: bool = False
    error: Optional[str] = None   # set when rejected/failed, slot == -1
    # streaming requests: one snapshot of ``out`` per fed audio chunk
    # (the partial hypotheses emitted while audio was still arriving)
    partials: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _StreamState:
    """Engine-side state of one open audio stream (slot-keyed)."""
    states: list                  # encoded chunk states, each (1, s_i, d)
    n_frames: int = 0             # frames fed == valid encoder positions
    anchored: bool = False        # prompt prefill has run at least once


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // 2048) * 2048


class ServeEngine:
    def __init__(self, model: Model, params: Any, *, n_slots: int = 8,
                 max_len: int = 256, enc_len: int = 64,
                 cache_dtype: str = "bf16",
                 platform: Optional[Any] = None,
                 dispatch_ctx: Optional[DispatchContext] = None):
        """``platform``: a registered hardware target (name or
        ``repro.platforms.Platform``). Supplies the default dispatch
        context (``DispatchContext.for_platform``) and enables
        ``energy_report()`` — the paper's joules-per-token accounting on
        the serving path.

        ``dispatch_ctx``: kernel-routing context (budget, backend
        policy — repro.kernels.api) applied while the prefill/decode
        functions trace; None uses the platform-derived (or env/default)
        context. Routing is baked in at first trace, so construct one
        engine per context.

        ``cache_dtype``: "bf16" (dense planes) or "q8_0" (int8+scale
        planes, decode reads via the q8_decode_attention op)."""
        if cache_dtype not in CACHE_DTYPES:
            raise ValueError(f"cache_dtype {cache_dtype!r}: expected one "
                             f"of {CACHE_DTYPES}")
        cfg = model.cfg
        if cache_dtype == "q8_0":
            if flags.BASELINE:
                raise ValueError("cache_dtype='q8_0' needs the stacked "
                                 "decode path (unset REPRO_BASELINE)")
            if cfg.attn_softcap is not None or cfg.sliding_window \
                    is not None or cfg.local_global:
                raise ValueError(
                    f"cache_dtype='q8_0' supports plain softmax decode "
                    f"attention only; {cfg.name} uses softcap/windowed "
                    f"attention")
        self.platform: Optional[Platform] = \
            get_platform(platform) if platform is not None else None
        if dispatch_ctx is None and self.platform is not None:
            # the tag scopes this engine's trace records: two engines on
            # the same platform in one process stay distinguishable
            dispatch_ctx = DispatchContext.for_platform(
                self.platform,
                tag=f"serve:{self.platform.name}#{next(_ENGINE_SEQ)}")
        self.model = model
        self.params = params
        self.dispatch_ctx = dispatch_ctx
        self.n_slots = n_slots
        self.max_len = max_len
        self.enc_len = enc_len
        self.enc_dec = bool(cfg.enc_dec)
        self.cache_dtype = cache_dtype
        cdt = "q8_0" if cache_dtype == "q8_0" else jnp.bfloat16
        self.cache = model.init_cache(n_slots, max_len, enc_len, dtype=cdt)
        self.free = list(range(n_slots))
        self.active: dict[int, RequestState] = {}   # slot -> state
        self._tokens = np.zeros((n_slots, 1), np.int32)
        # parked lanes decode at pos 0 (one attendable position) and the
        # results are discarded; _free_slot zeroes pos/tokens so a dead
        # lane never attends its stale context.
        self._pos = np.zeros((n_slots,), np.int32)
        self._enc_lens = np.zeros((n_slots,), np.int32)
        self._decode = self._build_decode()
        self._prefill_fns: dict[tuple, Any] = {}
        # streaming audio: open streams by slot + jitted encoder helpers
        # (jit retraces per chunk length — fixed chunks + one tail)
        self._streams: dict[int, _StreamState] = {}
        if self.enc_dec:
            cfg_ = cfg
            self._encode = jax.jit(self.model.encode)
            self._cross_kv = jax.jit(
                lambda params, states: encdec_mod.cross_attn_kv(
                    params, cfg_, states))
        # serving-energy accounting (energy_report)
        self._ticks = 0        # executed batched decode steps
        self._generated = 0    # tokens emitted (prefill firsts + decode)

    # ------------------------------------------------------------------
    def _build_decode(self):
        model, enc_dec = self.model, self.enc_dec

        @jax.jit
        def decode(params, cache, tokens, pos, enc_lens):
            batch = {"tokens": tokens}
            if enc_dec:
                batch["enc_lens"] = enc_lens
            logits, new_cache = model.forward(
                params, batch, mode="decode", cache=cache, pos=pos)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, new_cache

        return decode

    def _prefill_fn(self, bucket: int, enc_s: Optional[int] = None,
                    from_states: bool = False):
        """Jitted prefill, keyed (token bucket, encoder length, input
        kind). ``from_states=True`` takes precomputed encoder states
        (streaming chunked encode / ``Request.enc_states``) instead of
        frame embeddings, skipping the in-prefill encoder pass."""
        key = (bucket, enc_s, from_states)
        if key not in self._prefill_fns:
            model, max_len, enc_len = self.model, self.max_len, self.enc_len
            q8 = self.cache_dtype == "q8_0"
            enc_key = "enc_states" if from_states else "enc_frames"

            @jax.jit
            def prefill(params, tokens, enc=None):
                cache = model.init_cache(1, max_len, enc_len)
                batch = {"tokens": tokens}
                if enc is not None:
                    batch[enc_key] = enc
                logits, cache = model.forward(params, batch,
                                              mode="prefill", cache=cache)
                if q8:
                    cache = quantize_kv_cache(cache)
                return logits, cache

            self._prefill_fns[key] = prefill
        return self._prefill_fns[key]

    # ------------------------------------------------------------------
    def validate(self, req: Request) -> Optional[str]:
        """Admission precheck: an error string (request can never be
        served by this engine), or None. The scheduler rejects failing
        requests at submit() instead of dying mid-tick."""
        n = len(req.tokens)
        if n + req.max_new >= self.max_len:
            return (f"request {req.uid} too long for engine "
                    f"({n}+{req.max_new} vs {self.max_len})")
        d_model = self.model.cfg.d_model
        if self.enc_dec:
            if isinstance(req, StreamingAudioRequest):
                total = 0
                for i, c in enumerate(req.chunks):
                    shp = np.shape(c)
                    if len(shp) != 2 or shp[1] != d_model or shp[0] < 1:
                        return (f"request {req.uid}: chunk {i} must be "
                                f"(s, {d_model}) with s >= 1, got {shp}")
                    total += shp[0]
                if total > self.enc_len:
                    return (f"request {req.uid}: {total} streamed encoder "
                            f"frames exceed the pool enc_len "
                            f"{self.enc_len}")
                return None
            if req.enc_frames is None and req.enc_states is None:
                return (f"request {req.uid}: enc-dec model "
                        f"{self.model.cfg.name} requires enc_frames or "
                        f"enc_states")
            if req.enc_frames is not None and req.enc_states is not None:
                return (f"request {req.uid}: pass enc_frames or "
                        f"enc_states, not both")
            enc = req.enc_frames if req.enc_frames is not None \
                else req.enc_states
            what = "enc_frames" if req.enc_frames is not None \
                else "enc_states"
            shp = np.shape(enc)
            if len(shp) != 2 or shp[1] != d_model:
                return (f"request {req.uid}: {what} must be "
                        f"(S_enc, {d_model}), got {shp}")
            if shp[0] > self.enc_len:
                return (f"request {req.uid}: {shp[0]} encoder "
                        f"positions exceed the pool enc_len "
                        f"{self.enc_len}")
        elif req.enc_frames is not None or req.enc_states is not None \
                or isinstance(req, StreamingAudioRequest):
            return (f"request {req.uid}: encoder input on decoder-only "
                    f"model {self.model.cfg.name}")
        return None

    def admit(self, req: Request) -> Optional[RequestState]:
        """Prefill a request into a free slot; None if the pool is full.
        Raises ValueError for requests that can never be served (use
        ``validate`` to precheck)."""
        if isinstance(req, StreamingAudioRequest):
            raise ValueError(
                f"request {req.uid}: streaming requests are served via "
                f"open_stream/stream_feed (or BatchScheduler.submit)")
        if not self.free:
            return None
        err = self.validate(req)
        if err is not None:
            raise ValueError(err)
        n = len(req.tokens)
        slot = self.free.pop()
        bucket = min(_bucket(n), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.tokens
        enc_s = None
        with use_context(self.dispatch_ctx):
            if self.enc_dec and req.enc_states is not None:
                # precomputed encoder states (chunked/streaming encode):
                # prefill skips the encoder pass entirely.
                states = jnp.asarray(req.enc_states)[None]
                enc_s = int(states.shape[1])
                logits, cache1 = self._prefill_fn(
                    bucket, enc_s, from_states=True)(
                        self.params, jnp.asarray(toks), states)
            elif self.enc_dec:
                # encode at the exact frame count: the encoder attends
                # bidirectionally, so bucket padding would corrupt every
                # frame state (one compile per distinct enc_s).
                frames = jnp.asarray(np.asarray(req.enc_frames),
                                     jnp.float32)[None]
                enc_s = int(frames.shape[1])
                logits, cache1 = self._prefill_fn(bucket, enc_s)(
                    self.params, jnp.asarray(toks), frames)
            else:
                logits, cache1 = self._prefill_fn(bucket)(
                    self.params, jnp.asarray(toks))
        self.cache = _scatter_slot(self.cache, cache1, slot)
        first = int(np.argmax(np.asarray(logits)[0, n - 1]))
        self._generated += 1
        st = RequestState(req=req, slot=slot, pos=n, out=[first])
        self._tokens[slot, 0] = first
        self._pos[slot] = n
        self._enc_lens[slot] = enc_s or 0
        if first == req.eos_id or len(st.out) >= req.max_new:
            st.done = True
            self._free_slot(slot)
        else:
            self.active[slot] = st
        return st

    # ---------------------------------------------------- streaming audio
    def open_stream(self, req: StreamingAudioRequest
                    ) -> Optional[RequestState]:
        """Allocate a slot for a streaming audio request; None if the
        pool is full. No prefill happens yet — the first ``stream_feed``
        anchors the prompt against the first chunk's states."""
        if not isinstance(req, StreamingAudioRequest):
            raise ValueError(f"request {req.uid}: open_stream takes a "
                             f"StreamingAudioRequest")
        err = self.validate(req)
        if err is not None:
            raise ValueError(err)
        if not self.free:
            return None
        slot = self.free.pop()
        st = RequestState(req=req, slot=slot, pos=0, out=[])
        self._streams[slot] = _StreamState(states=[])
        return st

    def stream_feed(self, st: RequestState, frames) -> RequestState:
        """Feed one chunk of frame embeddings ((s, d_model)) to an open
        stream: encode the chunk (block-diagonal — its states never
        change as more audio arrives), extend the slot's cached cross
        K/V in place, and grow the lane's ``enc_lens`` so the very next
        decode tick attends the new audio. Appends a partial-hypothesis
        snapshot to ``st.partials``."""
        slot = st.slot
        ss = self._streams[slot]
        fr = jnp.asarray(np.asarray(frames, np.float32))[None]
        s_new = int(fr.shape[1])
        if ss.n_frames + s_new > self.enc_len:
            raise ValueError(
                f"request {st.req.uid}: stream overflows the pool "
                f"enc_len {self.enc_len} ({ss.n_frames}+{s_new})")
        with use_context(self.dispatch_ctx):
            states = self._encode(self.params, fr)
        ss.states.append(states)
        first_feed = not ss.anchored
        if not first_feed:
            # incremental extension: project the new states through each
            # decoder layer's cross K/V and write them after the
            # already-cached positions (quantizing for a q8_0 pool).
            with use_context(self.dispatch_ctx):
                k, v = self._cross_kv(self.params, states)
            self._extend_cross(slot, k, v, ss.n_frames)
        ss.n_frames += s_new
        if first_feed:
            self._anchor(st, ss, final=False)
        else:
            self._enc_lens[slot] = ss.n_frames
        st.partials.append(list(st.out))
        return st

    def stream_finalize(self, st: RequestState) -> RequestState:
        """End of audio: re-anchor the prompt against the *full* encoder
        states (one bucketed prefill — the encoder work is NOT redone),
        so the final transcript is token-identical to one-shot serving
        of the same chunked audio. The mid-stream hypothesis is kept as
        the last entry of ``st.partials``."""
        slot = st.slot
        ss = self._streams.pop(slot)
        if st.out:
            st.partials.append(list(st.out))
        self.active.pop(slot, None)
        self._anchor(st, ss, final=True)
        return st

    def _anchor(self, st: RequestState, ss: _StreamState,
                final: bool) -> None:
        """Prompt prefill for a streaming lane over the states fed so
        far (the same jitted states-prefill the one-shot path uses; the
        scatter re-writes the slot's cross planes with values identical
        to the incremental extension)."""
        req, slot = st.req, st.slot
        n = len(req.tokens)
        states = ss.states[0] if len(ss.states) == 1 \
            else jnp.concatenate(ss.states, axis=1)
        bucket = min(_bucket(n), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.tokens
        with use_context(self.dispatch_ctx):
            logits, cache1 = self._prefill_fn(
                bucket, int(states.shape[1]), from_states=True)(
                    self.params, jnp.asarray(toks), states)
        self.cache = _scatter_slot(self.cache, cache1, slot)
        first = int(np.argmax(np.asarray(logits)[0, n - 1]))
        self._generated += 1
        ss.anchored = True
        st.out = [first]
        st.pos = n
        self._tokens[slot, 0] = first
        self._pos[slot] = n
        self._enc_lens[slot] = ss.n_frames
        finished = first == req.eos_id or req.max_new <= 1
        if final and finished:
            st.done = True
            self._free_slot(slot)
        elif not finished:
            self.active[slot] = st
        # mid-stream + finished: lane pauses (stays allocated, resumes
        # at the next anchor)

    def _extend_cross(self, slot: int, k, v, offset: int) -> None:
        """Write new cross-K/V positions ((L, 1, s_new, Hkv, ·)) into
        lane ``slot`` of the pool's cross cache at ``offset``."""
        cross = self.cache["layers"]["cross"]

        def dus(plane, new):
            return jax.lax.dynamic_update_slice(
                plane, new.astype(plane.dtype), (0, slot, offset, 0, 0))

        if self.cache_dtype == "q8_0":
            kt = quantize_q8_0(k, axis=-1)
            vt = quantize_q8_0(v, axis=-1)
            new_cross = {"kq": dus(cross["kq"], kt.q),
                         "ks": dus(cross["ks"], kt.scale),
                         "vq": dus(cross["vq"], vt.q),
                         "vs": dus(cross["vs"], vt.scale)}
        else:
            new_cross = {"k": dus(cross["k"], k), "v": dus(cross["v"], v)}
        self.cache = {"layers": {**self.cache["layers"],
                                 "cross": new_cross}}

    def encode_chunks(self, chunks) -> jnp.ndarray:
        """Encode a list of frame-embedding chunks through the engine's
        jitted per-size encoder — the exact functions ``stream_feed``
        uses — and concatenate the states (1, sum(s_i), d_model). The
        one-shot ``transcribe`` path uses this so its states are
        bit-identical to the streaming path's."""
        outs = []
        with use_context(self.dispatch_ctx):
            for c in chunks:
                fr = jnp.asarray(np.asarray(c, np.float32))[None]
                outs.append(self._encode(self.params, fr))
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)

    @property
    def n_streams(self) -> int:
        """Open (not yet finalized) audio streams."""
        return len(self._streams)

    # ------------------------------------------------------------------
    def step(self) -> list[RequestState]:
        """One batched decode tick over the whole pool."""
        if not self.active:
            return []
        with use_context(self.dispatch_ctx):
            nxt, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self._tokens),
                jnp.asarray(self._pos), jnp.asarray(self._enc_lens))
        nxt = np.asarray(nxt)
        self._ticks += 1
        self._generated += len(self.active)
        finished = []
        for slot, st in list(self.active.items()):
            tok = int(nxt[slot])
            st.out.append(tok)
            st.pos += 1
            self._tokens[slot, 0] = tok
            self._pos[slot] = st.pos
            if tok == st.req.eos_id or len(st.out) >= st.req.max_new \
                    or st.pos >= self.max_len - 1:
                if slot in self._streams:
                    # mid-stream hypothesis complete: pause the lane
                    # (keep the slot and its growing encoder cache);
                    # stream_finalize re-anchors and decodes the final
                    # transcript.
                    self.active.pop(slot)
                    continue
                st.done = True
                self.active.pop(slot)
                self._free_slot(slot)
                finished.append(st)
        return finished

    def _free_slot(self, slot: int) -> None:
        """Return a lane to the pool and zero its decode inputs — a
        parked lane then attends exactly one (stale but harmless)
        position instead of its full dead context."""
        self.free.append(slot)
        self._tokens[slot, 0] = 0
        self._pos[slot] = 0
        self._enc_lens[slot] = 0

    @property
    def n_active(self) -> int:
        return len(self.active)

    # ------------------------------------------------------------------
    def cache_report(self) -> dict:
        """Cache footprint / decode-traffic accounting.

        ``bytes_per_step`` is the full-pool KV stream of one decode tick
        (this dense implementation reads every cache position and masks
        after the dot — exactly the paper's LOAD term). The analytic
        per-token figure uses ``core.quantize.stored_bytes`` under the
        paper's dense packing (C3)."""
        kv_bytes, state_bytes = _cache_bytes(self.cache)
        cfg = self.model.cfg
        dt = "q8_0" if self.cache_dtype == "q8_0" else "bf16"
        per_tok = 2 * cfg.n_layers * stored_bytes(
            (cfg.n_kv_heads, cfg.head_dim), dt)
        return {
            "cache_dtype": self.cache_dtype,
            "kv_bytes_total": kv_bytes,
            "state_bytes_total": state_bytes,
            "bytes_per_step": kv_bytes,
            "self_kv_bytes_per_token": per_tok,
            "traffic_ratio_vs_bf16":
                cache_traffic_ratio() if self.cache_dtype == "q8_0" else 1.0,
        }

    def dispatch_report(self) -> dict:
        """Kernel-routing counters (trace-time, keyed (op, decision,
        backend); process-global — reset via api.reset_dispatch_log())
        plus the engine's cache footprint/traffic accounting."""
        return {
            "counters": dict(dispatch_counters()),
            "cache": self.cache_report(),
        }

    # ------------------------------------------------------------------
    def reset_serve_stats(self) -> None:
        """Zero the serve-energy accounting (executed ticks / emitted
        tokens) so the next ``energy_report()`` prices only work from
        this point on. Per-call reports on a reused engine
        (``repro.transcribe(engine=...)``) reset before serving."""
        self._ticks = 0
        self._generated = 0

    def _param_stats(self) -> tuple[int, int]:
        """(element count, stored bytes) of the served parameters."""
        leaves = jax.tree.leaves(self.params)
        return (sum(int(l.size) for l in leaves),
                sum(int(l.nbytes) for l in leaves))

    def energy_report(self, kernel: str = "fp16") -> dict:
        """Joules-per-token / PDP accounting for the serve so far on the
        engine's platform — the paper's headline metric (Eq. 1), live on
        the serving path.

        The decode phase dominates serving energy, and every decode tick
        streams the weights plus the whole KV pool through the cache
        matvec; the model here is the platform roofline over exactly
        those terms:

        * memory: ``ticks x (weight_bytes + cache bytes/step)`` at the
          platform's DRAM/HBM bandwidth,
        * compute: ``2 x N_params`` FLOPs per generated token at the
          platform's ``kernel``-dtype rate,
        * modeled latency = max(memory, compute) (the binding resource),
        * power: the platform ``PowerModel`` — Table-II curve targets
          interpolate at their LMM size for the ``kernel`` family
          ("fp16" | "q8_0" — the served weight family, *not* the cache
          dtype); flat targets scale nominal power by compute
          utilization.

        The dispatch trace records stamped with this platform fold in as
        the ACCEL/HOST mix (``accel_flops_share``); cache traffic folds
        in via ``cache_report()`` — so a q8_0 cache pool shows up
        directly as a smaller ``cache_energy_j``.
        """
        if self.platform is None:
            raise ValueError(
                "energy_report() needs a platform: construct the engine "
                "with ServeEngine(..., platform='imax3-28nm/32k')")
        p = self.platform
        cache = self.cache_report()
        n_elems, weight_bytes = self._param_stats()
        ticks = self._ticks
        tokens = self._generated
        cache_bytes = ticks * cache["bytes_per_step"]
        stream_bytes = ticks * weight_bytes + cache_bytes
        flops = 2.0 * n_elems * tokens
        bw = max(p.memory.main_bw, 1e-9)
        rate = p.peak_flops("q8_0" if kernel == "q8_0" else "f16")
        t_mem = stream_bytes / bw
        t_comp = flops / rate
        latency_s = max(t_mem, t_comp)
        util = t_comp / latency_s if latency_s > 0 else 0.0
        power_w = p.power.power(kernel, p.memory.local_bytes or None,
                                util=util)
        energy_j = latency_s * power_w
        # ACCEL/HOST mix from the trace records THIS engine produced
        # (its context's unique tag); a caller-supplied dispatch_ctx has
        # no engine tag, so fall back to platform-name attribution
        tag = self.dispatch_ctx.tag if self.dispatch_ctx else None
        if tag:
            recs = [r for r in dispatch_trace() if r.tag == tag]
        else:
            recs = [r for r in dispatch_trace() if r.platform == p.name]
        accel_flops = sum(r.spec.flops for r in recs
                          if r.decision == "accel")
        trace_flops = sum(r.spec.flops for r in recs)
        return {
            "platform": p.name,
            "kernel": kernel,
            "cache_dtype": self.cache_dtype,
            "ticks": ticks,
            "tokens": tokens,
            "weight_bytes": weight_bytes,
            "cache_bytes_per_step": cache["bytes_per_step"],
            "stream_bytes_total": stream_bytes,
            "modeled_flops": flops,
            "memory_s": t_mem,
            "compute_s": t_comp,
            "latency_s": latency_s,
            "bound": "memory" if t_mem >= t_comp else "compute",
            "power_w": power_w,
            "pdp_j": energy_j,
            "joules_per_token": energy_j / max(tokens, 1),
            "cache_energy_j": (cache_bytes / bw) * power_w,
            "accel_flops_share":
                accel_flops / trace_flops if trace_flops else 0.0,
            "trace_records": len(recs),
        }


def _cache_bytes(tree) -> tuple[int, int]:
    """(KV-plane bytes, recurrent-state bytes) of a cache pytree."""
    if isinstance(tree, dict):
        if set(tree) in ({"k", "v"}, {"kq", "ks", "vq", "vs"}):
            return sum(int(l.nbytes) for l in jax.tree.leaves(tree)), 0
        kv = st = 0
        for sub in tree.values():
            a, b = _cache_bytes(sub)
            kv += a
            st += b
        return kv, st
    return 0, sum(int(l.nbytes) for l in jax.tree.leaves(tree))


def _scatter_slot(pool: Any, one: Any, slot: int) -> Any:
    """Write a batch-1 cache pytree into lane ``slot`` of the pool.

    Every cache leaf is (stacked_layers, B, ...) — transformer segments,
    encdec layers, and tails all stack with jnp.broadcast_to /scan — so
    the slot axis is axis 1 throughout."""
    def scat(p, o):
        assert p.shape[0] == o.shape[0] and o.shape[1] == 1, (p.shape, o.shape)
        return jax.lax.dynamic_update_slice_in_dim(
            p, o.astype(p.dtype), slot, axis=1)
    return jax.tree.map(scat, pool, one)
