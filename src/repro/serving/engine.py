"""Serving engine: spec-declared per-lane state + jitted prefill/decode.

Per-lane state is **model-declared** (``Model.state_spec()`` →
``LaneStateSpec``), not assumed: attention families carry slot/paged KV
planes, SSM/mLSTM/sLSTM families carry constant-size recurrent buffers
(``conv``/``h``, ``(C, n, m)``, ``(c, n, h, m)``) that are fully
rewritten every step, MoE families add per-lane expert-routing counters
— and one engine serves all of them. Admission (exact-length prefill
for recurrent lanes), the fused decode tick, donation, q8_0 storage,
abort/free, and the traffic/energy accounting all key off the spec;
``LaneStatePool`` (lanestate.py) is the host-side ledger of which state
each live lane holds.

Continuous-batching design (vLLM-style, adapted to JAX's static shapes):

* the engine owns a fixed pool of ``n_slots`` cache slots — one batched
  KV/state cache pytree; every decode tick runs **one** jitted step over
  the whole pool with *per-lane positions* (the model's decode path
  accepts ``pos`` as a (B,) vector), so requests at different depths
  batch together;
* prefill runs per-request at a bucketed sequence length (powers of two:
  compile once per bucket) and the resulting cache is scattered into a
  free lane **inside the prefill jit** (the pool buffer is donated, so
  the scatter is an in-place lane write, and only the first-token argmax
  — a single scalar — crosses back to host, never the
  ``[1, bucket, vocab]`` logits); lanes whose spec sets
  ``prefill_exact`` (recurrent state — scans fold padding into the
  state) prefill at the exact prompt length instead;
* Q8_0 weights (``core.quantize.quantize_tree``) serve through the same
  forward — the paper's quantized serving variant is a flag, not a fork.

Device-resident fused decode (``decode_block``): all per-lane decode
state — last token, position, encoder length, active/EOS masks, emitted
counts, per-lane ``max_new`` budgets — lives in device arrays owned by
the engine. One ``step()`` runs ``decode_block`` decode steps fused in a
single jit (``lax.scan`` over the step body) with the cache pool and
state buffers donated, and syncs to host **once per tick**: the
``(K, n_slots)`` token block plus its emit mask. On-device
EOS/max-new/max-len masking freezes finished lanes mid-scan (their
token/position stop advancing and their emits are masked off), so a
``K``-step fused tick is token-identical to ``K`` single steps. Host
Python then replays the emit mask to run the bookkeeping no jit can:
appending to ``RequestState.out``, freeing slots, pausing streams.

Sync-point inventory (everything that crosses host<->device):
  * ``admit()``/``_anchor()`` — one int32 scalar (the first token);
  * ``step()``       — one fetch of the ``(K, n_slots)`` token block +
    emit mask (``_host_syncs`` counts these; ``_decode_steps`` counts
    the fused decode steps they bought);
  * everything else (lane-state updates at admit/free, stream cross-K/V
    extension) is host->device only and never blocks.

Cache-dtype policy (``cache_dtype="bf16" | "q8_0"``): a q8_0 pool stores
int8+f16-scale planes (``models.attention.init_kv_cache``); prefill
caches are quantized before the slot scatter, decode writes quantize the
new token in place, and the decode cache matvec routes through
``dispatch("q8_decode_attention", ...)`` — the paper's Q8_0 LOAD saving
(~0.53x cache bytes/step, ``kernels.q8_attention.ops.cache_traffic_ratio``)
applied to the decode bottleneck. Recurrent state stays at the spec's
``recurrent_dtype`` (bf16) in both tiers — it is O(1)-sized and fully
rewritten every step, so there is no LOAD win to quantize for; models
with no KV planes at all (pure xLSTM/SSM) reject q8_0 outright.

Encoder-decoder serving (whisper): requests carry ``enc_frames``; admit
encodes them at their exact length (bidirectional attention — padding
would corrupt the states), caches the per-slot encoder K/V in the pool's
cross-cache (padded to ``enc_len``), and decode masks each lane's cross
attention to its true encoder length.

The batch scheduler (scheduler.py) decides admission; this module is the
mechanism: slot allocation, cache scatter, masked fused decode.
"""

from __future__ import annotations

import contextlib
import dataclasses
import enum
import functools
import hashlib
import itertools
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import flags
from repro.core.quantize import (Q4Tensor, Q8Tensor, quantize_q4_0,
                                 quantize_q8_0, quantize_tree,
                                 stored_bytes)
from repro.kernels.api import (DispatchContext, dispatch_counters,
                               dispatch_trace, use_context)
from repro.kernels.q4_attention.ops import cache_traffic_ratio_q4
from repro.kernels.q8_attention.ops import cache_traffic_ratio
from repro.models import encdec as encdec_mod
from repro.models.attention import quantize_kv_cache
from repro.models.model import Model
from repro.paging import PageAllocError, PagedKV
from repro.serving.lanestate import LaneStatePool
from repro.platforms import Platform, get_platform


@contextlib.contextmanager
def _quiet_donation():
    """CPU has no donation support; jit warns once per compile that the
    donated pool/state buffers fell back to copies. The donation is
    still correct (and is what makes TPU/GPU decode update the pool in
    place), so silence exactly that warning — scoped to the engine's
    own jit calls, never process-wide."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


EOS_DEFAULT = 2

CACHE_DTYPES = ("bf16", "q8_0", "q4_0")

QUANT_TIERS = ("q8_0", "q4_0")

_ENGINE_SEQ = itertools.count()   # unique dispatch-trace tags per engine


class RejectCode(enum.Enum):
    """Machine-readable rejection/shed reasons. The first group is
    produced by ``ServeEngine.validate`` (the request can never be
    served by this engine); the second by the gateway's admission and
    lifecycle paths (``repro.gateway`` — load shedding, deadlines,
    client-side aborts). One enum so every failed request, wherever it
    failed, classifies the same way in metrics and tests."""

    # --- engine validation
    TOO_LONG = "too_long"                        # prompt+max_new vs max_len
    MISSING_ENC_INPUT = "missing_enc_input"      # enc-dec model, no frames
    AMBIGUOUS_ENC_INPUT = "ambiguous_enc_input"  # frames AND states given
    BAD_ENC_SHAPE = "bad_enc_shape"              # misshapen frames/chunk
    ENC_OVERFLOW = "enc_overflow"                # frames exceed pool enc_len
    ENC_ON_DECODER_ONLY = "enc_on_decoder_only"  # frames for a text model
    POOL_EXHAUSTED = "pool_exhausted"            # paged KV pool out of pages
    #   (validate: the request's page demand exceeds the whole pool —
    #    permanent; gateway: load-shed because free pages ran low)
    # --- gateway admission / lifecycle (repro.gateway)
    QUEUE_FULL = "queue_full"                    # bounded-queue backpressure
    DEADLINE_UNMEETABLE = "deadline_unmeetable"  # shed at submit (estimate)
    DEADLINE_MISSED = "deadline_missed"          # shed at admit, pre-prefill
    CANCELLED = "cancelled"                      # client cancelled mid-flight
    TIMEOUT = "timeout"                          # client-side timeout_s hit


@dataclasses.dataclass(frozen=True)
class Rejection:
    """A structured rejection: ``code`` for machines, ``message`` for
    humans. ``str(rejection)`` is the human message, so callers that
    only ever stored the string keep working."""

    code: RejectCode
    message: str

    def __str__(self) -> str:
        return self.message


class RejectionError(ValueError):
    """``admit``/``open_stream``/``stream_feed`` failure carrying the
    structured ``Rejection`` (``.rejection``); still a ValueError for
    existing callers."""

    def __init__(self, rejection: Rejection):
        super().__init__(rejection.message)
        self.rejection = rejection


@dataclasses.dataclass
class Request:
    uid: int
    tokens: list             # prompt token ids
    max_new: int = 16
    eos_id: int = EOS_DEFAULT
    # enc-dec (audio) requests: precomputed frame embeddings
    # (S_enc, d_model); required when the served model is enc_dec.
    enc_frames: Optional[Any] = None
    # alternatively, precomputed *encoder states* (S_enc, d_model) —
    # e.g. from the chunked streaming encoder — which skip the
    # engine-side encode entirely (exactly one of the two for enc-dec).
    enc_states: Optional[Any] = None


@dataclasses.dataclass
class AudioRequest(Request):
    """A Request that must carry encoder input — the whisper serving
    path: either ``enc_frames`` (encoded once at admit) or precomputed
    ``enc_states`` (chunked/streaming encode output). Same scheduler/
    engine treatment as text requests; the encoder result is cached per
    slot."""

    def __post_init__(self):
        if self.enc_frames is None and self.enc_states is None:
            raise ValueError(
                f"AudioRequest {self.uid} requires enc_frames or "
                f"enc_states")


@dataclasses.dataclass
class StreamingAudioRequest(Request):
    """An audio request whose encoder frames arrive incrementally.

    ``chunks`` is the list of frame-embedding chunks ((s_i, d_model),
    fixed size except the tail — ``repro.audio.stream`` produces them
    from raw samples). The scheduler feeds one chunk per tick through
    ``ServeEngine.open_stream``/``stream_feed``: each chunk is encoded
    once (block-diagonal chunked encode), the slot's cached encoder K/V
    is *extended* in place, and the lane's ``enc_lens`` grows — decode
    ticks in between emit partial hypotheses (``RequestState.partials``).
    ``stream_finalize`` re-anchors the prompt against the full audio, so
    the final transcript is token-identical to one-shot serving."""

    chunks: Optional[list] = None

    def __post_init__(self):
        if not self.chunks:
            raise ValueError(
                f"StreamingAudioRequest {self.uid} requires a non-empty "
                f"list of frame chunks")
        if self.enc_frames is not None or self.enc_states is not None:
            raise ValueError(
                f"StreamingAudioRequest {self.uid}: frames arrive via "
                f"chunks, not enc_frames/enc_states")


@dataclasses.dataclass
class RequestState:
    req: Request
    slot: int
    pos: int                 # next position to write
    out: list                # generated ids
    done: bool = False
    error: Optional[str] = None   # set when rejected/failed, slot == -1
    error_code: Optional[RejectCode] = None   # machine-readable reason
    # streaming requests: one snapshot of ``out`` per fed audio chunk
    # (the partial hypotheses emitted while audio was still arriving)
    partials: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class PendingTick:
    """A dispatched-but-unfetched fused decode tick (``step_begin``):
    the device arrays holding the ``(k, n_slots)`` token block and emit
    mask, still materializing on device until ``step_fetch`` blocks on
    them."""

    k: int
    tok_blk: Any
    emit_blk: Any


@dataclasses.dataclass
class _StreamState:
    """Engine-side state of one open audio stream (slot-keyed)."""
    states: list                  # encoded chunk states, each (1, s_i, d)
    n_frames: int = 0             # frames fed == valid encoder positions
    anchored: bool = False        # prompt prefill has run at least once


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // 2048) * 2048


class ServeEngine:
    def __init__(self, model: Model, params: Any, *, n_slots: int = 8,
                 max_len: int = 256, enc_len: int = 64,
                 cache_dtype: str = "bf16",
                 decode_block: int = 1,
                 platform: Optional[Any] = None,
                 dispatch_ctx: Optional[DispatchContext] = None,
                 paged: bool = False, page_size: int = 8,
                 n_pages: Optional[int] = None,
                 n_cross_pages: Optional[int] = None,
                 spec_k: int = 0, draft_dtype: str = "q4_0",
                 draft_params: Optional[Any] = None):
        """``platform``: a registered hardware target (name or
        ``repro.platforms.Platform``). Supplies the default dispatch
        context (``DispatchContext.for_platform``) and enables
        ``energy_report()`` — the paper's joules-per-token accounting on
        the serving path.

        ``dispatch_ctx``: kernel-routing context (budget, backend
        policy — repro.kernels.api) applied while the prefill/decode
        functions trace; None uses the platform-derived (or env/default)
        context. Routing is baked in at first trace, so construct one
        engine per context.

        ``cache_dtype``: "bf16" (dense planes), "q8_0" (int8+scale
        planes, decode reads via the q8_decode_attention op), or
        "q4_0" (nibble-packed uint8+scale planes via
        q4_decode_attention — ~0.28x bf16 cache bytes/step).

        ``spec_k``: > 0 enables self-speculative decoding — each round
        drafts ``spec_k - 1`` tokens with ``draft_dtype``-quantized
        weights and verifies all ``spec_k`` positions in ONE full-model
        forward, inside the same donated tick (still exactly one host
        sync per tick). ``decode_block`` must be a multiple of
        ``spec_k``. Greedy decode only; token-identical to plain
        serving. ``draft_params`` overrides the engine-built draft
        weights (``quantize_tree(params, tier=draft_dtype)``) — pass it
        when the served params are already quantized.

        ``decode_block``: decode steps fused per ``step()`` tick (one
        host sync per tick regardless of the block size). A mutable
        knob — ``engine.decode_block = 16`` retunes a live engine; one
        compile per distinct block size.

        ``paged=True`` (enc-dec only): the per-lane slot pool becomes a
        shared page pool (``repro.paging``) — ``n_pages`` self-KV and
        ``n_cross_pages`` cross-KV pages of ``page_size`` tokens (page 0
        is reserved scratch; defaults size the pools to the slot pool's
        byte budget), with per-lane page tables carried through the
        donated decode jit. Lanes hold ``ceil((n+max_new)/P)`` self and
        ``ceil(enc_s/P)`` cross pages — actual request bytes, not
        ``max_len``/``enc_len`` padding — and identical anchor-prompt /
        audio prefixes share pages copy-on-write. Decode output is
        token-identical to the slot pool (same projections, same masked
        softmax over the gathered pages)."""
        if cache_dtype not in CACHE_DTYPES:
            raise ValueError(f"cache_dtype {cache_dtype!r}: expected one "
                             f"of {CACHE_DTYPES}")
        if int(decode_block) < 1:
            raise ValueError(f"decode_block must be >= 1, got "
                             f"{decode_block}")
        cfg = model.cfg
        # the model-declared per-lane state (LaneStateSpec): which state
        # kinds a lane carries, how prefill must run, and whether the
        # q8_0 tier applies — every family-specific decision below keys
        # off this instead of the config
        self.spec = model.state_spec()
        if cache_dtype in QUANT_TIERS:
            if flags.BASELINE:
                raise ValueError(f"cache_dtype={cache_dtype!r} needs the "
                                 f"stacked decode path (unset "
                                 f"REPRO_BASELINE)")
            if not self.spec.self_kv and not self.spec.cross_kv:
                raise ValueError(
                    f"cache_dtype={cache_dtype!r} quantizes attention KV "
                    f"planes; {cfg.name} lanes carry only recurrent "
                    f"state ({'/'.join(self.spec.recurrent)}) — serve it "
                    f"with cache_dtype='bf16'")
            if cfg.attn_softcap is not None or cfg.sliding_window \
                    is not None or cfg.local_global:
                raise ValueError(
                    f"cache_dtype={cache_dtype!r} supports plain softmax "
                    f"decode attention only; {cfg.name} uses "
                    f"softcap/windowed attention")
            if cfg.head_dim % 32:
                raise ValueError(
                    f"cache_dtype={cache_dtype!r} blocks scales 32-wide "
                    f"along head_dim; {cfg.name} has "
                    f"head_dim={cfg.head_dim}")
            if not self.spec.supports_tier(cache_dtype):
                raise ValueError(
                    f"{cfg.name} declares quant tiers "
                    f"{self.spec.quant_tiers}; cache_dtype="
                    f"{cache_dtype!r} is not among them")
        self.platform: Optional[Platform] = \
            get_platform(platform) if platform is not None else None
        if dispatch_ctx is None and self.platform is not None:
            # the tag scopes this engine's trace records: two engines on
            # the same platform in one process stay distinguishable
            dispatch_ctx = DispatchContext.for_platform(
                self.platform,
                tag=f"serve:{self.platform.name}#{next(_ENGINE_SEQ)}")
        self.model = model
        self.params = params
        self.dispatch_ctx = dispatch_ctx
        self.n_slots = n_slots
        self.max_len = max_len
        self.enc_len = enc_len
        self.enc_dec = bool(cfg.enc_dec)
        self.cache_dtype = cache_dtype
        self.decode_block = int(decode_block)
        cdt = cache_dtype if cache_dtype in QUANT_TIERS else jnp.bfloat16
        # --- self-speculative decoding (draft with quantized weights,
        # verify every position in one full-model multi-query forward)
        self.spec_k = int(spec_k)
        self.draft_dtype = draft_dtype
        self.draft_params = None
        if self.spec_k:
            if self.spec_k < 2:
                raise ValueError(f"spec_k must be >= 2 (1 draft + 1 "
                                 f"verify minimum), got {spec_k}")
            if flags.BASELINE:
                raise ValueError("speculative decoding needs the stacked "
                                 "decode path (unset REPRO_BASELINE)")
            if draft_dtype not in QUANT_TIERS:
                raise ValueError(f"draft_dtype {draft_dtype!r}: expected "
                                 f"one of {QUANT_TIERS}")
            if not self.spec.self_kv:
                raise ValueError(
                    f"speculative decoding rewinds self-KV write "
                    f"cursors; {cfg.name} lanes carry "
                    f"{'/'.join(self.spec.recurrent) or 'no'} recurrent "
                    f"state, which cannot be rolled back")
            if self.spec.moe_experts:
                raise ValueError(
                    f"speculative decoding does not thread the per-lane "
                    f"routing counters through draft/verify; {cfg.name} "
                    f"is MoE")
            if cfg.attn_softcap is not None or cfg.sliding_window \
                    is not None or cfg.local_global:
                raise ValueError(
                    f"speculative decoding supports plain softmax decode "
                    f"attention only; {cfg.name} uses softcap/windowed "
                    f"attention")
            if self.decode_block % self.spec_k:
                raise ValueError(
                    f"decode_block ({decode_block}) must be a multiple "
                    f"of spec_k ({spec_k}): a tick scans "
                    f"decode_block // spec_k draft-verify rounds")
            if draft_params is not None:
                self.draft_params = draft_params
            else:
                # QTensors are pytree nodes: flattening blindly would
                # dissolve them into plain arrays and hide the tier
                leaves = jax.tree.leaves(
                    params,
                    is_leaf=lambda l: isinstance(l, (Q4Tensor, Q8Tensor)))
                if not all(isinstance(l, jax.Array) for l in leaves):
                    raise ValueError(
                        "served params are already quantized; pass "
                        "draft_params= explicitly (the engine builds "
                        "draft weights from float params only)")
                self.draft_params = quantize_tree(params,
                                                  tier=draft_dtype)
        self.paged = bool(paged)
        self.page_size = int(page_size)
        self.pages: Optional[PagedKV] = None
        if self.paged:
            if not self.enc_dec:
                raise ValueError(
                    f"paged=True requires an enc-dec model; {cfg.name} "
                    f"is decoder-only")
            if flags.BASELINE:
                raise ValueError("paged=True needs the stacked decode "
                                 "path (unset REPRO_BASELINE)")
            if max_len % self.page_size or enc_len % self.page_size:
                raise ValueError(
                    f"max_len ({max_len}) and enc_len ({enc_len}) must "
                    f"be multiples of page_size ({self.page_size})")
            # defaults match the slot pool's byte budget (+1 scratch)
            if n_pages is None:
                n_pages = n_slots * (max_len // self.page_size) + 1
            if n_cross_pages is None:
                n_cross_pages = n_slots * (enc_len // self.page_size) + 1
            self.pages = PagedKV(
                n_slots=n_slots, max_len=max_len, enc_len=enc_len,
                page_size=self.page_size, n_pages=n_pages,
                n_cross_pages=n_cross_pages)
            self.cache = model.init_paged_cache(
                n_pages, n_cross_pages, self.page_size, dtype=cdt)
        else:
            self.cache = model.init_cache(n_slots, max_len, enc_len,
                                          dtype=cdt)
        self.free = list(range(n_slots))
        self.active: dict[int, RequestState] = {}   # slot -> state
        # host-side ledger of which state each lane holds (reserved at
        # admit/open_stream, extended per streamed chunk, released by
        # _free_slot) — the conformance suite's leak check
        self.lanestate = LaneStatePool(n_slots)
        # --- device-resident decode state (never re-uploaded per tick):
        # last emitted token, write position, valid encoder length, and
        # the per-lane masks/budgets the fused scan needs to freeze
        # finished lanes on device. Parked lanes decode at pos 0 (one
        # attendable position) with active=False so their emits are
        # masked; _free_slot zeroes pos/tokens so a dead lane never
        # attends its stale context.
        self._tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self._pos = jnp.zeros((n_slots,), jnp.int32)
        self._enc_lens = jnp.zeros((n_slots,), jnp.int32)
        self._lane_active = jnp.zeros((n_slots,), bool)
        self._lane_eos = jnp.zeros((n_slots,), jnp.int32)
        self._lane_max = jnp.zeros((n_slots,), jnp.int32)
        self._lane_out = jnp.zeros((n_slots,), jnp.int32)
        self._decode_fns: dict[int, Any] = {}   # block size -> fused jit
        self._prefill_fns: dict[tuple, Any] = {}
        # streaming audio: open streams by slot + jitted encoder helpers
        # (jit retraces per chunk length — fixed chunks + one tail)
        self._streams: dict[int, _StreamState] = {}
        if self.enc_dec:
            cfg_ = cfg
            self._encode = jax.jit(self.model.encode)
            self._cross_kv = jax.jit(
                lambda params, states: encdec_mod.cross_attn_kv(
                    params, cfg_, states))
            self._extend = jax.jit(
                functools.partial(
                    _extend_paged_cross_cache if self.paged
                    else _extend_cross_cache,
                    tier=cache_dtype if cache_dtype in QUANT_TIERS
                    else None),
                donate_argnums=(0,))
        # serving-energy accounting (energy_report)
        self._ticks = 0         # executed fused decode ticks (host syncs)
        self._decode_steps = 0  # executed full-model decode steps
        self._generated = 0     # tokens emitted (prefill firsts + decode)
        self._host_syncs = 0    # device->host fetches on the decode path
        # speculative accounting: draft forwards, multi-query verify
        # forwards, rounds, and the emit stats behind the acceptance rate
        self._draft_steps = 0
        self._verify_steps = 0
        self._spec_rounds = 0
        self._spec_emitted = 0      # tokens emitted by spec ticks
        self._spec_live_rounds = 0  # (round, lane) pairs that emitted

    # ------------------------------------------------------------------
    def _build_decode(self, k: int):
        """The fused decode tick: ``k`` decode steps scanned inside one
        jit. Carry = (cache, tokens, pos, active, n_out) — all donated,
        so the KV pool and lane state are updated in place instead of
        copied every step. Finished lanes (EOS / max_new / max_len) are
        frozen on device: their token/pos stop advancing and their
        emits are masked, which makes the fused tick token-identical to
        ``k`` sequential single steps.

        Paged engines take the per-lane page tables as an extra donated
        argument; the tick never remaps pages, so the tables pass
        through unchanged (aliased outputs) and the engine re-adopts
        them after the donation invalidated the inputs."""
        if self.spec_k:
            return self._build_spec_decode(k)
        model, enc_dec, max_len = self.model, self.enc_dec, self.max_len

        if self.paged:
            @functools.partial(jax.jit,
                               donate_argnums=(1, 2, 3, 4, 5, 6))
            def paged_decode_block(params, cache, tables, tokens, pos,
                                   active, n_out, enc_lens, eos, max_new):
                def one(carry, _):
                    cache, tokens, pos, active, n_out = carry
                    batch = {"tokens": tokens, "enc_lens": enc_lens}
                    logits, cache = model.forward(
                        params, batch, mode="decode", cache=cache,
                        pos=pos, pages=tables)
                    nxt = jnp.argmax(logits[:, -1],
                                     axis=-1).astype(jnp.int32)
                    emit = active
                    tokens = jnp.where(active[:, None], nxt[:, None],
                                       tokens)
                    pos = jnp.where(active, pos + 1, pos)
                    n_out = jnp.where(active, n_out + 1, n_out)
                    stop = (nxt == eos) | (n_out >= max_new) \
                        | (pos >= max_len - 1)
                    active = active & ~stop
                    return (cache, tokens, pos, active, n_out), (nxt, emit)

                carry = (cache, tokens, pos, active, n_out)
                carry, (tok_blk, emit_blk) = jax.lax.scan(
                    one, carry, None, length=k)
                cache, tokens, pos, active, n_out = carry
                return (tok_blk, emit_blk, cache, tables, tokens, pos,
                        active, n_out)

            return paged_decode_block

        @functools.partial(jax.jit, donate_argnums=(1, 2, 3, 4, 5))
        def decode_block(params, cache, tokens, pos, active, n_out,
                         enc_lens, eos, max_new):
            def one(carry, _):
                cache, tokens, pos, active, n_out = carry
                batch = {"tokens": tokens}
                if enc_dec:
                    batch["enc_lens"] = enc_lens
                logits, cache = model.forward(
                    params, batch, mode="decode", cache=cache, pos=pos)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                emit = active
                tokens = jnp.where(active[:, None], nxt[:, None], tokens)
                pos = jnp.where(active, pos + 1, pos)
                n_out = jnp.where(active, n_out + 1, n_out)
                stop = (nxt == eos) | (n_out >= max_new) \
                    | (pos >= max_len - 1)
                active = active & ~stop
                return (cache, tokens, pos, active, n_out), (nxt, emit)

            carry = (cache, tokens, pos, active, n_out)
            carry, (tok_blk, emit_blk) = jax.lax.scan(
                one, carry, None, length=k)
            cache, tokens, pos, active, n_out = carry
            return tok_blk, emit_blk, cache, tokens, pos, active, n_out

        return decode_block

    def _build_spec_decode(self, k: int):
        """The fused *speculative* decode tick: ``k // spec_k``
        draft-verify rounds scanned inside one donated jit.

        Each round, per lane:

        * **draft** — ``spec_k - 1`` greedy steps with the quantized
          draft weights, writing draft KV at ``pos .. pos+spec_k-2``;
        * **verify** — ONE multi-query full-model forward over
          ``[token, d_0, .., d_{spec_k-2}]`` at the same positions
          (its writes overwrite every draft KV entry with
          full-precision-projected values), giving the true greedy
          continuation ``o_j`` at every position;
        * **accept** — the emitted prefix is ``o_0 .. o_{m-1}`` where
          ``m-1`` counts leading draft hits (``d_j == o_j``), cut
          further by the same EOS/max_new/max_len stops the plain tick
          applies. ``pos`` advances by ``m`` — rejected tails are
          rolled back by *not* advancing the write cursor; the next
          round's writes land on top of the garbage before any query
          ever attends it.

        ``o_0`` is exactly the plain tick's argmax, so the emitted
        stream is token-identical to plain greedy decode; a round
        always makes >= 1 token of progress per active lane. Stacked
        rounds yield the same ``(k, n_slots)`` token/emit block
        contract (rows ``r*spec_k .. r*spec_k+m-1`` of round ``r`` are
        emitted; the emit mask is no longer prefix-contiguous across
        rounds, which ``step_replay`` handles). Still exactly one host
        sync per tick."""
        model, enc_dec, max_len = self.model, self.enc_dec, self.max_len
        spec_k = self.spec_k
        gamma = spec_k - 1
        n_rounds = k // spec_k
        draft_params_const = self.draft_params
        paged = self.paged

        def spec_round(params, tables, enc_lens, eos, max_new, carry, _):
            cache, tokens, pos, active, n_out = carry
            kw = {"pages": tables} if paged else {}

            # --- draft: gamma greedy steps with the quantized weights
            def draft_one(c, _):
                dcache, dtok, dpos = c
                batch = {"tokens": dtok}
                if enc_dec:
                    batch["enc_lens"] = enc_lens
                logits, dcache = model.forward(
                    draft_params_const, batch, mode="decode",
                    cache=dcache, pos=dpos, **kw)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return (dcache, nxt[:, None], dpos + 1), nxt

            (cache, _, _), drafts = jax.lax.scan(
                draft_one, (cache, tokens, pos), None, length=gamma)
            drafts = drafts.T                      # (B, gamma)

            # --- verify: one multi-query full-model forward over the
            # current token plus every draft, at positions pos..pos+gamma
            ver_in = jnp.concatenate([tokens, drafts], axis=1)
            batch = {"tokens": ver_in}
            if enc_dec:
                batch["enc_lens"] = enc_lens
            logits, cache = model.forward(
                params, batch, mode="decode", cache=cache, pos=pos, **kw)
            o = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, spec_k)

            # --- accept: leading draft hits, cut by the stop conditions
            nb = tokens.shape[0]
            match = drafts == o[:, :gamma]
            prefix_ok = jnp.concatenate(
                [jnp.ones((nb, 1), bool),
                 jnp.cumprod(match, axis=1) > 0], axis=1)
            jj = jnp.arange(spec_k)[None, :]
            cand_stop = (o == eos[:, None]) \
                | (n_out[:, None] + jj + 1 >= max_new[:, None]) \
                | (pos[:, None] + jj + 1 >= max_len - 1)
            no_prior_stop = jnp.concatenate(
                [jnp.ones((nb, 1), bool),
                 jnp.cumprod(~cand_stop[:, :-1], axis=1) > 0], axis=1)
            emit = active[:, None] & prefix_ok & no_prior_stop
            m = emit.sum(axis=1).astype(jnp.int32)
            last = jnp.take_along_axis(
                o, jnp.clip(m - 1, 0, spec_k - 1)[:, None], axis=1)[:, 0]
            tokens = jnp.where(m > 0, last, tokens[:, 0])[:, None]
            pos = pos + m
            n_out = n_out + m
            active = active & ~(emit & cand_stop).any(axis=1)
            return (cache, tokens, pos, active, n_out), (o.T, emit.T)

        if paged:
            @functools.partial(jax.jit,
                               donate_argnums=(1, 2, 3, 4, 5, 6))
            def paged_spec_block(params, cache, tables, tokens, pos,
                                 active, n_out, enc_lens, eos, max_new):
                carry = (cache, tokens, pos, active, n_out)
                carry, (tok_blk, emit_blk) = jax.lax.scan(
                    functools.partial(spec_round, params, tables,
                                      enc_lens, eos, max_new),
                    carry, None, length=n_rounds)
                cache, tokens, pos, active, n_out = carry
                # (n_rounds, spec_k, B) -> the plain (k, B) block shape
                tok_blk = tok_blk.reshape(k, -1)
                emit_blk = emit_blk.reshape(k, -1)
                return (tok_blk, emit_blk, cache, tables, tokens, pos,
                        active, n_out)

            return paged_spec_block

        @functools.partial(jax.jit, donate_argnums=(1, 2, 3, 4, 5))
        def spec_block(params, cache, tokens, pos, active, n_out,
                       enc_lens, eos, max_new):
            carry = (cache, tokens, pos, active, n_out)
            carry, (tok_blk, emit_blk) = jax.lax.scan(
                functools.partial(spec_round, params, None, enc_lens,
                                  eos, max_new),
                carry, None, length=n_rounds)
            cache, tokens, pos, active, n_out = carry
            tok_blk = tok_blk.reshape(k, -1)
            emit_blk = emit_blk.reshape(k, -1)
            return tok_blk, emit_blk, cache, tokens, pos, active, n_out

        return spec_block

    def _decode_fn(self, k: int):
        fn = self._decode_fns.get(k)
        if fn is None:
            fn = self._decode_fns[k] = self._build_decode(k)
        return fn

    def _prefill_fn(self, bucket: int, enc_s: Optional[int] = None,
                    from_states: bool = False):
        """Jitted prefill, keyed (token bucket, encoder length, input
        kind). ``from_states=True`` takes precomputed encoder states
        (streaming chunked encode / ``Request.enc_states``) instead of
        frame embeddings, skipping the in-prefill encoder pass.

        The function takes the whole slot pool (donated: the scatter is
        an in-place lane write) and returns ``(first, pool)`` where
        ``first`` is the argmax of the last prompt position — computed
        on device so admission fetches one scalar, not the full
        ``[1, bucket, vocab]`` logits.

        Paged engines replace the ``slot`` index with two physical-page
        vectors (one per pool): the dense batch-1 cache is reshaped into
        page rows and scattered at the lane's pages — unmapped logical
        pages point at the scratch page, which absorbs the padding."""
        key = (bucket, enc_s, from_states)
        if key not in self._prefill_fns:
            model, max_len, enc_len = self.model, self.max_len, self.enc_len
            tier = self.cache_dtype \
                if self.cache_dtype in QUANT_TIERS else None
            enc_key = "enc_states" if from_states else "enc_frames"
            page_size = self.page_size

            if self.paged:
                @functools.partial(jax.jit, donate_argnums=(1,))
                def paged_prefill(params, pool, tokens, n, pv_self,
                                  pv_cross, enc=None):
                    cache = model.init_cache(1, max_len, enc_len)
                    batch = {"tokens": tokens}
                    if enc is not None:
                        batch[enc_key] = enc
                    logits, cache = model.forward(
                        params, batch, mode="prefill", cache=cache)
                    if tier:
                        cache = quantize_kv_cache(cache, tier)
                    pool = _scatter_pages(pool, cache, pv_self, pv_cross,
                                          page_size)
                    first = jnp.argmax(
                        jnp.take(logits[0], n - 1,
                                 axis=0)).astype(jnp.int32)
                    return first, pool

                self._prefill_fns[key] = paged_prefill
                return paged_prefill

            @functools.partial(jax.jit, donate_argnums=(1,))
            def prefill(params, pool, tokens, n, slot, enc=None):
                cache = model.init_cache(1, max_len, enc_len)
                # n_valid: bucket padding must not win MoE expert
                # capacity (non-enc-dec families ignore it)
                batch = {"tokens": tokens, "n_valid": n}
                if enc is not None:
                    batch[enc_key] = enc
                logits, cache = model.forward(params, batch,
                                              mode="prefill", cache=cache)
                if tier:
                    cache = quantize_kv_cache(cache, tier)
                pool = _scatter_slot(pool, cache, slot)
                first = jnp.argmax(
                    jnp.take(logits[0], n - 1, axis=0)).astype(jnp.int32)
                return first, pool

            self._prefill_fns[key] = prefill
        return self._prefill_fns[key]

    def _set_lane(self, slot: int, *, token: int, pos: int, enc_len: int,
                  eos: int, max_new: int, n_out: int,
                  active: bool) -> None:
        """Write one lane's device-resident decode state (admission /
        anchor / free — never the per-tick hot path)."""
        self._tokens = self._tokens.at[slot, 0].set(token)
        self._pos = self._pos.at[slot].set(pos)
        self._enc_lens = self._enc_lens.at[slot].set(enc_len)
        self._lane_eos = self._lane_eos.at[slot].set(eos)
        self._lane_max = self._lane_max.at[slot].set(max_new)
        self._lane_out = self._lane_out.at[slot].set(n_out)
        self._lane_active = self._lane_active.at[slot].set(active)

    # ------------------------------------------------------------------
    def validate(self, req: Request) -> Optional[Rejection]:
        """Admission precheck: a ``Rejection`` (machine-readable
        ``code`` + human ``message``; the request can never be served by
        this engine), or None. The scheduler rejects failing requests at
        submit() instead of dying mid-tick; the gateway's shed
        accounting classifies by ``code``."""
        C = RejectCode
        n = len(req.tokens)
        # speculative lanes write draft/verify KV up to spec_k - 1
        # positions past the last emitted token before the stop masks
        # bind — keep that whole extent inside the pool so slab writes
        # never clamp onto live positions
        headroom = self.spec_k - 1 if self.spec_k else 0
        if n + req.max_new + headroom >= self.max_len:
            return Rejection(C.TOO_LONG,
                             f"request {req.uid} too long for engine "
                             f"({n}+{req.max_new}"
                             + (f"+{headroom} speculative headroom"
                                if headroom else "")
                             + f" vs {self.max_len})")
        d_model = self.model.cfg.d_model
        if self.enc_dec:
            if isinstance(req, StreamingAudioRequest):
                total = 0
                for i, c in enumerate(req.chunks):
                    shp = np.shape(c)
                    if len(shp) != 2 or shp[1] != d_model or shp[0] < 1:
                        return Rejection(
                            C.BAD_ENC_SHAPE,
                            f"request {req.uid}: chunk {i} must be "
                            f"(s, {d_model}) with s >= 1, got {shp}")
                    total += shp[0]
                if total > self.enc_len:
                    return Rejection(
                        C.ENC_OVERFLOW,
                        f"request {req.uid}: {total} streamed encoder "
                        f"frames exceed the pool enc_len {self.enc_len}")
                if self.paged and not self.pages.fits(
                        n, req.max_new + headroom, total):
                    return Rejection(
                        C.POOL_EXHAUSTED,
                        f"request {req.uid}: page demand exceeds the "
                        f"whole pool (can never be admitted)")
                return None
            if req.enc_frames is None and req.enc_states is None:
                return Rejection(
                    C.MISSING_ENC_INPUT,
                    f"request {req.uid}: enc-dec model "
                    f"{self.model.cfg.name} requires enc_frames or "
                    f"enc_states")
            if req.enc_frames is not None and req.enc_states is not None:
                return Rejection(
                    C.AMBIGUOUS_ENC_INPUT,
                    f"request {req.uid}: pass enc_frames or enc_states, "
                    f"not both")
            enc = req.enc_frames if req.enc_frames is not None \
                else req.enc_states
            what = "enc_frames" if req.enc_frames is not None \
                else "enc_states"
            shp = np.shape(enc)
            if len(shp) != 2 or shp[1] != d_model:
                return Rejection(C.BAD_ENC_SHAPE,
                                 f"request {req.uid}: {what} must be "
                                 f"(S_enc, {d_model}), got {shp}")
            if shp[0] > self.enc_len:
                return Rejection(
                    C.ENC_OVERFLOW,
                    f"request {req.uid}: {shp[0]} encoder positions "
                    f"exceed the pool enc_len {self.enc_len}")
            if self.paged and not self.pages.fits(
                    n, req.max_new + headroom, shp[0]):
                return Rejection(
                    C.POOL_EXHAUSTED,
                    f"request {req.uid}: page demand exceeds the whole "
                    f"pool (can never be admitted)")
        elif req.enc_frames is not None or req.enc_states is not None \
                or isinstance(req, StreamingAudioRequest):
            return Rejection(
                C.ENC_ON_DECODER_ONLY,
                f"request {req.uid}: encoder input on decoder-only "
                f"model {self.model.cfg.name}")
        return None

    def admit(self, req: Request) -> Optional[RequestState]:
        """Prefill a request into a free slot; None if the pool is full.
        Raises ValueError for requests that can never be served (use
        ``validate`` to precheck)."""
        if isinstance(req, StreamingAudioRequest):
            raise ValueError(
                f"request {req.uid}: streaming requests are served via "
                f"open_stream/stream_feed (or BatchScheduler.submit)")
        if not self.free:
            return None
        err = self.validate(req)
        if err is not None:
            raise RejectionError(err)
        n = len(req.tokens)
        slot = self.free.pop()
        # recurrent lanes (LaneStateSpec.prefill_exact) fold every input
        # position into the end-of-prompt state, so bucket zero-padding
        # would corrupt it — prefill at the exact prompt length (one
        # compile per distinct length; attention-only lanes keep the
        # power-of-2 bucket grid)
        bucket = n if self.spec.prefill_exact \
            else min(_bucket(n), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.tokens
        enc_s = None
        # resolve the encoder input host-side first: the paged path
        # needs enc_s (and the content digest) before any page moves
        states = frames = None
        if self.enc_dec and req.enc_states is not None:
            # precomputed encoder states (chunked/streaming encode):
            # prefill skips the encoder pass entirely.
            states = jnp.asarray(req.enc_states)[None]
            enc_s = int(states.shape[1])
        elif self.enc_dec:
            # encode at the exact frame count: the encoder attends
            # bidirectionally, so bucket padding would corrupt every
            # frame state (one compile per distinct enc_s).
            frames = jnp.asarray(np.asarray(req.enc_frames),
                                 jnp.float32)[None]
            enc_s = int(frames.shape[1])
        pv_self = pv_cross = None
        if self.paged:
            from_states = req.enc_states is not None
            digest = _enc_digest(
                req.enc_states if from_states else req.enc_frames,
                "states" if from_states else "frames")
            try:
                self.pages.admit_lane(
                    slot, req.tokens, digest,
                    max_new=req.max_new + (self.spec_k - 1
                                           if self.spec_k else 0),
                    enc_s=enc_s)
            except PageAllocError:
                # transient: pages drain as lanes finish — same retry
                # contract as a full slot pool (scheduler re-queues)
                self.free.append(slot)
                return None
            pv_self = jnp.asarray(self.pages.self_table.row(slot),
                                  jnp.int32)
            pv_cross = jnp.asarray(self.pages.cross_table.row(slot),
                                   jnp.int32)
        with use_context(self.dispatch_ctx), _quiet_donation():
            if self.paged:
                fn = self._prefill_fn(bucket, enc_s,
                                      from_states=states is not None)
                first, self.cache = fn(
                    self.params, self.cache, jnp.asarray(toks), n,
                    pv_self, pv_cross,
                    states if states is not None else frames)
            elif states is not None:
                first, self.cache = self._prefill_fn(
                    bucket, enc_s, from_states=True)(
                        self.params, self.cache, jnp.asarray(toks), n,
                        slot, states)
            elif self.enc_dec:
                first, self.cache = self._prefill_fn(bucket, enc_s)(
                    self.params, self.cache, jnp.asarray(toks), n, slot,
                    frames)
            else:
                first, self.cache = self._prefill_fn(bucket)(
                    self.params, self.cache, jnp.asarray(toks), n, slot)
        first = int(first)   # scalar fetch — the only admit-time sync
        self._generated += 1
        self.lanestate.reserve(slot, self.spec, n_tokens=n + req.max_new,
                               enc_frames=enc_s or 0)
        st = RequestState(req=req, slot=slot, pos=n, out=[first])
        done = first == req.eos_id or len(st.out) >= req.max_new
        self._set_lane(slot, token=first, pos=n, enc_len=enc_s or 0,
                       eos=req.eos_id, max_new=req.max_new, n_out=1,
                       active=not done)
        if done:
            st.done = True
            self._free_slot(slot)
        else:
            self.active[slot] = st
        return st

    # ---------------------------------------------------- streaming audio
    def open_stream(self, req: StreamingAudioRequest
                    ) -> Optional[RequestState]:
        """Allocate a slot for a streaming audio request; None if the
        pool is full. No prefill happens yet — the first ``stream_feed``
        anchors the prompt against the first chunk's states."""
        if not isinstance(req, StreamingAudioRequest):
            raise ValueError(f"request {req.uid}: open_stream takes a "
                             f"StreamingAudioRequest")
        err = self.validate(req)
        if err is not None:
            raise RejectionError(err)
        if not self.free:
            return None
        slot = self.free.pop()
        if self.paged:
            # register the lane with empty page sets — cross pages are
            # allocated per chunk in stream_feed, self pages at the
            # first anchor (when the prompt+budget extent is known)
            self.pages.admit_stream_lane(slot)
        self.lanestate.reserve(
            slot, self.spec, n_tokens=len(req.tokens) + req.max_new)
        st = RequestState(req=req, slot=slot, pos=0, out=[])
        self._streams[slot] = _StreamState(states=[])
        return st

    def stream_feed(self, st: RequestState, frames) -> RequestState:
        """Feed one chunk of frame embeddings ((s, d_model)) to an open
        stream: encode the chunk (block-diagonal — its states never
        change as more audio arrives), extend the slot's cached cross
        K/V in place, and grow the lane's ``enc_lens`` so the very next
        decode tick attends the new audio. Appends a partial-hypothesis
        snapshot to ``st.partials``."""
        slot = st.slot
        ss = self._streams[slot]
        fr = jnp.asarray(np.asarray(frames, np.float32))[None]
        s_new = int(fr.shape[1])
        if ss.n_frames + s_new > self.enc_len:
            raise RejectionError(Rejection(
                RejectCode.ENC_OVERFLOW,
                f"request {st.req.uid}: stream overflows the pool "
                f"enc_len {self.enc_len} ({ss.n_frames}+{s_new})"))
        with use_context(self.dispatch_ctx):
            states = self._encode(self.params, fr)
        ss.states.append(states)
        first_feed = not ss.anchored
        if self.paged:
            # grow the lane's cross pages to cover the new chunk before
            # anything writes it (the first feed's pages are written by
            # the anchor prefill, later feeds by the extend jit)
            try:
                phys, off = self.pages.extend_cross(slot, ss.n_frames,
                                                    s_new)
            except PageAllocError as e:
                raise RejectionError(Rejection(
                    RejectCode.POOL_EXHAUSTED,
                    f"request {st.req.uid}: cross-KV page pool "
                    f"exhausted mid-stream ({e})"))
        if not first_feed:
            # incremental extension: project the new states through each
            # decoder layer's cross K/V and write them after the
            # already-cached positions (quantizing for a q8_0 pool; the
            # pool buffer is donated — an in-place plane write).
            with use_context(self.dispatch_ctx), _quiet_donation():
                k, v = self._cross_kv(self.params, states)
                if self.paged:
                    self.cache = self._extend(
                        self.cache, k, v, jnp.asarray(phys, jnp.int32),
                        jnp.asarray(off, jnp.int32))
                else:
                    self.cache = self._extend(self.cache, k, v, slot,
                                              ss.n_frames)
        ss.n_frames += s_new
        self.lanestate.extend_cross(slot, s_new)
        if first_feed:
            self._anchor(st, ss, final=False)
        else:
            self._enc_lens = self._enc_lens.at[slot].set(ss.n_frames)
        st.partials.append(list(st.out))
        return st

    def stream_finalize(self, st: RequestState) -> RequestState:
        """End of audio: re-anchor the prompt against the *full* encoder
        states (one bucketed prefill — the encoder work is NOT redone),
        so the final transcript is token-identical to one-shot serving
        of the same chunked audio. The mid-stream hypothesis is kept as
        the last entry of ``st.partials``."""
        slot = st.slot
        ss = self._streams.pop(slot)
        if st.out:
            st.partials.append(list(st.out))
        self.active.pop(slot, None)
        self._anchor(st, ss, final=True)
        return st

    def _anchor(self, st: RequestState, ss: _StreamState,
                final: bool) -> None:
        """Prompt prefill for a streaming lane over the states fed so
        far (the same jitted states-prefill the one-shot path uses; the
        scatter re-writes the slot's cross planes with values identical
        to the incremental extension)."""
        req, slot = st.req, st.slot
        n = len(req.tokens)
        states = ss.states[0] if len(ss.states) == 1 \
            else jnp.concatenate(ss.states, axis=1)
        bucket = min(_bucket(n), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.tokens
        if self.paged:
            lane = self.pages.lanes[slot]
            if not lane.self_pages:
                # first anchor: allocate the lane's full self-KV extent
                # (prompt + decode budget) so no tick ever allocates
                try:
                    self.pages.alloc_self(
                        slot, n, req.max_new + (self.spec_k - 1
                                                if self.spec_k else 0))
                except PageAllocError as e:
                    raise RejectionError(Rejection(
                        RejectCode.POOL_EXHAUSTED,
                        f"request {req.uid}: self-KV page pool "
                        f"exhausted at anchor ({e})"))
            pv_self = jnp.asarray(self.pages.self_table.row(slot),
                                  jnp.int32)
            pv_cross = jnp.asarray(self.pages.cross_table.row(slot),
                                   jnp.int32)
            with use_context(self.dispatch_ctx), _quiet_donation():
                first, self.cache = self._prefill_fn(
                    bucket, int(states.shape[1]), from_states=True)(
                        self.params, self.cache, jnp.asarray(toks), n,
                        pv_self, pv_cross, states)
        else:
            with use_context(self.dispatch_ctx), _quiet_donation():
                first, self.cache = self._prefill_fn(
                    bucket, int(states.shape[1]), from_states=True)(
                        self.params, self.cache, jnp.asarray(toks), n,
                        slot, states)
        first = int(first)   # scalar fetch, as in admit()
        self._generated += 1
        ss.anchored = True
        st.out = [first]
        st.pos = n
        finished = first == req.eos_id or req.max_new <= 1
        self._set_lane(slot, token=first, pos=n, enc_len=ss.n_frames,
                       eos=req.eos_id, max_new=req.max_new, n_out=1,
                       active=not finished)
        if final and finished:
            st.done = True
            self._free_slot(slot)
        elif not finished:
            self.active[slot] = st
        # mid-stream + finished: lane pauses (stays allocated, resumes
        # at the next anchor)

    def encode_chunks(self, chunks) -> jnp.ndarray:
        """Encode a list of frame-embedding chunks through the engine's
        jitted per-size encoder — the exact functions ``stream_feed``
        uses — and concatenate the states (1, sum(s_i), d_model). The
        one-shot ``transcribe`` path uses this so its states are
        bit-identical to the streaming path's."""
        outs = []
        with use_context(self.dispatch_ctx):
            for c in chunks:
                fr = jnp.asarray(np.asarray(c, np.float32))[None]
                outs.append(self._encode(self.params, fr))
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)

    @property
    def n_streams(self) -> int:
        """Open (not yet finalized) audio streams."""
        return len(self._streams)

    # ------------------------------------------------------------------
    def step_begin(self, k: Optional[int] = None) -> Optional[PendingTick]:
        """Dispatch one fused decode tick and return immediately —
        the device runs the ``k``-step scan while the host keeps
        working (JAX async dispatch). The engine's cache/lane-state
        references already point at the tick's (still materializing)
        outputs; the returned ``PendingTick`` holds the un-fetched
        token/emit blocks for ``step_fetch``/``step_replay``. Returns
        None when no lane is active (nothing to dispatch).

        This is the gateway's double-buffering hook: between
        ``step_begin`` and ``step_fetch`` the host resolves futures,
        drains streams, and picks the next tick's admissions while the
        device decodes."""
        if not self.active:
            return None
        k = self.decode_block if k is None else int(k)
        if k < 1:   # a 0-length scan would emit nothing and never drain
            raise ValueError(f"decode block must be >= 1, got {k}")
        if self.spec_k and k % self.spec_k:
            raise ValueError(f"decode block ({k}) must be a multiple of "
                             f"spec_k ({self.spec_k})")
        fn = self._decode_fn(k)
        with use_context(self.dispatch_ctx), _quiet_donation():
            if self.paged:
                # the tick donates the device tables and returns them
                # aliased (it never remaps pages); re-adopt them guarded
                # by the host tables' version so a concurrent admit
                # (between step_begin and step_fetch) wins
                sv = self.pages.self_table.version
                cv = self.pages.cross_table.version
                tables = {"self": self.pages.self_table.device(),
                          "cross": self.pages.cross_table.device()}
                (tok_blk, emit_blk, self.cache, tables, self._tokens,
                 self._pos, self._lane_active, self._lane_out) = fn(
                    self.params, self.cache, tables, self._tokens,
                    self._pos, self._lane_active, self._lane_out,
                    self._enc_lens, self._lane_eos, self._lane_max)
                self.pages.self_table.adopt(tables["self"], sv)
                self.pages.cross_table.adopt(tables["cross"], cv)
            else:
                (tok_blk, emit_blk, self.cache, self._tokens, self._pos,
                 self._lane_active, self._lane_out) = fn(
                    self.params, self.cache, self._tokens, self._pos,
                    self._lane_active, self._lane_out, self._enc_lens,
                    self._lane_eos, self._lane_max)
        return PendingTick(k=k, tok_blk=tok_blk, emit_blk=emit_blk)

    def step_fetch(self, pending: PendingTick):
        """THE host sync of a tick: block until the device finishes and
        fetch the ``(k, n_slots)`` token block + emit mask in one
        device_get. Safe to call off-thread (the gateway fetches in an
        executor so its event loop stays live during the device wait)."""
        tok_blk, emit_blk = jax.device_get(
            (pending.tok_blk, pending.emit_blk))
        self._host_syncs += 1
        self._ticks += 1
        emitted = int(emit_blk.sum())
        self._generated += emitted
        if self.spec_k:
            # a spec tick executes rounds, not plain steps: each round
            # is spec_k - 1 draft forwards + ONE multi-query verify
            # forward of the full model
            rounds = pending.k // self.spec_k
            self._spec_rounds += rounds
            self._draft_steps += rounds * (self.spec_k - 1)
            self._verify_steps += rounds
            self._spec_emitted += emitted
            # (round, lane) pairs that emitted at all — the denominator
            # of the draft-acceptance rate
            live = emit_blk.reshape(rounds, self.spec_k, -1).any(axis=1)
            self._spec_live_rounds += int(live.sum())
        else:
            self._decode_steps += pending.k
        return tok_blk, emit_blk

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft tokens the verify forward accepted so far
        (0.0 if no speculative round has emitted yet). Each live round
        emits 1 verified token plus ``accepted`` drafts out of
        ``spec_k - 1``."""
        if not self._spec_live_rounds or self.spec_k < 2:
            return 0.0
        accepted = self._spec_emitted - self._spec_live_rounds
        return accepted / (self._spec_live_rounds * (self.spec_k - 1))

    def step_replay(self, pending: PendingTick, tok_blk,
                    emit_blk) -> list[RequestState]:
        """Host replay of a fetched tick: append emitted tokens to each
        lane's ``RequestState``, free finished slots, pause streaming
        lanes — the bookkeeping no jit can do."""
        k = pending.k
        finished = []
        for slot, st in list(self.active.items()):
            for j in range(k):
                if not emit_blk[j, slot]:
                    # plain ticks freeze lanes prefix-contiguously, but
                    # a speculative round that accepts m < spec_k tokens
                    # leaves a gap before the next round's rows — keep
                    # scanning the whole block
                    continue
                tok = int(tok_blk[j, slot])
                st.out.append(tok)
                st.pos += 1
                # replay of the on-device stop condition, token for token
                if tok == st.req.eos_id or len(st.out) >= st.req.max_new \
                        or st.pos >= self.max_len - 1:
                    if slot in self._streams:
                        # mid-stream hypothesis complete: pause the lane
                        # (keep the slot and its growing encoder cache);
                        # stream_finalize re-anchors and decodes the
                        # final transcript.
                        self.active.pop(slot)
                    else:
                        st.done = True
                        self.active.pop(slot)
                        self._free_slot(slot)
                        finished.append(st)
                    break
            if self.paged:
                # advance the lane's valid-token extent (fragmentation
                # accounting only; allocation already covered max_new;
                # no-op for lanes freed above)
                self.pages.note_len(slot, st.pos)
        return finished

    def step_end(self, pending: Optional[PendingTick]
                 ) -> list[RequestState]:
        """Fetch + replay a dispatched tick (None — from an idle
        ``step_begin`` — is a no-op)."""
        if pending is None:
            return []
        tok_blk, emit_blk = self.step_fetch(pending)
        return self.step_replay(pending, tok_blk, emit_blk)

    def step(self, k: Optional[int] = None) -> list[RequestState]:
        """One fused decode tick over the whole pool: ``k`` (default
        ``decode_block``) decode steps in a single donated jit, then
        exactly one host sync — the ``(k, n_slots)`` token block and its
        emit mask — to run the Python bookkeeping (append to
        ``RequestState.out``, free finished slots, pause streaming
        lanes). Token-identical to ``k`` calls of ``step(1)``.
        Equivalent to ``step_end(step_begin(k))``."""
        return self.step_end(self.step_begin(k))

    def abort(self, st: RequestState, code: RejectCode = None,
              message: Optional[str] = None) -> None:
        """Evict an in-flight request (client cancelled / timed out):
        close its open stream, deactivate its lane, and zero+free the
        slot so the next admission reuses it cleanly. Safe on requests
        that already completed (no-op)."""
        slot = st.slot
        if st.done or slot < 0:
            return
        self._streams.pop(slot, None)
        self.active.pop(slot, None)
        if slot not in self.free:
            self._free_slot(slot)
        st.done = True
        st.error_code = code or RejectCode.CANCELLED
        st.error = message or \
            f"request {st.req.uid} {st.error_code.value}"

    def _free_slot(self, slot: int) -> None:
        """Return a lane to the pool and zero its decode inputs — a
        parked lane then attends exactly one (stale but harmless)
        position instead of its full dead context, and its emit mask
        stays off."""
        if self.paged:
            # drop page refs and point the lane's table rows at the
            # scratch page (any in-flight device write for this lane
            # lands there, never on a page another lane now owns)
            self.pages.free_lane(slot)
        if self.lanestate.holds(slot):
            self.lanestate.release(slot)
        self.free.append(slot)
        self._set_lane(slot, token=0, pos=0, enc_len=0, eos=0, max_new=0,
                       n_out=0, active=False)

    @property
    def n_active(self) -> int:
        return len(self.active)

    # ------------------------------------------------------------------
    def cache_report(self) -> dict:
        """Cache footprint / decode-traffic accounting.

        ``bytes_per_step`` is the full-pool cache stream of one decode
        step (this dense implementation reads every cache position and
        masks after the dot — exactly the paper's LOAD term; a fused
        tick streams it ``decode_block`` times). Recurrent/routing
        state (LaneStateSpec) is read AND fully rewritten every step,
        so it streams twice per step — constant in sequence length,
        which is the whole O(1)-state memory story; pure-KV engines see
        a zero delta. The analytic per-token figure uses
        ``core.quantize.stored_bytes`` under the paper's dense packing
        (C3)."""
        kv_bytes, state_bytes = _cache_bytes(self.cache)
        cfg = self.model.cfg
        dt = self.cache_dtype if self.cache_dtype in QUANT_TIERS \
            else "bf16"
        per_tok = 2 * cfg.n_layers * stored_bytes(
            (cfg.n_kv_heads, cfg.head_dim), dt)
        state_per_step = 2 * state_bytes
        out = {
            "cache_dtype": self.cache_dtype,
            "family": self.spec.family,
            "state_kinds": list(self.spec.state_kinds),
            "kv_bytes_total": kv_bytes,
            "state_bytes_total": state_bytes,
            "state_bytes_per_step": state_per_step,
            "bytes_per_step": kv_bytes + state_per_step,
            "self_kv_bytes_per_token": per_tok,
            "traffic_ratio_vs_bf16":
                cache_traffic_ratio() if self.cache_dtype == "q8_0"
                else cache_traffic_ratio_q4()
                if self.cache_dtype == "q4_0" else 1.0,
        }
        if self.paged:
            # paged pools stream only MAPPED pages per step (the gather
            # reads through the tables), so the decode LOAD term — and
            # the energy model built on it — prices actual resident
            # request bytes, not n_slots x max_len padding.
            rep = self.pages.report()
            layers = self.cache["layers"]
            sb = sum(int(l.nbytes) for l in jax.tree.leaves(layers["self"]))
            cb = sum(int(l.nbytes)
                     for l in jax.tree.leaves(layers["cross"]))
            spb = sb // self.pages.self_pool.n_pages
            cpb = cb // self.pages.cross_pool.n_pages
            resident = (rep["self"]["pages_in_use"] * spb
                        + rep["cross"]["pages_in_use"] * cpb)
            out["paging"] = {
                **rep,
                "self_page_bytes": spb,
                "cross_page_bytes": cpb,
                "resident_kv_bytes": resident,
            }
            out["bytes_per_step"] = resident + state_per_step
        return out

    def paging_report(self) -> dict:
        """Page-pool occupancy / fragmentation / prefix-sharing stats
        (``repro.paging`` accounting; paged engines only)."""
        if not self.paged:
            raise ValueError("paging_report() requires paged=True")
        return self.pages.report()

    def lane_report(self) -> dict:
        """The host-side lane-state ledger (``LaneStatePool.report``):
        which state kinds each live lane holds, with extents."""
        return self.lanestate.report()

    def routing_report(self) -> dict:
        """MoE engines: fetch the per-lane expert-routing counters the
        decode/prefill jits accumulate in the cache's "routing" planes.
        A diagnostic host sync (inventoried, NOT on the per-tick path):
        counters count *executed* top-k assignments — the fused tick
        decodes every slot, parked lanes included, so this is the
        device-work / expert-load picture the energy model prices, not
        a per-request billing meter."""
        if not self.spec.moe_experts:
            raise ValueError(
                f"routing_report() needs an MoE model; "
                f"{self.model.cfg.name} declares no routing state")
        planes = []

        def grab(tree):
            if isinstance(tree, dict):
                for key, sub in tree.items():
                    if key == "routing":
                        planes.append(sub)
                    else:
                        grab(sub)

        grab(self.cache)
        stacked = jax.device_get(planes)   # [(n_layers_i, n_slots, E)]
        per_lane = sum(p.sum(axis=0) for p in stacked)  # (n_slots, E)
        totals = per_lane.sum(axis=0)
        return {
            "n_experts": self.spec.moe_experts,
            "top_k": self.spec.moe_top_k,
            "moe_layers": sum(int(p.shape[0]) for p in stacked),
            "per_lane": per_lane.tolist(),
            "per_expert": totals.tolist(),
            "executed_assignments": int(totals.sum()),
        }

    def page_headroom(self) -> float:
        """Free-page fraction of the tighter pool (1.0 for slot
        engines) — the gateway's load-shed signal: when this drops
        below its threshold, BATCH-class work is shed first so
        interactive admissions keep finding pages."""
        if not self.paged:
            return 1.0
        sp, cp = self.pages.self_pool, self.pages.cross_pool
        return min(sp.free_pages / max(sp.n_pages - 1, 1),
                   cp.free_pages / max(cp.n_pages - 1, 1))

    def dispatch_report(self) -> dict:
        """Kernel-routing counters (trace-time, keyed (op, decision,
        backend); process-global — reset via api.reset_dispatch_log())
        plus the engine's cache footprint/traffic accounting."""
        return {
            "counters": dict(dispatch_counters()),
            "cache": self.cache_report(),
        }

    # ------------------------------------------------------------------
    def reset_serve_stats(self) -> None:
        """Zero the serve-energy accounting (executed ticks / decode
        steps / emitted tokens / host syncs) so the next
        ``energy_report()`` prices only work from this point on.
        Per-call reports on a reused engine
        (``repro.transcribe(engine=...)``) reset before serving."""
        self._ticks = 0
        self._decode_steps = 0
        self._generated = 0
        self._host_syncs = 0
        self._draft_steps = 0
        self._verify_steps = 0
        self._spec_rounds = 0
        self._spec_emitted = 0
        self._spec_live_rounds = 0

    def _param_stats(self) -> tuple[int, int]:
        """(element count, stored bytes) of the served parameters."""
        leaves = jax.tree.leaves(self.params)
        return (sum(int(l.size) for l in leaves),
                sum(int(l.nbytes) for l in leaves))

    def energy_report(self, kernel: str = "fp16") -> dict:
        """Joules-per-token / PDP accounting for the serve so far on the
        engine's platform — the paper's headline metric (Eq. 1), live on
        the serving path.

        The decode phase dominates serving energy, and every decode
        step streams the weights plus the whole KV pool through the
        cache matvec; the model here is the platform roofline over
        exactly those terms:

        * memory: ``decode_steps x (weight_bytes + cache bytes/step)``
          at the platform's DRAM/HBM bandwidth — a fused tick executes
          ``decode_block`` steps, so the stream is priced per *step*,
          never per host tick (joules/token stays correct when
          ``_ticks`` advances once per ``decode_block`` tokens),
        * compute: ``2 x N_params`` FLOPs per generated token at the
          platform's ``kernel``-dtype rate,
        * modeled latency = max(memory, compute) (the binding resource),
        * power: the platform ``PowerModel`` — Table-II curve targets
          interpolate at their LMM size for the ``kernel`` family
          ("fp16" | "q8_0" — the served weight family, *not* the cache
          dtype); flat targets scale nominal power by compute
          utilization.

        The dispatch trace records stamped with this platform fold in as
        the ACCEL/HOST mix (``accel_flops_share``); cache traffic folds
        in via ``cache_report()`` — so a q8_0 cache pool shows up
        directly as a smaller ``cache_energy_j``.
        """
        if self.platform is None:
            raise ValueError(
                "energy_report() needs a platform: construct the engine "
                "with ServeEngine(..., platform='imax3-28nm/32k')")
        p = self.platform
        cache = self.cache_report()
        n_elems, weight_bytes = self._param_stats()
        ticks = self._ticks
        steps = self._decode_steps
        tokens = self._generated
        cbs = cache["bytes_per_step"]
        cache_bytes = steps * cbs
        stream_bytes = steps * weight_bytes + cache_bytes
        flops = 2.0 * n_elems * tokens
        spec = None
        if self.spec_k:
            # speculative roofline: every draft forward streams the
            # (smaller) draft weights + the cache once; every verify
            # forward streams the full weights + the cache ONCE for all
            # spec_k positions — that amortization is the whole win
            d_leaves = jax.tree.leaves(self.draft_params)
            d_elems = sum(int(l.size) for l in d_leaves)
            d_bytes = sum(int(l.nbytes) for l in d_leaves)
            cache_bytes += (self._draft_steps + self._verify_steps) * cbs
            stream_bytes = cache_bytes \
                + steps * weight_bytes \
                + self._draft_steps * d_bytes \
                + self._verify_steps * weight_bytes
            flops = 2.0 * n_elems * (steps
                                     + self._verify_steps * self.spec_k) \
                + 2.0 * d_elems * self._draft_steps
            spec = {
                "spec_k": self.spec_k,
                "draft_dtype": self.draft_dtype,
                "rounds": self._spec_rounds,
                "draft_steps": self._draft_steps,
                "verify_steps": self._verify_steps,
                "acceptance_rate": self.acceptance_rate,
                "draft_weight_bytes": d_bytes,
            }
        bw = max(p.memory.main_bw, 1e-9)
        rate = p.peak_flops("q8_0" if kernel == "q8_0" else "f16")
        t_mem = stream_bytes / bw
        t_comp = flops / rate
        latency_s = max(t_mem, t_comp)
        util = t_comp / latency_s if latency_s > 0 else 0.0
        power_w = p.power.power(kernel, p.memory.local_bytes or None,
                                util=util)
        energy_j = latency_s * power_w
        # ACCEL/HOST mix from the trace records THIS engine produced
        # (its context's unique tag); a caller-supplied dispatch_ctx has
        # no engine tag, so fall back to platform-name attribution
        tag = self.dispatch_ctx.tag if self.dispatch_ctx else None
        if tag:
            recs = [r for r in dispatch_trace() if r.tag == tag]
        else:
            recs = [r for r in dispatch_trace() if r.platform == p.name]
        accel_flops = sum(r.spec.flops for r in recs
                          if r.decision == "accel")
        trace_flops = sum(r.spec.flops for r in recs)
        return {
            "platform": p.name,
            "kernel": kernel,
            "cache_dtype": self.cache_dtype,
            "ticks": ticks,
            "decode_steps": steps,
            "decode_block": self.decode_block,
            "host_syncs": self._host_syncs,
            "tokens": tokens,
            "weight_bytes": weight_bytes,
            "cache_bytes_per_step": cache["bytes_per_step"],
            "stream_bytes_total": stream_bytes,
            "modeled_flops": flops,
            "memory_s": t_mem,
            "compute_s": t_comp,
            "latency_s": latency_s,
            "bound": "memory" if t_mem >= t_comp else "compute",
            "power_w": power_w,
            "pdp_j": energy_j,
            "joules_per_token": energy_j / max(tokens, 1),
            "cache_energy_j": (cache_bytes / bw) * power_w,
            "accel_flops_share":
                accel_flops / trace_flops if trace_flops else 0.0,
            "trace_records": len(recs),
            "modeled_tokens_per_s":
                tokens / latency_s if latency_s > 0 else 0.0,
            **({"speculative": spec} if spec else {}),
        }


def _cache_bytes(tree) -> tuple[int, int]:
    """(KV-plane bytes, recurrent-state bytes) of a cache pytree."""
    if isinstance(tree, dict):
        if set(tree) in ({"k", "v"}, {"kq", "ks", "vq", "vs"},
                         {"kp", "ks", "vp", "vs"}):
            return sum(int(l.nbytes) for l in jax.tree.leaves(tree)), 0
        kv = st = 0
        for sub in tree.values():
            a, b = _cache_bytes(sub)
            kv += a
            st += b
        return kv, st
    return 0, sum(int(l.nbytes) for l in jax.tree.leaves(tree))


def _scatter_slot(pool: Any, one: Any, slot) -> Any:
    """Write a batch-1 cache pytree into lane ``slot`` of the pool.

    Every cache leaf is (stacked_layers, B, ...) — transformer segments,
    encdec layers, and tails all stack with jnp.broadcast_to /scan — so
    the slot axis is axis 1 throughout. ``slot`` may be a traced scalar
    (the prefill jit passes it dynamically, so one compile covers every
    lane)."""
    def scat(p, o):
        assert p.shape[0] == o.shape[0] and o.shape[1] == 1, (p.shape, o.shape)
        return jax.lax.dynamic_update_slice_in_dim(
            p, o.astype(p.dtype), slot, axis=1)
    return jax.tree.map(scat, pool, one)


def _quantize_cross_planes(k, v, tier: str) -> dict:
    """Chunk cross-K/V -> the tier's plane dict (pre-write)."""
    if tier == "q4_0":
        kt = quantize_q4_0(k, axis=-1)
        vt = quantize_q4_0(v, axis=-1)
        return {"kp": kt.q, "ks": kt.scale, "vp": vt.q, "vs": vt.scale}
    kt = quantize_q8_0(k, axis=-1)
    vt = quantize_q8_0(v, axis=-1)
    return {"kq": kt.q, "ks": kt.scale, "vq": vt.q, "vs": vt.scale}


def _extend_cross_cache(cache: dict, k, v, slot, offset, *,
                        tier: Optional[str]) -> dict:
    """Write new cross-K/V positions ((L, 1, s_new, Hkv, ·)) into lane
    ``slot`` of the pool's cross cache at ``offset`` (streaming audio:
    the chunk's planes land after the already-cached positions). Jitted
    by the engine with the pool donated — an in-place plane write."""
    cross = cache["layers"]["cross"]

    def dus(plane, new):
        return jax.lax.dynamic_update_slice(
            plane, new.astype(plane.dtype), (0, slot, offset, 0, 0))

    if tier:
        planes = _quantize_cross_planes(k, v, tier)
        new_cross = {key: dus(cross[key], val)
                     for key, val in planes.items()}
    else:
        new_cross = {"k": dus(cross["k"], k), "v": dus(cross["v"], v)}
    return {"layers": {**cache["layers"], "cross": new_cross}}


def _enc_digest(x, kind: str) -> str:
    """Content key of a request's encoder input for paged prefix
    sharing. Decoder self-K/V flows through cross-attention, so shared
    prompt pages are only valid between lanes with identical audio —
    the digest is part of the self-prefix key, not just the cross key.
    ``kind`` ("frames"/"states") keeps the two input encodings from
    ever colliding."""
    arr = np.asarray(x)
    return hashlib.sha1(kind.encode() + arr.tobytes()).hexdigest()


def _scatter_pages(pool: Any, one: Any, pv_self, pv_cross,
                   page_size: int) -> Any:
    """Write a batch-1 dense cache pytree into a lane's physical pages.

    Each dense leaf ``(L, 1, S, ...)`` is reshaped into page rows
    ``(L, S // P, P, ...)`` and scattered at the lane's page vector
    (``pv`` covers the full logical extent: mapped pages first, then
    the scratch page, which absorbs the bucket padding — duplicate
    scratch indices are benign, last-write-wins over garbage). Shared
    prefix pages are rewritten with bit-identical content (prefill is
    deterministic), so the scatter never corrupts another lane."""
    def scat(plane, dense, pv):
        lead, s = dense.shape[0], dense.shape[2]
        rows = dense[:, 0].reshape(
            (lead, s // page_size, page_size) + dense.shape[3:])
        return plane.at[:, pv].set(rows.astype(plane.dtype))

    layers, dense_layers = pool["layers"], one["layers"]
    new = {kind: {key: scat(layers[kind][key], dense_layers[kind][key],
                            pv)
                  for key in layers[kind]}
           for kind, pv in (("self", pv_self), ("cross", pv_cross))}
    return {"layers": new}


def _extend_paged_cross_cache(cache: dict, k, v, phys, off, *,
                              tier: Optional[str]) -> dict:
    """Paged variant of ``_extend_cross_cache``: the chunk's s_new new
    positions land at ``(layer, phys[i], off[i])`` in the shared cross
    planes (gather targets from ``PagedKV.extend_cross``). Jitted with
    the pool donated — an in-place plane write; one compile per
    distinct chunk length."""
    cross = cache["layers"]["cross"]

    def scat(plane, new):
        return plane.at[:, phys, off].set(new[:, 0].astype(plane.dtype))

    if tier:
        planes = _quantize_cross_planes(k, v, tier)
        new_cross = {key: scat(cross[key], val)
                     for key, val in planes.items()}
    else:
        new_cross = {"k": scat(cross["k"], k), "v": scat(cross["v"], v)}
    return {"layers": {**cache["layers"], "cross": new_cross}}
