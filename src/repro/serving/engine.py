"""Serving engine: slot-based KV cache + jitted prefill/decode.

Continuous-batching design (vLLM-style, adapted to JAX's static shapes):

* the engine owns a fixed pool of ``n_slots`` cache slots — one batched
  KV/state cache pytree; every decode tick runs **one** jitted step over
  the whole pool with *per-lane positions* (the model's decode path
  accepts ``pos`` as a (B,) vector), so requests at different depths
  batch together;
* prefill runs per-request at a bucketed sequence length (powers of two:
  compile once per bucket) and the resulting cache is scattered into a
  free lane. Bucket-padding junk beyond the prompt is never attendable:
  decode writes position ``pos`` before attending ``[0, pos]``;
* Q8_0 weights (``core.quantize.quantize_tree``) serve through the same
  forward — the paper's quantized serving variant is a flag, not a fork.

The batch scheduler (scheduler.py) decides admission; this module is the
mechanism: slot allocation, cache scatter, masked decode.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.api import (DispatchContext, dispatch_counters,
                               use_context)
from repro.models.model import Model

EOS_DEFAULT = 2


@dataclasses.dataclass
class Request:
    uid: int
    tokens: list             # prompt token ids
    max_new: int = 16
    eos_id: int = EOS_DEFAULT


@dataclasses.dataclass
class RequestState:
    req: Request
    slot: int
    pos: int                 # next position to write
    out: list                # generated ids
    done: bool = False


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // 2048) * 2048


class ServeEngine:
    def __init__(self, model: Model, params: Any, *, n_slots: int = 8,
                 max_len: int = 256, enc_len: int = 64,
                 dispatch_ctx: Optional[DispatchContext] = None):
        """``dispatch_ctx``: kernel-routing context (budget, backend
        policy — repro.kernels.api) applied while the prefill/decode
        functions trace; None uses the env/default context. Routing is
        baked in at first trace, so construct one engine per context."""
        self.model = model
        self.params = params
        self.dispatch_ctx = dispatch_ctx
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.init_cache(n_slots, max_len, enc_len)
        self.free = list(range(n_slots))
        self.active: dict[int, RequestState] = {}   # slot -> state
        self._tokens = np.zeros((n_slots, 1), np.int32)
        # parked lanes decode at pos 0 harmlessly; results are discarded
        self._pos = np.zeros((n_slots,), np.int32)
        self._decode = self._build_decode()
        self._prefill_fns: dict[int, Any] = {}

    # ------------------------------------------------------------------
    def _build_decode(self):
        model = self.model

        @jax.jit
        def decode(params, cache, tokens, pos):
            logits, new_cache = model.forward(
                params, {"tokens": tokens}, mode="decode",
                cache=cache, pos=pos)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, new_cache

        return decode

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_fns:
            model, max_len = self.model, self.max_len

            @jax.jit
            def prefill(params, tokens):
                cache = model.init_cache(1, max_len)
                logits, cache = model.forward(params, {"tokens": tokens},
                                              mode="prefill", cache=cache)
                return logits, cache

            self._prefill_fns[bucket] = prefill
        return self._prefill_fns[bucket]

    # ------------------------------------------------------------------
    def admit(self, req: Request) -> Optional[RequestState]:
        """Prefill a request into a free slot; None if the pool is full."""
        if not self.free:
            return None
        n = len(req.tokens)
        if n + req.max_new >= self.max_len:
            raise ValueError(f"request {req.uid} too long for engine "
                             f"({n}+{req.max_new} vs {self.max_len})")
        slot = self.free.pop()
        bucket = min(_bucket(n), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.tokens
        with use_context(self.dispatch_ctx):
            logits, cache1 = self._prefill_fn(bucket)(self.params,
                                                      jnp.asarray(toks))
        self.cache = _scatter_slot(self.cache, cache1, slot)
        first = int(np.argmax(np.asarray(logits)[0, n - 1]))
        st = RequestState(req=req, slot=slot, pos=n, out=[first])
        self._tokens[slot, 0] = first
        self._pos[slot] = n
        if first == req.eos_id or len(st.out) >= req.max_new:
            st.done = True
            self.free.append(slot)
        else:
            self.active[slot] = st
        return st

    # ------------------------------------------------------------------
    def step(self) -> list[RequestState]:
        """One batched decode tick over the whole pool."""
        if not self.active:
            return []
        with use_context(self.dispatch_ctx):
            nxt, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self._tokens),
                jnp.asarray(self._pos))
        nxt = np.asarray(nxt)
        finished = []
        for slot, st in list(self.active.items()):
            tok = int(nxt[slot])
            st.out.append(tok)
            st.pos += 1
            self._tokens[slot, 0] = tok
            self._pos[slot] = st.pos
            if tok == st.req.eos_id or len(st.out) >= st.req.max_new \
                    or st.pos >= self.max_len - 1:
                st.done = True
                self.active.pop(slot)
                self.free.append(slot)
                finished.append(st)
        return finished

    @property
    def n_active(self) -> int:
        return len(self.active)

    def dispatch_report(self) -> dict:
        """Trace-time kernel-routing counters, keyed (op, decision,
        backend). Process-global: reset via api.reset_dispatch_log()."""
        return dict(dispatch_counters())


def _scatter_slot(pool: Any, one: Any, slot: int) -> Any:
    """Write a batch-1 cache pytree into lane ``slot`` of the pool.

    Every cache leaf is (stacked_layers, B, ...) — transformer segments,
    encdec layers, and tails all stack with jnp.broadcast_to /scan — so
    the slot axis is axis 1 throughout."""
    def scat(p, o):
        assert p.shape[0] == o.shape[0] and o.shape[1] == 1, (p.shape, o.shape)
        return jax.lax.dynamic_update_slice_in_dim(
            p, o.astype(p.dtype), slot, axis=1)
    return jax.tree.map(scat, pool, one)
