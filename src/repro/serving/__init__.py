from repro.serving.engine import (AudioRequest, PendingTick, RejectCode,
                                  Rejection, RejectionError, Request,
                                  RequestState, ServeEngine,
                                  StreamingAudioRequest)
from repro.serving.scheduler import (BatchScheduler, SchedMetrics,
                                     SchedulerStuckError)
