from repro.serving.engine import ServeEngine, Request, RequestState
from repro.serving.scheduler import BatchScheduler
