from repro.serving.engine import (AudioRequest, Request, RequestState,
                                  ServeEngine)
from repro.serving.scheduler import BatchScheduler
