"""Spec-driven per-lane state ledger for the serving engine.

The engine's device pools — slot KV planes, paged KV pools, recurrent
``(C, n, m)`` / ``(h, c)`` buffers, MoE routing counters — are fixed
allocations; what varies per lane is which slices are *live*. The
``LaneStatePool`` is the host-side authority for that liveness:
admission reserves a lane's declared state kinds
(``LaneStateSpec.state_kinds``) with their extents, streaming feeds
extend the cross reservation, abort/free releases everything, and
``check()`` asserts the ledger is internally consistent.

Reservation units by kind:

* ``self_kv``  — causal-KV token budget (prompt + max_new)
* ``cross_kv`` — cached encoder frames (grows per streamed chunk)
* ``ssm`` / ``mstate`` / ``sstate`` — constant-size recurrent buffers,
  always exactly 1 per declaring layer family (O(1) state is the point)
* ``routing``  — per-lane expert counters (units = n_experts)

``drained`` (no live reservations) is the conformance suite's
end-of-battery invariant: no engine path — EOS, mid-block EOS, abort,
stream finalize — leaks lane state. The allocator is deliberately
family-agnostic: one pool can carry lanes of different specs (the
hypothesis property test drives exactly that mix), while a real engine
reserves every lane with its single model's spec.
"""

from __future__ import annotations

from typing import Optional

from repro.models.model import LaneStateSpec

RECURRENT_KINDS = ("ssm", "mstate", "sstate")


class LaneStatePool:
    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = int(n_slots)
        self._lanes: dict[int, dict] = {}      # slot -> {kind: units}
        self._specs: dict[int, LaneStateSpec] = {}

    # ------------------------------------------------------------- reserve
    def reserve(self, slot: int, spec: LaneStateSpec, *,
                n_tokens: int = 0, enc_frames: int = 0) -> dict:
        """Mark ``slot`` live with every state kind ``spec`` declares.
        ``n_tokens`` is the lane's self-KV token extent (prompt +
        decode budget); ``enc_frames`` the initially cached encoder
        frames. Returns the reservation dict (a copy)."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range "
                             f"[0, {self.n_slots})")
        if slot in self._lanes:
            raise ValueError(f"slot {slot} already reserved")
        if n_tokens < 0 or enc_frames < 0:
            raise ValueError("negative reservation extent")
        r: dict = {}
        if spec.self_kv:
            r["self_kv"] = int(n_tokens)
        if spec.cross_kv:
            r["cross_kv"] = int(enc_frames)
        for kind in spec.recurrent:
            r[kind] = 1
        if spec.moe_experts:
            r["routing"] = int(spec.moe_experts)
        self._lanes[slot] = r
        self._specs[slot] = spec
        return dict(r)

    def extend_cross(self, slot: int, frames: int) -> None:
        """Grow a streaming lane's cached-encoder-frame extent."""
        r = self._lanes[slot]
        if "cross_kv" not in r:
            raise ValueError(f"slot {slot}: lane spec declares no "
                             f"cross-KV state")
        if frames < 0:
            raise ValueError("negative extension")
        r["cross_kv"] += int(frames)

    def release(self, slot: int) -> dict:
        """Free every reservation of ``slot`` (KeyError if not live)."""
        self._specs.pop(slot)
        return self._lanes.pop(slot)

    # ------------------------------------------------------------- queries
    def holds(self, slot: int) -> bool:
        return slot in self._lanes

    def held(self, slot: int) -> Optional[dict]:
        r = self._lanes.get(slot)
        return None if r is None else dict(r)

    def spec_of(self, slot: int) -> Optional[LaneStateSpec]:
        return self._specs.get(slot)

    @property
    def n_live(self) -> int:
        return len(self._lanes)

    @property
    def drained(self) -> bool:
        return not self._lanes

    def totals(self) -> dict:
        """Aggregate live units by kind (all-zero iff drained)."""
        out = {k: 0 for k in ("self_kv", "cross_kv", "routing")
               + RECURRENT_KINDS}
        for r in self._lanes.values():
            for k, v in r.items():
                out[k] += v
        return out

    def report(self) -> dict:
        return {"n_slots": self.n_slots, "live_lanes": self.n_live,
                "totals": self.totals(),
                "lanes": {s: dict(r)
                          for s, r in sorted(self._lanes.items())}}

    def check(self) -> None:
        """Internal-consistency invariants (property-test hook)."""
        assert len(self._lanes) == len(self._specs)
        for slot, r in self._lanes.items():
            spec = self._specs[slot]
            assert 0 <= slot < self.n_slots, slot
            assert set(r) == set(spec.state_kinds), (r, spec)
            for kind in RECURRENT_KINDS:
                if kind in r:
                    assert r[kind] == 1, (slot, kind, r[kind])
            if "routing" in r:
                assert r["routing"] == spec.moe_experts
            assert all(v >= 0 for v in r.values()), (slot, r)
