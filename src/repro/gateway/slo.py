"""SLO classes and the prioritized admission queue.

An ``SLOClass`` names a service tier: a priority (lower = served
first) and an end-to-end deadline budget counted from submit. The
``AdmissionQueue`` replaces the scheduler's FCFS deque with
**earliest-deadline-first within priority class**: all queued
interactive requests outrank all standard ones, and within a class the
request whose deadline expires soonest is admitted first (ties broken
by submit order). The queue is bounded — a full queue is backpressure,
and the gateway sheds the submit with ``RejectCode.QUEUE_FULL``
instead of growing an unbounded backlog.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A service tier: admission priority + end-to-end deadline.

    ``priority``: lower value = admitted first (class-strict).
    ``deadline_s``: seconds from submit within which the request must
    complete to count toward goodput; also the shed threshold.
    """

    name: str
    priority: int
    deadline_s: float

    def __post_init__(self):
        if self.deadline_s <= 0:
            raise ValueError(
                f"SLO {self.name!r}: deadline_s must be > 0, got "
                f"{self.deadline_s}")


# The default tiers. Deadlines are generous for CI-class hardware (the
# repo serves reduced/micro models on shared CPU runners); production
# deployments register their own.
INTERACTIVE = SLOClass("interactive", priority=0, deadline_s=15.0)
STANDARD = SLOClass("standard", priority=1, deadline_s=60.0)
BATCH = SLOClass("batch", priority=2, deadline_s=600.0)

DEFAULT_CLASSES = (INTERACTIVE, STANDARD, BATCH)


class AdmissionQueue:
    """Bounded EDF-within-priority admission queue.

    Entries are gateway tickets (anything with ``.slo`` and
    ``.deadline_t``); ordering key is ``(priority, deadline_t, seq)``.
    ``push`` returns False when the queue is full (the caller sheds);
    cancelled tickets are removed lazily at ``pop`` (``ticket.cancelled``
    truthy), so client-side aborts cost O(1).
    """

    def __init__(self, limit: int = 64):
        if limit < 1:
            raise ValueError(f"queue limit must be >= 1, got {limit}")
        self.limit = limit
        self._heap: list = []
        self._seq = itertools.count()
        self._live = 0          # pushed minus popped/cancelled-at-pop

    def __len__(self) -> int:
        return self._live

    @property
    def full(self) -> bool:
        return self._live >= self.limit

    def push(self, ticket) -> bool:
        """Enqueue; False (backpressure) when the queue is at limit."""
        if self.full:
            return False
        heapq.heappush(self._heap,
                       (ticket.slo.priority, ticket.deadline_t,
                        next(self._seq), ticket))
        self._live += 1
        return True

    def cancelled_dropped(self, n: int = 1) -> None:
        """Account a queued ticket cancelled in place (it stays in the
        heap until popped, but no longer occupies a live slot)."""
        self._live = max(0, self._live - n)

    def pop(self):
        """Highest-priority, earliest-deadline live ticket; None when
        empty. Skips (and discards) cancelled tickets."""
        while self._heap:
            *_, ticket = heapq.heappop(self._heap)
            if getattr(ticket, "cancelled", False):
                continue
            self._live -= 1
            return ticket
        self._live = 0
        return None

    def shed_class(self, min_priority: int) -> list:
        """Remove and return every live ticket at or below service tier
        ``min_priority`` (higher value = lower priority; BATCH is 2).

        The load-shed hook: when the engine's page pool runs low, the
        gateway drops queued batch-class work first so interactive
        admissions keep finding pages. Cancelled tickets are discarded
        (they were already resolved, and sweeping them here settles the
        lazy-removal debt); the heap is rebuilt from the survivors."""
        keep, shed = [], []
        for entry in self._heap:
            ticket = entry[-1]
            if getattr(ticket, "cancelled", False):
                continue
            if ticket.slo.priority >= min_priority:
                shed.append(ticket)
            else:
                keep.append(entry)
        heapq.heapify(keep)
        self._heap = keep
        self._live = len(keep)
        return shed

    def peek(self):
        """The ticket ``pop`` would return, without removing it."""
        while self._heap:
            *_, ticket = self._heap[0]
            if getattr(ticket, "cancelled", False):
                heapq.heappop(self._heap)
                continue
            return ticket
        return None
