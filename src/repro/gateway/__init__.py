"""Async serving gateway: SLO-aware continuous batching over
``ServeEngine`` with earliest-deadline-first admission, load shedding,
wall-clock observability, and a seeded Poisson load generator."""

from repro.gateway.gateway import Gateway, GatewayResult, StreamSession
from repro.gateway.loadgen import (AUDIO_S_PER_FRAME, LoadSpec,
                                   RequestDesc, offered_load,
                                   poisson_arrivals, run_load,
                                   sync_baseline, synth_load)
from repro.gateway.metrics import (GatewayMetrics, RequestRecord,
                                   percentile)
from repro.gateway.slo import (BATCH, DEFAULT_CLASSES, INTERACTIVE,
                               STANDARD, AdmissionQueue, SLOClass)
