"""Async serving gateway: the traffic layer in front of ``ServeEngine``.

``Gateway`` turns the hand-cranked ``BatchScheduler`` loop into a real
front door: many concurrent clients submit one-shot
(``submit_tokens``/``submit_audio``) and streaming
(``open_session``/``feed``/``finalize``) requests as awaitables, each
tagged with an :class:`~repro.gateway.slo.SLOClass` (deadline +
priority). Admission is **earliest-deadline-first within priority
class** over a bounded queue (``AdmissionQueue``); a full queue or an
already-unmeetable deadline sheds the request at submit with a
structured ``RejectCode`` instead of growing a backlog.

Double-buffered tick loop (one background asyncio task)::

     tick N on device                host (event loop)
    ┌─────────────────────┐   ┌──────────────────────────────────┐
    │ fused decode scan   │   │ resolve futures / accept submits │
    │ (decode_block steps,│ ∥ │ shed expired queue entries       │
    │  donated pool)      │   │ pick tick N+1's admissions (EDF) │
    └──────────┬──────────┘   └──────────────────────────────────┘
               │ one host sync: (K, n_slots) tokens + emit mask
               ▼              (fetched in an executor — the event
        replay bookkeeping     loop stays live during the wait)

``step_begin`` dispatches the fused tick and returns immediately (JAX
async dispatch); the blocking ``step_fetch`` runs in a thread-pool
executor so client coroutines keep running while the device decodes.
Admissions *picked* during tick N prefill at the next tick boundary
(their one-scalar argmax sync queues behind the in-flight scan). The
one-host-sync-per-tick invariant of the fused decode loop is
preserved under load — the gateway adds zero extra device round trips.

Token parity: for the same request set, gateway results are
token-identical to the synchronous ``BatchScheduler`` (per-lane cache
isolation makes outputs independent of admission composition);
``benchmarks/serve_load.py`` and ``tests/test_gateway.py`` pin this.
"""

from __future__ import annotations

import asyncio
import dataclasses
import itertools
import time
from typing import Optional, Sequence

import numpy as np

from repro.gateway.metrics import GatewayMetrics, RequestRecord
from repro.gateway.slo import (BATCH, INTERACTIVE, STANDARD,
                               AdmissionQueue, SLOClass)
from repro.serving.engine import (AudioRequest, RejectCode,
                                  RejectionError, Request, RequestState,
                                  ServeEngine, StreamingAudioRequest)


@dataclasses.dataclass
class GatewayResult:
    """What one gateway request produced. ``ok=False`` carries the shed
    / abort classification in ``code`` (+ human ``error``) — shedding
    resolves the awaitable with a result, it does not raise."""

    uid: int
    ok: bool
    tokens: list
    partials: list
    slo: str
    code: Optional[RejectCode]
    error: Optional[str]
    record: RequestRecord

    @property
    def ttft_s(self) -> Optional[float]:
        return self.record.ttft_s

    @property
    def e2e_s(self) -> Optional[float]:
        return self.record.e2e_s

    @property
    def in_deadline(self) -> bool:
        return self.record.in_deadline


@dataclasses.dataclass
class _Ticket:
    """Internal per-request lifecycle state (queue entry + running)."""

    uid: int
    slo: SLOClass
    kind: str                       # "oneshot" | "stream"
    fut: asyncio.Future
    rec: RequestRecord
    req: Optional[Request] = None   # one-shot: the prebuilt request
    # streaming fields
    tokens: Sequence = ()
    max_new: int = 16
    eos_id: int = -1
    chunks: list = dataclasses.field(default_factory=list)
    chunk_t: list = dataclasses.field(default_factory=list)
    delivered: int = 0
    eos: bool = False               # finalize() called
    finalized: bool = False         # engine re-anchor ran
    # lifecycle
    state: Optional[RequestState] = None
    queued: bool = False
    cancelled: bool = False
    done: bool = False
    result: Optional[GatewayResult] = None

    @property
    def deadline_t(self) -> float:
        return self.rec.deadline_t


class Gateway:
    """Asyncio front door over one ``ServeEngine``.

    Use as an async context manager (starts/stops the background tick
    loop), or call ``start()``/``close()`` explicitly::

        async with Gateway(engine) as gw:
            r = await gw.submit_audio(frames, slo=INTERACTIVE)

    ``queue_limit`` bounds the admission queue (backpressure →
    ``RejectCode.QUEUE_FULL`` sheds); ``max_admit_per_tick`` caps
    prefills per tick boundary; ``shed_on_submit`` enables the
    deadline-unmeetable estimate shed (off until the tick/admit time
    estimators have warmed up past jit compilation).
    """

    def __init__(self, engine: ServeEngine, *, queue_limit: int = 64,
                 max_admit_per_tick: int = 2,
                 shed_on_submit: bool = True,
                 idle_wait_s: float = 0.02,
                 page_shed_headroom: float = 0.1,
                 shed_batch_priority: int = BATCH.priority):
        self.engine = engine
        self.queue = AdmissionQueue(queue_limit)
        self.max_admit_per_tick = max_admit_per_tick
        self.shed_on_submit = shed_on_submit
        self.idle_wait_s = idle_wait_s
        # paged engines: when the tighter page pool's free fraction
        # drops below this, queued work at/below ``shed_batch_priority``
        # (BATCH by default) is shed with POOL_EXHAUSTED so interactive
        # admissions keep finding pages. Slot engines report headroom
        # 1.0, so the path never fires there.
        self.page_shed_headroom = page_shed_headroom
        self.shed_batch_priority = shed_batch_priority
        self.metrics = GatewayMetrics()
        self._uid = itertools.count()
        self._running: dict[int, _Ticket] = {}     # uid -> admitted ticket
        self._selected: list[_Ticket] = []         # picked, not prefilled
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._accepting = False
        self._stopping = False
        # latency estimators for the unmeetable-deadline shed (EMA,
        # seconds; None until warmed up — never shed on compile time)
        self._tick_ema: Optional[float] = None
        self._admit_ema: Optional[float] = None

    # ------------------------------------------------------------ lifecycle
    @staticmethod
    def _now() -> float:
        return time.monotonic()

    async def start(self) -> "Gateway":
        if self._task is not None:
            raise RuntimeError("gateway already started")
        self._wake = asyncio.Event()
        self._accepting = True
        self._stopping = False
        self.metrics.started_t = self._now()
        self._task = asyncio.create_task(self._run(), name="gateway-tick")
        return self

    async def close(self, drain: bool = True) -> None:
        """Stop accepting new requests; with ``drain`` (default) serve
        everything already submitted first (open sessions that were
        never finalized are aborted — they could wait forever)."""
        self._accepting = False
        if self._task is None:
            return
        if not drain:
            for t in list(self._running.values()):
                self._client_abort(t, RejectCode.CANCELLED)
            while self.queue:
                t = self.queue.pop()
                if t is not None:
                    self._shed(t, RejectCode.CANCELLED, "gateway closed")
        self._stopping = True
        self._wake.set()
        await self._task
        self._task = None

    async def __aenter__(self) -> "Gateway":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close(drain=exc == (None, None, None))

    @property
    def n_queued(self) -> int:
        return len(self.queue)

    @property
    def n_running(self) -> int:
        return len(self._running)

    def report(self, kernel: str = "fp16") -> dict:
        """Metrics summary; folds in the engine's platform energy
        report (J/audio-s) when the engine has a platform, plus the
        served family's lane-state spec so a fleet of mixed-family
        gateways stays distinguishable in rolled-up metrics."""
        energy = None
        if self.engine.platform is not None:
            energy = self.engine.energy_report(kernel)
        out = self.metrics.summary(energy)
        spec = self.engine.spec
        out["engine"] = {
            "arch": self.engine.model.cfg.name,
            "family": spec.family,
            "state_kinds": list(spec.state_kinds),
            "cache_dtype": self.engine.cache_dtype,
            "prefill_exact": spec.prefill_exact,
        }
        if self.engine.spec_k:
            out["engine"]["speculative"] = {
                "spec_k": self.engine.spec_k,
                "draft_dtype": self.engine.draft_dtype,
                "acceptance_rate": self.engine.acceptance_rate,
            }
        return out

    # ------------------------------------------------------------- submit
    async def submit_tokens(self, tokens, *, max_new: int = 16,
                            eos_id: int = -1, slo: SLOClass = STANDARD,
                            timeout_s: Optional[float] = None
                            ) -> GatewayResult:
        """One-shot text request (decoder-only models): awaitable that
        resolves when the request completes, is shed, or times out."""
        req = Request(uid=next(self._uid), tokens=list(tokens),
                      max_new=max_new, eos_id=eos_id)
        return await self._submit_oneshot(req, slo, timeout_s, 0.0)

    async def submit_audio(self, frames=None, tokens=(1,), *,
                           enc_states=None, max_new: int = 16,
                           eos_id: int = -1, slo: SLOClass = INTERACTIVE,
                           timeout_s: Optional[float] = None,
                           audio_s: float = 0.0) -> GatewayResult:
        """One-shot audio request: frame embeddings (or precomputed
        encoder states) + decoder prompt. ``audio_s`` feeds the
        J/audio-s accounting."""
        req = AudioRequest(uid=next(self._uid), tokens=list(tokens),
                           max_new=max_new, eos_id=eos_id,
                           enc_frames=frames, enc_states=enc_states)
        return await self._submit_oneshot(req, slo, timeout_s, audio_s)

    async def open_session(self, tokens=(1,), *, max_new: int = 16,
                           eos_id: int = -1, slo: SLOClass = INTERACTIVE,
                           audio_s: float = 0.0) -> "StreamSession":
        """Open a streaming transcription session. The session enters
        the admission queue once its first chunk arrives (``feed``);
        its deadline counts from *now*."""
        self._check_accepting()
        ticket = self._ticket("stream", slo, audio_s)
        ticket.tokens = list(tokens)
        ticket.max_new = max_new
        ticket.eos_id = eos_id
        # mirror ServeEngine.validate's bound, speculative KV headroom
        # included, so a session the gateway accepts is never rejected
        # later at admit
        headroom = self.engine.spec_k - 1 if self.engine.spec_k else 0
        if len(ticket.tokens) + max_new + headroom >= self.engine.max_len:
            self._shed(ticket, RejectCode.TOO_LONG,
                       f"request {ticket.uid} too long for engine "
                       f"({len(ticket.tokens)}+{max_new} vs "
                       f"{self.engine.max_len})")
        return StreamSession(self, ticket)

    # ---------------------------------------------------------- internals
    def _check_accepting(self) -> None:
        if not self._accepting:
            raise RuntimeError("gateway is not accepting requests "
                               "(not started, or closing)")

    def _ticket(self, kind: str, slo: SLOClass,
                audio_s: float) -> _Ticket:
        uid = next(self._uid)
        now = self._now()
        rec = RequestRecord(uid=uid, slo=slo.name, submit_t=now,
                            deadline_t=now + slo.deadline_s,
                            audio_s=audio_s, streaming=kind == "stream")
        fut = asyncio.get_running_loop().create_future()
        return _Ticket(uid=uid, slo=slo, kind=kind, fut=fut, rec=rec)

    def _ttft_estimate(self) -> Optional[float]:
        """Expected seconds until a request submitted now gets its first
        token — queue drain time at the observed tick rate plus one
        prefill. None until both estimators warmed up (the first
        requests pay jit compilation; shedding on compile time would
        reject every cold-start load)."""
        if self._tick_ema is None or self._admit_ema is None:
            return None
        ticks_ahead = 1 + len(self.queue) / max(self.max_admit_per_tick, 1)
        return ticks_ahead * self._tick_ema + self._admit_ema

    @staticmethod
    def _ema(old: Optional[float], x: float, a: float = 0.3) -> float:
        return x if old is None else (1 - a) * old + a * x

    async def _submit_oneshot(self, req: Request, slo: SLOClass,
                              timeout_s: Optional[float],
                              audio_s: float) -> GatewayResult:
        self._check_accepting()
        ticket = self._ticket("oneshot", slo, audio_s)
        req.uid = ticket.uid
        ticket.req = req
        rej = self.engine.validate(req)
        if rej is not None:
            return self._shed(ticket, rej.code, str(rej))
        if not self._enqueue(ticket):
            return ticket.result
        return await self._await_ticket(ticket, timeout_s)

    def _enqueue(self, ticket: _Ticket) -> bool:
        """Shed-or-queue at admission time: unmeetable deadline first
        (reject-on-admission), then bounded-queue backpressure. False
        when shed (``ticket.result`` is set)."""
        now = self._now()
        est = self._ttft_estimate()
        if self.shed_on_submit and est is not None \
                and now + est > ticket.deadline_t:
            self._shed(ticket, RejectCode.DEADLINE_UNMEETABLE,
                       f"request {ticket.uid}: estimated TTFT "
                       f"{est:.3f}s exceeds the {ticket.slo.name} "
                       f"deadline ({ticket.deadline_t - now:.3f}s left)")
            return False
        if not self.queue.push(ticket):
            self._shed(ticket, RejectCode.QUEUE_FULL,
                       f"request {ticket.uid}: admission queue at limit "
                       f"{self.queue.limit}")
            return False
        ticket.queued = True
        self._wake.set()
        return True

    async def _await_ticket(self, ticket: _Ticket,
                            timeout_s: Optional[float]) -> GatewayResult:
        try:
            if timeout_s is None:
                return await ticket.fut
            return await asyncio.wait_for(ticket.fut, timeout_s)
        except asyncio.TimeoutError:
            return self._client_abort(ticket, RejectCode.TIMEOUT)
        except asyncio.CancelledError:
            self._client_abort(ticket, RejectCode.CANCELLED)
            raise

    # ------------------------------------------------- shed / abort / done
    def _finish(self, ticket: _Ticket, result: GatewayResult) -> None:
        ticket.done = True
        ticket.result = result
        self._running.pop(ticket.uid, None)
        self.metrics.record(ticket.rec)
        if not ticket.fut.done():
            ticket.fut.set_result(result)

    def _shed(self, ticket: _Ticket, code: RejectCode,
              message: str) -> GatewayResult:
        """Resolve a ticket as shed/rejected (never admitted, or failed
        before completion)."""
        ticket.rec.code = code
        ticket.rec.done_t = self._now()
        result = GatewayResult(uid=ticket.uid, ok=False, tokens=[],
                               partials=[], slo=ticket.slo.name,
                               code=code, error=message,
                               record=ticket.rec)
        self._finish(ticket, result)
        return result

    def _client_abort(self, ticket: _Ticket,
                      code: RejectCode) -> GatewayResult:
        """Client cancelled or timed out: free whatever the request
        holds (queue slot or engine lane) and resolve its record."""
        if ticket.done:
            return ticket.result
        ticket.cancelled = True
        if ticket.queued and ticket.state is None:
            self.queue.cancelled_dropped()   # lazy heap removal
        if ticket.state is not None:
            self.engine.abort(ticket.state, code)
        return self._shed(ticket, code,
                          f"request {ticket.uid} {code.value}")

    def _complete(self, st: RequestState) -> None:
        ticket = self._running.get(st.req.uid)
        if ticket is None or ticket.done:
            return
        now = self._now()
        ticket.rec.done_t = now
        ticket.rec.n_tokens = len(st.out)
        ticket.rec.ok = True
        if ticket.rec.first_token_t is None and st.out:
            ticket.rec.first_token_t = now
        result = GatewayResult(
            uid=ticket.uid, ok=True, tokens=list(st.out),
            partials=[list(p) for p in st.partials], slo=ticket.slo.name,
            code=None, error=None, record=ticket.rec)
        self._finish(ticket, result)

    # -------------------------------------------------------- the tick loop
    def _has_work(self) -> bool:
        return bool(len(self.queue) or self._selected or self._running
                    or self.engine.n_active)

    def _feed_streams(self) -> None:
        """Deliver one buffered chunk per open session (the real-time
        arrival model the scheduler uses), finalizing sessions whose
        audio has fully arrived."""
        for ticket in list(self._running.values()):
            if ticket.kind != "stream" or ticket.done \
                    or ticket.state is None:
                continue
            if ticket.delivered < len(ticket.chunks):
                i = ticket.delivered
                try:
                    self.engine.stream_feed(ticket.state,
                                            ticket.chunks[i])
                except RejectionError as e:
                    self.engine.abort(ticket.state, e.rejection.code,
                                      str(e))
                    self._shed(ticket, e.rejection.code, str(e))
                    continue
                ticket.delivered += 1
                now = self._now()
                ticket.rec.chunk_lags.append(now - ticket.chunk_t[i])
                if ticket.rec.first_token_t is None and ticket.state.out:
                    ticket.rec.first_token_t = now
            elif ticket.eos and not ticket.finalized:
                st = self.engine.stream_finalize(ticket.state)
                ticket.finalized = True
                if st.done:
                    self._complete(st)

    def _select_admissions(self) -> None:
        """The overlap-window half of admission: pop the EDF queue while
        free slots remain, shedding entries whose deadline has already
        passed (**before** any prefill is spent on them). Selected
        tickets prefill at the next tick boundary."""
        now = self._now()
        headroom = self.engine.page_headroom()
        if headroom < self.page_shed_headroom and len(self.queue):
            # page pool nearly dry: shed batch-class backlog first, so
            # the pages that do drain go to interactive work
            for t in self.queue.shed_class(self.shed_batch_priority):
                self._shed(t, RejectCode.POOL_EXHAUSTED,
                           f"request {t.uid}: page pool low (headroom "
                           f"{headroom:.2f} < {self.page_shed_headroom}"
                           f") — {t.slo.name}-class work shed")
        budget = min(self.max_admit_per_tick,
                     len(self.engine.free)) - len(self._selected)
        while budget > 0:
            ticket = self.queue.pop()
            if ticket is None:
                break
            if now > ticket.deadline_t:
                self._shed(ticket, RejectCode.DEADLINE_MISSED,
                           f"request {ticket.uid}: deadline passed "
                           f"{now - ticket.deadline_t:.3f}s before "
                           f"prefill — shed unstarted")
                continue
            self._selected.append(ticket)
            budget -= 1

    def _prefill_selected(self) -> None:
        """The tick-boundary half of admission: run the engine prefill
        (one scalar host sync each) for the tickets picked during the
        previous overlap window."""
        pending, self._selected = self._selected, []
        for ticket in pending:
            if ticket.cancelled or ticket.done:
                continue
            t0 = self._now()
            try:
                if ticket.kind == "stream":
                    req = StreamingAudioRequest(
                        uid=ticket.uid, tokens=list(ticket.tokens),
                        max_new=ticket.max_new, eos_id=ticket.eos_id,
                        chunks=ticket.chunks)
                    st = self.engine.open_stream(req)
                else:
                    st = self.engine.admit(ticket.req)
            except RejectionError as e:
                self._shed(ticket, e.rejection.code, str(e))
                continue
            if st is None:                 # pool filled after selection
                self.queue.push(ticket)
                continue
            ticket.state = st
            ticket.rec.admit_t = t0
            self._running[ticket.uid] = ticket
            if ticket.kind == "stream":
                # anchor against the first chunk immediately (the
                # scheduler does the same at admission)
                self.engine.stream_feed(st, ticket.chunks[0])
                ticket.delivered = 1
                now = self._now()
                ticket.rec.chunk_lags.append(now - ticket.chunk_t[0])
                if st.out:
                    ticket.rec.first_token_t = now
            else:
                ticket.rec.first_token_t = self._now()
            self._admit_ema = self._ema(self._admit_ema,
                                        self._now() - t0)
            if ticket.kind == "oneshot" and st.done:
                self._complete(st)

    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        try:
            while True:
                self._feed_streams()
                self._prefill_selected()
                pending = self.engine.step_begin()
                if pending is None:
                    # no lane decoding: admit immediately, else sleep
                    # until a submit/feed wakes us (bounded, so paused
                    # streams and close() are re-checked)
                    self._select_admissions()
                    if self._selected:
                        continue
                    if self._stopping and not self._has_work():
                        break
                    if self._stopping:
                        self._abort_unfinalized()
                        continue
                    self._wake.clear()
                    try:
                        await asyncio.wait_for(self._wake.wait(),
                                               self.idle_wait_s)
                    except asyncio.TimeoutError:
                        pass
                    continue
                t0 = self._now()
                # ---- overlap window: the device is running this tick.
                # Pick next tick's admissions, shed expired work, and
                # yield so client coroutines submit/cancel/feed.
                self._select_admissions()
                await asyncio.sleep(0)
                # THE host sync — in an executor so the event loop (and
                # every client) stays live during the device wait.
                tok_blk, emit_blk = await loop.run_in_executor(
                    None, self.engine.step_fetch, pending)
                finished = self.engine.step_replay(pending, tok_blk,
                                                   emit_blk)
                self._tick_ema = self._ema(self._tick_ema,
                                           self._now() - t0)
                self.metrics.ticks += 1
                for st in finished:
                    self._complete(st)
                await asyncio.sleep(0)     # let clients see results
        finally:
            self.metrics.stopped_t = self._now()

    def _abort_unfinalized(self) -> None:
        """Closing: sessions that were never finalized would wait for
        audio forever — abort them so ``close(drain=True)`` terminates."""
        for ticket in list(self._running.values()):
            stuck = ticket.kind == "stream" and not ticket.eos \
                and ticket.delivered >= len(ticket.chunks)
            if stuck:
                self._client_abort(ticket, RejectCode.CANCELLED)

    def _session_fail(self, ticket: _Ticket, code: RejectCode,
                      message: str) -> None:
        """A feed-side validation failure sheds the whole session: abort
        the engine lane if one is held, drop the queue entry, resolve."""
        if ticket.done:
            return
        if ticket.state is not None:
            self.engine.abort(ticket.state, code, message)
        elif ticket.queued:
            ticket.cancelled = True
            self.queue.cancelled_dropped()
        self._shed(ticket, code, message)


class StreamSession:
    """Client handle for one streaming transcription: ``feed`` audio
    chunks as they arrive, ``finalize`` to close the audio and await
    the transcript. Mirrors ``StreamingAudioRequest`` semantics — the
    final tokens are identical to one-shot serving of the same audio."""

    def __init__(self, gw: Gateway, ticket: _Ticket):
        self._gw = gw
        self._ticket = ticket

    @property
    def uid(self) -> int:
        return self._ticket.uid

    @property
    def partials(self) -> list:
        st = self._ticket.state
        return [list(p) for p in st.partials] if st is not None else []

    @property
    def done(self) -> bool:
        return self._ticket.done

    async def feed(self, frames) -> None:
        """Buffer one chunk of frame embeddings ``(s, d_model)``; the
        tick loop delivers one chunk per tick. The session enters the
        admission queue at the first feed. Misshapen or overflowing
        chunks shed the whole session (``finalize`` returns the shed
        result)."""
        gw, ticket = self._gw, self._ticket
        if ticket.done:
            return
        if ticket.eos:
            raise RuntimeError(f"session {ticket.uid}: feed after "
                               f"finalize")
        shp = np.shape(frames)
        d_model = gw.engine.model.cfg.d_model
        if len(shp) != 2 or shp[1] != d_model or shp[0] < 1:
            gw._session_fail(ticket, RejectCode.BAD_ENC_SHAPE,
                             f"session {ticket.uid}: chunk must be "
                             f"(s, {d_model}) with s >= 1, got {shp}")
            return
        total = sum(np.shape(c)[0] for c in ticket.chunks) + shp[0]
        if total > gw.engine.enc_len:
            gw._session_fail(ticket, RejectCode.ENC_OVERFLOW,
                             f"session {ticket.uid}: {total} streamed "
                             f"frames exceed the pool enc_len "
                             f"{gw.engine.enc_len}")
            return
        ticket.chunks.append(np.asarray(frames, np.float32))
        ticket.chunk_t.append(gw._now())
        if not ticket.queued:
            gw._enqueue(ticket)
        else:
            gw._wake.set()
        await asyncio.sleep(0)             # let the tick loop run

    async def finalize(self, timeout_s: Optional[float] = None
                       ) -> GatewayResult:
        """End of audio: await the final transcript (the engine
        re-anchors, so it is token-identical to one-shot serving)."""
        gw, ticket = self._gw, self._ticket
        if ticket.done:
            return ticket.result
        if not ticket.chunks:
            return gw._shed(ticket, RejectCode.MISSING_ENC_INPUT,
                            f"session {ticket.uid}: finalized with no "
                            f"audio")
        ticket.eos = True
        gw._wake.set()
        return await gw._await_ticket(ticket, timeout_s)

    async def cancel(self) -> GatewayResult:
        """Client-side abort: frees the lane/queue slot immediately."""
        return self._gw._client_abort(self._ticket, RejectCode.CANCELLED)
