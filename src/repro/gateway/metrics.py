"""Wall-clock serving observability for the gateway.

Everything here is measured in **seconds** (``time.monotonic``), not
ticks: the numbers an operator alarms on. One ``RequestRecord`` per
finished (or shed) request; ``GatewayMetrics.summary()`` aggregates:

* p50/p99 time-to-first-token and end-to-end latency,
* streaming lag (how long a fed audio chunk waited before the engine
  attended it) — mean and p99 across all delivered chunks,
* **goodput**: completed-within-deadline requests per second — the
  throughput number that actually respects the SLO (a request finishing
  after its deadline counts toward throughput but not goodput),
* shed/timeout/cancel counts classified by ``RejectCode``,
* J/audio-s when the engine has a platform (``energy_report()`` folded
  over the served audio seconds).
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Optional

import numpy as np

from repro.serving.engine import RejectCode


def percentile(values, q) -> float:
    """p-th percentile of a list (0.0 when empty) — nearest-rank via
    numpy, returned as a plain float for JSON."""
    if not values:
        return 0.0
    return float(np.percentile(np.asarray(values, np.float64), q))


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle timestamps (``time.monotonic`` seconds) and outcome of
    one gateway request."""

    uid: int
    slo: str
    submit_t: float
    deadline_t: float
    admit_t: Optional[float] = None        # queue popped, pre-prefill
    first_token_t: Optional[float] = None  # prefill/anchor argmax fetched
    done_t: Optional[float] = None
    n_tokens: int = 0
    audio_s: float = 0.0                   # seconds of audio served
    ok: bool = False                       # completed with tokens
    code: Optional[RejectCode] = None      # shed/abort classification
    streaming: bool = False
    chunk_lags: list = dataclasses.field(default_factory=list)

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.admit_t is None:
            return None
        return self.admit_t - self.submit_t

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def e2e_s(self) -> Optional[float]:
        if self.done_t is None:
            return None
        return self.done_t - self.submit_t

    @property
    def in_deadline(self) -> bool:
        return self.ok and self.done_t is not None \
            and self.done_t <= self.deadline_t


class GatewayMetrics:
    """Aggregates ``RequestRecord``s; ``summary()`` is the JSON-ready
    rollup the load benchmark emits into BENCH_platforms.json."""

    def __init__(self, clock=None):
        self.records: list[RequestRecord] = []
        self.shed: Counter = Counter()     # RejectCode.value -> n
        self.ticks = 0                     # gateway tick-loop iterations
        self.started_t: Optional[float] = None
        self.stopped_t: Optional[float] = None

    def record(self, rec: RequestRecord) -> None:
        self.records.append(rec)
        if rec.code is not None:
            self.shed[rec.code.value] += 1

    # ------------------------------------------------------------------
    def summary(self, energy: Optional[dict] = None) -> dict:
        """The rollup. ``energy``: an ``engine.energy_report()`` dict —
        folds in J/audio-s over the audio seconds actually served."""
        ok = [r for r in self.records if r.ok]
        ttft = [r.ttft_s for r in ok if r.ttft_s is not None]
        e2e = [r.e2e_s for r in ok if r.e2e_s is not None]
        waits = [r.queue_wait_s for r in ok if r.queue_wait_s is not None]
        lags = [lag for r in ok for lag in r.chunk_lags]
        in_deadline = sum(r.in_deadline for r in ok)
        wall = 0.0
        if self.started_t is not None:
            end = self.stopped_t if self.stopped_t is not None else max(
                [r.done_t for r in ok if r.done_t is not None],
                default=self.started_t)
            wall = max(end - self.started_t, 1e-9)
        audio_s = sum(r.audio_s for r in ok)
        out = {
            "requests": len(self.records),
            "completed": len(ok),
            "completed_in_deadline": in_deadline,
            "deadline_misses": len(ok) - in_deadline,
            "shed": dict(sorted(self.shed.items())),
            "shed_total": sum(self.shed.values()),
            "ticks": self.ticks,
            "wall_s": wall,
            "throughput_rps": len(ok) / wall if wall else 0.0,
            "goodput_rps": in_deadline / wall if wall else 0.0,
            "tokens": sum(r.n_tokens for r in ok),
            "audio_s": audio_s,
            "ttft_s": {"p50": percentile(ttft, 50),
                       "p99": percentile(ttft, 99),
                       "mean": float(np.mean(ttft)) if ttft else 0.0},
            "e2e_s": {"p50": percentile(e2e, 50),
                      "p99": percentile(e2e, 99),
                      "mean": float(np.mean(e2e)) if e2e else 0.0},
            "queue_wait_s": {"p50": percentile(waits, 50),
                             "p99": percentile(waits, 99)},
            "stream_lag_s": {"mean": float(np.mean(lags)) if lags else 0.0,
                             "p99": percentile(lags, 99),
                             "chunks": len(lags)},
        }
        if energy is not None:
            out["energy"] = {
                "platform": energy.get("platform"),
                "pdp_j": energy.get("pdp_j"),
                "joules_per_token": energy.get("joules_per_token"),
                "joules_per_audio_s":
                    (energy.get("pdp_j", 0.0) / audio_s) if audio_s else 0.0,
            }
        return out
