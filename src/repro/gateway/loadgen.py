"""Seeded open-loop Poisson load generation for the gateway.

Open-loop means arrivals are scheduled on a fixed clock **independent
of completions** — the generator does not wait for one request to
finish before sending the next, so the measured latencies include real
queueing (a closed-loop generator self-throttles and hides overload,
the classic coordinated-omission trap). Inter-arrival gaps are drawn
from a seeded exponential distribution (``numpy.random.default_rng``),
so a (rate, n, seed) triple always reproduces the exact same workload:
same arrival offsets, same audio, same prompts, same SLO mix.

``sync_baseline`` replays the identical request set through the
synchronous ``BatchScheduler`` — the token-parity oracle for the
gateway (per-lane cache isolation makes engine outputs independent of
admission order/composition, so the two must agree token-for-token).
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.gateway.gateway import Gateway, GatewayResult
from repro.gateway.slo import BATCH, INTERACTIVE, STANDARD, SLOClass
from repro.serving.engine import (AudioRequest, ServeEngine,
                                  StreamingAudioRequest)
from repro.serving.scheduler import BatchScheduler

# Nominal seconds of source audio one encoder frame covers (Whisper's
# 2x-strided conv over 20 ms hops) — used only for J/audio-s accounting.
AUDIO_S_PER_FRAME = 0.04


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """One load point: arrival rate + workload shape, fully seeded."""

    rate_rps: float                 # mean arrival rate (open loop)
    n_requests: int = 32
    seed: int = 0
    stream_fraction: float = 0.25   # fraction served as streaming sessions
    max_new: int = 8
    # (frame counts for one-shot audio, chunk sizes are fixed) — a small
    # fixed set keeps the jit bucket count bounded under load
    oneshot_frames: tuple = (8, 12)
    stream_chunk_frames: int = 4
    stream_chunks: tuple = (2, 3)
    slo_mix: tuple = ((INTERACTIVE, 0.5), (STANDARD, 0.3), (BATCH, 0.2))


@dataclasses.dataclass
class RequestDesc:
    """One synthesized request: everything both serving paths need."""

    idx: int
    kind: str                       # "oneshot" | "stream"
    arrival_s: float                # offset from load start
    tokens: list
    max_new: int
    eos_id: int
    chunks: list                    # one array (oneshot) or several
    slo: SLOClass
    audio_s: float

    @property
    def frames(self) -> np.ndarray:
        return np.concatenate(self.chunks, axis=0)


def poisson_arrivals(rate_rps: float, n: int, seed: int) -> np.ndarray:
    """Cumulative arrival offsets (seconds) of a seeded Poisson process:
    exponential inter-arrival gaps with mean ``1/rate_rps``."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    return np.cumsum(gaps)


def synth_load(cfg, spec: LoadSpec) -> list[RequestDesc]:
    """Deterministic workload for one ``LoadSpec``: mixed one-shot and
    streaming audio requests with Poisson arrivals and the spec's SLO
    mix. Same spec → identical descriptors, bit-for-bit."""
    arrivals = poisson_arrivals(spec.rate_rps, spec.n_requests, spec.seed)
    rng = np.random.default_rng(spec.seed + 1)
    slos = [s for s, _ in spec.slo_mix]
    weights = np.asarray([w for _, w in spec.slo_mix], np.float64)
    weights = weights / weights.sum()
    descs = []
    for i in range(spec.n_requests):
        streaming = rng.random() < spec.stream_fraction
        slo = slos[int(rng.choice(len(slos), p=weights))]
        prompt = [1] + [int(t) for t in
                        rng.integers(2, min(cfg.vocab, 200),
                                     size=int(rng.integers(0, 3)))]
        if streaming:
            n_chunks = int(rng.choice(spec.stream_chunks))
            chunks = [rng.standard_normal(
                (spec.stream_chunk_frames, cfg.d_model)
            ).astype(np.float32) * 0.02 for _ in range(n_chunks)]
        else:
            s = int(rng.choice(spec.oneshot_frames))
            chunks = [rng.standard_normal((s, cfg.d_model)
                                          ).astype(np.float32) * 0.02]
        n_frames = sum(c.shape[0] for c in chunks)
        descs.append(RequestDesc(
            idx=i, kind="stream" if streaming else "oneshot",
            arrival_s=float(arrivals[i]), tokens=prompt,
            max_new=spec.max_new, eos_id=-1, chunks=chunks, slo=slo,
            audio_s=n_frames * AUDIO_S_PER_FRAME))
    return descs


async def _serve_one(gw: Gateway, desc: RequestDesc, start_t: float,
                     timeout_s: Optional[float]) -> GatewayResult:
    # open loop: sleep to the absolute arrival offset, regardless of
    # what every other request is doing
    delay = start_t + desc.arrival_s - time.monotonic()
    if delay > 0:
        await asyncio.sleep(delay)
    if desc.kind == "oneshot":
        return await gw.submit_audio(
            frames=desc.frames, tokens=desc.tokens, max_new=desc.max_new,
            eos_id=desc.eos_id, slo=desc.slo, timeout_s=timeout_s,
            audio_s=desc.audio_s)
    sess = await gw.open_session(tokens=desc.tokens, max_new=desc.max_new,
                                 eos_id=desc.eos_id, slo=desc.slo,
                                 audio_s=desc.audio_s)
    for chunk in desc.chunks:
        if sess.done:
            break
        await sess.feed(chunk)
    return await sess.finalize(timeout_s=timeout_s)


async def offered_load(gw: Gateway, descs: Sequence[RequestDesc], *,
                       timeout_s: Optional[float] = None
                       ) -> list[GatewayResult]:
    """Offer the whole workload open-loop; results in descriptor order
    (shed/timeout requests come back with ``ok=False``, never raise)."""
    start_t = time.monotonic()
    return list(await asyncio.gather(
        *(_serve_one(gw, d, start_t, timeout_s) for d in descs)))


def run_load(engine: ServeEngine, spec: LoadSpec, *,
             queue_limit: int = 64, max_admit_per_tick: int = 2,
             shed_on_submit: bool = True,
             timeout_s: Optional[float] = None):
    """Synthesize ``spec``'s workload, serve it through a fresh
    ``Gateway`` over ``engine``, and return
    ``(results, summary_dict, gateway)``."""
    descs = synth_load(engine.model.cfg, spec)

    async def _go():
        async with Gateway(engine, queue_limit=queue_limit,
                           max_admit_per_tick=max_admit_per_tick,
                           shed_on_submit=shed_on_submit) as gw:
            results = await offered_load(gw, descs, timeout_s=timeout_s)
        return results, gw

    results, gw = asyncio.run(_go())
    return results, gw.report(), gw


def sync_baseline(engine: ServeEngine, descs: Sequence[RequestDesc], *,
                  max_ticks: int = 10_000) -> dict[int, list]:
    """Serve the same descriptors through the synchronous FCFS
    ``BatchScheduler``: ``desc.idx -> final tokens``. The gateway must
    match this token-for-token (the parity oracle)."""
    sched = BatchScheduler(engine)
    uid0 = 1_000_000
    for d in descs:
        if d.kind == "stream":
            req = StreamingAudioRequest(
                uid=uid0 + d.idx, tokens=list(d.tokens),
                max_new=d.max_new, eos_id=d.eos_id,
                chunks=[np.asarray(c) for c in d.chunks])
        else:
            req = AudioRequest(uid=uid0 + d.idx, tokens=list(d.tokens),
                               max_new=d.max_new, eos_id=d.eos_id,
                               enc_frames=d.frames)
        sched.submit(req)
    sched.run_until_drained(max_ticks)
    return {d.idx: list(sched.results[uid0 + d.idx].out) for d in descs}
