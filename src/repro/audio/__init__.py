"""Audio frontend + streaming encode: samples -> log-mel -> frame
embeddings -> (chunked) encoder states -> tokens.

The paper evaluates full Whisper ASR; this package closes the repo's
audio->tokens gap on top of the existing serving/dispatch stack:

* ``features``   — Whisper-style log-mel frontend in pure JAX (framing,
  Hann window, RFFT power spectrum, mel filterbank as a dispatched
  matmul) with a NumPy golden reference;
* ``stream``     — streaming frontend/encoder: fixed-size encoder
  chunks, sample-exact incremental framing, state accumulation;
* ``transcribe`` — the one-call ``repro.transcribe()`` API over the
  serving engine (platform-aware, bf16/q8_0 cache policies).
"""

from repro.audio.features import (FrontendConfig, audio_frames,
                                  frame_starts, hann_window, log_mel,
                                  log_mel_ref, mel_filterbank,
                                  mel_to_frames)
from repro.audio.stream import (StreamingFrontend, chunk_list,
                                synth_waveform)
from repro.audio.transcribe import TranscribeResult, transcribe

__all__ = [
    "FrontendConfig", "StreamingFrontend", "TranscribeResult",
    "audio_frames", "chunk_list", "frame_starts", "hann_window",
    "log_mel", "log_mel_ref", "mel_filterbank", "mel_to_frames",
    "synth_waveform", "transcribe",
]
