"""``repro.transcribe``: samples in, tokens out — the paper's full ASR
workload (log-mel frontend -> chunked encoder -> continuous-batching
decoder) in one call, with platform-aware dispatch and energy
accounting.

The repo serves *randomly-initialized* reproductions of the paper's
models (there are no trained checkpoints), so the emitted token ids are
not human text — what this API exercises end to end is the compute
pipeline the paper measures: every frontend GEMM, encoder chunk,
cross-K/V extension, and decode tick routes through the kernel-dispatch
control law, and ``TranscribeResult.energy`` carries the platform's
joules-per-audio-second.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.audio.features import (FrontendConfig, audio_frames,
                                  resample_linear)
from repro.audio.stream import chunk_list
from repro.configs import get_config
from repro.configs import reduced as reduced_cfg
from repro.models.model import build
from repro.serving.engine import (AudioRequest, ServeEngine,
                                  StreamingAudioRequest)
from repro.serving.scheduler import BatchScheduler

DEFAULT_PROMPT = (1,)        # stand-in for whisper's <|sot|> sequence
DEFAULT_CHUNK_FRAMES = 16    # encoder chunk (frame embeddings) for streaming


@dataclasses.dataclass
class TranscribeResult:
    """What one transcription produced and what it cost."""

    tokens: list                     # final transcript token ids
    partials: list                   # streaming: one hypothesis per chunk
    audio_s: float                   # seconds of input audio
    n_frames: int                    # encoder frame embeddings consumed
    ticks: int                       # fused decode ticks executed
    wall_s: float                    # serve wall time (incl. jit on first use)
    compute_ms_per_audio_s: float    # wall_s / audio_s * 1000
    platform: Optional[str]
    cache_dtype: str
    energy: Optional[dict]           # energy_report + joules_per_audio_s
    decode_block: int = 1            # decode steps fused per tick
    decode_steps: int = 0            # executed decode steps (ticks x block)
    host_syncs: int = 0              # device->host fetches on the decode path
    engine: Any = dataclasses.field(default=None, repr=False)

    @property
    def text(self) -> str:
        """Space-joined token ids (no trained tokenizer exists here)."""
        return " ".join(str(t) for t in self.tokens)


def _default_model(arch: str, reduced: bool, seed: int):
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_cfg(cfg)
    model = build(cfg)
    return model, model.init_values(jax.random.key(seed))


def transcribe(samples, sr: int = 16_000, *,
               arch: str = "whisper-tiny-en", reduced: bool = True,
               model=None, params=None,
               platform: Optional[str] = None,
               cache_dtype: Optional[str] = None,
               decode_block: Optional[int] = None,
               chunk_frames: int = DEFAULT_CHUNK_FRAMES,
               prompt=DEFAULT_PROMPT, max_new: int = 16,
               eos_id: int = -1, stream: bool = False,
               frontend: Optional[FrontendConfig] = None,
               seed: int = 0, engine: Optional[ServeEngine] = None
               ) -> TranscribeResult:
    """Transcribe one waveform end to end.

    ``samples``: float waveform at ``sr`` Hz (resampled to the frontend
    rate if needed). ``platform`` (a ``repro.platforms`` name) derives
    the dispatch context and enables the energy report. ``stream=True``
    serves through the chunk-at-a-time streaming path (one chunk per
    scheduler tick, partial hypotheses in ``result.partials``); the
    final tokens are identical to ``stream=False`` on the same audio.
    ``decode_block`` fuses that many decode steps per engine tick (one
    host sync per tick — tokens are identical for any block size).
    Pass ``engine=`` (e.g. ``result.engine`` from a previous call with
    the same shapes) to reuse compiled prefill/decode functions; the
    reused engine's platform/cache policy apply (conflicting explicit
    ``platform``/``cache_dtype`` arguments raise; ``decode_block`` is a
    mutable knob and simply retunes the reused engine), and the serve
    stats are reset so ticks/energy in the result cover this call only.
    """
    if decode_block is not None and int(decode_block) < 1:
        raise ValueError(f"decode_block must be >= 1, got {decode_block}")
    fe = frontend or FrontendConfig()
    x = resample_linear(samples, sr, fe.sample_rate)
    audio_s = len(x) / fe.sample_rate
    if model is None or params is None:
        model, params = _default_model(arch, reduced, seed)
    if not model.cfg.enc_dec:
        raise ValueError(f"transcribe needs an enc-dec (audio) model; "
                         f"{model.cfg.name} is {model.cfg.family}")
    frames = np.asarray(audio_frames(x, model.cfg.d_model, fe))
    if frames.shape[0] == 0:
        raise ValueError(
            f"audio too short: {len(x)} samples produce no frames "
            f"(need >= 1 hop = {fe.hop} samples)")
    chunks = chunk_list(frames, chunk_frames)
    n_frames = frames.shape[0]
    if engine is None:
        cache_dtype = cache_dtype or "bf16"
        engine = ServeEngine(
            model, params, n_slots=1,
            max_len=len(prompt) + max_new + 2, enc_len=n_frames,
            cache_dtype=cache_dtype, decode_block=decode_block or 1,
            platform=platform)
    else:
        # the reused engine's policies are the truth — refuse silent
        # mismatches with explicitly requested ones
        if cache_dtype is not None and cache_dtype != engine.cache_dtype:
            raise ValueError(
                f"cache_dtype={cache_dtype!r} conflicts with the reused "
                f"engine's {engine.cache_dtype!r}")
        if platform is not None:
            from repro.platforms import get_platform
            want = get_platform(platform).name
            have = engine.platform.name if engine.platform else None
            if want != have:
                raise ValueError(
                    f"platform={platform!r} conflicts with the reused "
                    f"engine's {have!r}")
        cache_dtype = engine.cache_dtype
        if decode_block is not None:
            engine.decode_block = int(decode_block)
    engine.reset_serve_stats()
    t0 = time.monotonic()
    if stream:
        sched = BatchScheduler(engine)
        req = StreamingAudioRequest(uid=0, tokens=list(prompt),
                                    max_new=max_new, eos_id=eos_id,
                                    chunks=chunks)
        sched.submit(req)
        sched.run_until_drained()
        st = sched.results[0]
        if st.error:
            raise ValueError(st.error)
    else:
        states = engine.encode_chunks(chunks)
        st = engine.admit(AudioRequest(uid=0, tokens=list(prompt),
                                       max_new=max_new, eos_id=eos_id,
                                       enc_states=states[0]))
        while engine.n_active:
            engine.step()
    wall = time.monotonic() - t0
    energy = None
    if engine.platform is not None:
        energy = engine.energy_report("fp16")
        energy["joules_per_audio_s"] = \
            energy["pdp_j"] / max(audio_s, 1e-9)
    return TranscribeResult(
        tokens=list(st.out), partials=[list(p) for p in st.partials],
        audio_s=audio_s, n_frames=n_frames, ticks=engine._ticks,
        wall_s=wall,
        compute_ms_per_audio_s=wall / max(audio_s, 1e-9) * 1e3,
        platform=engine.platform.name if engine.platform else None,
        cache_dtype=cache_dtype, energy=energy,
        decode_block=engine.decode_block,
        decode_steps=engine._decode_steps, host_syncs=engine._host_syncs,
        engine=engine)
