"""Streaming audio frontend: sample-exact incremental log-mel frames.

``StreamingFrontend`` accepts audio in arbitrary-size pushes and emits
encoder frame embeddings *incrementally*, guaranteeing that

    concat(push(c) for c in chunks) + flush()  ==  audio_frames(audio)

bit-for-bit: a mel frame is emitted only once its full ``n_fft`` sample
window has arrived (the frontend holds ``n_fft - hop`` samples of
lookback), and embedding frames are emitted in whole stride groups so
the temporal pooling sees the same row groups as the one-shot path.
``flush()`` zero-pads the tail exactly like ``features.log_mel`` does.

The downstream encoder-chunk streaming (fixed-size chunks, block-
diagonal attention, incremental cross-K/V extension) lives in
``serving.engine`` (``open_stream`` / ``stream_feed``); this module is
pure frontend.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.audio.features import (FrontendConfig, log_mel, mel_to_frames)


class StreamingFrontend:
    """Incremental ``audio_frames``: push samples, get frame embeddings.

    ``push`` returns the newly-completed (k, d_model) embedding frames
    (possibly empty); ``flush`` pads and emits the tail. The
    concatenation of all outputs equals the one-shot
    ``features.audio_frames`` on the same samples, exactly.
    """

    def __init__(self, d_model: int,
                 cfg: FrontendConfig = FrontendConfig()):
        self.cfg = cfg
        self.d_model = d_model
        self._buf = np.zeros(0, np.float32)   # samples from _mel_done*hop on
        self._total = 0                       # samples received
        self._mel_done = 0                    # emitted mel frames (k*stride)
        self._closed = False

    @property
    def samples_received(self) -> int:
        return self._total

    @property
    def frames_emitted(self) -> int:
        """Embedding frames emitted so far."""
        return self._mel_done // self.cfg.stride

    def push(self, samples) -> np.ndarray:
        """Feed more samples; returns the newly-final embedding frames
        ((k, d_model), k >= 0)."""
        if self._closed:
            raise ValueError("push() after flush()")
        cfg = self.cfg
        x = np.asarray(samples, np.float32).reshape(-1)
        self._buf = np.concatenate([self._buf, x])
        self._total += len(x)
        # mel frame t is final once t*hop + n_fft samples have arrived
        complete = 0 if self._total < cfg.n_fft \
            else (self._total - cfg.n_fft) // cfg.hop + 1
        m1 = (complete // cfg.stride) * cfg.stride   # whole stride groups
        if m1 <= self._mel_done:
            return np.zeros((0, self.d_model), np.float32)
        # samples for mel frames [_mel_done, m1), relative to the buffer
        # (the buffer starts at global offset _mel_done * hop)
        n_new = m1 - self._mel_done
        end = (n_new - 1) * cfg.hop + cfg.n_fft
        lm = log_mel(self._buf[:end], cfg)[:n_new]
        out = np.asarray(mel_to_frames(lm, self.d_model, cfg))
        self._buf = self._buf[n_new * cfg.hop:]
        self._mel_done = m1
        return out

    def flush(self) -> np.ndarray:
        """End of stream: emit the remaining (zero-padded) tail frames."""
        if self._closed:
            return np.zeros((0, self.d_model), np.float32)
        self._closed = True
        cfg = self.cfg
        remaining = cfg.n_frames(self._total) - self._mel_done
        if remaining <= 0:
            return np.zeros((0, self.d_model), np.float32)
        lm = log_mel(self._buf, cfg)
        assert lm.shape[0] == remaining, (lm.shape, remaining)
        out = np.asarray(mel_to_frames(lm, self.d_model, cfg))
        self._buf = np.zeros(0, np.float32)
        self._mel_done += remaining
        return out


def chunk_list(frames, chunk: int) -> List[np.ndarray]:
    """Split (T, d) frames into fixed-size encoder chunks (last partial)."""
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    fr = np.asarray(frames)
    return [fr[i:i + chunk] for i in range(0, fr.shape[0], chunk)]


def synth_waveform(seconds: float = 1.0, sr: int = 16_000,
                   seed: int = 0) -> np.ndarray:
    """Deterministic synthetic test waveform: two tones + a chirp +
    light noise, peak-normalized — the CLI/benchmark/test input (the
    repo serves randomly-initialized models, so no real speech needed)."""
    rng = np.random.default_rng(seed)
    t = np.arange(int(seconds * sr)) / sr
    x = (0.4 * np.sin(2 * np.pi * 220.0 * t)
         + 0.3 * np.sin(2 * np.pi * 440.0 * t + 0.7)
         + 0.2 * np.sin(2 * np.pi * (300.0 + 600.0 * t) * t)
         + 0.05 * rng.standard_normal(t.shape))
    peak = np.abs(x).max() or 1.0
    return (x / peak * 0.8).astype(np.float32)
