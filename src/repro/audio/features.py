"""Whisper-style log-mel frontend in pure JAX + a NumPy golden reference.

Pipeline (``audio_frames`` = the whole thing):

  samples (float32, 16 kHz) --frame/Hann/RFFT--> power spectrum
          --mel filterbank (dispatched matmul)--> mel energies
          --log10 + fixed-reference clamp + /4 norm--> log-mel (T, n_mels)
          --stride-2 pool + fixed cosine projection + GELU-->
          frame embeddings (T//2, d_model)  [the encoder's ``enc_frames``]

Two deliberate deviations from OpenAI Whisper, both forced by streaming:

* **no center padding** — frames start at ``t * hop`` and read
  ``n_fft`` samples forward, so a frame is final as soon as its window
  has arrived; the tail frame is zero-padded (flush);
* **fixed-reference normalization** — Whisper clamps at
  ``log_spec.max() - 8`` over the whole utterance, which needs the
  future; we clamp at the fixed floor ``-8`` (i.e. assume a 0 dBFS
  reference), so streaming and one-shot extraction are sample-exact.

The mel-filterbank application and the d_model projection are routed
through ``dispatch("fp16_matmul", ..., tag="frontend")`` so the
ACCEL/HOST control law and the energy/dispatch accounting see the
frontend GEMMs like every other kernel in the model.

The conv2 stem of real Whisper is replaced by a *deterministic* cosine
projection (this repo serves randomly-initialized reproductions — there
are no trained frontend weights to load); the stride-2 temporal pooling
keeps Whisper's 2x frame-rate reduction so ``enc_frames`` counts match
the paper's workload model (1500 frames per 30 s window).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.api import dispatch

SAMPLE_RATE = 16_000

LOG_FLOOR = -8.0       # fixed dynamic-range floor (log10 units)
MEL_EPS = 1e-10


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Whisper's frontend constants (25 ms window / 10 ms hop at 16 kHz)."""

    sample_rate: int = SAMPLE_RATE
    n_fft: int = 400
    hop: int = 160
    n_mels: int = 80
    fmin: float = 0.0
    fmax: Optional[float] = None   # None -> sample_rate / 2
    stride: int = 2                # temporal pooling of the conv-stem stand-in

    @property
    def n_freq(self) -> int:
        return self.n_fft // 2 + 1

    def n_frames(self, n_samples: int) -> int:
        """Mel frames for ``n_samples``: one per started hop (tail padded)."""
        return -(-n_samples // self.hop) if n_samples > 0 else 0

    def n_embed_frames(self, n_samples: int) -> int:
        """Frame embeddings after the stride-``stride`` pooling."""
        return -(-self.n_frames(n_samples) // self.stride)


def frame_starts(n_samples: int, cfg: FrontendConfig) -> np.ndarray:
    """Sample offset of each mel frame (frame t covers
    ``[t*hop, t*hop + n_fft)``; the tail is zero-padded)."""
    return np.arange(cfg.n_frames(n_samples)) * cfg.hop


def hann_window(n: int) -> np.ndarray:
    """Periodic Hann window (what torch.hann_window/Whisper uses)."""
    return (0.5 - 0.5 * np.cos(2.0 * np.pi * np.arange(n) / n)) \
        .astype(np.float32)


@functools.lru_cache(maxsize=8)
def _mel_filterbank_cached(n_mels: int, n_fft: int, sr: int, fmin: float,
                           fmax: float) -> np.ndarray:
    def hz_to_mel(f):
        return 2595.0 * np.log10(1.0 + np.asarray(f, np.float64) / 700.0)

    def mel_to_hz(m):
        return 700.0 * (10.0 ** (np.asarray(m, np.float64) / 2595.0) - 1.0)

    pts = mel_to_hz(np.linspace(hz_to_mel(fmin), hz_to_mel(fmax),
                                n_mels + 2))
    freqs = np.linspace(0.0, sr / 2.0, n_fft // 2 + 1)
    fb = np.zeros((n_fft // 2 + 1, n_mels), np.float64)
    for m in range(n_mels):
        lo, center, hi = pts[m], pts[m + 1], pts[m + 2]
        up = (freqs - lo) / max(center - lo, 1e-9)
        down = (hi - freqs) / max(hi - center, 1e-9)
        tri = np.maximum(0.0, np.minimum(up, down))
        fb[:, m] = tri * (2.0 / max(hi - lo, 1e-9))   # slaney area norm
    return fb.astype(np.float32)


def mel_filterbank(cfg: FrontendConfig) -> np.ndarray:
    """(n_freq, n_mels) triangular HTK-mel filterbank, slaney-normalized."""
    fmax = cfg.fmax if cfg.fmax is not None else cfg.sample_rate / 2.0
    return _mel_filterbank_cached(cfg.n_mels, cfg.n_fft, cfg.sample_rate,
                                  float(cfg.fmin), float(fmax))


def _frame_signal_np(samples: np.ndarray, cfg: FrontendConfig) -> np.ndarray:
    """(T, n_fft) frame matrix; the last frame is zero-padded. Input of
    any shape is flattened first ((1, N)/(N, 1) loader outputs frame
    identically to (N,))."""
    x = np.asarray(samples, np.float32).reshape(-1)
    t = cfg.n_frames(len(x))
    if t == 0:
        return np.zeros((0, cfg.n_fft), np.float32)
    need = (t - 1) * cfg.hop + cfg.n_fft
    if need > len(x):
        x = np.pad(x, (0, need - len(x)))
    idx = (np.arange(t) * cfg.hop)[:, None] + np.arange(cfg.n_fft)
    return x[idx]


def log_mel(samples, cfg: FrontendConfig = FrontendConfig()) -> jnp.ndarray:
    """Log-mel spectrogram (T, n_mels), float32 — the JAX frontend.

    Framing/window/RFFT run row-independent (each output frame depends
    only on its own sample window), so streaming extraction is exact.
    The mel matmul routes through the kernel-dispatch API.
    """
    frames = jnp.asarray(_frame_signal_np(samples, cfg))
    if frames.shape[0] == 0:
        return jnp.zeros((0, cfg.n_mels), jnp.float32)
    win = jnp.asarray(hann_window(cfg.n_fft))
    spec = jnp.fft.rfft(frames * win[None, :], axis=-1)
    power = (jnp.abs(spec) ** 2).astype(jnp.float32)
    mel = dispatch("fp16_matmul", power, jnp.asarray(mel_filterbank(cfg)),
                   out_dtype=jnp.float32, tag="frontend")
    log_spec = jnp.log10(jnp.maximum(mel, MEL_EPS))
    log_spec = jnp.maximum(log_spec, LOG_FLOOR)
    return ((log_spec + 4.0) / 4.0).astype(jnp.float32)


def log_mel_ref(samples, cfg: FrontendConfig = FrontendConfig()) -> np.ndarray:
    """NumPy golden reference for ``log_mel`` (same math, np.fft)."""
    frames = _frame_signal_np(samples, cfg)
    if frames.shape[0] == 0:
        return np.zeros((0, cfg.n_mels), np.float32)
    spec = np.fft.rfft(frames * hann_window(cfg.n_fft)[None, :], axis=-1)
    power = (np.abs(spec) ** 2).astype(np.float32)
    mel = power @ mel_filterbank(cfg)
    log_spec = np.log10(np.maximum(mel, MEL_EPS))
    log_spec = np.maximum(log_spec, LOG_FLOOR)
    return ((log_spec + 4.0) / 4.0).astype(np.float32)


@functools.lru_cache(maxsize=8)
def _cosine_projection(n_mels: int, d_model: int) -> np.ndarray:
    """Deterministic (n_mels, d_model) DCT-like projection — the
    conv-stem stand-in's mixing matrix (no trained weights exist)."""
    m = np.arange(n_mels, dtype=np.float64)[:, None]
    j = np.arange(d_model, dtype=np.float64)[None, :]
    p = np.cos(np.pi * (m + 0.5) * (j + 1.0) / n_mels)
    return (p * math.sqrt(2.0 / n_mels)).astype(np.float32)


def mel_to_frames(logmel, d_model: int,
                  cfg: FrontendConfig = FrontendConfig()) -> jnp.ndarray:
    """Log-mel (T, n_mels) -> encoder frame embeddings (ceil(T/stride),
    d_model): stride-mean temporal pooling (Whisper's conv2 stride-2
    frame-rate halving) then the fixed cosine projection + GELU. The
    projection GEMM is dispatched, tagged ``frontend``. Row-independent
    in pooled-frame units, so streaming emission is exact."""
    x = jnp.asarray(logmel, jnp.float32)
    t = x.shape[0]
    s = cfg.stride
    tp = -(-t // s) if t else 0
    if tp * s > t:
        x = jnp.pad(x, ((0, tp * s - t), (0, 0)))
    if tp == 0:
        return jnp.zeros((0, d_model), jnp.float32)
    pooled = x.reshape(tp, s, cfg.n_mels).mean(axis=1)
    proj = jnp.asarray(_cosine_projection(cfg.n_mels, d_model))
    y = dispatch("fp16_matmul", pooled, proj, out_dtype=jnp.float32,
                 tag="frontend")
    return jax.nn.gelu(y, approximate=False).astype(jnp.float32)


def audio_frames(samples, d_model: int,
                 cfg: FrontendConfig = FrontendConfig()) -> jnp.ndarray:
    """samples -> (n_embed_frames, d_model) encoder frame embeddings:
    the full frontend (``log_mel`` then ``mel_to_frames``)."""
    return mel_to_frames(log_mel(samples, cfg), d_model, cfg)


def resample_linear(samples, sr_in: int, sr_out: int) -> np.ndarray:
    """Cheap linear-interpolation resampler (NumPy) so ``transcribe``
    accepts non-16 kHz input; use a real resampler for quality."""
    x = np.asarray(samples, np.float32).reshape(-1)
    if sr_in == sr_out or len(x) == 0:
        return x
    n_out = int(round(len(x) * sr_out / sr_in))
    t_out = np.arange(n_out, dtype=np.float64) * (sr_in / sr_out)
    return np.interp(t_out, np.arange(len(x), dtype=np.float64),
                     x).astype(np.float32)
