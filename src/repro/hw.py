"""Compatibility shim over ``repro.platforms``.

The hardware constants that used to live here moved into the platform
registry (``repro.platforms`` — the ``Platform`` objects — with the raw
paper tables in ``repro.platforms.paper``). Every historical name is
re-exported so out-of-tree code keeps working; new code should resolve
targets through ``repro.platforms.get_platform(...)`` instead.
"""

from __future__ import annotations

from repro.platforms.paper import (  # noqa: F401
    ChipSpec,
    IMAX_ASIC_FREQ_HZ,
    IMAX_FPGA_FREQ_HZ,
    IMAX_PES_PER_LANE,
    IMAX_POWER_FP16_W,
    IMAX_POWER_Q8_W,
    PAPER_DOT_COUNTS,
    PAPER_EXEC_SHARE,
    PAPER_LATENCY_S,
    PAPER_PDP_J,
    PAPER_TABLE1,
    PAPER_TABLE4,
    PLATFORM_POWER_W,
    TPU_V5E,
    TPU_V5E_PEAK_FLOPS_INT8,
)

__all__ = [
    "ChipSpec", "TPU_V5E", "TPU_V5E_PEAK_FLOPS_INT8",
    "IMAX_POWER_FP16_W", "IMAX_POWER_Q8_W", "IMAX_ASIC_FREQ_HZ",
    "IMAX_FPGA_FREQ_HZ", "IMAX_PES_PER_LANE", "PLATFORM_POWER_W",
    "PAPER_LATENCY_S", "PAPER_PDP_J", "PAPER_DOT_COUNTS", "PAPER_TABLE1",
    "PAPER_TABLE4", "PAPER_EXEC_SHARE",
]
