"""Deterministic sharded synthetic data pipeline.

Every batch is a pure function of ``(seed, step, shard)`` via Philox
counter-based RNG — no iterator state exists, so:

* **restart determinism** — resuming from a checkpoint at step *t* replays
  exactly the batches a non-interrupted run would have seen;
* **elastic resharding** — a restore onto a different data-parallel degree
  re-partitions the *same* global batch (shards are slices of the global
  sample index space, not per-host streams);
* **straggler-free** — no host ever waits on a shared queue.

Token streams follow a Zipfian unigram distribution (vocab realism for the
CE loss); audio-frame / image-patch stubs are Gaussian embeddings, per the
brief's frontend-stub rule. ``targets`` are next-token shifted with the
final position masked (ignore_id = -1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import ArchConfig

IGNORE_ID = -1


def _rng(seed: int, step: int, shard: int) -> np.random.Generator:
    # Philox counter-based: key packs (seed, step<<20 | shard) — pure
    # function of the triple, no sequential state.
    return np.random.Generator(
        np.random.Philox(key=[seed, (step << 20) | shard]))


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf(1.1)-distributed token ids folded into [0, vocab)."""
    z = rng.zipf(1.1, size=shape).astype(np.int64)
    return (z % vocab).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class SyntheticDataset:
    cfg: ArchConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1

    def __post_init__(self):
        assert self.global_batch % self.n_shards == 0, (
            self.global_batch, self.n_shards)

    @property
    def shard_batch(self) -> int:
        return self.global_batch // self.n_shards

    def shard_batch_at(self, step: int, shard: int) -> dict:
        """The ``shard``-th slice of the global batch for ``step``."""
        return batch_for_step(self.cfg, self.seq_len, self.shard_batch,
                              seed=self.seed, step=step,
                              shard=shard, n_shards=self.n_shards)

    def global_batch_at(self, step: int) -> dict:
        out = [self.shard_batch_at(step, s) for s in range(self.n_shards)]
        return {k: np.concatenate([o[k] for o in out], axis=0)
                for k in out[0]}


def batch_for_step(cfg: ArchConfig, seq_len: int, batch: int, *,
                   seed: int = 0, step: int = 0, shard: int = 0,
                   n_shards: int = 1) -> dict:
    """One training batch: tokens/targets (+ frontend stub tensors)."""
    rng = _rng(seed, step, shard)
    d = cfg.d_model

    if cfg.enc_dec:
        s2 = seq_len // 2
        tokens = _zipf_tokens(rng, (batch, s2 + 1), cfg.vocab)
        frames = rng.standard_normal((batch, s2, d)).astype(np.float32)
        return {"enc_frames": frames * 0.02,
                "tokens": tokens[:, :-1],
                "targets": _shift_targets(tokens)}
    if cfg.vlm:
        n_img = cfg.n_img_tokens
        s_text = seq_len - n_img
        tokens = _zipf_tokens(rng, (batch, s_text + 1), cfg.vocab)
        img = rng.standard_normal((batch, n_img, d)).astype(np.float32)
        tgt_text = _shift_targets(tokens)
        # image-prefix positions carry no next-token loss
        tgt = np.concatenate(
            [np.full((batch, n_img), IGNORE_ID, np.int32), tgt_text], axis=1)
        return {"img_embed": img * 0.02, "tokens": tokens[:, :-1],
                "targets": tgt}
    tokens = _zipf_tokens(rng, (batch, seq_len + 1), cfg.vocab)
    return {"tokens": tokens[:, :-1], "targets": _shift_targets(tokens)}


def _shift_targets(tokens: np.ndarray) -> np.ndarray:
    """Next-token targets for tokens[:, :-1]: i.e. tokens[:, 1:]."""
    return tokens[:, 1:].astype(np.int32)
