from repro.data.synthetic import SyntheticDataset, batch_for_step
